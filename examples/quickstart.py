"""Quickstart: build an app from the template API, optimize one query's
graph, and run it end-to-end on REAL JAX engines (CPU).

  PYTHONPATH=src python examples/quickstart.py
"""
import time

from repro.core.apps import build_engines, naive_rag
from repro.core.teola import Teola
from repro.training.data import doc_corpus


def main():
    print("building engines (tiny JAX models on CPU)...")
    engines = build_engines()
    app = naive_rag(engines)
    teola = Teola(app, engines)

    query = {"question": "what is fact 3 about optics",
             "docs": doc_corpus(2)}

    g = teola.build_egraph(query)
    print(f"\noptimized e-graph: {len(g.nodes)} primitives")
    for n in sorted(g.nodes.values(), key=lambda n: -n.depth):
        print(f"  depth={n.depth:2d} {n.op:20s} engine={n.engine:10s} "
              f"component={n.component}")

    print("\nwarmup (jit compilation)...")
    teola.query(dict(query), timeout=300)

    t0 = time.time()
    answer, ctx = teola.query(dict(query), timeout=300)
    print(f"\nanswer tokens: {answer!r}")
    print(f"end-to-end latency: {(time.time() - t0) * 1000:.1f} ms")
    print(f"retrieved context: "
          f"{[c['text'][:40] for c in ctx.store.get('retrieved', [])][:2]}")
    teola.shutdown()


if __name__ == "__main__":
    main()
