"""Co-located applications (paper §7.2): naive-RAG QA and search-engine
generation sharing one engine pool, orchestrated by Teola simultaneously.

  PYTHONPATH=src python examples/colocated_apps.py
"""
import time

import numpy as np

from repro.core.apps import build_engines, naive_rag, search_gen
from repro.core.teola import Teola
from repro.training.data import doc_corpus


def main():
    engines = build_engines()
    rag = Teola(naive_rag(engines), engines)
    sg = Teola(search_gen(engines), engines)
    docs = doc_corpus(2)

    print("warmup...")
    rag.query({"question": "warmup q", "docs": docs}, timeout=300)
    sg.query({"question": "warmup q"}, timeout=300)

    print("submitting interleaved queries from both apps...")
    ctxs = {"rag": [], "search_gen": []}
    for i in range(3):
        ctxs["rag"].append(rag.submit(
            {"question": f"what is fact {i} about optics", "docs": docs}))
        ctxs["search_gen"].append(sg.submit(
            {"question": f"who discovered fact {i}"}))
        time.sleep(0.1)
    for k, cs in ctxs.items():
        for c in cs:
            c.done.wait(600)
        lat = [c.latency for c in cs]
        print(f"{k:12s} avg latency {np.mean(lat) * 1000:.0f}ms "
              f"({len(cs)} queries)")
    rag.shutdown()
    sg.shutdown()


if __name__ == "__main__":
    main()
