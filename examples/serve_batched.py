"""End-to-end serving driver: serve the advanced-RAG app on REAL JAX
engines with a stream of batched concurrent requests (the paper-kind e2e
deliverable — serving a small model with batched requests).

  PYTHONPATH=src python examples/serve_batched.py [n_queries]
"""
import sys
import time

import numpy as np

from repro.core.apps import build_engines, advanced_rag
from repro.core.teola import Teola
from repro.training.data import doc_corpus

QUESTIONS = [
    "what is fact 3 about optics",
    "tell me fact 7 about finance",
    "what is fact 5 about biology",
    "explain fact 9 about chess",
]


def main(n=6):
    engines = build_engines()
    app = advanced_rag(engines)
    teola = Teola(app, engines)
    docs = doc_corpus(2)

    print("warmup...")
    teola.query({"question": QUESTIONS[0], "docs": docs}, timeout=300)

    print(f"submitting {n} concurrent queries (Poisson arrivals)...")
    rng = np.random.default_rng(0)
    ctxs = []
    t0 = time.time()
    for i in range(n):
        q = {"question": QUESTIONS[i % len(QUESTIONS)], "docs": docs}
        ctxs.append(teola.submit(q))
        time.sleep(float(rng.exponential(0.3)))
    for c in ctxs:
        c.done.wait(600)
    wall = time.time() - t0

    lats = [c.latency for c in ctxs]
    print(f"\nserved {n} queries in {wall:.1f}s "
          f"(throughput {n / wall:.2f} q/s)")
    print(f"latency avg={np.mean(lats) * 1000:.0f}ms "
          f"p50={np.percentile(lats, 50) * 1000:.0f}ms "
          f"max={np.max(lats) * 1000:.0f}ms")
    llm = engines["core_llm"]
    print(f"core LLM engine: {llm.stats['calls']} batched calls, "
          f"{llm.stats['prefill_tokens']} prefill tokens, "
          f"{llm.stats['decode_tokens']} decoded tokens, "
          f"busy {llm.stats['busy_s']:.1f}s")
    sched = teola.runtime.scheds["core_llm"]
    sizes = [s for s, _ in sched.batches]
    print(f"LLM batch sizes formed by topology-aware batching: "
          f"avg={np.mean(sizes):.2f} max={max(sizes)}")
    teola.shutdown()


if __name__ == "__main__":
    main(int(sys.argv[1]) if len(sys.argv) > 1 else 6)
