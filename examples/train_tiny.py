"""Training-substrate driver: train the engine-scale core LLM for a few
hundred steps on the synthetic pipeline with checkpointing.

  PYTHONPATH=src python examples/train_tiny.py [steps]
"""
import sys
import time

import jax
import jax.numpy as jnp

from repro.configs.base import get_config
from repro.models.transformer import init_params
from repro.training.checkpoint import load_checkpoint, save_checkpoint
from repro.training.data import SyntheticLM
from repro.training.optimizer import AdamWConfig, init_opt_state
from repro.training.train_step import make_train_step


def main(steps=200):
    cfg = get_config("tiny-core-llm")
    params = init_params(cfg, jax.random.key(0))
    n_params = sum(p.size for p in jax.tree.leaves(params))
    print(f"model {cfg.name}: {n_params / 1e6:.1f}M params")

    oc = AdamWConfig(lr=3e-3, warmup_steps=20, total_steps=steps)
    opt = init_opt_state(oc, params)
    step_fn = jax.jit(make_train_step(cfg, oc, num_microbatches=2,
                                      compute_dtype=jnp.float32,
                                      q_block=64))
    data = SyntheticLM(cfg.vocab_size, batch=8, seq_len=64)
    t0 = time.time()
    for i, batch in enumerate(data):
        if i >= steps:
            break
        batch = {"tokens": jnp.asarray(batch["tokens"])}
        params, opt, m = step_fn(params, opt, batch)
        if i % 20 == 0 or i == steps - 1:
            print(f"step {i:4d}  ce={float(m['ce']):.4f}  "
                  f"gnorm={float(m['gnorm']):.3f}  "
                  f"{(time.time() - t0):.1f}s")
    data.close()
    save_checkpoint("/tmp/repro_ckpt", params, step=steps)
    restored = load_checkpoint("/tmp/repro_ckpt", params)
    assert jax.tree.all(jax.tree.map(
        lambda a, b: bool(jnp.allclose(a, b)), params, restored))
    print(f"checkpoint round-trip OK; final ce={float(m['ce']):.4f}")


if __name__ == "__main__":
    main(int(sys.argv[1]) if len(sys.argv) > 1 else 200)
