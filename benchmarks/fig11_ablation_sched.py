"""Paper Fig. 11: runtime-scheduling ablation — topology-aware batching
vs blind FIFO batching (policy 'to') for Teola's e-graphs, single-query
and under multi-query load."""
from __future__ import annotations

import numpy as np

from benchmarks.common import fmt_row, make_queries, run_load
from repro.core.apps import advanced_rag


def run(n_queries: int = 8):
    print("setting,policy,avg_ms,speedup")
    for setting, rate in (("single", 0.2), ("load_r2", 2.0)):
        res = {}
        for scheme_policy in ("to", "topo"):
            queries = make_queries(1 if setting == "single" else n_queries)
            # reuse the Teola orchestrator with a swapped engine policy
            from benchmarks.common import SCHEMES
            SCHEMES["_tmp"] = (SCHEMES["Teola"][0], scheme_policy)
            lats, _ = run_load(advanced_rag, "_tmp", queries, rate)
            del SCHEMES["_tmp"]
            res[scheme_policy] = float(np.mean(lats))
        print(fmt_row(setting, "blind_TO", round(res["to"] * 1000, 1), 1.0))
        print(fmt_row(setting, "topology_aware",
                      round(res["topo"] * 1000, 1),
                      round(res["to"] / res["topo"], 2)))


if __name__ == "__main__":
    run()
