"""Overload control & graceful degradation (serving/overload.py),
emitting BENCH_overload.json.

A sim engine set (pooled encoders, paged LLM KV) serves advanced-RAG
queries arriving far above the sustainable service rate, classes
alternating interactive/batch, while a seeded burst fault slows one
embedding replica mid-run.  Two runs:

  control_off  every query admitted, no deadlines, no hedging, no
               degradation — the queue convoys and late arrivals blow
               their (externally scored) deadlines.
  control_on   the overload layer armed: per-class deadlines decomposed
               along the e-graph, front-door shedding against the
               admission ledger (interactive protected), hedged encoder
               dispatch around the bursting replica, and the brown-out
               degradation ladder.

Goodput is queries finished WITHIN their class deadline per second of
wall time.  Acceptance: control_on goodput >= 2x control_off, completed
interactive p99 latency bounded by its deadline, shedding actually
fired while interactive shed stays below batch shed, and zero leaked
KV blocks on every replica afterwards.
"""
from __future__ import annotations

import json
import time
from pathlib import Path

import numpy as np

from repro.core.apps import advanced_rag
from repro.core.engine_pool import replicas_of
from repro.core.teola import Teola
from repro.engines.sim_engines import build_sim_engines
from repro.serving.faults import FaultInjector, FaultSpec
from repro.serving.overload import (Overloaded, OverloadConfig,
                                    OverloadManager, query_token_estimate)
from repro.training.data import doc_corpus

N_QUERIES = 48
# arrival rate vs the measured SINGLE-QUERY latency: the runtime overlaps
# queries, so ~3x capacity needs a much denser arrival train than 3x the
# sequential rate
OVERCAPACITY = 16.0
INTER_DL_X = 2.5             # interactive deadline, in single-query latencies
BATCH_DL_X = 3.5             # batch deadline
QUEUE_X = 1.0                # shed threshold, in per-query token estimates

_Q = {"question": "what is fact 3 about optics", "docs": doc_corpus(2)}


def _engines():
    return build_sim_engines(encoder_instances=2, paged_kv=True)


def _burst():
    # one embedding replica stalls for 4 consecutive calls mid-run — the
    # hedge's backup target is the second (healthy) pool replica
    return FaultInjector([FaultSpec("burst", "embedding", "encode",
                                    at=3, duration=0.4, width=4)])


def _calibrate():
    """Single-query latency + per-query token estimate (no faults)."""
    engines = _engines()
    orch = Teola(advanced_rag(engines), engines, continuous_batching=True)
    try:
        orch.query(dict(_Q), timeout=120)          # warm the e-graph cache
        t0 = time.time()
        orch.query(dict(_Q), timeout=120)
        lat = time.time() - t0
        tokens = query_token_estimate(orch.build_egraph(dict(_Q)))
    finally:
        orch.shutdown()
    return lat, tokens


def _run(overload, base_lat, label):
    engines = _engines()
    inj = _burst()
    inj.arm(engines, encoders=True)                # same fault in BOTH runs
    orch = Teola(advanced_rag(engines), engines, continuous_batching=True,
                 overload=overload)
    gap = base_lat / OVERCAPACITY
    dls = {"interactive": INTER_DL_X * base_lat,
           "batch": BATCH_DL_X * base_lat}
    t0 = time.time()
    subs = []                                      # (cls, t_sub, ctx)
    try:
        for i in range(N_QUERIES):
            cls = "interactive" if i % 2 == 0 else "batch"
            subs.append((cls, time.time(), orch.submit(dict(_Q), slo=cls)))
            time.sleep(gap)
        for _cls, _ts, c in subs:
            c.done.wait(180)
        wall = time.time() - t0
        rows = {}
        for cls in ("interactive", "batch"):
            lats = [c.t_done - ts for cc, ts, c in subs
                    if cc == cls and c.t_done and c.error is None]
            good = [x for x in lats if x <= dls[cls]]
            shed = sum(1 for cc, _ts, c in subs
                       if cc == cls and isinstance(c.error, Overloaded))
            rows[cls] = {
                "submitted": sum(1 for cc, _a, _b in subs if cc == cls),
                "completed": len(lats),
                "in_deadline": len(good),
                "shed": shed,
                "p50_s": round(float(np.percentile(lats, 50)), 3)
                if lats else None,
                "p99_s": round(float(np.percentile(lats, 99)), 3)
                if lats else None,
            }
        leaked = 0
        for eng in engines.values():
            for inst in replicas_of(eng):
                alloc = getattr(inst, "alloc", None)
                if alloc is not None:
                    rep = alloc.audit()
                    leaked += rep["leaked"] + rep["bad_free"]
        total_good = sum(rows[c]["in_deadline"] for c in rows)
        out = {
            "classes": rows,
            "wall_s": round(wall, 3),
            "goodput_qps": round(total_good / wall, 3),
            "burst_fires": len(inj.log),
            "leaked_blocks": leaked,
        }
        if overload is not None:
            out["overload"] = overload.snapshot()
            out["degraded_queries"] = {
                q: sorted(s)
                for q, s in overload.degrade.degraded_queries().items()}
        print(f"{label}: goodput {out['goodput_qps']} q/s, "
              f"interactive p99 {rows['interactive']['p99_s']}s "
              f"(dl {round(dls['interactive'], 2)}s), shed "
              f"i={rows['interactive']['shed']} b={rows['batch']['shed']}")
        return out
    finally:
        orch.shutdown()


def run(out_path: Path = None):
    base_lat, q_tokens = _calibrate()
    print(f"calibration: single-query latency {base_lat:.2f}s, "
          f"{q_tokens:.0f} tokens/query")

    off = _run(None, base_lat, "control_off")

    cfg = OverloadConfig(
        interactive_deadline_s=INTER_DL_X * base_lat,
        batch_deadline_s=BATCH_DL_X * base_lat,
        shed=True, max_queue_tokens=QUEUE_X * q_tokens,
        interactive_factor=2.0,
        hedge=True, hedge_after_s=0.2,
        degrade=True, degrade_after=2, cooldown_s=0.1)
    on = _run(OverloadManager(cfg), base_lat, "control_on")

    inter_p99 = on["classes"]["interactive"]["p99_s"]
    results = {
        "setup": {"n_queries": N_QUERIES, "overcapacity_x": OVERCAPACITY,
                  "base_latency_s": round(base_lat, 3),
                  "tokens_per_query": q_tokens,
                  "interactive_deadline_s": round(INTER_DL_X * base_lat, 3),
                  "batch_deadline_s": round(BATCH_DL_X * base_lat, 3)},
        "control_off": off,
        "control_on": on,
    }
    shed_on = {c: on["classes"][c]["shed"] for c in on["classes"]}
    results["accept"] = {
        "goodput_gain_x": round(on["goodput_qps"]
                                / max(off["goodput_qps"], 1e-9), 2),
        "goodput_ge_2x": on["goodput_qps"] >= 2.0 * off["goodput_qps"],
        "interactive_p99_bounded": inter_p99 is not None
        and inter_p99 <= INTER_DL_X * base_lat * 1.1,
        "shedding_fired": sum(shed_on.values()) > 0,
        "interactive_protected":
            shed_on["interactive"] <= shed_on["batch"],
        "burst_fired_both_runs": off["burst_fires"] > 0
        and on["burst_fires"] > 0,
        "hedges_issued": on["overload"]["hedge"]["issued"] > 0,
        "zero_leaked_blocks": off["leaked_blocks"] == 0
        and on["leaked_blocks"] == 0,
    }
    print(f"accept={results['accept']}")
    out_path = out_path or Path(__file__).parent / "BENCH_overload.json"
    out_path.write_text(json.dumps(results, indent=2))
    print(f"wrote {out_path}")
    return results


if __name__ == "__main__":
    run()
