"""Paper Fig. 10: graph-optimization ablation on advanced-RAG QA.
Parallelization = Pass 1 (pruning) + Pass 3 (prefill split);
Pipelining     = Pass 2 (stage decomposition) + Pass 4 (decode pipeline).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import fmt_row, make_queries
from repro.core.apps import advanced_rag
from repro.core.teola import Teola
from repro.engines.sim_engines import build_sim_engines

VARIANTS = {
    "no_opt": (),
    "parallel_only": ("prune", "prefill_split"),
    "pipeline_only": ("prune", "stage", "decode_pipeline"),
    "full": ("prune", "stage", "prefill_split", "decode_pipeline"),
}
# note: pipelining passes require pruned data edges to act on, so 'prune'
# is included; 'no_opt' is the raw p-graph (template edges intact).


def _single(passes, n=3):
    lats = []
    for i in range(n):
        engines = build_sim_engines()
        app = advanced_rag(engines)
        orch = Teola(app, engines, passes=passes)
        q = make_queries(1, seed=i)[0]
        _, ctx = orch.query(q, timeout=300)
        lats.append(ctx.latency)
        orch.shutdown()
    return float(np.mean(lats))


def run():
    print("variant,avg_single_query_ms,speedup_vs_no_opt")
    base = None
    for name, passes in VARIANTS.items():
        avg = _single(passes)
        base = base or avg
        print(fmt_row(name, round(avg * 1000, 1), round(base / avg, 2)))


if __name__ == "__main__":
    run()
