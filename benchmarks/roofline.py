"""Roofline table: reads the dry-run artifacts (experiments/dryrun/*.json)
and prints per-(arch x shape x mesh) compute/memory/collective terms,
dominant bottleneck, and useful-FLOPs ratio — deliverable (g)."""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import fmt_row


def load(out_dir="experiments/dryrun"):
    recs = []
    for f in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        with open(f) as fh:
            recs.append(json.load(fh))
    return recs


def run(out_dir: str = "experiments/dryrun"):
    recs = load(out_dir)
    if not recs:
        print("roofline,no dry-run artifacts found (run "
              "`python -m repro.launch.dryrun --all` first)")
        return
    print("arch,shape,mesh,compute_ms,memory_ms,collective_ms,dominant,"
          "useful_flops_ratio,args_GiB,temp_GiB")
    for r in recs:
        if r.get("status") != "ok":
            continue
        t = r["roofline_terms_s"]
        mem = r.get("memory_analysis", {})
        print(fmt_row(
            r["arch"], r["shape"], r["mesh"],
            round(t["compute_s"] * 1e3, 3),
            round(t["memory_s"] * 1e3, 3),
            round(t["collective_s"] * 1e3, 3),
            r["dominant_term"],
            round(r.get("useful_flops_ratio") or 0.0, 3),
            round(mem.get("argument_size_in_bytes", 0) / 2 ** 30, 2),
            round(mem.get("temp_size_in_bytes", 0) / 2 ** 30, 2)))
    skipped = [r for r in recs if r.get("status") == "skipped"]
    for r in skipped:
        print(fmt_row(r["arch"], r["shape"], r["mesh"], "skip", "", "",
                      r.get("skip_reason", ""), "", "", ""))


if __name__ == "__main__":
    run()
