"""Radix prefix-cache study (REAL JAX engines): prefill latency and TTFT
under 0% / 50% / 90% shared-prefix traffic, three serving modes:

  off          paged KV, no prefix reuse — every prompt prefills fully
  instruction  the PR 3 instruction-prefix cache: the caller pre-splits
               each prompt and passes an explicitly warmed prefix_state
               (only works when the split is known a priori)
  radix        the global radix-tree prefix cache: full prompts go in
               unannotated; any block-aligned prefix cached by ANY
               earlier query is forked automatically

(a) prefill latency: sequential prompt stream per share level; wall time
    and prefilled-token count per mode. The radix win at 90% share is
    the tentpole claim (>= 2x vs off).
(b) TTFT + decode throughput under Poisson load: open-loop arrivals at
    fixed request rates, continuous decode loop; time-to-first-token per
    request and aggregate decoded tokens/s (the no-decode-regression
    check).

Emits BENCH_radix_cache.json next to this file and CSV rows on stdout.
"""
from __future__ import annotations

import json
import threading
import time
from pathlib import Path

import numpy as np

from benchmarks.common import fmt_row
from repro.configs.base import get_config
from repro.engines.llm_engine import LLMEngine

ARCH = "tiny-lite-llm"
MAX_LEN = 384
BLOCK = 16
SHARED_WORDS = 160          # shared prefix: 10 full blocks
TAIL_WORDS = 12             # unique tail per shared-traffic request
UNIQUE_WORDS = SHARED_WORDS + TAIL_WORDS
N_PREFIX = 2                # distinct shared prefixes (tenants)
N_REQ = 20
SHARES = (0.0, 0.5, 0.9)
RATES = (4.0, 6.0)          # req/s for the TTFT study (decode-loop
                            # service capacity is ~7.5 req/s — 8+ is
                            # purely queueing-dominated)
MAX_NEW = 16


def _prefixes():
    return [" ".join(f"p{t}w{j}" for j in range(SHARED_WORDS))
            for t in range(N_PREFIX)]


def _workload(share: float, tag: str):
    """Deterministic request stream: request i is shared-prefix traffic
    iff rng says so; shared requests round-robin over the tenants."""
    rng = np.random.default_rng(7)
    reqs = []
    for i in range(N_REQ):
        if rng.random() < share:
            t = i % N_PREFIX
            text = (_prefixes()[t] + " " +
                    " ".join(f"{tag}{i}t{j}" for j in range(TAIL_WORDS)))
            reqs.append((f"{tag}{i}", text, t))
        else:
            text = " ".join(f"{tag}{i}u{j}" for j in range(UNIQUE_WORDS))
            reqs.append((f"{tag}{i}", text, None))
    return reqs


def _engine(mode: str) -> LLMEngine:
    return LLMEngine("bench", get_config(ARCH), max_len=MAX_LEN, seed=0,
                     max_batch=8, paged=True, block_size=BLOCK,
                     num_blocks=640,
                     prefix_cache="radix" if mode == "radix" else "none")


def _prefill_run(eng: LLMEngine, mode: str, reqs, warmed) -> tuple:
    """Sequential prefill of the stream; returns (wall_s, tokens)."""
    tokens = 0
    t0 = time.time()
    for sid, text, tenant in reqs:
        task = {"sid": sid, "text": text}
        if mode == "instruction" and tenant is not None:
            task = {"sid": sid, "prefix_state": warmed[tenant],
                    "text": text[len(_prefixes()[tenant]) + 1:]}
        eng.op_prefill([task])
        tokens += eng.states[sid].pos
        eng.release(sid)                # cached blocks outlive the seq
    wall = time.time() - t0
    return wall, tokens


def _prefill_study(mode: str, share: float) -> dict:
    eng = _engine(mode)
    warmed = None
    if mode == "instruction":
        warmed = [eng.get_prefix_state(p) for p in _prefixes()]
    _prefill_run(eng, mode, _workload(share, "w"), warmed)  # jit rehearsal
    wall, _ = _prefill_run(eng, mode, _workload(share, "s"), warmed)
    # prefilled tokens = resident pos minus radix/instruction-forked part
    stats = dict(eng.radix.stats) if eng.radix is not None else {}
    return {"wall_s": round(wall, 3),
            "hit_tokens": int(stats.get("hit_tokens", 0))}


def _ttft_study(mode: str, share: float, rate: float) -> dict:
    """Open-loop Poisson arrivals into prefill + continuous decode; TTFT
    measured from arrival to the first streamed token."""
    eng = _engine(mode)
    warmed = [eng.get_prefix_state(p) for p in _prefixes()] \
        if mode == "instruction" else None

    def drive(reqs, timed):
        rng = np.random.default_rng(11)
        ttfts, seqs, threads = [], [], []
        lock = threading.Lock()
        t_start = time.time()
        for sid, text, tenant in reqs:
            task = {"sid": sid, "text": text}
            if warmed is not None and tenant is not None:
                task = {"sid": sid, "prefix_state": warmed[tenant],
                        "text": text[len(_prefixes()[tenant]) + 1:]}

            def submit(task=task, sid=sid):
                t_arr = time.time()
                seen = []

                def first_tok(_txt):
                    if not seen:
                        seen.append(time.time() - t_arr)
                eng.op_prefill([task])
                sq = eng.submit_decode(sid, MAX_NEW, on_text=first_tok)
                with lock:
                    seqs.append((sid, sq, seen))
            th = threading.Thread(target=submit, daemon=True)
            th.start()
            threads.append(th)
            time.sleep(float(rng.exponential(1.0 / rate)))
        for th in threads:
            th.join(300)
        for sid, sq, seen in seqs:
            sq.wait(300)
            if timed and seen:
                ttfts.append(seen[0])
        wall = time.time() - t_start
        for sid, _, _ in seqs:
            eng.release(sid)
        return ttfts, wall

    drive(_workload(share, "w"), timed=False)       # jit rehearsal
    ttfts, wall = drive(_workload(share, "s"), timed=True)
    eng.stop_decode_loop()
    return {"ttft_avg_ms": round(float(np.mean(ttfts)) * 1000, 1),
            "ttft_p90_ms": round(float(np.percentile(ttfts, 90)) * 1000, 1),
            "decode_tokens_per_s": round(N_REQ * MAX_NEW / wall, 1)}


def run():
    print("study,config,value,detail")
    out = {"arch": ARCH, "max_len": MAX_LEN, "block_size": BLOCK,
           "shared_words": SHARED_WORDS, "n_requests": N_REQ,
           "prefill": {}, "ttft": {}}

    for share in SHARES:
        row = {}
        for mode in ("off", "instruction", "radix"):
            r = _prefill_study(mode, share)
            row[mode] = r
            print(fmt_row("prefill_latency", f"{mode}_share{share:.0%}",
                          r["wall_s"], f"hit_tokens={r['hit_tokens']}"))
        row["radix_speedup_vs_off"] = round(
            row["off"]["wall_s"] / row["radix"]["wall_s"], 2)
        print(fmt_row("prefill_latency", f"radix_speedup_share{share:.0%}",
                      row["radix_speedup_vs_off"], "wall ratio off/radix"))
        out["prefill"][f"share_{share:.0%}"] = row

    share = 0.9
    for rate in RATES:
        row = {}
        for mode in ("off", "radix"):
            # best-of-2: open-loop thread interleaving can hit jit
            # buckets the rehearsal pass missed; the repeat damps both
            # that and container scheduling noise
            r = min((_ttft_study(mode, share, rate) for _ in range(2)),
                    key=lambda x: x["ttft_avg_ms"])
            row[mode] = r
            print(fmt_row("ttft_load", f"{mode}_r{rate:g}",
                          r["ttft_avg_ms"],
                          f"p90={r['ttft_p90_ms']}ms "
                          f"decode={r['decode_tokens_per_s']}tok/s"))
        row["ttft_ratio_off_over_radix"] = round(
            row["off"]["ttft_avg_ms"] / row["radix"]["ttft_avg_ms"], 2)
        row["decode_tput_ratio_radix_over_off"] = round(
            row["radix"]["decode_tokens_per_s"] /
            row["off"]["decode_tokens_per_s"], 3)
        out["ttft"][f"rate_{rate:g}"] = row

    path = Path(__file__).resolve().parent / "BENCH_radix_cache.json"
    path.write_text(json.dumps(out, indent=2) + "\n")
    print(f"# wrote {path}")


if __name__ == "__main__":
    run()
