"""Paper Fig. 12: breakdown of Teola's execution critical path — graph
optimization overhead, queueing, and execution time."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import fmt_row, make_queries
from repro.core.apps import advanced_rag
from repro.core.pgraph import graph_transform
from repro.core.passes import graph_opt
from repro.core.teola import Teola
from repro.engines.sim_engines import build_sim_engines


def run(n: int = 4):
    engines = build_sim_engines()
    app = advanced_rag(engines)
    orch = Teola(app, engines)
    opt_times, e2e, exec_times = [], [], []
    for i in range(n):
        q = make_queries(1, seed=i)[0]
        t0 = time.time()
        g = graph_transform(app, q)
        g = graph_opt(g, app.engines)
        opt_times.append(time.time() - t0)
        _, ctx = orch.query(q, timeout=300)
        e2e.append(ctx.latency)
        busy = sum((b or a) - a for a, b in ctx.node_spans.values())
        exec_times.append(busy)
    orch.shutdown()
    print("metric,ms,share_pct")
    opt = float(np.mean(opt_times))
    tot = float(np.mean(e2e))
    print(fmt_row("graph_optimization", round(opt * 1000, 2),
                  round(100 * opt / tot, 2)))
    print(fmt_row("end_to_end", round(tot * 1000, 2), 100.0))


if __name__ == "__main__":
    run()
