"""Shared benchmark machinery: load generation, scheme table, CSV output.

Benchmarks run on the SIMULATION engine pool (latency profiles calibrated
to the paper's 3090-class measurements, divided by REPRO_SIM_SPEED so the
suite fits in container time — ratios between schemes are preserved; see
engines/sim_engines.py). Table 3 uses the REAL JAX engines.
"""
from __future__ import annotations

import random
import time

import numpy as np

from repro.core.teola import AutoGenLike, LlamaDist, LlamaDistPC, Teola
from repro.engines.sim_engines import SPEED, build_sim_engines
from repro.training.data import doc_corpus

QUESTIONS = [
    "what is fact 3 about optics",
    "tell me fact 7 about finance",
    "which value belongs to fact 12 about llm systems",
    "what is fact 5 about biology",
    "explain fact 9 about chess",
    "what is fact 2 about espresso",
    "summarize fact 4 about sailing",
    "give the value of fact 8 about volcanoes",
]

SCHEMES = {
    # name -> (orchestrator class, engine scheduling policy)
    "LlamaDist-PO": (LlamaDist, "po"),
    "LlamaDist-TO": (LlamaDist, "to"),
    "LlamaDistPC-TO": (LlamaDistPC, "to"),
    "AutoGen-TO": (AutoGenLike, "to"),
    "Teola": (Teola, "topo"),
}


def make_queries(n: int, num_docs: int = 3, seed: int = 0):
    rng = random.Random(seed)
    docs = doc_corpus(num_docs)
    return [{"question": rng.choice(QUESTIONS), "docs": docs}
            for _ in range(n)]


def run_one(app_factory, scheme: str, query: dict, **app_kw):
    engines = build_sim_engines()
    app = app_factory(engines, **app_kw)
    cls, policy = SCHEMES[scheme]
    orch = cls(app, engines, policy=policy)
    out, ctx = orch.query(dict(query), timeout=300)
    orch.shutdown()
    return ctx


def run_load(app_factory, scheme: str, queries, rate_per_s: float,
             seed: int = 0, timeout: float = 300, **app_kw):
    """Poisson arrivals at `rate_per_s` (wall-clock; the sim SPEED factor
    applies to rates and service times alike). Returns per-query latencies."""
    engines = build_sim_engines()
    app = app_factory(engines, **app_kw)
    cls, policy = SCHEMES[scheme]
    orch = cls(app, engines, policy=policy)
    rng = np.random.default_rng(seed)
    ctxs = []
    for q in queries:
        ctxs.append(orch.submit(dict(q)))
        time.sleep(float(rng.exponential(1.0 / (rate_per_s * SPEED))))
    for c in ctxs:
        c.done.wait(timeout)
    lats = [c.latency for c in ctxs if c.t_done]
    orch.shutdown()
    return np.array(lats), engines


def fmt_row(*cols):
    return ",".join(str(c) for c in cols)
