"""Disaggregated prefill/decode vs unified serving under the PR 5
long-prompt-arrival scenario (table3_prefill study b), emitting
BENCH_disagg.json.

BENCH_chunked_prefill.json showed the unified trade: monolithic prefill
spikes decode TBT (head-of-line blocking), chunked prefill bounds TBT but
gives back ~8% throughput (per-chunk setup overhead paid in-loop).
Disaggregation gets both: the long prompt prefills at FULL token budget
on a prefill specialist (no co-resident decodes to protect), then the
sequence's paged KV blocks migrate to the decode specialist
(``export_seq``/``import_seq``) whose loop never runs a prefill chunk —
decode cadence is disturbed only by the block transfer.

Two studies:

(a) SIM (headline, acceptance): every replica models its OWN
    accelerator, so prefill-side and decode-side compute genuinely
    overlap — the deployment disaggregation targets. Unified-monolithic
    is modeled as a single whole-prompt chunk through the loop (the
    pool-lock head-of-line block); unified-chunked interleaves chunks
    with decodes in one loop; disagg runs chunks back-to-back on the
    prefill replica and migrates (modeled transfer cost) to the decode
    replica. Acceptance: disagg decode TBT p99 at-or-better than
    unified-chunked, with at least half of chunking's throughput
    giveback vs monolithic recovered.

(b) REAL JAX engine: token-identity proof across all three configs plus
    the migration mechanism cost (ms per migration, per block). This
    host serializes all engines onto shared CPU cores, so the
    cross-replica compute OVERLAP is not measurable here — the sim
    carries the scheduling comparison; the real engine carries
    correctness and the handoff's actual price.
"""
from __future__ import annotations

import json
import time
from pathlib import Path

import numpy as np

from benchmarks.common import fmt_row
from benchmarks.table3_prefill import (CHUNK, DECODE_TOK, MAX_LEN,
                                       N_DECODES, PROMPT_TOK, _words)
from repro.configs.base import get_config
from repro.engines.llm_engine import LLMEngine
from repro.engines.sim_engines import SimLLMEngine


def _drive(pe, de, mode, phase, submit_long):
    """Shared scenario driver: resident decodes on ``de``, a long prompt
    arriving mid-decode handled by ``submit_long``, migration from
    ``pe`` to ``de`` when the roles are split. Returns (stamps,
    t_prefill, wall, outs)."""

    def _land(sid):
        if mode == "disagg":
            de.import_seq(pe.export_seq(sid))

    for i in range(N_DECODES):
        pe.op_prefill([{"sid": f"{phase}_d{i}",
                        "text": _words(16, f"p{i}_")}])
        _land(f"{phase}_d{i}")
    stamps = [[] for _ in range(N_DECODES)]
    seqs = []
    t0 = time.time()
    for i in range(N_DECODES):
        seqs.append(de.submit_decode(
            f"{phase}_d{i}", DECODE_TOK,
            on_text=lambda _txt, i=i: stamps[i].append(time.time())))
    deadline = time.time() + 120
    while seqs[0].steps < 4:              # prompt arrives mid-decode
        if seqs[0].done.is_set() or time.time() > deadline:
            raise RuntimeError(
                f"decode never reached arrival point: {seqs[0]}")
        time.sleep(0.001)
    t_arrival = time.time()
    submit_long(f"{phase}_long")
    _land(f"{phase}_long")
    t_prefill = time.time() - t_arrival   # disagg: incl. migration
    outs = [s.wait(300) for s in seqs]
    wall = time.time() - t0
    outs.append(de.op_decode([{"sid": f"{phase}_long",
                               "max_new": 8}])[0])
    for i in range(N_DECODES):
        de.release(f"{phase}_d{i}")
    de.release(f"{phase}_long")
    return stamps, t_prefill, wall, outs


def _metrics(stamps, t_prefill, wall):
    tbt = np.concatenate([np.diff(s) for s in stamps if len(s) > 1])
    total_tok = N_DECODES * DECODE_TOK + PROMPT_TOK
    return {
        "tbt_p50_ms": round(float(np.percentile(tbt, 50)) * 1000, 2),
        "tbt_p99_ms": round(float(np.percentile(tbt, 99)) * 1000, 2),
        "tbt_max_ms": round(float(tbt.max()) * 1000, 2),
        "prefill_ms": round(t_prefill * 1000, 2),
        "wall_s": round(wall, 3),
        "tok_per_s": round(total_tok / wall, 1),
    }


# ---------------------------------------------------------------------------
# study (a): sim — per-replica accelerators, genuine overlap

def _run_sim_study(mode: str):
    kw = dict(max_batch=4, paged=True, block_size=16,
              chunked_prefill=True)
    if mode == "disagg":
        pe = SimLLMEngine("sim_dis_p", prefill_chunk=CHUNK, **kw)
        de = pe.clone(1)
    else:
        # unified: one engine, one loop. "monolithic" lands the whole
        # prompt as a single in-loop chunk — the head-of-line block the
        # real engine's pool lock imposes; "chunked" interleaves
        # CHUNK-token slices with resident decodes.
        chunk = PROMPT_TOK if mode == "monolithic" else CHUNK
        pe = de = SimLLMEngine(f"sim_dis_{mode[0]}", prefill_chunk=chunk,
                               **kw)

    def submit_long(sid):
        pe.submit_prefill({"sid": sid, "text": _words(PROMPT_TOK)}).wait(300)

    stamps, t_prefill, wall, outs = _drive(pe, de, mode, "sim",
                                           submit_long)
    mig = {"migrations_in": de.stats["migrations_in"],
           "migrated_blocks": de.stats["migrated_blocks"]} \
        if mode == "disagg" else None
    de.stop_decode_loop()
    if mode == "disagg":
        pe.stop_decode_loop()
    return _metrics(stamps, t_prefill, wall), outs, mig


# ---------------------------------------------------------------------------
# study (b): real engine — token identity + migration mechanism cost

def _run_real_study(mode: str):
    """A full rehearsal pass runs first and is discarded so the measured
    pass contains no one-time jit compiles, for every config alike."""
    cfg = get_config("tiny-core-llm")
    kw = dict(max_len=MAX_LEN, max_batch=4, paged=True, block_size=16)
    if mode == "disagg":
        # prefill specialist: chunked at full budget (chunks run
        # back-to-back — no decodes to time-slice against)
        pe = LLMEngine("bench_dis_p", cfg, chunked_prefill=True,
                       prefill_chunk=CHUNK, **kw)
        de = pe.clone(1)
    else:
        pe = de = LLMEngine(f"bench_dis_{mode[0]}", cfg,
                            chunked_prefill=(mode == "chunked"),
                            prefill_chunk=CHUNK, **kw)

    def submit_long(sid):
        if mode == "monolithic":
            pe.op_prefill([{"sid": sid, "text": _words(PROMPT_TOK)}])
        else:
            pe.submit_prefill({"sid": sid,
                               "text": _words(PROMPT_TOK)}).wait(300)

    for phase in ("warm", "meas"):
        stamps, t_prefill, wall, outs = _drive(pe, de, mode, phase,
                                               submit_long)
    mig = {"migrations_in": de.stats.get("migrations_in", 0),
           "migrated_blocks": de.stats.get("migrated_blocks", 0),
           "migrate_ms": round(de.stats.get("migrate_s", 0.0) * 1000, 2)} \
        if mode == "disagg" else None
    de.stop_decode_loop()
    if mode == "disagg":
        pe.stop_decode_loop()
    return _metrics(stamps, t_prefill, wall), outs, mig


MODES = ("monolithic", "chunked", "disagg")


def run(out_path: Path = None):
    results = {}
    for study, runner in (("sim", _run_sim_study),
                          ("real", _run_real_study)):
        print(f"{study}: config,tbt_p50_ms,tbt_p99_ms,prefill_ms,"
              f"wall_s,tok_per_s")
        rows, outputs = {}, {}
        for mode in MODES:
            r, outs, mig = runner(mode)
            if mig is not None:
                r["migration"] = mig
            rows[mode], outputs[mode] = r, outs
            print(fmt_row(mode, r["tbt_p50_ms"], r["tbt_p99_ms"],
                          r["prefill_ms"], r["wall_s"], r["tok_per_s"]))
        assert outputs["disagg"] == outputs["monolithic"] == \
            outputs["chunked"], \
            f"{study}: disaggregated serving diverged token-wise!"
        rows["token_identical"] = True
        results[study] = rows

    # acceptance from the sim study (per-replica accelerators — the
    # deployment the comparison is about): chunked-level TBT AND at
    # least half of chunking's throughput giveback recovered
    mono, chk, dis = (results["sim"][m] for m in MODES)
    tput_floor = chk["tok_per_s"] + \
        0.5 * max(mono["tok_per_s"] - chk["tok_per_s"], 0.0)
    results["accept"] = {
        "tbt_p99_leq_chunked": dis["tbt_p99_ms"] <= chk["tbt_p99_ms"],
        "tok_per_s_floor": round(tput_floor, 1),
        "throughput_recovered": dis["tok_per_s"] >= tput_floor,
    }
    results["setup"] = {"prompt_tok": PROMPT_TOK, "decode_tok": DECODE_TOK,
                        "n_decodes": N_DECODES, "prefill_chunk": CHUNK,
                        "prefill_replicas": 1, "decode_replicas": 1}
    print(f"sim decode TBT p99: monolithic {mono['tbt_p99_ms']}ms / "
          f"chunked {chk['tbt_p99_ms']}ms / disagg {dis['tbt_p99_ms']}ms; "
          f"throughput {mono['tok_per_s']} / {chk['tok_per_s']} / "
          f"{dis['tok_per_s']} tok/s (floor {tput_floor:.1f}); "
          f"accept={results['accept']}")
    out_path = out_path or Path(__file__).parent / "BENCH_disagg.json"
    out_path.write_text(json.dumps(results, indent=2))
    print(f"wrote {out_path}")
    return results


if __name__ == "__main__":
    run()
