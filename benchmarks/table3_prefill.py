"""Paper Table 3 + chunked prefill: decomposed / chunked prefilling vs
one complete prefill — REAL JAX engine on CPU (not the simulation
profiles).

Two studies:

(a) Table 3 (paper): decomposed (partial + full) prefilling vs one
    complete prefill — the execution-efficiency cost of Teola's prefill
    split. Paper splits (tokens): 200+800, 850+850, 2500+500 on
    llama-2-7B; here the engine-scale model uses proportionally scaled
    splits within its context.

(b) Stall-free chunked prefill: the latency metric Table 3 cannot see.
    A long prompt arrives while decodes are resident in the continuous
    loop. Monolithic prefill head-of-line-blocks every decode iteration
    for a whole-prompt forward (on the paged path it holds the pool
    lock for the full step), spiking decode time-between-tokens (TBT);
    chunked prefill lands the same prompt in bounded chunks BETWEEN
    decode iterations, so TBT is bounded by one chunk's compute. Both
    configs are asserted token-identical; results land in
    BENCH_chunked_prefill.json.
"""
from __future__ import annotations

import json
import time
from pathlib import Path

import numpy as np

from benchmarks.common import fmt_row
from repro.configs.base import get_config
from repro.engines.llm_engine import LLMEngine

# bucket-aligned splits (partial, full, and their sum are all jit-bucket
# sizes, so padding does not distort the comparison); ratios mirror the
# paper's 1:4 / 1:1 / 5:1
SPLITS = [(128, 256), (256, 256), (384, 128)]

# study (b) shape: a 448-token prompt arriving over two 48-token decodes
PROMPT_TOK = 448
DECODE_TOK = 48
N_DECODES = 2
CHUNK = 64
MAX_LEN = 512


def _words(n, tag="tok"):
    return " ".join(f"{tag}{i}" for i in range(n))


def run_table3(reps: int = 5):
    eng = LLMEngine("bench_llm", get_config("tiny-core-llm"), max_len=768)
    print("partial_tok,full_tok,decomposed_ms,single_ms,overhead_pct")
    for pa, fu in SPLITS:
        # warmup shapes
        for mode in ("split", "single"):
            eng.op_prefill([{"sid": f"warm_{mode}_{pa}",
                             "text": _words(pa if mode == 'split' else
                                            pa + fu)}])
            if mode == "split":
                eng.op_prefill([{"sid": f"warm_{mode}_{pa}",
                                 "text": _words(fu)}])
        dec, sing = [], []
        for r in range(reps):
            sid = f"d{pa}_{fu}_{r}"
            t0 = time.time()
            eng.op_prefill([{"sid": sid, "text": _words(pa)}])
            eng.op_prefill([{"sid": sid, "text": _words(fu)}])
            dec.append(time.time() - t0)
            sid = f"s{pa}_{fu}_{r}"
            t0 = time.time()
            eng.op_prefill([{"sid": sid, "text": _words(pa + fu)}])
            sing.append(time.time() - t0)
        d = 1000 * min(dec)
        s = 1000 * min(sing)
        print(fmt_row(pa, fu, round(d, 2), round(s, 2),
                      round(100 * (d - s) / s, 2)))


def _run_chunked_study(chunked: bool):
    """Resident decodes + one long-prompt arrival. A full REHEARSAL pass
    runs first and is discarded — it compiles every jit shape the
    scenario touches (decode block-table width buckets included), so the
    measured pass contains no one-time compiles, for both configs alike.
    Returns per-decode iteration timestamps, prefill wall time, total
    wall and outputs of the measured pass."""
    eng = LLMEngine("bench_chunk", get_config("tiny-core-llm"),
                    max_len=MAX_LEN, max_batch=4, paged=True,
                    block_size=16, chunked_prefill=chunked,
                    prefill_chunk=CHUNK)
    for phase in ("warm", "meas"):
        for i in range(N_DECODES):
            eng.op_prefill([{"sid": f"{phase}_d{i}",
                             "text": _words(16, f"p{i}_")}])
        stamps = [[] for _ in range(N_DECODES)]
        seqs = []
        t0 = time.time()
        for i in range(N_DECODES):
            seqs.append(eng.submit_decode(
                f"{phase}_d{i}", DECODE_TOK,
                on_text=lambda _txt, i=i: stamps[i].append(time.time())))
        deadline = time.time() + 120
        while seqs[0].steps < 4:          # prompt arrives mid-decode
            if seqs[0].done.is_set() or time.time() > deadline:
                raise RuntimeError(
                    f"decode never reached arrival point: {seqs[0]}")
            time.sleep(0.001)
        t_arrival = time.time()
        if chunked:
            job = eng.submit_prefill({"sid": f"{phase}_long",
                                      "text": _words(PROMPT_TOK)})
            job.wait(300)
        else:
            # monolithic: one whole-prompt forward on this thread while
            # the decode loop contends for the pool lock and the cores
            eng.op_prefill([{"sid": f"{phase}_long",
                             "text": _words(PROMPT_TOK)}])
        t_prefill = time.time() - t_arrival
        outs = [s.wait(300) for s in seqs]
        wall = time.time() - t0
        outs.append(eng.op_decode([{"sid": f"{phase}_long",
                                    "max_new": 8}])[0])
        for i in range(N_DECODES):
            eng.release(f"{phase}_d{i}")
        eng.release(f"{phase}_long")
    eng.stop_decode_loop()
    return stamps, t_prefill, wall, outs


def run_chunked(out_path: Path = None):
    print("\nconfig,tbt_p50_ms,tbt_p99_ms,prefill_ms,wall_s,tok_per_s")
    results = {}
    outputs = {}
    for chunked in (False, True):
        tag = "chunked" if chunked else "monolithic"
        stamps, t_prefill, wall, outs = _run_chunked_study(chunked)
        tbt = np.concatenate([np.diff(s) for s in stamps if len(s) > 1])
        total_tok = N_DECODES * DECODE_TOK + PROMPT_TOK
        results[tag] = {
            "tbt_p50_ms": round(float(np.percentile(tbt, 50)) * 1000, 2),
            "tbt_p99_ms": round(float(np.percentile(tbt, 99)) * 1000, 2),
            "tbt_max_ms": round(float(tbt.max()) * 1000, 2),
            "prefill_ms": round(t_prefill * 1000, 2),
            "wall_s": round(wall, 3),
            "tok_per_s": round(total_tok / wall, 1),
        }
        outputs[tag] = outs
        r = results[tag]
        print(fmt_row(tag, r["tbt_p50_ms"], r["tbt_p99_ms"],
                      r["prefill_ms"], r["wall_s"], r["tok_per_s"]))
    assert outputs["chunked"] == outputs["monolithic"], \
        "chunked prefill diverged from monolithic tokens!"
    speedup = results["monolithic"]["tbt_p99_ms"] / \
        max(results["chunked"]["tbt_p99_ms"], 1e-9)
    results["tbt_p99_speedup"] = round(speedup, 2)
    results["token_identical"] = True
    results["setup"] = {"prompt_tok": PROMPT_TOK, "decode_tok": DECODE_TOK,
                        "n_decodes": N_DECODES, "prefill_chunk": CHUNK}
    print(f"decode TBT p99 under long-prompt arrival: "
          f"{results['monolithic']['tbt_p99_ms']}ms -> "
          f"{results['chunked']['tbt_p99_ms']}ms "
          f"({speedup:.1f}x better, outputs token-identical)")
    out_path = out_path or Path(__file__).parent / \
        "BENCH_chunked_prefill.json"
    out_path.write_text(json.dumps(results, indent=2))
    print(f"wrote {out_path}")
    return results


def run(reps: int = 5):
    run_table3(reps)
    run_chunked()


if __name__ == "__main__":
    run()
