"""Paper Table 3: decomposed (partial + full) prefilling vs one complete
prefill — REAL JAX engine on CPU (not the simulation profiles): measures
the actual execution-efficiency cost of Teola's prefill split.

Paper splits (tokens): 200+800, 850+850, 2500+500 on llama-2-7B; here the
engine-scale model uses proportionally scaled splits within its context.
"""
from __future__ import annotations

import time


from benchmarks.common import fmt_row
from repro.configs.base import get_config
from repro.engines.llm_engine import LLMEngine

# bucket-aligned splits (partial, full, and their sum are all jit-bucket
# sizes, so padding does not distort the comparison); ratios mirror the
# paper's 1:4 / 1:1 / 5:1
SPLITS = [(128, 256), (256, 256), (384, 128)]


def _words(n):
    return " ".join(f"tok{i}" for i in range(n))


def run(reps: int = 5):
    eng = LLMEngine("bench_llm", get_config("tiny-core-llm"), max_len=768)
    print("partial_tok,full_tok,decomposed_ms,single_ms,overhead_pct")
    for pa, fu in SPLITS:
        # warmup shapes
        for mode in ("split", "single"):
            eng.op_prefill([{"sid": f"warm_{mode}_{pa}",
                             "text": _words(pa if mode == 'split' else
                                            pa + fu)}])
            if mode == "split":
                eng.op_prefill([{"sid": f"warm_{mode}_{pa}",
                                 "text": _words(fu)}])
        dec, sing = [], []
        for r in range(reps):
            sid = f"d{pa}_{fu}_{r}"
            t0 = time.time()
            eng.op_prefill([{"sid": sid, "text": _words(pa)}])
            eng.op_prefill([{"sid": sid, "text": _words(fu)}])
            dec.append(time.time() - t0)
            sid = f"s{pa}_{fu}_{r}"
            t0 = time.time()
            eng.op_prefill([{"sid": sid, "text": _words(pa + fu)}])
            sing.append(time.time() - t0)
        d = 1000 * min(dec)
        s = 1000 * min(sing)
        print(fmt_row(pa, fu, round(d, 2), round(s, 2),
                      round(100 * (d - s) / s, 2)))


if __name__ == "__main__":
    run()
