"""Paper Fig. 8: end-to-end latency of the four applications under each
scheme at low and high request rates."""
from __future__ import annotations

import numpy as np

from benchmarks.common import SCHEMES, fmt_row, make_queries, run_load
from repro.core.apps import (advanced_rag, contextual_retrieval, naive_rag,
                             search_gen)

APPS = [("search_gen", search_gen), ("naive_rag", naive_rag),
        ("advanced_rag", advanced_rag),
        ("contextual_retrieval", contextual_retrieval)]
RATES = [("low", 1.0), ("high", 3.0)]


def run(n_queries: int = 10, quick: bool = False):
    rows = []
    apps = APPS[:2] if quick else APPS
    for app_name, factory in apps:
        base = {}
        for rate_name, rate in RATES:
            queries = make_queries(n_queries)
            for scheme in SCHEMES:
                lats, _ = run_load(factory, scheme, queries, rate)
                avg = float(np.mean(lats)) if len(lats) else float("nan")
                p99 = float(np.percentile(lats, 99)) if len(lats) else 0
                base.setdefault(rate_name, avg)
                rows.append((app_name, rate_name, scheme,
                             round(avg * 1000, 1), round(p99 * 1000, 1),
                             round(base[rate_name] / avg, 2)))
    print("app,rate,scheme,avg_ms,p99_ms,speedup_vs_first")
    for r in rows:
        print(fmt_row(*r))
    return rows


if __name__ == "__main__":
    run()
