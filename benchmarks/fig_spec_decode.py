"""Speculative decoding study (REAL JAX engines): target-model steps per
generated token, baseline greedy decode vs draft-k/verify-once
speculative decode on RAG-app synthesize prompts.

The workload is the RAG apps' generation primitive: an instruction
prefix (`core/prompts.INSTRUCTIONS`), retrieved doc-corpus passages and
a question, prefilled on `core_llm`-config engines, then a long greedy
decode. Three speculative configs run against the baseline:

  ngram/dense   — model-free prompt-lookup drafter, dense KV
  ngram/paged   — same drafter over the block-paged pool (verification
                  writes k+1 tokens through the block tables; rejected
                  overshoot blocks are trimmed back to the pool)
  draft-engine  — a real draft LLMEngine paired via EngineDrafter (here
                  a same-weights engine: the acceptance CEILING, every
                  draft accepted, steps/token -> 1/(k+1))

Every config's token stream is asserted IDENTICAL to the baseline (the
speculative correctness contract). Emits BENCH_spec_decode.json with
mean acceptance length (tokens emitted per target verification step) and
the measured reduction in target-model steps per generated token.
"""
from __future__ import annotations

import json
import time
from pathlib import Path

from benchmarks.common import fmt_row
from repro.configs.base import get_config
from repro.core.prompts import INSTRUCTIONS
from repro.engines.llm_engine import LLMEngine
from repro.training.data import doc_corpus

ARCH = "tiny-core-llm"
MAX_LEN = 384
DRAFT_K = 4
MAX_NEW = 96
N_QUERIES = 4


def _rag_prompts():
    docs = doc_corpus(4)
    prompts = []
    for i in range(N_QUERIES):
        passage = " ".join(docs[i % len(docs)]["text"].split()[:48])
        prompts.append((f"q{i}",
                        f"{INSTRUCTIONS['tree']} context: {passage} "
                        f"question: what is fact {i} about "
                        f"{docs[i % len(docs)]['topic']}"))
    return prompts


def _engine(*, paged=False, spec=False, draft=None):
    eng = LLMEngine("bench", get_config(ARCH), max_len=MAX_LEN, seed=0,
                    paged=paged, block_size=16)
    if spec:
        eng.enable_speculative(draft=draft, k=DRAFT_K)
    return eng


def _decode_all(eng):
    prompts = _rag_prompts()
    for sid, text in prompts:
        eng.op_prefill([{"sid": sid, "text": text}])
    t0 = time.time()
    outs = eng.op_decode([{"sid": sid, "max_new": MAX_NEW}
                          for sid, _ in prompts])
    return outs, time.time() - t0


def _measure(tag, *, paged=False, draft_fn=None, baseline=None):
    draft = draft_fn() if draft_fn else None
    eng = _engine(paged=paged, spec=True, draft=draft)
    outs, wall = _decode_all(eng)
    if baseline is not None:
        assert outs == baseline, f"{tag}: speculative output diverged!"
    s = eng.spec.stats
    tokens = N_QUERIES * MAX_NEW
    forwards = s["target_steps"] + s["fallback_steps"]
    # per-SEQUENCE accounting (batch-size independent): a sequence's
    # baseline decode participates in one target step per token, so its
    # speculative steps-per-token is seq_steps / tokens and the mean
    # acceptance length is tokens / seq_steps
    res = {
        "config": tag,
        "tokens": tokens,
        "target_forwards": forwards,
        "seq_steps": s["seq_steps"],
        "mean_acceptance_len": round(tokens / max(1, s["seq_steps"]), 3),
        "seq_steps_per_token": round(s["seq_steps"] / tokens, 3),
        "forwards_per_token": round(forwards / tokens, 3),
        "drafted": s["drafted"],
        "accepted_drafts": s["accepted"],
        "wall_s": round(wall, 2),
        "token_identical": baseline is not None,
    }
    return res


def run():
    print("study,config,value,detail")
    base_eng = _engine()
    base_outs, base_wall = _decode_all(base_eng)
    tokens = N_QUERIES * MAX_NEW
    # baseline: every sequence takes one target step per token; the
    # batched run-to-completion decode spends MAX_NEW forwards total
    base_forwards = MAX_NEW
    print(fmt_row("seq_steps_per_token", "baseline", 1.0,
                  f"{tokens} tokens, {base_forwards} forwards, "
                  f"{base_wall:.1f}s"))

    results = [
        _measure("ngram_dense", baseline=base_outs),
        _measure("ngram_paged", paged=True, baseline=base_outs),
        _measure("draft_engine_dense",
                 draft_fn=lambda: LLMEngine("draft", get_config(ARCH),
                                            max_len=MAX_LEN, seed=0),
                 baseline=base_outs),
    ]
    for r in results:
        print(fmt_row("seq_steps_per_token", r["config"],
                      r["seq_steps_per_token"],
                      f"accept_len {r['mean_acceptance_len']}; "
                      f"{r['target_forwards']} forwards "
                      f"(base {base_forwards})"))

    out = {
        "arch": ARCH, "draft_k": DRAFT_K, "max_new": MAX_NEW,
        "queries": N_QUERIES,
        "baseline": {"seq_steps_per_token": 1.0,
                     "target_forwards": base_forwards},
        "speculative": {r["config"]: r for r in results},
        "seq_step_reduction_vs_baseline": {
            r["config"]: round(1.0 - r["seq_steps_per_token"], 3)
            for r in results},
    }
    path = Path(__file__).resolve().parent / "BENCH_spec_decode.json"
    path.write_text(json.dumps(out, indent=2) + "\n")
    print(f"# wrote {path}")
    assert all(r["token_identical"] for r in results)
    assert all(r["mean_acceptance_len"] > 1.0 for r in results), \
        "a config failed acceptance length > 1"
    assert all(r["seq_steps_per_token"] < 1.0 for r in results), \
        "a config failed to reduce target steps per token"


if __name__ == "__main__":
    run()
