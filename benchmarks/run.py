"""Benchmark aggregator: one section per paper table/figure, CSV output.

  PYTHONPATH=src python -m benchmarks.run [--quick] [--only SECTION]
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller query counts / app subset")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    from benchmarks import (fig1_breakdown, fig4_batching, fig8_end_to_end,
                            fig9_colocation, fig10_ablation_graph,
                            fig11_ablation_sched, fig12_critical_path,
                            fig_disagg, fig_fault_tolerance, fig_overload,
                            fig_paged_kv, fig_radix_cache, fig_slo,
                            fig_spec_decode, instances_scaling, roofline,
                            table3_prefill)

    sections = [
        ("fig1_breakdown", lambda: fig1_breakdown.run()),
        ("fig4_batching", lambda: fig4_batching.run()),
        ("fig8_end_to_end", lambda: fig8_end_to_end.run(
            n_queries=6 if args.quick else 10, quick=args.quick)),
        ("fig9_colocation", lambda: fig9_colocation.run()),
        ("fig10_ablation_graph", lambda: fig10_ablation_graph.run()),
        ("fig11_ablation_sched", lambda: fig11_ablation_sched.run()),
        ("fig12_critical_path", lambda: fig12_critical_path.run()),
        ("table3_prefill", lambda: table3_prefill.run_table3()),
        ("chunked_prefill", lambda: table3_prefill.run_chunked()),
        ("fig_disagg", lambda: fig_disagg.run()),
        ("fig_fault_tolerance", lambda: fig_fault_tolerance.run()),
        ("fig_overload", lambda: fig_overload.run()),
        ("fig_paged_kv", lambda: fig_paged_kv.run()),
        ("fig_radix_cache", lambda: fig_radix_cache.run()),
        ("fig_slo", lambda: fig_slo.run()),
        ("fig_spec_decode", lambda: fig_spec_decode.run()),
        ("instances_scaling", lambda: instances_scaling.run()),
        ("roofline", lambda: roofline.run()),
    ]
    failed = []
    for name, fn in sections:
        if args.only and args.only != name:
            continue
        print(f"\n===== {name} =====")
        t0 = time.time()
        try:
            fn()
        except Exception:  # noqa: BLE001
            traceback.print_exc()
            failed.append(name)
        print(f"----- {name} done in {time.time() - t0:.1f}s -----")
    if failed:
        print(f"\nFAILED sections: {failed}")
        sys.exit(1)
    print("\nall benchmark sections completed")


if __name__ == "__main__":
    main()
