"""Paper Fig. 1: latency breakdown by task module under module-level
orchestration (LlamaDist) — shows non-LLM modules' share of end-to-end
time, the paper's motivating observation."""
from __future__ import annotations

from collections import defaultdict

from benchmarks.common import fmt_row, make_queries, run_one
from repro.core.apps import (advanced_rag, contextual_retrieval, naive_rag,
                             search_gen)


def run():
    print("app,component,share_pct,ms")
    for name, factory in [("search_gen", search_gen),
                          ("naive_rag", naive_rag),
                          ("advanced_rag", advanced_rag),
                          ("contextual_retrieval", contextual_retrieval)]:
        q = make_queries(1)[0]
        ctx = run_one(factory, "LlamaDist-TO", q)
        per_comp = defaultdict(float)
        for pid, (a, b) in ctx.node_spans.items():
            comp = ctx.graph.nodes[pid].component
            per_comp[comp] += (b or a) - a
        total = sum(per_comp.values()) or 1.0
        llm_share = 0.0
        for comp, t in sorted(per_comp.items(), key=lambda kv: -kv[1]):
            print(fmt_row(name, comp, round(100 * t / total, 1),
                          round(t * 1000, 1)))
            if "synthesize" in comp or "expansion" in comp:
                llm_share += t / total
        print(fmt_row(name, "NON_LLM_TOTAL",
                      round(100 * (1 - llm_share), 1), ""))


if __name__ == "__main__":
    run()
