"""Paper Fig. 9: co-located applications (naive + advanced RAG QA sharing
one engine pool) — Teola vs the stronger baseline LlamaDistPC.

Engines follow the paper's testbed provisioning: each LLM runs as an
EnginePool of TWO replicas (§7.1); both schemes get the same pools, and
the pooled lower-tier scheduler load-balances the colocated apps' fused
batches across replicas by outstanding tokens + KV occupancy."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import SCHEMES, fmt_row, make_queries
from repro.core.apps import advanced_rag, naive_rag
from repro.engines.sim_engines import SPEED, build_sim_engines

LLM_INSTANCES = 2


def _run(scheme: str, n_per_app: int = 6, rate: float = 1.5):
    engines = build_sim_engines(llm_instances=LLM_INSTANCES)
    cls, policy = SCHEMES[scheme]
    apps = {"naive": naive_rag(engines), "advanced": advanced_rag(engines)}
    orchs = {k: cls(a, engines, policy=policy) for k, a in apps.items()}
    rng = np.random.default_rng(0)
    ctxs = {"naive": [], "advanced": []}
    for i in range(n_per_app):
        for k in ("naive", "advanced"):
            q = make_queries(1, seed=i)[0]
            ctxs[k].append(orchs[k].submit(q))
            time.sleep(float(rng.exponential(1.0 / (rate * SPEED))))
    out = {}
    for k, cs in ctxs.items():
        for c in cs:
            c.done.wait(300)
        out[k] = float(np.mean([c.latency for c in cs if c.t_done]))
    for o in orchs.values():
        o.shutdown()
    return out


def run():
    print("app,scheme,avg_ms,speedup")
    pc = _run("LlamaDistPC-TO")
    te = _run("Teola")
    for k in ("naive", "advanced"):
        print(fmt_row(k, "LlamaDistPC-TO", round(pc[k] * 1000, 1), 1.0))
        print(fmt_row(k, "Teola", round(te[k] * 1000, 1),
                      round(pc[k] / te[k], 2)))


if __name__ == "__main__":
    run()
