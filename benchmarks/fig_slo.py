"""SLO-aware multi-tenant scheduling (serving/slo.py), emitting
BENCH_slo.json.

A slot-constrained sim engine serves a MIXED load: a batch tenant
floods long throughput-bound decodes at t=0, then an interactive tenant
trickles in short TTFT-bound requests while every decode slot is
occupied.  Two runs:

  slo_off   the pre-existing FIFO continuous loop — interactive
            requests queue behind the whole batch flood.
  slo_on    the SLO policy armed: priority admission ranks interactive
            first, per-tenant fair share bounds the batch tenant's slot
            hold, and paged preemption (evict-to-recompute) frees a
            slot the moment an urgent waiter is deferred.

Reported per class: TTFT / end-to-end percentiles and batch token
throughput.  A second REAL-engine study proves the preemption path's
correctness contract end to end: a preempted-and-resumed decode is
token-identical to an uninterrupted baseline (dense AND paged) and the
paged block pool audits clean after release.  Acceptance: interactive
p99 TTFT improves >= 2x under slo_on, batch throughput stays within
10%, preemptions actually fired, zero leaked blocks, token identity
holds.
"""
from __future__ import annotations

import json
import time
from pathlib import Path

import numpy as np

from repro.engines.sim_engines import SimLLMEngine
from repro.serving.slo import attach_slo, derive_tag

N_BATCH = 8            # batch-tenant flood, long decodes
BATCH_TOKENS = 160
N_INTER = 8            # interactive trickle, short decodes
INTER_TOKENS = 4
INTER_GAP_S = 0.03
MAX_BATCH = 4          # decode slots — flood saturates them twice over


def _percentiles(xs):
    if not xs:
        return {"p50_ms": 0.0, "p99_ms": 0.0}
    return {"p50_ms": round(float(np.percentile(xs, 50)) * 1e3, 1),
            "p99_ms": round(float(np.percentile(xs, 99)) * 1e3, 1)}


def _run_sim(slo_on: bool):
    eng = SimLLMEngine("llm", max_batch=MAX_BATCH,
                       decode_ms_per_step=8.0)
    if slo_on:
        attach_slo({"llm": eng}, aging_s=5.0, preempt_cooldown_s=0.1)
    t0 = time.time()
    ttft = {}

    def _first_text(sid):
        def cb(_chunk):
            ttft.setdefault(sid, time.time())
        return cb

    batch = []
    for i in range(N_BATCH):
        sid = f"b{i}"
        tag = derive_tag(slo="batch", tenant="tb")
        batch.append((sid, time.time(), eng.submit_decode(
            sid, BATCH_TOKENS, on_text=_first_text(sid), slo=tag)))
    time.sleep(0.1)                      # let the flood occupy the slots
    inter = []
    for i in range(N_INTER):
        sid = f"i{i}"
        tag = derive_tag(slo="interactive", tenant="ti")
        inter.append((sid, time.time(), eng.submit_decode(
            sid, INTER_TOKENS, on_text=_first_text(sid), slo=tag)))
        time.sleep(INTER_GAP_S)
    for _sid, _ts, sq in inter + batch:
        sq.wait(300)
    batch_wall = max(sq.t_done for _s, _t, sq in batch) - t0
    i_ttft = [ttft[sid] - ts for sid, ts, _sq in inter]
    i_e2e = [sq.t_done - ts for _sid, ts, sq in inter]
    loop = eng._decode_loop
    row = {
        "interactive_ttft": _percentiles(i_ttft),
        "interactive_e2e": _percentiles(i_e2e),
        "batch_tput_tok_s": round(N_BATCH * BATCH_TOKENS / batch_wall, 1),
        "batch_wall_s": round(batch_wall, 3),
        "preemptions": len(loop.preemptions),
        "tenant_stats": eng.tenant_stats(),
    }
    # correctness even in the sim: every decode returned its full text
    for _sid, _ts, sq in inter + batch:
        assert sq.result == " ".join(sq.words), "sim decode corrupted"
    eng.stop_decode_loop()
    return row


# ---------------------------------------------------------------------------
# real-engine study: preempt -> resume token identity + block-pool audit

def _run_real(paged: bool):
    from repro.configs.base import get_config
    from repro.engines.decode_loop import DecodeSeq
    from repro.engines.llm_engine import LLMEngine
    cfg = get_config("tiny-lite-llm")
    kw = dict(max_len=128, seed=0, max_batch=4)
    if paged:
        kw.update(paged=True, block_size=8, num_blocks=64)

    def fresh():
        eng = LLMEngine("t", cfg, **kw)
        attach_slo({"llm": eng}, preempt_cooldown_s=0.0)
        eng.op_prefill([{"sid": "s",
                         "text": "benchmark prompt about slo scheduling"}])
        seq = DecodeSeq("s", eng.states["s"], 12,
                        text_fn=lambda q: eng.tok.decode(q.tokens))
        assert eng.try_admit(seq)
        eng.note_slot_acquired(seq)
        return eng, seq

    def drive(eng, seq, iters):
        for _ in range(iters):
            before = len(seq.tokens)
            eng.decode_iteration([seq])
            seq.steps += max(1, len(seq.tokens) - before)

    eng0, base = fresh()
    t0 = time.time()
    drive(eng0, base, 12)
    base_wall = time.time() - t0

    eng, seq = fresh()
    t0 = time.time()
    drive(eng, seq, 5)
    assert eng.can_preempt(seq)
    eng.preempt_decode(seq)
    assert eng.try_admit(seq)
    eng.note_slot_acquired(seq)
    drive(eng, seq, 7)
    wall = time.time() - t0

    identical = seq.tokens == base.tokens
    for e, s in ((eng, seq), (eng0, base)):
        e.note_slot_released(s)
        e.release("s")
    row = {"token_identical": identical,
           "preempt_overhead_s": round(wall - base_wall, 3),
           "preempted": eng.tenant_stats()
           .get("default/batch", {}).get("preempted", 0)}
    if paged:
        rep = eng.alloc.audit()
        row["blocks_leaked"] = rep["leaked"] + rep["bad_free"]
        row["pool_restored"] = \
            eng.alloc.free_blocks() == eng.alloc.capacity
    return row


def run(out_path: Path = None):
    results = {}
    off = _run_sim(slo_on=False)
    on = _run_sim(slo_on=True)
    results["sim"] = {"slo_off": off, "slo_on": on}
    for name, row in results["sim"].items():
        print(f"{name}: interactive ttft p99 "
              f"{row['interactive_ttft']['p99_ms']}ms, batch "
              f"{row['batch_tput_tok_s']} tok/s, "
              f"{row['preemptions']} preemptions")

    real = {"dense": _run_real(paged=False),
            "paged": _run_real(paged=True)}
    results["real"] = real
    print(f"real: dense identical={real['dense']['token_identical']}, "
          f"paged identical={real['paged']['token_identical']} "
          f"(leaked={real['paged']['blocks_leaked']})")

    ttft_gain = off["interactive_ttft"]["p99_ms"] / \
        max(on["interactive_ttft"]["p99_ms"], 1e-9)
    tput_ratio = on["batch_tput_tok_s"] / max(off["batch_tput_tok_s"],
                                              1e-9)
    results["accept"] = {
        "interactive_ttft_p99_gain_x": round(ttft_gain, 1),
        "ttft_gain_ge_2x": ttft_gain >= 2.0,
        "batch_tput_within_10pct": tput_ratio >= 0.9,
        "preemptions_fired": on["preemptions"] > 0,
        "real_token_identical": real["dense"]["token_identical"]
        and real["paged"]["token_identical"],
        "zero_blocks_leaked": real["paged"]["blocks_leaked"] == 0
        and real["paged"]["pool_restored"],
    }
    results["setup"] = {
        "n_batch": N_BATCH, "batch_tokens": BATCH_TOKENS,
        "n_interactive": N_INTER, "inter_tokens": INTER_TOKENS,
        "max_batch": MAX_BATCH,
    }
    print(f"accept={results['accept']}")
    out_path = out_path or Path(__file__).parent / "BENCH_slo.json"
    out_path.write_text(json.dumps(results, indent=2))
    print(f"wrote {out_path}")
    return results


if __name__ == "__main__":
    run()
