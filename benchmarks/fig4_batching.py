"""Paper Fig. 4: request- vs application-level scheduling toy studies.
(a) embedding engine: 48 requests at batch 4 vs 16 — total completion time
(b) LLM tree-synthesis: blind batch-2 vs topology/depth-aware batching
(c) decode under STAGGERED arrivals: run-to-completion batching vs
    iteration-level continuous batching (the persistent decode loop) —
    §5's phase-aware scheduling argument applied to the decode phase
"""
from __future__ import annotations

import threading
import time
from collections import deque

from benchmarks.common import fmt_row
from repro.engines.sim_engines import SPEED, SimEmbeddingEngine, \
    SimLLMEngine


def _staggered_decode(continuous: bool, *, n_req: int = 8,
                      max_new: int = 24, stagger_ms: float = 80.0,
                      max_batch: int = 4, decode_ms: float = 50.0):
    """`n_req` decode requests arrive `stagger_ms` (model time) apart.
    Run-to-completion: the server batches whatever has arrived (up to
    max_batch) and steps the batch until its LONGEST member finishes —
    arrivals mid-batch wait a whole batch-time. Continuous: every request
    is admitted into a free decode slot at the NEXT iteration and evicted
    the moment it finishes. Returns (total_model_ms, decode tokens/s)."""
    eng = SimLLMEngine("llm", max_batch=max_batch,
                       decode_ms_per_step=decode_ms)
    arrived = deque()
    lock = threading.Lock()

    def producer():
        for i in range(n_req):
            with lock:
                arrived.append(f"s{i}")
            time.sleep(stagger_ms / 1000.0 / SPEED)

    t0 = time.time()
    th = threading.Thread(target=producer)
    th.start()
    if continuous:
        seqs, submitted = [], 0
        while submitted < n_req:
            with lock:
                new = [arrived.popleft() for _ in range(len(arrived))]
            for sid in new:
                seqs.append(eng.submit_decode(sid, max_new))
                submitted += 1
            if submitted < n_req:
                time.sleep(0.0005)
        for s in seqs:
            s.wait(300)
        eng.stop_decode_loop()
    else:
        served = 0
        while served < n_req:
            with lock:
                batch = [arrived.popleft()
                         for _ in range(min(len(arrived), max_batch))]
            if not batch:
                time.sleep(0.0005)
                continue
            eng.op_decode([{"sid": sid, "max_new": max_new}
                           for sid in batch])
            served += len(batch)
    th.join()
    wall_ms = (time.time() - t0) * 1000.0 * SPEED
    tput = n_req * max_new / (wall_ms / 1000.0)
    return wall_ms, tput


def run():
    print("study,config,total_ms,speedup")
    # (a) embedding batching
    n = 48
    times = {}
    for bs in (4, 16):
        eng = SimEmbeddingEngine(max_batch=bs)
        t0 = time.time()
        for i in range(0, n, bs):
            eng.op_embed([{"texts": [f"chunk {j}" for j in
                                     range(i, min(i + bs, n))]}])
        times[bs] = (time.time() - t0) * SPEED
    print(fmt_row("embedding_48req", "batch4",
                  round(times[4] * 1000), 1.0))
    print(fmt_row("embedding_48req", "batch16",
                  round(times[16] * 1000),
                  round(times[4] / times[16], 2)))

    # (b) LLM tree synthesis: 3 leaves + 1 root (depth 2)
    def tree_blind():
        eng = SimLLMEngine("llm", max_batch=2)
        t0 = time.time()
        # blind batch-2: leaves in two batches, then root alone
        eng.op_decode([{"sid": "l0", "max_new": 24},
                       {"sid": "l1", "max_new": 24}])
        eng.op_decode([{"sid": "l2", "max_new": 24}])
        eng.op_decode([{"sid": "root", "max_new": 32}])
        return (time.time() - t0) * SPEED

    def tree_depth_aware():
        eng = SimLLMEngine("llm", max_batch=4)
        t0 = time.time()
        # same-depth leaves batched at the max-efficient size, then root
        eng.op_decode([{"sid": f"l{i}", "max_new": 24} for i in range(3)])
        eng.op_decode([{"sid": "root", "max_new": 32}])
        return (time.time() - t0) * SPEED

    tb, ta = tree_blind(), tree_depth_aware()
    print(fmt_row("llm_tree_depth2", "blind_batch2", round(tb * 1000), 1.0))
    print(fmt_row("llm_tree_depth2", "depth_aware", round(ta * 1000),
                  round(tb / ta, 2)))

    # (c) staggered decode arrivals: run-to-completion vs continuous
    rtc_ms, rtc_tput = _staggered_decode(continuous=False)
    cont_ms, cont_tput = _staggered_decode(continuous=True)
    print(fmt_row("decode_staggered_8req", "run_to_completion",
                  round(rtc_ms), 1.0))
    print(fmt_row("decode_staggered_8req", "continuous",
                  round(cont_ms), round(rtc_ms / cont_ms, 2)))
    print(f"# decode throughput: run_to_completion {rtc_tput:.0f} tok/s, "
          f"continuous {cont_tput:.0f} tok/s "
          f"({cont_tput / rtc_tput:.2f}x)")


if __name__ == "__main__":
    run()
