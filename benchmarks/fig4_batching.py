"""Paper Fig. 4: request- vs application-level scheduling toy studies.
(a) embedding engine: 48 requests at batch 4 vs 16 — total completion time
(b) LLM tree-synthesis: blind batch-2 vs topology/depth-aware batching
"""
from __future__ import annotations

import time

from benchmarks.common import fmt_row
from repro.engines.sim_engines import SPEED, SimEmbeddingEngine, \
    SimLLMEngine


def run():
    print("study,config,total_ms,speedup")
    # (a) embedding batching
    n = 48
    times = {}
    for bs in (4, 16):
        eng = SimEmbeddingEngine(max_batch=bs)
        t0 = time.time()
        for i in range(0, n, bs):
            eng.op_embed([{"texts": [f"chunk {j}" for j in
                                     range(i, min(i + bs, n))]}])
        times[bs] = (time.time() - t0) * SPEED
    print(fmt_row("embedding_48req", "batch4",
                  round(times[4] * 1000), 1.0))
    print(fmt_row("embedding_48req", "batch16",
                  round(times[16] * 1000),
                  round(times[4] / times[16], 2)))

    # (b) LLM tree synthesis: 3 leaves + 1 root (depth 2)
    def tree_blind():
        eng = SimLLMEngine("llm", max_batch=2)
        t0 = time.time()
        # blind batch-2: leaves in two batches, then root alone
        eng.op_decode([{"sid": "l0", "max_new": 24},
                       {"sid": "l1", "max_new": 24}])
        eng.op_decode([{"sid": "l2", "max_new": 24}])
        eng.op_decode([{"sid": "root", "max_new": 32}])
        return (time.time() - t0) * SPEED

    def tree_depth_aware():
        eng = SimLLMEngine("llm", max_batch=4)
        t0 = time.time()
        # same-depth leaves batched at the max-efficient size, then root
        eng.op_decode([{"sid": f"l{i}", "max_new": 24} for i in range(3)])
        eng.op_decode([{"sid": "root", "max_new": 32}])
        return (time.time() - t0) * SPEED

    tb, ta = tree_blind(), tree_depth_aware()
    print(fmt_row("llm_tree_depth2", "blind_batch2", round(tb * 1000), 1.0))
    print(fmt_row("llm_tree_depth2", "depth_aware", round(ta * 1000),
                  round(tb / ta, 2)))


if __name__ == "__main__":
    run()
