"""LLM engine instance scaling (paper §7.1 testbed provisions 2 LLM
instances) + e-graph cache overhead: extensions beyond the core figures.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import fmt_row, make_queries
from repro.core.apps import advanced_rag
from repro.core.teola import Teola
from repro.engines.sim_engines import SPEED, build_sim_engines


def run(n_queries: int = 8, rate: float = 3.0):
    print("study,config,avg_ms,speedup")
    base = None
    for inst in (1, 2):
        engines = build_sim_engines(llm_instances=inst)
        app = advanced_rag(engines)
        orch = Teola(app, engines)
        rng = np.random.default_rng(0)
        ctxs = []
        for q in make_queries(n_queries):
            ctxs.append(orch.submit(q))
            time.sleep(float(rng.exponential(1.0 / (rate * SPEED))))
        for c in ctxs:
            c.done.wait(300)
        avg = float(np.mean([c.latency for c in ctxs if c.t_done]))
        base = base or avg
        print(fmt_row("llm_instances", f"x{inst}", round(avg * 1000, 1),
                      round(base / avg, 2)))
        orch.shutdown()

    # e-graph cache: build time cold vs hot
    engines = build_sim_engines()
    app = advanced_rag(engines)
    orch = Teola(app, engines)
    q = make_queries(1)[0]
    t0 = time.time()
    orch.build_egraph(dict(q), use_cache=False)
    cold = (time.time() - t0) * 1000
    orch.build_egraph(dict(q))           # populate
    t0 = time.time()
    orch.build_egraph(dict(q))
    hot = (time.time() - t0) * 1000
    print(fmt_row("egraph_cache", "cold_build", round(cold, 3), 1.0))
    print(fmt_row("egraph_cache", "cached", round(hot, 3),
                  round(cold / max(hot, 1e-6), 1)))
    orch.shutdown()


if __name__ == "__main__":
    run()
