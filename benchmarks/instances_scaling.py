"""LLM engine-pool instance scaling (paper §7.1 provisions 2 instances
per LLM; Fig. 9's colocation numbers rest on the same mechanism) +
e-graph cache overhead.

Drives real EnginePools: every model engine (core/lite LLM, embedder,
reranker) is replicated behind the pooled lower-tier scheduler, which
routes fused batches to the least-loaded replica (outstanding tokens +
KV occupancy) with sequence affinity. Under a saturating closed load,
end-to-end throughput should increase monotonically 1 -> 2 -> 4
replicas; per-replica max_batch is kept small so batching alone cannot
absorb the offered load. (Scaling only the LLM pool flattens early: the
single shared embedder becomes the Amdahl bottleneck.)
"""
from __future__ import annotations

import time

from benchmarks.common import fmt_row, make_queries
from repro.core.apps import advanced_rag
from repro.core.engine_pool import EnginePool, build_pools
from repro.core.teola import Teola
from repro.engines.sim_engines import build_sim_engines


def run(n_queries: int = 12, llm_max_batch: int = 2):
    print("study,config,value,speedup")
    base = None
    for inst in (1, 2, 4):
        engines = build_sim_engines(llm_instances=inst,
                                    llm_max_batch=llm_max_batch)
        engines = build_pools(engines, {"embedding": inst, "rerank": inst})
        assert inst == 1 or isinstance(engines["core_llm"], EnginePool)
        app = advanced_rag(engines)
        orch = Teola(app, engines)
        # warm the e-graph cache so graph build cost is off the clock
        qs = make_queries(n_queries)
        orch.build_egraph(dict(qs[0]))
        t0 = time.time()
        ctxs = [orch.submit(q) for q in qs]     # closed saturating load
        for c in ctxs:
            c.done.wait(300)
        wall = time.time() - t0
        thru = n_queries / wall
        base = base or thru
        row = fmt_row("llm_pool_throughput", f"x{inst}",
                      f"{thru:.2f}qps", round(thru / base, 2))
        if inst > 1:
            sched = orch.runtime.scheds["core_llm"]
            used = {r for r, _, _, _ in sched.routes}
            row += f"  # replicas used: {sorted(used)}"
        print(row)
        orch.shutdown()

    # e-graph cache: build time cold vs hot
    engines = build_sim_engines()
    app = advanced_rag(engines)
    orch = Teola(app, engines)
    q = make_queries(1)[0]
    t0 = time.time()
    orch.build_egraph(dict(q), use_cache=False)
    cold = (time.time() - t0) * 1000
    orch.build_egraph(dict(q))           # populate
    t0 = time.time()
    orch.build_egraph(dict(q))
    hot = (time.time() - t0) * 1000
    print(fmt_row("egraph_cache", "cold_build", round(cold, 3), 1.0))
    print(fmt_row("egraph_cache", "cached", round(hot, 3),
                  round(cold / max(hot, 1e-6), 1)))
    orch.shutdown()


if __name__ == "__main__":
    run()
