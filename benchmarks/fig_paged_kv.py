"""Paged-KV study: resident-sequence capacity at fixed memory and
steady-state decode throughput, dense vs block-paged (REAL JAX engines).

(a) capacity: the dense engine allocates a full max_len cache per
    sequence, so a fixed memory budget caps residency at
    budget / dense_seq_bytes regardless of how short prompts are. The
    paged engine carves the SAME budget into blocks and is measured by
    admitting prompts until pool-exhaustion backpressure; block-granular
    allocation (and COW prefix sharing on top) multiplies residency.
(b) decode throughput: 8 staggered sequences through the continuous
    decode loop — the dense loop restacks its batch KV pytree on every
    admission/eviction, the paged loop only rebuilds a (B, maxblk) int32
    table — plus per-iteration step latency at steady state.

Emits BENCH_paged_kv.json next to this file (machine-readable capacity +
tokens/s trajectory) and CSV rows on stdout.
"""
from __future__ import annotations

import json
import time
from pathlib import Path

from benchmarks.common import fmt_row
from repro.configs.base import get_config
from repro.engines.llm_engine import LLMEngine
from repro.serving import kv_cache as kvc

ARCH = "tiny-lite-llm"
MAX_LEN = 256
BLOCK = 16
PROMPT_TOKENS = 32          # realistic short RAG-style prompt
DENSE_BUDGET_SEQS = 6       # memory budget = 6 dense max_len caches
PREFIX_TOKENS = 48          # shared instruction for the sharing variant


def _prompt(i: int, n: int, prefix: str = "") -> str:
    body = " ".join(f"q{i}w{j}" for j in range(n))
    return (prefix + " " + body) if prefix else body


def _capacity_paged(share_prefix: bool) -> dict:
    cfg = get_config(ARCH)
    budget = DENSE_BUDGET_SEQS * kvc.cache_bytes(cfg, 1, MAX_LEN)
    block_bytes = kvc.paged_block_bytes(cfg, BLOCK)
    num_blocks = 1 + budget // block_bytes          # +1 reserved pad block
    eng = LLMEngine("cap", cfg, max_len=MAX_LEN, seed=0, paged=True,
                    block_size=BLOCK, num_blocks=int(num_blocks))
    eng.ALLOC_TIMEOUT = 0.05                        # fail fast when full
    prefix = ""
    n_unique = PROMPT_TOKENS
    pre = None
    if share_prefix:
        prefix = " ".join(f"instr{j}" for j in range(PREFIX_TOKENS))
        pre = eng.get_prefix_state(prefix)
        n_unique = PROMPT_TOKENS - PREFIX_TOKENS // 3   # shorter unique tail
    admitted = 0
    try:
        while admitted < 4096:                      # measured, not computed
            batch = []
            for k in range(4):
                t = {"sid": f"s{admitted + k}",
                     "text": _prompt(admitted + k, n_unique)}
                if pre is not None:
                    t["prefix_state"] = pre
                batch.append(t)
            eng.op_prefill(batch)
            admitted += len(batch)
    except kvc.OutOfBlocks:
        pass
    return {"resident_seqs": admitted,
            "blocks_used": eng.alloc.used_blocks(),
            "pool_blocks": eng.alloc.capacity,
            "budget_bytes": int(budget)}


def _decode_tput(paged: bool, n_seqs: int = 8, max_new: int = 64,
                 stagger_s: float = 0.03) -> dict:
    """Staggered arrivals into the continuous decode loop (admissions and
    evictions force residency changes — the dense loop's restack path).
    STEADY-STATE methodology: the full workload runs once untimed first,
    so every jit shape both engines will hit (batch buckets for dense,
    batch x table-width buckets for paged) is compiled before the timed
    pass — one-time compiles are a cold-start cost, not throughput."""
    cfg = get_config(ARCH)
    eng = LLMEngine("tput", cfg, max_len=MAX_LEN, seed=0, paged=paged,
                    block_size=BLOCK)

    def run_once(tag):
        for i in range(n_seqs):
            eng.op_prefill([{"sid": f"{tag}{i}",
                             "text": _prompt(i, PROMPT_TOKENS)}])
        t0 = time.time()
        seqs = []
        for i in range(n_seqs):
            seqs.append(eng.submit_decode(f"{tag}{i}", max_new))
            time.sleep(stagger_s)
        for s in seqs:
            s.wait(300)
        wall = time.time() - t0
        for i in range(n_seqs):
            eng.release(f"{tag}{i}")
        return wall

    run_once("w")                       # untimed rehearsal: compile shapes
    wall = run_once("s")
    loop = eng._decode_loop
    iters = loop.iterations
    eng.stop_decode_loop()
    return {"tokens_per_s": round(n_seqs * max_new / wall, 1),
            "wall_s": round(wall, 3), "iterations": iters}


def run():
    print("study,config,value,detail")
    cfg = get_config(ARCH)
    dense_seq_bytes = kvc.cache_bytes(cfg, 1, MAX_LEN)
    budget = DENSE_BUDGET_SEQS * dense_seq_bytes
    # dense residency at this budget is allocation-bound by construction
    dense_cap = DENSE_BUDGET_SEQS
    paged_cap = _capacity_paged(share_prefix=False)
    shared_cap = _capacity_paged(share_prefix=True)
    ratio = paged_cap["resident_seqs"] / dense_cap
    ratio_shared = shared_cap["resident_seqs"] / dense_cap
    print(fmt_row("capacity_fixed_mem", "dense", dense_cap,
                  f"{budget} bytes budget"))
    print(fmt_row("capacity_fixed_mem", "paged", paged_cap["resident_seqs"],
                  f"{paged_cap['blocks_used']}/{paged_cap['pool_blocks']} "
                  f"blocks; {ratio:.1f}x"))
    print(fmt_row("capacity_fixed_mem", "paged_shared_prefix",
                  shared_cap["resident_seqs"], f"{ratio_shared:.1f}x"))

    # best-of-2 per engine: damps container thread-scheduling noise
    tput_dense = max((_decode_tput(paged=False) for _ in range(2)),
                     key=lambda r: r["tokens_per_s"])
    tput_paged = max((_decode_tput(paged=True) for _ in range(2)),
                     key=lambda r: r["tokens_per_s"])
    speedup = tput_paged["tokens_per_s"] / tput_dense["tokens_per_s"]
    print(fmt_row("decode_tput_staggered8", "dense",
                  tput_dense["tokens_per_s"], f"{tput_dense['wall_s']}s"))
    print(fmt_row("decode_tput_staggered8", "paged",
                  tput_paged["tokens_per_s"],
                  f"{tput_paged['wall_s']}s; {speedup:.2f}x"))

    out = {
        "arch": ARCH, "max_len": MAX_LEN, "block_size": BLOCK,
        "prompt_tokens": PROMPT_TOKENS,
        "capacity": {
            "budget_bytes": int(budget),
            "dense": dense_cap,
            "paged": paged_cap["resident_seqs"],
            "paged_shared_prefix": shared_cap["resident_seqs"],
            "ratio": round(ratio, 2),
            "ratio_shared_prefix": round(ratio_shared, 2),
        },
        "decode_tput": {
            "dense_tokens_per_s": tput_dense["tokens_per_s"],
            "paged_tokens_per_s": tput_paged["tokens_per_s"],
            "ratio": round(speedup, 3),
        },
    }
    path = Path(__file__).resolve().parent / "BENCH_paged_kv.json"
    path.write_text(json.dumps(out, indent=2) + "\n")
    print(f"# wrote {path}")


if __name__ == "__main__":
    run()
