"""Fault-tolerant serving under replica failure (PR 8), emitting
BENCH_fault_tolerance.json.

A 4-replica sim fleet (per-replica accelerators, paged KV) serves a
batch of RAG queries under three scenarios:

  healthy       FT layer ON, no faults — the gating cost of health
                tracking, deadline stamping and recovery bookkeeping on
                the hot path (compare ft_off).
  replica_kill  one replica crashes at its 2nd decode pass. In-flight
                sequences are re-queued onto healthy replicas and
                replayed token-identically (prompt + emitted tokens
                teacher-forced); the dead replica's paged blocks are
                reclaimed with a refcount audit.
  replica_hang  one replica stops making progress; the heartbeat
                watchdog declares it dead and the same recovery path
                drains it.

The sim carries the fleet-scale numbers (goodput/latency degradation
under failure, recovery event counts, block-leak audit); its generated
text embeds the process-global query id, so cross-run output comparison
is meaningless there. A second REAL-engine study (4-replica pool, one
replica killed mid-decode) proves token identity against a no-fault
baseline — the greedy decode depends only on the prompt tokens — and
prices the recovery detour. Acceptance: every sim query completes under
both fault scenarios with zero leaked blocks, and the real kill run is
token-identical to its baseline.
"""
from __future__ import annotations

import json
import time
from pathlib import Path

import numpy as np

from benchmarks.common import fmt_row, make_queries
from repro.core.apps import naive_rag
from repro.core.teola import Teola
from repro.engines.sim_engines import build_sim_engines
from repro.serving.faults import (FaultInjector, FaultSpec, FTConfig,
                                  RequestError)

N_QUERIES = 12
N_REPLICAS = 4
# sim passes are ms-scale: fast-converging recovery knobs (same rationale
# as tests/test_faults.py::_FT)
FT = dict(max_retries=3, backoff=0.02, suspect_after=0.5, dead_after=1.0,
          watchdog_period=0.05)
# real-engine knobs: heartbeat thresholds above the worst-case single
# pass (first pass JIT-compiles) so a busy replica isn't misread as hung
FT_REAL = dict(max_retries=3, backoff=0.05, suspect_after=20.0,
               dead_after=45.0, watchdog_period=0.2)

SCENARIOS = {
    "ft_off": (None, None),
    "healthy": (None, FT),
    "replica_kill": ([FaultSpec("crash", "core_llm", "decode", at=2)], FT),
    "replica_hang": ([FaultSpec("hang", "core_llm", "decode", at=2,
                                duration=30.0)], FT),
}


def _run_scenario(name):
    specs, ft = SCENARIOS[name]
    engines = build_sim_engines(llm_instances=N_REPLICAS, paged_kv=True)
    inj = FaultInjector(specs) if specs else None
    if inj is not None:
        inj.arm(engines)
    orch = Teola(naive_rag(engines), engines, continuous_batching=True,
                 fault_tolerance=FTConfig(**ft) if ft else None)
    queries = make_queries(N_QUERIES, seed=8)
    outs, errors = [], 0
    t0 = time.time()
    try:
        ctxs = [orch.submit(dict(q)) for q in queries]
        lats = []
        for c in ctxs:
            assert c.done.wait(300), f"{name}: query {c.qid} hung"
            if c.error is not None:
                assert isinstance(c.error, RequestError), \
                    f"{name}: unstructured failure {c.error!r}"
                errors += 1
                outs.append(None)
            else:
                lats.append(c.latency)
                outs.append(c.store.get(c.output_key))
        wall = time.time() - t0
        mgr = orch.runtime.scheds["core_llm"].ftmgr
        events = [e[0] for e in mgr.events] if mgr else []
        leaked = 0
        pool = engines["core_llm"]
        for i in range(len(pool)):
            alloc = getattr(pool[i], "alloc", None)
            if alloc is not None and pool.health(i) != "dead":
                leaked += alloc.audit()["bad_free"]
        if mgr:
            for rep in mgr.reclaim_reports:
                if not rep.get("written_off"):
                    leaked += rep.get("leaked", 0)
        row = {
            "completed": N_QUERIES - errors,
            "failed_structured": errors,
            "lat_p50_s": round(float(np.percentile(lats, 50)), 3),
            "lat_p99_s": round(float(np.percentile(lats, 99)), 3),
            "wall_s": round(wall, 3),
            "goodput_qps": round((N_QUERIES - errors) / wall, 2),
            "faults_fired": len(inj.log) if inj else 0,
            "replicas_dead": events.count("replica_dead"),
            "retries": events.count("retry"),
            "blocks_leaked": leaked,
        }
        return row, outs
    finally:
        orch.shutdown()


# ---------------------------------------------------------------------------
# real-engine study: token identity through a replica kill + recovery cost

def _run_real(specs, ft):
    from repro.core.apps import build_engines
    from repro.core.engine_pool import build_pools
    engines = build_pools(build_engines(paged_kv=True), {"core_llm": 4})
    inj = FaultInjector(specs) if specs else None
    if inj is not None:
        inj.arm(engines)
    orch = Teola(naive_rag(engines), engines, continuous_batching=True,
                 fault_tolerance=FTConfig(**ft) if ft else None)
    q = {"question": "what is fact 3 about optics",
         "docs": make_queries(1, seed=8)[0]["docs"]}
    try:
        t0 = time.time()
        out, ctx = orch.query(q, timeout=600)
        wall = time.time() - t0
        assert ctx.error is None, ctx.error
        mgr = orch.runtime.scheds["core_llm"].ftmgr
        leaked = 0
        if mgr:
            for rep in mgr.reclaim_reports:
                if not rep.get("written_off"):
                    leaked += rep.get("leaked", 0)
        return out, {"wall_s": round(wall, 2),
                     "faults_fired": len(inj.log) if inj else 0,
                     "retries": sum(1 for e in (mgr.events if mgr else [])
                                    if e[0] == "retry"),
                     "blocks_leaked": leaked}
    finally:
        orch.shutdown()


def _run_real_study():
    base_out, base = _run_real(None, None)
    kill_out, kill = _run_real(
        [FaultSpec("crash", "core_llm", "decode", at=2)], FT_REAL)
    kill["token_identical"] = kill_out == base_out
    # the recovery detour's price: replay prefill + teacher-forced
    # catch-up on a healthy replica, on top of the crash detection
    kill["recovery_overhead_s"] = round(kill["wall_s"] - base["wall_s"], 2)
    return {"baseline": base, "replica_kill": kill}


def run(out_path: Path = None):
    results = {}
    print("scenario,completed,lat_p50_s,lat_p99_s,goodput_qps,"
          "replicas_dead,retries,blocks_leaked")
    sim = {}
    for name in SCENARIOS:
        row, _outs = _run_scenario(name)
        sim[name] = row
        print(fmt_row(name, row["completed"], row["lat_p50_s"],
                      row["lat_p99_s"], row["goodput_qps"],
                      row["replicas_dead"], row["retries"],
                      row["blocks_leaked"]))
    results["sim"] = sim

    real = _run_real_study()
    results["real"] = real
    print(f"real: baseline {real['baseline']['wall_s']}s, kill "
          f"{real['replica_kill']['wall_s']}s "
          f"(+{real['replica_kill']['recovery_overhead_s']}s recovery), "
          f"token_identical={real['replica_kill']['token_identical']}")

    kill, hang, healthy = (sim[k] for k in
                           ("replica_kill", "replica_hang", "healthy"))
    results["accept"] = {
        "kill_completes_all": kill["completed"] == N_QUERIES,
        "hang_completes_all": hang["completed"] == N_QUERIES,
        "real_kill_token_identical":
            real["replica_kill"]["token_identical"],
        "zero_blocks_leaked":
            all(r["blocks_leaked"] == 0 for r in
                (healthy, kill, hang, real["replica_kill"])),
        # gating: the FT layer's no-fault overhead stays small
        "ft_overhead_pct": round(
            100.0 * (healthy["wall_s"] / sim["ft_off"]["wall_s"] - 1),
            1),
    }
    results["setup"] = {"n_queries": N_QUERIES, "replicas": N_REPLICAS,
                        "ft": FT, "ft_real": FT_REAL}
    print(f"accept={results['accept']}")
    out_path = out_path or Path(__file__).parent / \
        "BENCH_fault_tolerance.json"
    out_path.write_text(json.dumps(results, indent=2))
    print(f"wrote {out_path}")
    return results


if __name__ == "__main__":
    run()
