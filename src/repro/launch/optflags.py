"""Beyond-paper optimization flags for the perf hillclimb (§Perf).

The paper-faithful baseline runs with NO flags. Each flag is one
hypothesis-driven change, recorded before/after in EXPERIMENTS.md:

  resident_weights  — serving/small-model layout: drop FSDP ('data')
                      sharding of weights so they stay resident per
                      device instead of being re-all-gathered every
                      decode step / microbatch (kills the dominant
                      collective term for serve and small-model train).
  ep_all_axes       — MoE expert parallelism over ('model','data')
                      jointly (DeepSeek-style EP-256): experts fully
                      resident at 1/device, all_to_all spans both axes;
                      required to fit 671B serving with resident weights.
  microbatches=N    — override the train gradient-accumulation depth
                      (fewer microbatch loop trips => fewer FSDP
                      gathers, more activation memory).
  pallas_paged_attn — route paged GQA attention (decode S=1 and
                      speculative verification S=k+1) through the Pallas
                      verify_attention kernel (block-table index maps)
                      instead of the XLA gather path. Read at TRACE time:
                      set before building an engine's jitted steps.
  pallas_chunk_prefill — route paged GQA PREFILL chunks (S>1) through
                      the Pallas chunk_prefill_attention kernel: the
                      chunk's queries stream the sequence's paged prefix
                      blocks via scalar-prefetched block-table index
                      maps with a causal intra-chunk mask, instead of
                      materializing the XLA gathered KV view. Read at
                      TRACE time, like pallas_paged_attn.
"""
from __future__ import annotations

ACTIVE: set = set()


def set_flags(flags):
    ACTIVE.clear()
    ACTIVE.update(f for f in flags if f)


def has(flag: str) -> bool:
    return flag in ACTIVE


def get_int(prefix: str, default: int) -> int:
    for f in ACTIVE:
        if f.startswith(prefix + "="):
            return int(f.split("=", 1)[1])
    return default
