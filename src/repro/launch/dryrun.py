import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run: AOT-lower + compile every (architecture × input
shape) on the production mesh, prove it fits, and extract roofline terms.

MUST be run as its own process (the XLA_FLAGS line above precedes any jax
import and locks the device count to 512 placeholder host devices).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch tinyllama-1.1b --shape prefill_32k
  PYTHONPATH=src python -m repro.launch.dryrun --all            # single-pod sweep
  PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod
Results land in experiments/dryrun/<arch>__<shape>__<mesh>.json.
"""
import argparse
import json
import re
import sys
import time
import traceback

import jax

from repro.configs.base import INPUT_SHAPES, get_config, list_configs
from repro.launch.mesh import (HBM_BW, ICI_BW, PEAK_FLOPS_BF16,
                               make_production_mesh)
from repro.launch.roofline_model import (analytic_bytes, analytic_flops,
                                         collective_bytes_corrected,
                                         collective_bytes_nested,
                                         loop_multiplier, trips_for_case)
from repro.launch.steps import build_case, case_supported
from repro.models.sharding import mesh_context

_DTYPE_BYTES = {"f64": 8, "s64": 8, "u64": 8, "c64": 8, "f32": 4, "s32": 4,
                "u32": 4, "bf16": 2, "f16": 2, "s16": 2, "u16": 2, "s8": 1,
                "u8": 1, "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}

_COLL_RE = re.compile(
    r"=\s*(.{0,400}?)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_SHAPE_RE = re.compile(r"(\w+?)\[([\d,]*)\]")


def _type_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str):
    per_type = {}
    for line in hlo_text.splitlines():
        if "-done" in line:
            continue
        m = _COLL_RE.search(line)
        if not m:
            continue
        b = _type_bytes(m.group(1))
        per_type[m.group(2)] = per_type.get(m.group(2), 0) + b
    return per_type, sum(per_type.values())


def model_flops(cfg, ishape) -> float:
    n_active = cfg.active_param_count()
    tokens = ishape.global_batch * (ishape.seq_len if ishape.mode != "decode"
                                    else 1)
    mult = 6.0 if ishape.mode == "train" else 2.0
    return mult * n_active * tokens


def run_case(arch: str, shape_name: str, multi_pod: bool, out_dir: str,
             q_block: int = 512, opt: str = ""):
    from repro.launch import optflags  # noqa: F811 (module-level import ok)
    optflags.set_flags(opt.split(",") if opt else [])
    cfg = get_config(arch)
    ishape = INPUT_SHAPES[shape_name]
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    tag = f"{arch}__{shape_name}__{mesh_name}"
    if opt:
        tag += "__opt_" + opt.replace(",", "+").replace("=", "")
    ok, why = case_supported(cfg, ishape)
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "status": "skipped", "skip_reason": why}
    if not ok:
        print(f"[dryrun] {tag}: SKIP ({why})")
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.size
    t0 = time.time()
    try:
        with mesh_context(mesh):
            step, args, meta = build_case(cfg, ishape, mesh, q_block=q_block)
            donate = meta.get("donate", ()) if optflags.has("donate") else ()
            lowered = jax.jit(step, donate_argnums=donate).lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
    except Exception as e:  # noqa: BLE001 - record the failure
        rec.update(status="FAILED", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-4000:])
        print(f"[dryrun] {tag}: FAILED {type(e).__name__}: {e}")
        return rec

    # jax < 0.5 returns a one-element list of per-program dicts; newer
    # versions return the dict directly.
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, list):
        cost = cost[0] if cost else {}
    mem = compiled.memory_analysis()
    mem_rec = {}
    if mem is not None:
        for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                     "temp_size_in_bytes", "generated_code_size_in_bytes",
                     "alias_size_in_bytes"):
            v = getattr(mem, attr, None)
            if v is not None:
                mem_rec[attr] = int(v)

    # RAW HLO numbers (XLA counts while-loop bodies ONCE — see
    # roofline_model.py; kept for transparency)
    per_type_raw, coll_raw = collective_bytes(compiled.as_text())
    flops_raw = float(cost.get("flops", 0.0))
    bytes_raw = float(cost.get("bytes accessed", 0.0))

    # ANALYTIC compute/memory terms + nested-loop-corrected collectives
    hlo = compiled.as_text()
    mult = loop_multiplier(cfg, ishape, meta.get("microbatches", 1))
    trips = trips_for_case(cfg, ishape, meta.get("microbatches", 1),
                           q_block)
    per_type, coll_total = collective_bytes_nested(hlo, trips)
    _, coll_flat = collective_bytes_corrected(hlo, mult)
    flops_dev = analytic_flops(cfg, ishape) / n_dev
    bytes_dev = analytic_bytes(cfg, ishape, n_dev)
    mf = model_flops(cfg, ishape)
    terms = {
        "compute_s": flops_dev / PEAK_FLOPS_BF16,
        "memory_s": bytes_dev / HBM_BW,
        "collective_s": coll_total / ICI_BW,
    }
    dominant = max(terms, key=terms.get)
    rec.update(
        status="ok", devices=n_dev, meta=meta,
        lower_s=round(t_lower, 1), compile_s=round(t_compile, 1),
        per_device={"analytic_flops": flops_dev, "analytic_bytes": bytes_dev,
                    "collective_bytes": coll_total,
                    "collectives_by_type": per_type,
                    "loop_trips": trips,
                    "collective_bytes_flat_estimate": coll_flat,
                    "hlo_flops_raw": flops_raw,
                    "hlo_bytes_raw": bytes_raw,
                    "collective_bytes_raw": coll_raw},
        memory_analysis=mem_rec,
        model_flops_global=mf,
        model_flops_per_device=mf / n_dev,
        useful_flops_ratio=(mf / n_dev) / flops_dev if flops_dev else None,
        roofline_terms_s=terms,
        dominant_term=dominant,
    )
    arg_gb = mem_rec.get("argument_size_in_bytes", 0) / 2 ** 30
    tmp_gb = mem_rec.get("temp_size_in_bytes", 0) / 2 ** 30
    print(f"[dryrun] {tag}: OK compile={t_compile:.0f}s "
          f"flops/dev={flops_dev:.3g} bytes/dev={bytes_dev:.3g} "
          f"coll/dev={coll_total:.3g} args={arg_gb:.2f}GiB "
          f"temp={tmp_gb:.2f}GiB dominant={dominant}")
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        with open(os.path.join(out_dir, tag + ".json"), "w") as f:
            json.dump(rec, f, indent=1)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--q-block", type=int, default=512)
    ap.add_argument("--opt", default="",
                    help="comma-separated optflags (see launch/optflags.py)")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    archs = ([args.arch] if args.arch else
             [a for a in list_configs() if not a.startswith("tiny-")])
    shapes = [args.shape] if args.shape else list(INPUT_SHAPES)
    results = []
    for a in archs:
        for s in shapes:
            results.append(run_case(a, s, args.multi_pod, args.out,
                                    args.q_block, args.opt))
    bad = [r for r in results if r["status"] == "FAILED"]
    print(f"[dryrun] done: {len(results)} cases, "
          f"{sum(r['status'] == 'ok' for r in results)} ok, "
          f"{sum(r['status'] == 'skipped' for r in results)} skipped, "
          f"{len(bad)} failed")
    sys.exit(1 if bad else 0)


if __name__ == "__main__":
    main()
