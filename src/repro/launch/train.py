"""Training launcher.

Local (this container): reduced variant of any assigned arch on CPU:
  PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \
      --steps 50 --reduced

Production: builds the pjit train step on the 16x16 / 2x16x16 mesh — on a
real pod this executes; here use launch.dryrun for the AOT compile proof.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.base import get_config
from repro.models.transformer import init_params
from repro.training.checkpoint import save_checkpoint
from repro.training.data import SyntheticLM
from repro.training.optimizer import AdamWConfig, init_opt_state
from repro.training.train_step import make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--reduced", action="store_true",
                    help="train the smoke-scale variant on CPU")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--microbatches", type=int, default=2)
    ap.add_argument("--ckpt", default=None)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if cfg.embed_stub and not args.reduced:
        raise SystemExit("stub-frontend archs train via embeds; use "
                         "--reduced for the local driver")

    params = init_params(cfg, jax.random.key(0))
    n = sum(p.size for p in jax.tree.leaves(params))
    print(f"[train] {cfg.name}: {n / 1e6:.1f}M params, "
          f"{jax.device_count()} device(s)")
    oc = AdamWConfig(lr=1e-3, warmup_steps=10, total_steps=args.steps)
    opt = init_opt_state(oc, params)
    stub = cfg.embed_stub is not None
    step_fn = jax.jit(make_train_step(
        cfg, oc, num_microbatches=args.microbatches,
        compute_dtype=jnp.float32, q_block=64, stub=stub))
    data = SyntheticLM(cfg.vocab_size, batch=args.batch, seq_len=args.seq)
    t0 = time.time()
    for i, batch in enumerate(data):
        if i >= args.steps:
            break
        toks = jnp.asarray(batch["tokens"])
        if stub:
            emb = jax.nn.one_hot(toks[:, :-1] % cfg.d_model, cfg.d_model)
            b = {"embeds": emb.astype(jnp.float32),
                 "targets": toks[:, 1:]}
        else:
            b = {"tokens": toks}
        params, opt, m = step_fn(params, opt, b)
        if i % 10 == 0 or i == args.steps - 1:
            print(f"[train] step {i:4d} ce={float(m['ce']):.4f} "
                  f"({time.time() - t0:.1f}s)")
    data.close()
    if args.ckpt:
        save_checkpoint(args.ckpt, params, step=args.steps)
        print(f"[train] checkpoint saved to {args.ckpt}")


if __name__ == "__main__":
    main()
