"""Production mesh construction.

Single pod:  (16, 16)      axes ('data', 'model')   — 256 chips (v5e pod)
Multi pod:   (2, 16, 16)   axes ('pod', 'data', 'model') — 512 chips

A FUNCTION, not a module constant, so importing never touches jax device
state (smoke tests must keep seeing 1 device).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many (possibly forced-host) devices exist —
    used by distributed correctness tests."""
    return jax.make_mesh((data, model), ("data", "model"))


# TPU v5e hardware constants (roofline denominators)
PEAK_FLOPS_BF16 = 197e12        # per chip
HBM_BW = 819e9                  # bytes/s per chip
ICI_BW = 50e9                   # bytes/s per link (~per chip, one direction)
HBM_BYTES = 16 * 2 ** 30        # 16 GiB per chip
