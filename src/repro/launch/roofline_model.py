"""Analytic roofline cost model + loop-aware HLO collective correction.

Why analytic: XLA's HloCostAnalysis counts each while-loop body ONCE, and
our production steps nest lax.scan (stages × microbatches × attention
q-blocks), so raw `compiled.cost_analysis()` undercounts FLOPs/bytes by
the loop trip products (~100-1000x). We therefore:

  - compute the compute & memory terms ANALYTICALLY from the config and
    input shape, mirroring what the implemented program actually does
    (e.g. full S^2 masked attention — not the causal half — until the
    block-skipping optimization lands; absorbed-MLA score FLOPs at the
    kv_lora rank),
  - correct HLO-parsed collective bytes per computation: collectives in
    the ENTRY computation count once; collectives inside loop-body
    computations are multiplied by the known trip product (layers x
    microbatches for train, layers for serve),
  - keep the raw HLO numbers in the record for transparency.
"""
from __future__ import annotations

import re

from repro.configs.base import InputShape, ModelConfig


def _attn_flops_per_layer(cfg: ModelConfig, spec, B, Sq, Skv):
    """Score+context matmul FLOPs for ONE layer (fwd), as implemented:
    full Skv attended (masked), no causal block skipping."""
    hd = cfg.resolved_head_dim
    H = cfg.num_heads
    if cfg.attention_kind == "mla":
        m = cfg.mla
        r, p = m.kv_lora_rank, m.qk_rope_head_dim
        # absorbed: q_eff einsum + scores(r) + rope scores(p) + ctx(r) +
        # out einsum
        return 2 * B * Sq * H * (m.qk_nope_head_dim * r        # q_eff
                                 + Skv * (r + p)               # scores
                                 + Skv * r                     # ctx
                                 + r * m.v_head_dim)           # out_h
    if spec.kind == "rwkv":
        s = cfg.ssm
        heads = cfg.d_model // s.head_dim
        # per-step state update + readout: ~4 * hd^2 per head per token
        return 4 * B * Sq * heads * s.head_dim * s.head_dim * 2
    from repro.launch import optflags
    win = spec.window
    eff_kv = min(Skv, win) if win else Skv
    if optflags.has("causal_skip") and Sq == Skv and not win:
        eff_kv = Skv * 0.5 + 256            # lower-triangular blocks only
    fl = 2 * B * H * Sq * eff_kv * hd * 2       # QK^T and PV
    if spec.kind == "hybrid":
        s = cfg.ssm
        fl += 6 * B * Sq * cfg.d_model * s.state_dim  # selective scan
    return fl


def linear_flops(cfg: ModelConfig, tokens: int) -> float:
    """Matmul-parameter FLOPs (2*N_active_linear per token), excluding the
    embedding gather but including the LM head."""
    n = cfg.active_param_count()
    n -= cfg.vocab_size * cfg.d_model       # embedding lookup isn't matmul
    if cfg.tie_embeddings:
        n += cfg.vocab_size * cfg.d_model   # tied head still multiplies
    return 2.0 * n * tokens


def analytic_flops(cfg: ModelConfig, ishape: InputShape) -> float:
    """Global forward(+backward) FLOPs for one step, as implemented."""
    B, S = ishape.global_batch, ishape.seq_len
    if ishape.mode == "decode":
        Sq, Skv, tokens = 1, S, B
    else:
        Sq, Skv, tokens = S, S, B * S
    total = linear_flops(cfg, tokens)
    for st in cfg.stages:
        for spec in st.pattern:
            total += st.repeat * _attn_flops_per_layer(cfg, spec, B, Sq,
                                                       Skv)
    if ishape.mode == "train":
        total *= 3.0                        # fwd + bwd
    return total


def param_bytes(cfg: ModelConfig, dtype_bytes: int) -> float:
    return cfg.param_count() * dtype_bytes


def analytic_bytes(cfg: ModelConfig, ishape: InputShape,
                   n_devices: int) -> float:
    """Per-DEVICE HBM traffic estimate for one step.
    serve: sharded weights read once + KV cache read/write + activations.
    train: fp32 master + bf16 compute copies + grads + 2x moments r/w,
    weights re-read in backward, activations saved+reread (remat'd layer
    inputs only)."""
    from repro.serving.kv_cache import cache_bytes
    B, S = ishape.global_batch, ishape.seq_len
    d = cfg.d_model
    L = cfg.num_layers
    act_elem = 2                                     # bf16 activations
    if ishape.mode == "decode":
        w = param_bytes(cfg, 2) / n_devices          # bf16 weights
        kv = cache_bytes(cfg, B, S) / n_devices      # read full cache
        act = B * d * L * 12 * act_elem / n_devices
        return w + kv + act
    if ishape.mode == "prefill":
        w = param_bytes(cfg, 2) / n_devices
        kv = cache_bytes(cfg, B, S) / n_devices      # write cache
        act = B * S * d * L * 12 * act_elem / n_devices
        return w + kv + act
    # train
    wmaster = param_bytes(cfg, 4) / n_devices
    wbf16 = param_bytes(cfg, 2) / n_devices
    moments = 2 * param_bytes(cfg, 2) / n_devices    # bf16 m, v r+w -> x2
    grads = param_bytes(cfg, 4) / n_devices
    act = B * S * d * L * (12 + 12) * act_elem / n_devices  # fwd + remat
    return 2 * wmaster + 2 * wbf16 + 2 * moments + 2 * grads + act


# ---------------------------------------------------------------------------
# Loop-aware collective correction

_DTYPE_BYTES = {"f64": 8, "s64": 8, "u64": 8, "c64": 8, "f32": 4, "s32": 4,
                "u32": 4, "bf16": 2, "f16": 2, "s16": 2, "u16": 2, "s8": 1,
                "u8": 1, "pred": 1}
_COLL_RE = re.compile(
    r"=\s*(.{0,400}?)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_SHAPE_RE = re.compile(r"(\w+?)\[([\d,]*)\]")


def _type_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for x in dims.split(","):
            if x:
                n *= int(x)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes_corrected(hlo_text: str, loop_mult: float):
    """Legacy flat correction: entry-computation collectives x1, any
    loop-body collective x loop_mult. Superseded by
    collective_bytes_nested (kept for comparison in the perf log)."""
    per_type: dict = {}
    in_entry = False
    for line in hlo_text.splitlines():
        if line.startswith("ENTRY"):
            in_entry = True
        elif line and not line[0].isspace() and "{" in line:
            in_entry = False
        if "-done" in line:
            continue
        m = _COLL_RE.search(line)
        if not m:
            continue
        b = _type_bytes(m.group(1)) * (1.0 if in_entry else loop_mult)
        per_type[m.group(2)] = per_type.get(m.group(2), 0.0) + b
    return per_type, sum(per_type.values())


_COMP_START_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*(?:\(.*)?\{")
_WHILE_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_CALL_RE = re.compile(
    r"(?:calls|to_apply|condition|branch_computations)=%?\{?([\w.\-, %]+)")


def collective_bytes_nested(hlo_text: str, trips_by_depth):
    """Nested-loop-aware collective accounting.

    Builds the computation call graph from while-op ``body=`` references;
    a collective inside a while body nested at depth d is multiplied by
    prod(trips_by_depth[:d]) (e.g. train: [microbatches, layers,
    inner-blocks]). Non-while calls (fusions, conditionals, scatter
    to_apply) inherit their caller's multiplier."""
    comp_colls: dict = {}          # comp -> {type: bytes}
    while_children: dict = {}      # comp -> set of while-body comps
    call_children: dict = {}       # comp -> set of plain-called comps
    entry = None
    cur = None
    for line in hlo_text.splitlines():
        if line and not line[0].isspace():
            m = _COMP_START_RE.match(line)
            if m:
                cur = m.group(1)
                if line.startswith("ENTRY"):
                    entry = cur
                comp_colls.setdefault(cur, {})
                while_children.setdefault(cur, set())
                call_children.setdefault(cur, set())
            continue
        if cur is None:
            continue
        for wb in _WHILE_BODY_RE.findall(line):
            while_children[cur].add(wb)
        for grp in _CALL_RE.findall(line):
            for name in grp.replace("%", "").replace("{", "").split(","):
                name = name.strip()
                if name:
                    call_children[cur].add(name)
        if "-done" in line:
            continue
        m = _COLL_RE.search(line)
        if m:
            d = comp_colls[cur]
            d[m.group(2)] = d.get(m.group(2), 0) + _type_bytes(m.group(1))

    # propagate multipliers from entry
    mult: dict = {}

    def visit(comp, m, depth):
        if comp not in comp_colls:
            return
        if comp in mult and mult[comp] >= m:
            return
        mult[comp] = max(mult.get(comp, 0.0), m)
        for c in call_children.get(comp, ()):  # same multiplier
            visit(c, m, depth)
        trip = trips_by_depth[min(depth, len(trips_by_depth) - 1)] \
            if trips_by_depth else 1.0
        for w in while_children.get(comp, ()):
            visit(w, m * trip, depth + 1)

    if entry is not None:
        visit(entry, 1.0, 0)
    per_type: dict = {}
    for comp, colls in comp_colls.items():
        f = mult.get(comp, 0.0)    # unreachable comps contribute nothing
        for t, b in colls.items():
            per_type[t] = per_type.get(t, 0.0) + b * f
    return per_type, sum(per_type.values())


def trips_for_case(cfg: ModelConfig, ishape: InputShape, microbatches: int,
                   q_block: int = 512):
    """trips_by_depth for collective_bytes_nested. Depth 1 is the
    outermost loop body: the microbatch scan for train, the layer scan
    for serve. Inner-most covers attention q-blocks / SSM chunk scans."""
    # layer-scan trip count = the stage repeat (a multi-element pattern
    # runs len(pattern) layers per iteration); dominant stage's repeat is
    # the best single estimate when stages differ.
    L = max(st.repeat for st in cfg.stages)
    S = ishape.seq_len if ishape.mode != "decode" else 1
    inner = max(1, S // q_block)
    if cfg.family in ("ssm", "hybrid"):
        inner = max(inner, S // 128)
    if ishape.mode == "train":
        return [float(max(1, microbatches)), float(L), float(inner),
                float(inner)]
    return [float(L), float(inner), float(inner)]


def loop_multiplier(cfg: ModelConfig, ishape: InputShape,
                    microbatches: int) -> float:
    """Trip product of the loops that contain the per-layer collectives:
    the layer scan (avg stage repeat) x the microbatch scan (train)."""
    L = cfg.num_layers
    if ishape.mode == "train":
        return float(L * max(1, microbatches))
    return float(L)
