"""Serving launcher: stand up an app (all four of the paper's workflows)
on the engine pool and serve queries.

  PYTHONPATH=src python -m repro.launch.serve --app advanced_rag \
      --queries 4 [--sim] [--scheme Teola|LlamaDist-TO|...] \
      [--llm-instances 2] [--streaming]

--llm-instances N puts each LLM engine behind an EnginePool of N replicas
(shared weights, per-replica KV stores; fused batches are routed to the
least-loaded replica). --streaming enables decode->downstream chunk
pipelining; --continuous-batching dispatches decodes into each replica's
persistent decode loop (iteration-level continuous batching) instead of
run-to-completion batches (both Teola scheme only). --paged-kv carves
each replica's KV cache into refcounted token blocks (copy-on-write
instruction-prefix sharing, block-table indexed decode, occupancy and
router backpressure counted in allocated blocks). --speculative enables
draft-verify speculative decoding on core_llm (--draft-k tokens drafted
per target verification step; --spec-drafter picks the model-free
prompt-lookup drafter or the co-located lite_llm replica pairing);
greedy outputs stay token-identical to plain decode. --chunked-prefill
streams prompts through each replica's continuous loop as bounded
chunks mixed with decode iterations (--prefill-chunk tokens per chunk
under a per-iteration --token-budget), so a long prompt never
head-of-line-blocks co-resident decodes; chunked prefill is
token-identical to monolithic prefill by construction.
--prefix-cache radix enables the global radix-tree prefix cache on each
LLM replica (requires --paged-kv): ANY prompt sharing a cached
block-aligned token prefix — across queries and tenants, not just
warmed instructions — forks the cached blocks and prefills only the
uncached tail, with LRU leaf eviction under memory pressure and
prefix-aware pool routing; outputs stay token-identical to the cache
being off. --disaggregate (requires --paged-kv and
--continuous-batching) splits each LLM into prefill-specialist and
decode-specialist replicas (--prefill-replicas/--decode-replicas,
default 1+1): prompts prefill at full token budget with no co-resident
decodes, then the scheduler's two-stage dispatch migrates each
sequence's paged KV blocks into a decode replica's pool
(export_seq/import_seq over the migrate_blocks primitive) and admits it
into that replica's continuous loop — prefill/decode interference is
removed entirely instead of time-sliced; outputs stay token-identical
to unified serving.
--slo-sched (requires --continuous-batching) arms SLO-aware
multi-tenant scheduling on every LLM replica: queries are stamped with
an SLO class (interactive vs batch, alternating here) and a tenant
identity; each replica's continuous loop then admits by
(class, priority, e-graph depth, arrival) rank with an --slo-aging
starvation bound, enforces weighted max-min fair shares of decode
slots and KV blocks per tenant, and under pressure preempts a batch
sequence via evict-to-recompute (paged KV freed, continuation replayed
token-identically on re-admission). Per-tenant/per-class stats print at
exit.
--fault-inject / --request-deadline / --max-retries enable the
fault-tolerance layer (requires --continuous-batching): a seeded
deterministic FaultInjector crashes/hangs/slows replicas at exact call
indices, the pool tracks replica health (suspect/dead) via a watchdog,
dead replicas' KV blocks are reclaimed, and in-flight sequences are
replayed onto healthy replicas via evict-to-recompute — greedy decode
makes the recovered output token-identical. Requests past the deadline
fail with a structured error instead of hanging.
--overload-control (requires --scheme Teola and --continuous-batching)
arms the overload-control/graceful-degradation layer: per-query
deadlines (--query-deadline) decomposed along the e-graph into
per-primitive budgets, front-door load shedding against the estimated
pool queue delay (--shed-queue-tokens; interactive queries keep a
protected share), hedged dispatch of idempotent encoder/search
primitives onto a second healthy replica (--hedge-after; needs pooled
encoders, --encoder-instances 2 with --sim), and a brown-out
degradation ladder (--degrade) that activates per-node degrade
annotations — shrink top_k, skip rerank, halve max_new, cap prefill
chunks — stepwise with hysteresis. Shed queries fail fast with a
structured Overloaded error; all knobs off is byte-identical to the
layer absent.
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.core.apps import ALL_APPS, build_engines
from repro.core.engine_pool import build_pools, disaggregate_pools
from repro.core.teola import AutoGenLike, LlamaDist, LlamaDistPC, Teola
from repro.training.data import doc_corpus

SCHEMES = {
    "Teola": (Teola, "topo"),
    "LlamaDist-PO": (LlamaDist, "po"),
    "LlamaDist-TO": (LlamaDist, "to"),
    "LlamaDistPC-TO": (LlamaDistPC, "to"),
    "AutoGen-TO": (AutoGenLike, "to"),
}


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser()
    ap.add_argument("--app", default="advanced_rag", choices=ALL_APPS)
    ap.add_argument("--scheme", default="Teola", choices=SCHEMES)
    ap.add_argument("--queries", type=int, default=4)
    ap.add_argument("--rate", type=float, default=2.0)
    ap.add_argument("--sim", action="store_true",
                    help="paper-calibrated latency-profile engines")
    ap.add_argument("--llm-instances", type=int, default=1,
                    help="EnginePool replicas per LLM engine")
    ap.add_argument("--streaming", action="store_true",
                    help="stream decode chunks to downstream primitives")
    ap.add_argument("--continuous-batching", action="store_true",
                    help="iteration-level decode batching (persistent "
                         "decode loop with per-iteration admission)")
    ap.add_argument("--paged-kv", action="store_true",
                    help="block-paged KV cache: COW prefix sharing, "
                         "block-table decode, block-based occupancy "
                         "routing with pool backpressure")
    ap.add_argument("--chunked-prefill", action="store_true",
                    help="stall-free chunked prefill: prompts advance in "
                         "bounded chunks between decode iterations under "
                         "a per-pass token budget (requires "
                         "--continuous-batching)")
    ap.add_argument("--prefill-chunk", type=int, default=None,
                    help="tokens per prefill chunk (default 128; requires "
                         "--chunked-prefill)")
    ap.add_argument("--token-budget", type=int, default=None,
                    help="per-iteration token budget shared by decode and "
                         "prefill tokens (default: decode slots + one "
                         "chunk; requires --chunked-prefill)")
    ap.add_argument("--prefix-cache", choices=("none", "radix"),
                    default="none",
                    help="global radix-tree prefix cache: any shared "
                         "block-aligned prompt prefix reuses cached KV "
                         "blocks across queries, with LRU leaf eviction "
                         "(requires --paged-kv)")
    ap.add_argument("--speculative", action="store_true",
                    help="draft-verify speculative decoding on core_llm "
                         "(token-identical greedy outputs, fewer target "
                         "steps per token)")
    ap.add_argument("--draft-k", type=int, default=None,
                    help="draft tokens per verification step (default 4; "
                         "requires --speculative)")
    ap.add_argument("--spec-drafter", choices=("ngram", "lite_llm"),
                    default=None,
                    help="drafter: model-free prompt lookup (default) or "
                         "the co-located lite_llm replica (requires "
                         "--speculative)")
    ap.add_argument("--disaggregate", action="store_true",
                    help="role-specialized LLM pools: prefill-specialist "
                         "replicas run prompts at full token budget, "
                         "completed sequences migrate their paged KV "
                         "blocks to decode-specialist replicas' loops "
                         "(requires --paged-kv and --continuous-batching)")
    ap.add_argument("--prefill-replicas", type=int, default=None,
                    help="prefill-specialist replicas per LLM pool "
                         "(default 1; requires --disaggregate)")
    ap.add_argument("--decode-replicas", type=int, default=None,
                    help="decode-specialist replicas per LLM pool "
                         "(default 1; requires --disaggregate)")
    ap.add_argument("--slo-sched", action="store_true",
                    help="SLO-aware multi-tenant scheduling: priority "
                         "admission by (class, priority, depth, arrival), "
                         "per-tenant fair-share decode slots / KV blocks, "
                         "paged preemption of batch work under pressure "
                         "(requires --continuous-batching)")
    ap.add_argument("--slo-aging", type=float, default=None,
                    metavar="SECONDS",
                    help="starvation bound: a batch-class item older than "
                         "this ranks as urgent (default 5.0; requires "
                         "--slo-sched)")
    ap.add_argument("--fault-inject", default=None, metavar="SPEC",
                    help="deterministic fault schedule, comma-separated "
                         "kind:engine:point:at[:duration[:width]] entries, "
                         "e.g. crash:core_llm.r1:decode:3 — kinds: crash, "
                         "hang, slow, burst, migrate_fail, alloc_fail; "
                         "implies fault tolerance (requires --continuous-"
                         "batching)")
    ap.add_argument("--request-deadline", type=float, default=None,
                    metavar="SECONDS",
                    help="per-request deadline: an in-flight request past "
                         "it fails with a structured DeadlineExceeded "
                         "instead of hanging (enables fault tolerance)")
    ap.add_argument("--max-retries", type=int, default=None,
                    help="recovery attempts per request before failing "
                         "loudly (default 2; enables fault tolerance)")
    ap.add_argument("--overload-control", action="store_true",
                    help="overload control + graceful degradation: "
                         "deadline propagation, admission control, hedged "
                         "dispatch, brown-out ladder (requires --scheme "
                         "Teola and --continuous-batching)")
    ap.add_argument("--query-deadline", type=float, default=None,
                    metavar="SECONDS",
                    help="per-query end-to-end deadline, decomposed into "
                         "per-primitive budgets along the e-graph "
                         "(requires --overload-control)")
    ap.add_argument("--shed-queue-tokens", type=float, default=None,
                    help="admission control: shed batch queries when the "
                         "estimated engine backlog exceeds this many "
                         "tokens; interactive queries get a 3x allowance "
                         "(requires --overload-control)")
    ap.add_argument("--hedge-after", type=float, default=None,
                    metavar="SECONDS",
                    help="hedged dispatch: send a backup for idempotent "
                         "encoder/search batches still unfinished after "
                         "this delay, first result wins (requires "
                         "--overload-control and a second replica)")
    ap.add_argument("--degrade", action="store_true",
                    help="brown-out degradation ladder: under deadline "
                         "pressure activate per-node degrade annotations "
                         "stepwise (requires --overload-control and "
                         "--query-deadline)")
    ap.add_argument("--encoder-instances", type=int, default=None,
                    help="EnginePool replicas for the embedding/rerank "
                         "encoders (sim engines only; default 1, use 2+ "
                         "to give hedged dispatch a backup target)")
    return ap


def validate_args(ap: argparse.ArgumentParser, args) -> None:
    """Reject incompatible flag combinations with a clear argparse error
    (exit code 2 + usage) instead of a deep runtime stack trace. Fills in
    speculative defaults after validation."""
    if args.prefill_chunk is not None and not args.chunked_prefill:
        ap.error("--prefill-chunk requires --chunked-prefill")
    if args.token_budget is not None and not args.chunked_prefill:
        ap.error("--token-budget requires --chunked-prefill")
    if args.chunked_prefill:
        if args.scheme != "Teola":
            ap.error("--chunked-prefill requires --scheme Teola (baseline "
                     "orchestrators drive monolithic prefill batches "
                     "outside the continuous loop)")
        if not args.continuous_batching:
            ap.error("--chunked-prefill requires --continuous-batching "
                     "(prefill chunks are packed into the persistent "
                     "decode loop's mixed iterations)")
        if args.prefill_chunk is not None and args.prefill_chunk < 1:
            ap.error(f"--prefill-chunk must be >= 1, got "
                     f"{args.prefill_chunk}")
        if args.token_budget is not None and args.token_budget < 1:
            ap.error(f"--token-budget must be >= 1, got "
                     f"{args.token_budget}")
    args.prefill_chunk = args.prefill_chunk if args.prefill_chunk \
        is not None else 128
    if args.prefix_cache == "radix" and not args.paged_kv:
        ap.error("--prefix-cache radix requires --paged-kv (cached "
                 "prefixes live in the refcounted block pool)")
    if args.draft_k is not None and not args.speculative:
        ap.error("--draft-k requires --speculative")
    if args.spec_drafter is not None and not args.speculative:
        ap.error("--spec-drafter requires --speculative")
    if args.speculative:
        if args.scheme != "Teola":
            ap.error("--speculative requires --scheme Teola (baseline "
                     "orchestrators drive run-to-completion decode "
                     "batches outside the speculative decode loop)")
        if not args.continuous_batching:
            ap.error("--speculative requires --continuous-batching (the "
                     "speculative path runs inside each replica's "
                     "persistent decode loop)")
        if args.draft_k is not None and args.draft_k < 1:
            ap.error(f"--draft-k must be >= 1, got {args.draft_k}")
        if args.sim and args.spec_drafter == "lite_llm":
            ap.error("--spec-drafter lite_llm needs real engines (the "
                     "sim models speculative cost with the lite profile "
                     "already; drop --sim or use --spec-drafter ngram)")
    args.draft_k = args.draft_k if args.draft_k is not None else 4
    args.spec_drafter = args.spec_drafter or "ngram"
    if args.prefill_replicas is not None and not args.disaggregate:
        ap.error("--prefill-replicas requires --disaggregate")
    if args.decode_replicas is not None and not args.disaggregate:
        ap.error("--decode-replicas requires --disaggregate")
    if args.disaggregate:
        if args.scheme != "Teola":
            ap.error("--disaggregate requires --scheme Teola (baseline "
                     "orchestrators bypass the pooled two-stage "
                     "dispatch)")
        if not args.paged_kv:
            ap.error("--disaggregate requires --paged-kv (the handoff "
                     "migrates refcounted KV blocks between replica "
                     "pools)")
        if not args.continuous_batching:
            ap.error("--disaggregate requires --continuous-batching "
                     "(completed prefills hand off into the decode "
                     "replicas' persistent loops)")
        if args.llm_instances > 1:
            ap.error("--disaggregate and --llm-instances > 1 are "
                     "mutually exclusive (replica counts come from "
                     "--prefill-replicas/--decode-replicas)")
        if args.prefill_replicas is not None and args.prefill_replicas < 1:
            ap.error(f"--prefill-replicas must be >= 1, got "
                     f"{args.prefill_replicas}")
        if args.decode_replicas is not None and args.decode_replicas < 1:
            ap.error(f"--decode-replicas must be >= 1, got "
                     f"{args.decode_replicas}")
    args.prefill_replicas = args.prefill_replicas \
        if args.prefill_replicas is not None else 1
    args.decode_replicas = args.decode_replicas \
        if args.decode_replicas is not None else 1
    if args.slo_aging is not None and not args.slo_sched:
        ap.error("--slo-aging requires --slo-sched")
    if args.slo_sched:
        if args.scheme != "Teola":
            ap.error("--slo-sched requires --scheme Teola (the SLO "
                     "policy lives in the continuous-loop admission "
                     "pass)")
        if not args.continuous_batching:
            ap.error("--slo-sched requires --continuous-batching "
                     "(priority admission and preemption run in the "
                     "persistent decode loops)")
        if args.slo_aging is not None and args.slo_aging < 0:
            ap.error(f"--slo-aging must be >= 0, got {args.slo_aging}")
    args.slo_aging = args.slo_aging if args.slo_aging is not None else 5.0
    ft_on = (args.fault_inject is not None
             or args.request_deadline is not None
             or args.max_retries is not None)
    if ft_on:
        if args.scheme != "Teola":
            ap.error("fault-tolerance flags require --scheme Teola "
                     "(recovery lives in the pooled two-tier scheduler)")
        if not args.continuous_batching:
            ap.error("fault-tolerance flags require --continuous-batching "
                     "(recovery replays sequences through the persistent "
                     "decode loops)")
        if args.request_deadline is not None and args.request_deadline <= 0:
            ap.error(f"--request-deadline must be > 0, got "
                     f"{args.request_deadline}")
        if args.max_retries is not None and args.max_retries < 0:
            ap.error(f"--max-retries must be >= 0, got {args.max_retries}")
        if args.fault_inject is not None:
            from repro.serving.faults import FaultInjector
            try:
                FaultInjector.parse(args.fault_inject)
            except ValueError as e:
                ap.error(f"--fault-inject: {e}")
    args.fault_tolerance_on = ft_on
    for flag, name in ((args.query_deadline, "--query-deadline"),
                       (args.shed_queue_tokens, "--shed-queue-tokens"),
                       (args.hedge_after, "--hedge-after")):
        if flag is not None and not args.overload_control:
            ap.error(f"{name} requires --overload-control")
    if args.degrade and not args.overload_control:
        ap.error("--degrade requires --overload-control")
    if args.overload_control:
        if args.scheme != "Teola":
            ap.error("--overload-control requires --scheme Teola (the "
                     "admission/degradation hooks live in the managed "
                     "runtime)")
        if not args.continuous_batching:
            ap.error("--overload-control requires --continuous-batching "
                     "(queue-delay estimation reads the pooled decode "
                     "loops' load signals)")
        if args.query_deadline is not None and args.query_deadline <= 0:
            ap.error(f"--query-deadline must be > 0, got "
                     f"{args.query_deadline}")
        if args.shed_queue_tokens is not None and args.shed_queue_tokens <= 0:
            ap.error(f"--shed-queue-tokens must be > 0, got "
                     f"{args.shed_queue_tokens}")
        if args.hedge_after is not None and args.hedge_after < 0:
            ap.error(f"--hedge-after must be >= 0, got {args.hedge_after}")
        if args.degrade and args.query_deadline is None:
            ap.error("--degrade requires --query-deadline (the brown-out "
                     "ladder steps on per-query deadline slack)")
    if args.encoder_instances is not None:
        if not args.sim:
            ap.error("--encoder-instances requires --sim (real encoder "
                     "pooling is not wired into this launcher)")
        if args.encoder_instances < 1:
            ap.error(f"--encoder-instances must be >= 1, got "
                     f"{args.encoder_instances}")
    args.encoder_instances = args.encoder_instances \
        if args.encoder_instances is not None else 1


def main():
    ap = build_parser()
    args = ap.parse_args()
    validate_args(ap, args)

    if args.sim:
        from repro.engines.sim_engines import build_sim_engines
        engines = build_sim_engines(llm_instances=args.llm_instances,
                                    paged_kv=args.paged_kv,
                                    speculative=args.speculative,
                                    draft_k=args.draft_k,
                                    chunked_prefill=args.chunked_prefill,
                                    prefill_chunk=args.prefill_chunk,
                                    token_budget=args.token_budget,
                                    prefix_cache=args.prefix_cache,
                                    disaggregate=args.disaggregate,
                                    prefill_replicas=args.prefill_replicas,
                                    decode_replicas=args.decode_replicas,
                                    encoder_instances=args.encoder_instances)
    else:
        engines = build_engines(paged_kv=args.paged_kv,
                                chunked_prefill=args.chunked_prefill,
                                prefill_chunk=args.prefill_chunk,
                                token_budget=args.token_budget,
                                prefix_cache=args.prefix_cache)
        if args.llm_instances > 1:
            engines = build_pools(engines, {
                "core_llm": args.llm_instances,
                "lite_llm": args.llm_instances})
        if args.disaggregate:
            engines = disaggregate_pools(
                engines, ("core_llm", "lite_llm"),
                args.prefill_replicas, args.decode_replicas)
        if args.speculative:
            from repro.engines.spec_decode import attach_speculative
            attach_speculative(
                engines,
                draft="lite_llm" if args.spec_drafter == "lite_llm"
                else None,
                k=args.draft_k)
    if args.slo_sched:
        from repro.serving.slo import attach_slo
        pols = attach_slo(engines, aging_s=args.slo_aging)
        print(f"[serve] SLO scheduling armed on {len(pols)} replicas "
              f"(aging {args.slo_aging:.1f}s)")
    ft = None
    injector = None
    if args.fault_tolerance_on:
        from repro.serving.faults import FaultInjector, FTConfig
        ft = FTConfig(
            max_retries=args.max_retries if args.max_retries is not None
            else 2,
            request_deadline=args.request_deadline)
        if args.fault_inject is not None:
            injector = FaultInjector.parse(args.fault_inject, seed=0)
            armed = injector.arm(engines,
                                 encoders=args.overload_control)
            print(f"[serve] fault injector armed on {armed}")
    overload = None
    if args.overload_control:
        from repro.serving.overload import OverloadConfig, OverloadManager
        ov_cfg = OverloadConfig(
            deadline_s=args.query_deadline,
            shed=args.shed_queue_tokens is not None,
            max_queue_tokens=args.shed_queue_tokens
            if args.shed_queue_tokens is not None else 4096.0,
            hedge=args.hedge_after is not None,
            hedge_after_s=args.hedge_after,
            degrade=args.degrade)
        overload = OverloadManager(ov_cfg)
        print(f"[serve] overload control armed "
              f"(deadline={args.query_deadline} "
              f"shed={ov_cfg.shed} hedge={ov_cfg.hedge} "
              f"degrade={ov_cfg.degrade})")
    app = ALL_APPS[args.app](engines)
    cls, policy = SCHEMES[args.scheme]
    if cls is Teola:
        orch = cls(app, engines, policy=policy, streaming=args.streaming,
                   continuous_batching=args.continuous_batching,
                   fault_tolerance=ft, overload=overload)
    else:
        orch = cls(app, engines, policy=policy)

    docs = doc_corpus(2)
    print(f"[serve] {args.app} via {args.scheme} "
          f"({'sim' if args.sim else 'real'} engines); warmup...")
    orch.query({"question": "warmup question", "docs": docs}, timeout=600)

    rng = np.random.default_rng(0)
    ctxs = []
    t0 = time.time()
    for i in range(args.queries):
        q = {"question": f"what is fact {i} about optics", "docs": docs}
        if args.slo_sched or args.overload_control:
            # two tenants, alternating SLO classes: tenant t0 is the
            # interactive user, t1 the throughput-bound batch tenant
            ctxs.append(orch.submit(
                q, slo="interactive" if i % 2 == 0 else "batch",
                tenant=f"t{i % 2}"))
        else:
            ctxs.append(orch.submit(q))
        time.sleep(float(rng.exponential(1.0 / args.rate)))
    for c in ctxs:
        c.done.wait(600)
    wall = time.time() - t0
    lats = [c.latency for c in ctxs if c.t_done]
    errs = [c for c in ctxs if c.error is not None]
    print(f"[serve] {len(lats)}/{args.queries} queries in {wall:.1f}s; "
          f"avg latency {np.mean(lats) * 1000:.0f}ms "
          f"p90 {np.percentile(lats, 90) * 1000:.0f}ms"
          + (f"; {len(errs)} failed" if errs else ""))
    if ft is not None:
        for s in orch.runtime.scheds.values():
            mgr = getattr(s, "ftmgr", None)
            if mgr is not None and mgr.events:
                print(f"[serve] recovery events ({s.pool.name}): "
                      f"{mgr.events}")
    if injector is not None and injector.log:
        print(f"[serve] injected faults: {injector.log}")
    if args.slo_sched:
        from repro.serving.slo import pool_tenant_stats
        for key, row in sorted(pool_tenant_stats(engines).items()):
            print(f"[serve] tenant {key}: "
                  + " ".join(f"{k}={v}" for k, v in sorted(row.items())))
    if overload is not None:
        from repro.core.engine_pool import replicas_of
        from repro.serving.overload import Overloaded
        shed = sum(1 for c in ctxs if isinstance(c.error, Overloaded))
        snap = overload.snapshot()
        print(f"[serve] overload: shed={shed} "
              f"admission={snap['admission']} hedge={snap['hedge']} "
              f"degrade={snap['degrade']}")
        leaked = bad = 0
        for eng in engines.values():
            for inst in replicas_of(eng):
                alloc = getattr(inst, "alloc", None)
                if alloc is not None and hasattr(alloc, "audit"):
                    rep = alloc.audit()
                    leaked += rep["leaked"]
                    bad += rep["bad_free"]
        print(f"[serve] kv audit: leaked={leaked} bad_free={bad}")
    orch.shutdown()


if __name__ == "__main__":
    main()
