"""Serving launcher: stand up an app (all four of the paper's workflows)
on the engine pool and serve queries.

  PYTHONPATH=src python -m repro.launch.serve --app advanced_rag \
      --queries 4 [--sim] [--scheme Teola|LlamaDist-TO|...] \
      [--llm-instances 2] [--streaming]

--llm-instances N puts each LLM engine behind an EnginePool of N replicas
(shared weights, per-replica KV stores; fused batches are routed to the
least-loaded replica). --streaming enables decode->downstream chunk
pipelining; --continuous-batching dispatches decodes into each replica's
persistent decode loop (iteration-level continuous batching) instead of
run-to-completion batches (both Teola scheme only). --paged-kv carves
each replica's KV cache into refcounted token blocks (copy-on-write
instruction-prefix sharing, block-table indexed decode, occupancy and
router backpressure counted in allocated blocks).
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.core.apps import ALL_APPS, build_engines
from repro.core.engine_pool import build_pools
from repro.core.teola import AutoGenLike, LlamaDist, LlamaDistPC, Teola
from repro.training.data import doc_corpus

SCHEMES = {
    "Teola": (Teola, "topo"),
    "LlamaDist-PO": (LlamaDist, "po"),
    "LlamaDist-TO": (LlamaDist, "to"),
    "LlamaDistPC-TO": (LlamaDistPC, "to"),
    "AutoGen-TO": (AutoGenLike, "to"),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--app", default="advanced_rag", choices=ALL_APPS)
    ap.add_argument("--scheme", default="Teola", choices=SCHEMES)
    ap.add_argument("--queries", type=int, default=4)
    ap.add_argument("--rate", type=float, default=2.0)
    ap.add_argument("--sim", action="store_true",
                    help="paper-calibrated latency-profile engines")
    ap.add_argument("--llm-instances", type=int, default=1,
                    help="EnginePool replicas per LLM engine")
    ap.add_argument("--streaming", action="store_true",
                    help="stream decode chunks to downstream primitives")
    ap.add_argument("--continuous-batching", action="store_true",
                    help="iteration-level decode batching (persistent "
                         "decode loop with per-iteration admission)")
    ap.add_argument("--paged-kv", action="store_true",
                    help="block-paged KV cache: COW prefix sharing, "
                         "block-table decode, block-based occupancy "
                         "routing with pool backpressure")
    args = ap.parse_args()

    if args.sim:
        from repro.engines.sim_engines import build_sim_engines
        engines = build_sim_engines(llm_instances=args.llm_instances,
                                    paged_kv=args.paged_kv)
    else:
        engines = build_engines(paged_kv=args.paged_kv)
        if args.llm_instances > 1:
            engines = build_pools(engines, {
                "core_llm": args.llm_instances,
                "lite_llm": args.llm_instances})
    app = ALL_APPS[args.app](engines)
    cls, policy = SCHEMES[args.scheme]
    if cls is Teola:
        orch = cls(app, engines, policy=policy, streaming=args.streaming,
                   continuous_batching=args.continuous_batching)
    else:
        orch = cls(app, engines, policy=policy)

    docs = doc_corpus(2)
    print(f"[serve] {args.app} via {args.scheme} "
          f"({'sim' if args.sim else 'real'} engines); warmup...")
    orch.query({"question": "warmup question", "docs": docs}, timeout=600)

    rng = np.random.default_rng(0)
    ctxs = []
    t0 = time.time()
    for i in range(args.queries):
        ctxs.append(orch.submit({
            "question": f"what is fact {i} about optics", "docs": docs}))
        time.sleep(float(rng.exponential(1.0 / args.rate)))
    for c in ctxs:
        c.done.wait(600)
    wall = time.time() - t0
    lats = [c.latency for c in ctxs if c.t_done]
    print(f"[serve] {len(lats)}/{args.queries} queries in {wall:.1f}s; "
          f"avg latency {np.mean(lats) * 1000:.0f}ms "
          f"p90 {np.percentile(lats, 90) * 1000:.0f}ms")
    orch.shutdown()


if __name__ == "__main__":
    main()
