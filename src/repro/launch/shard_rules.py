"""Parameter & cache PartitionSpec assignment.

Logical layout:
  - 'fsdp' -> 'data'   (weights/optimizer sharded over the data axis,
                        all-gathered at use — ZeRO-3 style)
  - 'tp'   -> 'model'  (tensor parallel: head/ffn/vocab dims)
  - batch  -> ('pod', 'data')
Expert weights are expert-sharded over 'model' + FSDP over 'data' on the
d axis — these specs MUST match moe.routed_ep's shard_map in_specs.

An axis is only sharded when divisible by the mesh axis OR large enough
that GSPMD's implicit padding waste is negligible (>= 4096).
"""
from __future__ import annotations

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

# last-path-component name -> logical spec for the UNSTACKED param
_RULES = {
    # embeddings / head. The table is vocab-sharded over 'model' ONLY:
    # GSPMD's gather partitioner handles single-axis vocab sharding (mask +
    # all-reduce) but chokes on (vocab x d) 2-D sharded lookups, and for
    # tied heads this layout gives vocab-TP logits for free.
    "embed": ("tp", None),
    "pos_embed": (None, None),
    "lm_head": ("fsdp", "tp"),
    # attention
    "wq": ("fsdp", "tp"),
    "wk": ("fsdp", "tp"),
    "wv": ("fsdp", "tp"),
    "wo": ("tp", "fsdp"),
    "bq": ("tp",), "bk": ("tp",), "bv": ("tp",),
    # mla
    "wq_a": ("fsdp", "tp"),
    "wq_b": ("fsdp", "tp"),
    "wkv_a": ("fsdp", None),
    "wkv_b": ("fsdp", "tp"),
    # dense ffn / shared experts
    "w_gate": ("fsdp", "tp"),
    "w_up": ("fsdp", "tp"),
    "w_down": ("tp", "fsdp"),
    # moe (expert-stacked: handled by rank-3 override below)
    "router": (None, None),
    # rwkv
    "wr": ("fsdp", "tp"),
    "mix_w1": ("fsdp", None),
    "mix_w2": (None, None, "tp"),
    "w_w1": ("fsdp", None),
    "w_w2": (None, "tp"),
    # mamba
    "w_in": ("fsdp", "tp"),
    "w_x": ("fsdp", None),
    "w_dt": (None, "tp"),
    "A_log": ("tp", None),
    "conv_w": (None, "tp"),
    "head": (None, None),
    "w1": (None, None), "w2": (None, None),
}

# MoE expert-stacked weights (E, d, f) / (E, f, d)
_MOE_RULES = {
    "w_gate": ("ep", "fsdp", None),
    "w_up": ("ep", "fsdp", None),
    "w_down": ("ep", None, "fsdp"),
}


def ep_axes(mesh):
    """Expert-parallel axes: 'model' by default; ('model','data') under
    the ep_all_axes opt flag (experts fully resident, DeepSeek-style
    wide EP). MUST match moe.routed_ep's shard_map specs."""
    from repro.launch import optflags
    if optflags.has("ep_all_axes"):
        return tuple(a for a in ("model", "data") if a in mesh.axis_names)
    return ("model",) if "model" in mesh.axis_names else ()


def fsdp_axes(mesh):
    """FSDP spans the data axis, extended across pods when present, so
    e.g. 671B-scale optimizer state keeps shrinking with pod count.
    With the 'resident_weights' opt flag, FSDP is disabled: weights stay
    resident (TP-sharded only) instead of being re-gathered per step."""
    from repro.launch import optflags
    if optflags.has("resident_weights"):
        return ()
    return tuple(a for a in ("data", "pod") if a in mesh.axis_names)


def _translate(logical, axes, shape, mesh):
    parts = []
    for l, dim in zip(logical, shape):
        if l is None:
            parts.append(None)
            continue
        from repro.launch import optflags
        if optflags.has("flat_dp"):
            # pure DP: weights FSDP-shard one dim over every axis, no
            # tensor parallelism ('tp'/'ep' dims stay unsharded)
            group = (tuple(a for a in ("data", "model", "pod")
                           if a in axes) if l == "fsdp" else ())
        elif l == "fsdp":
            group = fsdp_axes(mesh)
        elif l == "ep":
            group = ep_axes(mesh)
        elif optflags.has("tp2d"):
            # 2-D resident tensor parallelism: TP dims shard over BOTH
            # axes (weights never re-gathered; small activations move)
            group = tuple(a for a in ("model", "data") if a in axes)
        else:
            group = ("model",)
        group = tuple(a for a in group if a in axes)
        n = 1
        for a in group:
            n *= mesh.shape[a]
        # jit argument shardings must divide evenly
        if group and dim % n == 0:
            parts.append(group if len(group) > 1 else group[0])
        else:
            parts.append(None)
    return P(*parts)


def param_spec(path, shape, mesh) -> P:
    """path: tuple of keys from tree_flatten_with_path."""
    keys = [getattr(k, "key", getattr(k, "idx", None)) for k in path]
    name = next((k for k in reversed(keys) if isinstance(k, str)), None)
    axes = mesh.axis_names
    in_moe = "moe" in keys
    rank = len(shape)

    if name in ("m", "v", "step"):
        # optimizer moments mirror their parameter (path continues past m/v)
        name = next((k for k in reversed(keys[:keys.index(name)])
                     if isinstance(k, str)), name)

    if in_moe and name in _MOE_RULES and rank >= 3:
        logical = _MOE_RULES[name]
    elif name in _RULES:
        logical = _RULES[name]
    else:
        logical = ()

    logical = tuple(logical[-rank:]) if logical else ()
    if len(logical) < rank:  # stacked leading dims (stage repeat) -> None
        logical = (None,) * (rank - len(logical)) + logical
    return _translate(logical, axes, shape, mesh)


def tree_shardings(tree, mesh):
    """NamedSharding pytree for a (possibly abstract) param/opt tree."""
    def one(path, leaf):
        return NamedSharding(mesh, param_spec(path, leaf.shape, mesh))
    return jax.tree_util.tree_map_with_path(one, tree)


def with_shardings(abstract_tree, mesh):
    """Attach shardings to a ShapeDtypeStruct tree."""
    def one(path, leaf):
        return jax.ShapeDtypeStruct(
            leaf.shape, leaf.dtype,
            sharding=NamedSharding(mesh, param_spec(path, leaf.shape, mesh)))
    return jax.tree_util.tree_map_with_path(one, abstract_tree)


# ---------------------------------------------------------------------------
# KV cache / activations

def batch_axes(mesh):
    """Batch shards over (pod, data); under the flat_dp opt flag the
    'model' axis joins them (pure 256/512-way data parallelism — the
    right regime for small models where TP activation all-reduces
    dominate)."""
    from repro.launch import optflags
    axes = ("pod", "data", "model") if optflags.has("flat_dp") \
        else ("pod", "data")
    return tuple(a for a in axes if a in mesh.axis_names)


def cache_spec(name: str, shape, mesh, *, batch: int) -> P:
    """Cache arrays have a leading stage-repeat dim. Sequence dim is
    sharded over 'model' (flash-decode layout); if the batch cannot use
    the data axis (e.g. long_500k B=1) the sequence takes both axes."""
    axes = mesh.axis_names
    ba = batch_axes(mesh)
    dp = 1
    for a in ba:
        dp *= mesh.shape[a]
    b_shardable = batch % dp == 0
    bspec = ba if b_shardable else None

    def seq_axes():
        if b_shardable:
            return "model" if "model" in axes else None
        both = tuple(a for a in ("data", "model") if a in axes)
        return both if both else None

    if name in ("k", "v"):          # (R,B,T,K,hd)
        return P(None, bspec, seq_axes(), None, None)
    if name in ("ckv", "krope"):    # (R,B,T,r)
        return P(None, bspec, seq_axes(), None)
    if name == "state":             # (R,B,H,dk,dv)
        H = shape[2]
        tp = "model" if ("model" in axes
                         and H % mesh.shape["model"] == 0) else None
        return P(None, bspec, tp, None, None)
    if name == "ssm_h":             # (R,B,dI,N)
        return P(None, bspec, "model" if "model" in axes else None, None)
    if name == "ssm_conv":          # (R,B,cw-1,dI)
        return P(None, bspec, None, None)
    if name in ("sx_tm", "sx_cm"):  # (R,B,d)
        return P(None, bspec, None)
    return P(*([None] * len(shape)))


def data_spec(mesh, shape, *, batch_dim: int = 0) -> P:
    ba = batch_axes(mesh)
    parts = [None] * len(shape)
    dp = 1
    for a in ba:
        dp *= mesh.shape[a]
    if shape[batch_dim] % dp == 0:
        parts[batch_dim] = ba
    return P(*parts)
