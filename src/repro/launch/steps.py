"""Step builders + abstract input specs for every (arch × input-shape):
the bridge between model substrate and the multi-pod dry-run / launchers.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import InputShape, ModelConfig
from repro.launch import shard_rules as sr
from repro.models.transformer import apply_model, param_shapes
from repro.serving import kv_cache as kvc
from repro.training.optimizer import AdamWConfig, init_opt_state
from repro.training.train_step import make_train_step


def sds(shape, dtype, mesh=None, spec=None):
    sharding = NamedSharding(mesh, spec) if mesh is not None else None
    return jax.ShapeDtypeStruct(shape, dtype, sharding=sharding)


def long_context_supported(cfg: ModelConfig) -> bool:
    """long_500k runs only for sub-quadratic archs: every layer must be
    windowed-attention, SSM, or hybrid (see DESIGN.md skip notes).
    Pure full-attention archs are skipped. gemma2 qualifies via its
    local/global alternation (global layers decode at O(S) with the
    model-sharded cache)."""
    if cfg.family in ("ssm", "hybrid"):
        return True
    if cfg.name.startswith("gemma2"):
        return True
    return False


def case_supported(cfg: ModelConfig, ishape: InputShape):
    if ishape.name == "long_500k" and not long_context_supported(cfg):
        return False, "pure full-attention arch; long_500k skipped (DESIGN.md)"
    return True, ""


# ---------------------------------------------------------------------------

def num_microbatches(cfg: ModelConfig, ishape: InputShape, mesh) -> int:
    from repro.launch import optflags
    dp = 1
    for a in sr.batch_axes(mesh):
        dp *= mesh.shape[a]
    b_local = max(1, ishape.global_batch // dp)
    # target: ~1 sequence per device per microbatch at 4k train
    m = b_local
    while ishape.global_batch % m:
        m -= 1
    return optflags.get_int("microbatches", max(1, m))


def abstract_params(cfg: ModelConfig, mesh, dtype):
    tree = param_shapes(cfg, dtype)
    return sr.with_shardings(tree, mesh)


def abstract_cache(cfg: ModelConfig, mesh, batch: int, max_len: int,
                   chunk: int = 256):
    def shardings(name, shape):
        return NamedSharding(mesh,
                             sr.cache_spec(name, shape, mesh, batch=batch))
    return kvc.init_cache(cfg, batch, max_len, chunk=chunk, abstract=True,
                          shardings=shardings)


def build_case(cfg: ModelConfig, ishape: InputShape, mesh, *,
               q_block: int = 512):
    """Returns (step_fn, args_abstract: tuple, meta: dict).
    step_fn(*args) is what the dry-run lowers and compiles."""
    B, S = ishape.global_batch, ishape.seq_len
    stub = cfg.embed_stub is not None

    if ishape.mode == "train":
        params = abstract_params(cfg, mesh, jnp.float32)   # fp32 master
        opt_cfg = AdamWConfig(moment_dtype="bfloat16")
        opt = sr.with_shardings(
            jax.eval_shape(lambda p: init_opt_state(opt_cfg, p), params),
            mesh)
        nmb = num_microbatches(cfg, ishape, mesh)
        step = make_train_step(cfg, opt_cfg, num_microbatches=nmb,
                               compute_dtype=jnp.bfloat16, q_block=q_block,
                               stub=stub)
        if stub:
            batch = {
                "embeds": sds((B, S, cfg.d_model), jnp.bfloat16, mesh,
                              sr.data_spec(mesh, (B, S, cfg.d_model))),
                "targets": sds((B, S), jnp.int32, mesh,
                               sr.data_spec(mesh, (B, S))),
            }
        else:
            batch = {"tokens": sds((B, S + 1), jnp.int32, mesh,
                                   sr.data_spec(mesh, (B, S + 1)))}
        return step, (params, opt, batch), {"microbatches": nmb,
                                            "donate": (0, 1)}

    params = abstract_params(cfg, mesh, jnp.bfloat16)
    # prefill writes the whole prompt in one chunk; decode writes 1 token
    cache = abstract_cache(cfg, mesh, B, S,
                           chunk=(S if ishape.mode == "prefill" else 1))
    pos = sds((), jnp.int32, mesh, P())

    if ishape.mode == "prefill":
        from repro.launch import optflags
        chunk = optflags.get_int("chunked_prefill", 0)

        if chunk and S % chunk == 0:
            # chunked prefill (the substrate-level form of Teola's
            # Partial/Full Prefilling): process the prompt in chunks so
            # transient activations / MoE dispatch buffers scale with the
            # chunk, not the prompt. fori_loop reuses buffers per chunk.
            def prefill(params, inputs, cache, pos):
                def body(i, cache):
                    sl = jax.lax.dynamic_slice_in_dim(inputs, i * chunk,
                                                      chunk, axis=1)
                    _, cache, _ = apply_model(cfg, params, sl, cache,
                                              pos + i * chunk,
                                              q_block=q_block, remat=False,
                                              logits_slice=1)
                    return cache
                cache = jax.lax.fori_loop(0, S // chunk - 1, body, cache)
                last = jax.lax.dynamic_slice_in_dim(inputs, S - chunk,
                                                    chunk, axis=1)
                logits, cache, _ = apply_model(cfg, params, last, cache,
                                               pos + S - chunk,
                                               q_block=q_block, remat=False,
                                               logits_slice=1)
                return logits, cache
        else:
            def prefill(params, inputs, cache, pos):
                logits, cache, _ = apply_model(cfg, params, inputs, cache,
                                               pos, q_block=q_block,
                                               remat=False, logits_slice=1)
                return logits, cache
        if stub:
            inp = sds((B, S, cfg.d_model), jnp.bfloat16, mesh,
                      sr.data_spec(mesh, (B, S, cfg.d_model)))
        else:
            inp = sds((B, S), jnp.int32, mesh, sr.data_spec(mesh, (B, S)))
        return prefill, (params, inp, cache, pos), {"donate": (2,)}

    # decode: ONE new token against a seq_len KV cache
    def decode(params, inputs, cache, pos):
        logits, cache, _ = apply_model(cfg, params, inputs, cache, pos,
                                       q_block=q_block, remat=False)
        return logits, cache
    if stub:
        inp = sds((B, 1, cfg.d_model), jnp.bfloat16, mesh,
                  sr.data_spec(mesh, (B, 1, cfg.d_model)))
    else:
        inp = sds((B, 1), jnp.int32, mesh, sr.data_spec(mesh, (B, 1)))
    return decode, (params, inp, cache, pos), {"donate": (2,)}
