"""Training step: next-token cross-entropy + AdamW, with microbatch
gradient accumulation (lax.scan) so production batch sizes fit HBM.

Master weights fp32 (FSDP/TP sharded by the launcher); compute in the
config dtype (bf16 on TPU). MoE aux load-balance loss added with a small
coefficient.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.models.transformer import apply_model
from repro.training.optimizer import AdamWConfig, adamw_update

AUX_COEF = 0.01


def next_token_loss(cfg, params, tokens, *, compute_dtype=jnp.bfloat16,
                    q_block=512):
    """tokens (B, S+0): inputs tokens[:, :-1] predict tokens[:, 1:]."""
    cparams = jax.tree.map(
        lambda p: p.astype(compute_dtype)
        if jnp.issubdtype(p.dtype, jnp.floating) else p, params)
    logits, _, aux = apply_model(cfg, cparams, tokens[:, :-1],
                                 q_block=q_block)
    logits = logits.astype(jnp.float32)
    targets = tokens[:, 1:]
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None],
                               axis=-1).squeeze(-1)
    ce = jnp.mean(logz - gold)
    return ce + AUX_COEF * aux, ce


def embed_stub_loss(cfg, params, embeds, targets, *,
                    compute_dtype=jnp.bfloat16, q_block=512):
    """For modality-stub archs: inputs are precomputed frame/patch
    embeddings (B,S,d); targets (B,S) token ids."""
    cparams = jax.tree.map(
        lambda p: p.astype(compute_dtype)
        if jnp.issubdtype(p.dtype, jnp.floating) else p, params)
    logits, _, aux = apply_model(cfg, cparams, embeds, q_block=q_block)
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None],
                               axis=-1).squeeze(-1)
    ce = jnp.mean(logz - gold)
    return ce + AUX_COEF * aux, ce


def make_train_step(cfg, opt_cfg: AdamWConfig, *, num_microbatches: int = 1,
                    compute_dtype=jnp.bfloat16, q_block=512,
                    stub: bool = False):
    """Returns train_step(params, opt_state, batch) -> (params, opt_state,
    metrics). batch: {'tokens': (B,S)} or {'embeds': (B,S,d),
    'targets': (B,S)} for stub archs. B must divide by num_microbatches."""

    def loss_fn(params, mb):
        if stub:
            return embed_stub_loss(cfg, params, mb["embeds"], mb["targets"],
                                   compute_dtype=compute_dtype,
                                   q_block=q_block)
        return next_token_loss(cfg, params, mb["tokens"],
                               compute_dtype=compute_dtype, q_block=q_block)

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(params, opt_state, batch):
        if num_microbatches == 1:
            (loss, ce), grads = grad_fn(params, batch)
        else:
            mbs = jax.tree.map(
                lambda a: a.reshape(num_microbatches,
                                    a.shape[0] // num_microbatches,
                                    *a.shape[1:]), batch)

            def acc(carry, mb):
                g_acc, l_acc, c_acc = carry
                (l, c), g = grad_fn(params, mb)
                g_acc = jax.tree.map(jnp.add, g_acc, g)
                return (g_acc, l_acc + l, c_acc + c), None

            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                 params)
            (grads, loss, ce), _ = jax.lax.scan(
                acc, (zeros, jnp.zeros(()), jnp.zeros(())), mbs)
            grads = jax.tree.map(lambda g: g / num_microbatches, grads)
            loss = loss / num_microbatches
            ce = ce / num_microbatches
        gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                             for g in jax.tree.leaves(grads)))
        scale = jnp.minimum(1.0, 1.0 / (gnorm + 1e-9))
        grads = jax.tree.map(lambda g: g * scale, grads)
        params, opt_state = adamw_update(opt_cfg, grads, opt_state, params)
        return params, opt_state, {"loss": loss, "ce": ce, "gnorm": gnorm}

    return train_step
