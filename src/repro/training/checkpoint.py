"""Minimal pytree checkpointing (numpy .npz + structure manifest)."""
from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp
import numpy as np


def save_checkpoint(path: str, tree, step: int | None = None):
    os.makedirs(path, exist_ok=True)
    leaves, treedef = jax.tree.flatten(tree)
    arrs, dtypes = {}, []
    for i, l in enumerate(leaves):
        a = np.asarray(l)
        dtypes.append(str(a.dtype))
        if a.dtype == jnp.bfloat16:   # numpy .npz has no native bf16
            a = a.astype(np.float32)
        arrs[f"leaf_{i}"] = a
    np.savez(os.path.join(path, "arrays.npz"), **arrs)
    with open(os.path.join(path, "manifest.json"), "w") as f:
        json.dump({"treedef": str(treedef), "num_leaves": len(leaves),
                   "step": step, "dtypes": dtypes}, f)


def load_checkpoint(path: str, like_tree):
    leaves, treedef = jax.tree.flatten(like_tree)
    data = np.load(os.path.join(path, "arrays.npz"))
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    assert manifest["num_leaves"] == len(leaves), "tree structure mismatch"
    new_leaves = [jnp.asarray(data[f"leaf_{i}"]).astype(l.dtype)
                  for i, l in enumerate(leaves)]
    return jax.tree.unflatten(treedef, new_leaves)
