"""AdamW built from scratch (no optax dependency), with optional bf16
moment storage for memory-constrained large-model dry-runs."""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    moment_dtype: str = "float32"     # 'bfloat16' halves optimizer memory
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_ratio: float = 0.1


def lr_at(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = cfg.lr * step / max(cfg.warmup_steps, 1)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (
        1 + jnp.cos(jnp.pi * prog))
    return jnp.where(step < cfg.warmup_steps, warm, cfg.lr * cos)


def init_opt_state(cfg: AdamWConfig, params):
    mdt = jnp.dtype(cfg.moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, mdt)
    return {
        "step": jnp.zeros((), jnp.int32),
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
    }


def adamw_update(cfg: AdamWConfig, grads, opt_state, params):
    """params: fp32 master weights. Returns (new_params, new_opt_state)."""
    step = opt_state["step"] + 1
    lr = lr_at(cfg, step)
    t = step.astype(jnp.float32)
    c1 = 1.0 - cfg.b1 ** t
    c2 = 1.0 - cfg.b2 ** t
    mdt = jnp.dtype(cfg.moment_dtype)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        m32 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g
        v32 = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * g * g
        mh = m32 / c1
        vh = v32 / c2
        newp = p - lr * (mh / (jnp.sqrt(vh) + cfg.eps)
                         + cfg.weight_decay * p)
        return newp, m32.astype(mdt), v32.astype(mdt)

    flat = jax.tree.map(upd, params, grads, opt_state["m"], opt_state["v"],
                        is_leaf=lambda x: isinstance(x, jax.Array))
    newp = jax.tree.map(lambda t3: t3[0], flat,
                        is_leaf=lambda x: isinstance(x, tuple))
    newm = jax.tree.map(lambda t3: t3[1], flat,
                        is_leaf=lambda x: isinstance(x, tuple))
    newv = jax.tree.map(lambda t3: t3[2], flat,
                        is_leaf=lambda x: isinstance(x, tuple))
    return newp, {"step": step, "m": newm, "v": newv}
