"""Synthetic data pipeline: deterministic, seekable token stream with
host-side prefetch — stands in for a real corpus loader with the same
interface (``__iter__`` of {'tokens': (B, S+1)} batches)."""
from __future__ import annotations

import queue
import threading

import numpy as np


class SyntheticLM:
    """Markov-ish synthetic LM data: structured enough that a model can
    reduce loss on it (token t+1 = f(t) + noise), deterministic per seed."""

    def __init__(self, vocab_size: int, batch: int, seq_len: int,
                 seed: int = 0, prefetch: int = 2):
        self.vocab = vocab_size
        self.batch = batch
        self.seq = seq_len
        self.seed = seed
        self._q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._produce, daemon=True)
        self._thread.start()

    def _gen(self, step: int) -> np.ndarray:
        rng = np.random.default_rng(self.seed * 100003 + step)
        x = np.zeros((self.batch, self.seq + 1), np.int32)
        x[:, 0] = rng.integers(0, self.vocab, self.batch)
        mult = 31
        for t in range(1, self.seq + 1):
            noise = rng.integers(0, 4, self.batch)
            x[:, t] = (x[:, t - 1] * mult + noise) % self.vocab
        return x

    def _produce(self):
        step = 0
        while not self._stop.is_set():
            try:
                self._q.put({"tokens": self._gen(step)}, timeout=0.5)
                step += 1
            except queue.Full:
                continue

    def __iter__(self):
        return self

    def __next__(self):
        return self._q.get()

    def close(self):
        self._stop.set()


def doc_corpus(num_docs: int = 8, seed: int = 1):
    """Tiny deterministic text corpus for the RAG workflows."""
    rng = np.random.default_rng(seed)
    topics = ["optics", "finance", "llm systems", "biology", "chess",
              "espresso", "sailing", "volcanoes"]
    docs = []
    for i in range(num_docs):
        t = topics[i % len(topics)]
        sents = [f"Fact {j} about {t}: value {int(rng.integers(0, 999))}."
                 for j in range(40)]
        docs.append({"id": f"doc{i}", "topic": t, "text": " ".join(sents)})
    return docs
