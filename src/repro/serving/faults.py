"""Fault injection and fault-tolerant serving (detection + recovery).

Three cooperating pieces:

``FaultInjector``
    Deterministic, seeded fault source. Engines that carry a non-None
    ``.faults`` attribute call ``fire(engine, point)`` at well-defined
    hook points ("decode", "prefill", "migrate", "alloc"); the injector
    counts calls per (replica, point) and triggers the configured fault
    at exactly the configured call index — crash (replica is dead from
    then on), hang/slow (sleep), migration failure, or allocator
    exhaustion. Seeded random schedules drive the chaos tests; parsed
    specs drive ``serve.py --fault-inject``. With no injector attached
    the hook is a single attribute read — the off path is byte-identical.

``FTConfig`` / ``RecoveryManager``
    Per-pooled-scheduler fault tolerance. The manager classifies
    failures (crash vs capacity vs bug), marks replica health in the
    ``EnginePool`` (suspect/dead with routing exclusion), reclaims a
    dead replica's paged blocks (``kv_cache.reclaim_replica`` — refcount
    audited), and runs a watchdog thread for hang detection (decode-loop
    heartbeat staleness) and per-request deadlines.

``TaskRecovery``
    One handle per loop-dispatched LLM task, bound by the executor
    submit functions. On a recoverable per-sequence failure it re-routes
    the sequence to a healthy replica with capped exponential backoff:
    the prompt is rebuilt from the query's e-graph (the orchestrator
    holds every prefill payload — app-level context module-level servers
    lack), already-emitted tokens are teacher-forced back into the KV
    cache, and greedy decode continues — the final text is
    token-identical to a no-fault run. When retries or the deadline are
    exhausted the task fails loudly with a structured ``RequestError``.
"""
from __future__ import annotations

import random
import threading
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple


# --------------------------------------------------------------------------
# errors


class FaultError(RuntimeError):
    """Base class for injected (or detected) replica faults."""


class ReplicaCrash(FaultError):
    """The replica process is gone: every call on it fails from now on."""


class MigrationFault(FaultError):
    """A paged-KV block transfer between replicas failed mid-flight."""


class RequestError(RuntimeError):
    """Structured request failure: carries enough context to answer
    *which* request failed, *where*, and *after how many attempts* —
    instead of a bare exception bubbling out of a worker thread."""

    def __init__(self, msg: str, *, qid: str = "", sid: str = "",
                 reason: str = "", attempts: int = 0, replica: str = ""):
        super().__init__(msg)
        self.qid = qid
        self.sid = sid
        self.reason = reason
        self.attempts = attempts
        self.replica = replica


class DeadlineExceeded(RequestError):
    """The per-request deadline expired before recovery could finish."""


#: error types worth retrying on a different replica (replica-local
#: failures). Anything else is treated as a bug and fails immediately.
RECOVERABLE = (FaultError, TimeoutError)


def is_recoverable(err) -> bool:
    if isinstance(err, RECOVERABLE):
        return True
    # allocator exhaustion / admission starvation is replica-local too:
    # another replica may have room. Checked by name to avoid importing
    # kv_cache here (OutOfBlocks lives there).
    if type(err).__name__ == "OutOfBlocks":
        return True
    return "decode loop" in str(err)  # loop stopped/died mid-flight


# --------------------------------------------------------------------------
# fault injection


_KINDS = ("crash", "hang", "slow", "migrate_fail", "alloc_fail", "burst")
_POINTS = ("decode", "prefill", "migrate", "alloc", "encode")


@dataclass
class FaultSpec:
    """One scheduled fault: trigger `kind` on replica `engine` at the
    `at`-th call of hook `point` (1-based). `duration` is the sleep for
    hang/slow/burst; `width` is the number of consecutive calls a
    ``burst`` (arrival-rate spike: every call in the window queues behind
    `duration` of extra backlog) stays hot."""
    kind: str
    engine: str
    point: str
    at: int = 1
    duration: float = 0.5
    width: int = 8

    def __post_init__(self):
        if self.kind not in _KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r} "
                             f"(choose from {_KINDS})")
        if self.point not in _POINTS:
            raise ValueError(f"unknown fault point {self.point!r} "
                             f"(choose from {_POINTS})")
        if self.at < 1:
            raise ValueError("fault trigger index `at` is 1-based")
        if self.width < 1:
            raise ValueError("burst `width` must be >= 1")


class FaultInjector:
    """Deterministic fault source shared by every armed replica.

    Determinism: triggers depend only on per-(replica, point) call
    counts and the spec list — two runs with the same seed/specs and the
    same per-replica call interleaving fire identically. A ``crash`` is
    persistent: once fired, *every* subsequent hook call on that replica
    raises ``ReplicaCrash`` (the process is gone)."""

    def __init__(self, specs=(), seed: int = 0):
        self.specs: List[FaultSpec] = list(specs)
        self.seed = seed
        self.rng = random.Random(seed)
        self._counts: Dict[Tuple[str, str], int] = {}
        self._dead = set()
        self._lock = threading.Lock()
        self.log: List[tuple] = []   # (kind, replica, point, call_index)

    # -- construction helpers ------------------------------------------

    @classmethod
    def parse(cls, text: str, seed: int = 0) -> "FaultInjector":
        """Parse ``kind:engine:point:at[:duration[:width]]`` specs, comma
        separated — e.g. ``crash:core_llm.r1:decode:5,burst:lite_llm:prefill:1:0.05:6``."""
        specs = []
        for part in text.split(","):
            part = part.strip()
            if not part:
                continue
            bits = part.split(":")
            if len(bits) < 3:
                raise ValueError(
                    f"bad fault spec {part!r}: want "
                    f"kind:engine:point[:at[:duration[:width]]]")
            kind, engine, point = bits[0], bits[1], bits[2]
            at = int(bits[3]) if len(bits) > 3 else 1
            duration = float(bits[4]) if len(bits) > 4 else 0.5
            width = int(bits[5]) if len(bits) > 5 else 8
            specs.append(FaultSpec(kind, engine, point, at, duration, width))
        return cls(specs, seed=seed)

    @classmethod
    def random_schedule(cls, names, seed: int, n_faults: int = 1,
                        kinds=("crash",), points=("decode", "prefill"),
                        max_at: int = 6) -> "FaultInjector":
        """Seeded random fault schedule over `names` (chaos tests)."""
        rng = random.Random(seed)
        specs = [FaultSpec(rng.choice(list(kinds)), rng.choice(list(names)),
                           rng.choice(list(points)), rng.randint(1, max_at))
                 for _ in range(n_faults)]
        return cls(specs, seed=seed)

    def arm(self, engines, encoders: bool = False) -> list:
        """Attach this injector to every LLM replica reachable from an
        engines mapping (or an iterable of engines/pools). With
        ``encoders=True`` also arm embed/rerank replicas (the "encode"
        hook point — burst/slow faults on non-LLM engines). Returns the
        armed replica names."""
        from repro.core.engine_pool import replicas_of
        vals = engines.values() if hasattr(engines, "values") else engines
        armed = []
        for eng in vals:
            for rep in replicas_of(eng):
                if hasattr(rep, "submit_decode") or (
                        encoders and (hasattr(rep, "op_embed")
                                      or hasattr(rep, "op_rerank"))):
                    rep.faults = self
                    armed.append(rep.name)
        return armed

    # -- runtime --------------------------------------------------------

    def dead_replicas(self) -> set:
        with self._lock:
            return set(self._dead)

    def fire(self, engine, point: str):
        """Engine hook. Raises / sleeps according to the schedule."""
        name = getattr(engine, "name", str(engine))
        with self._lock:
            if name in self._dead:
                raise ReplicaCrash(f"{name}: replica is dead (injected crash)")
            k = self._counts.get((name, point), 0) + 1
            self._counts[(name, point)] = k
            hits = [s for s in self.specs
                    if s.engine == name and s.point == point
                    and (k == s.at or (s.kind == "slow" and k >= s.at)
                         or (s.kind == "burst"
                             and s.at <= k < s.at + s.width))]
        for s in hits:
            self._trigger(s, engine, name, point, k)

    def _trigger(self, spec: FaultSpec, engine, name: str, point: str,
                 k: int):
        self.log.append((spec.kind, name, point, k))
        if spec.kind == "crash":
            with self._lock:
                self._dead.add(name)
            try:
                engine.health = "dead"
            except Exception:  # noqa: BLE001 — health attr is best-effort
                pass
            raise ReplicaCrash(
                f"{name}: injected crash at {point} call #{k}")
        if spec.kind in ("hang", "slow", "burst"):
            time.sleep(spec.duration)
            return
        if spec.kind == "migrate_fail":
            if point == "migrate":
                raise MigrationFault(
                    f"{name}: injected migration failure at transfer #{k}")
            return
        if spec.kind == "alloc_fail":
            if point == "alloc":
                from repro.serving.kv_cache import OutOfBlocks
                raise OutOfBlocks(
                    f"{name}: injected allocator exhaustion at alloc #{k}")
            return


def fire(engine, point: str):
    """Module-level hook helper: no-op unless an injector is attached."""
    inj = getattr(engine, "faults", None)
    if inj is not None:
        inj.fire(engine, point)


# --------------------------------------------------------------------------
# fault-tolerance config


@dataclass
class FTConfig:
    """Fault-tolerance policy knobs (``Teola(..., fault_tolerance=...)``)."""
    max_retries: int = 2            # per-sequence recovery attempts
    request_deadline: Optional[float] = None  # s per dispatched LLM task
    backoff: float = 0.05           # base of exponential retry backoff (s)
    # heartbeat staleness thresholds: the loop stamps its heartbeat once
    # per pass, so these must exceed the worst-case SINGLE pass (a real
    # engine's first pass JIT-compiles and can take seconds) or a busy
    # replica is misread as hung
    suspect_after: float = 10.0     # loop heartbeat staleness -> suspect
    dead_after: float = 30.0        # loop heartbeat staleness -> dead
    watchdog_period: float = 0.2    # watchdog poll interval (s)


# --------------------------------------------------------------------------
# recovery manager (one per pooled scheduler)


class RecoveryManager:
    """Owns health marking, block reclamation, replica re-selection and
    the watchdog (hang + deadline detection) for one ``EnginePool``."""

    def __init__(self, sched, cfg: FTConfig):
        self.sched = sched
        self.pool = sched.pool
        self.cfg = cfg
        self._lock = threading.Lock()
        self._outstanding: Dict[int, "TaskRecovery"] = {}
        self._thread: Optional[threading.Thread] = None
        self._running = True
        self.events: List[tuple] = []   # (kind, detail...) — tests/benches
        self.reclaim_reports: List[dict] = []

    # -- lifecycle ------------------------------------------------------

    def start(self):
        with self._lock:
            if self._thread is not None or not self._running:
                return
            self._thread = threading.Thread(
                target=self._watch, daemon=True,
                name=f"ft-watchdog:{getattr(self.pool, 'name', 'pool')}")
        self._thread.start()

    def stop(self):
        self._running = False

    # -- task registration ---------------------------------------------

    def handle(self, task, route: dict, kind: str) -> "TaskRecovery":
        h = TaskRecovery(self, task, route, kind)
        with self._lock:
            self._outstanding[id(h)] = h
        self.start()
        return h

    def finish(self, h: "TaskRecovery"):
        with self._lock:
            self._outstanding.pop(id(h), None)

    # -- health ---------------------------------------------------------

    def note_failure(self, idx: int, err) -> None:
        """Classify a failure observed on replica `idx` and mark health.
        Crash-like -> dead (+ reclaim); deadline/unknown -> suspect;
        capacity (OutOfBlocks) -> no mark, the replica is healthy-but-full."""
        if isinstance(err, ReplicaCrash) or "decode loop died" in str(err):
            self.mark_dead(idx, str(err))
        elif type(err).__name__ == "OutOfBlocks":
            pass
        elif isinstance(err, (MigrationFault, TimeoutError, Exception)):
            self.pool.mark_suspect(idx, str(err))

    def mark_dead(self, idx: int, reason: str = ""):
        first = self.pool.mark_dead(idx, reason)
        if not first:
            return
        rep = self.pool[idx]
        self.events.append(("replica_dead", rep.name, reason))
        try:
            from repro.serving.kv_cache import reclaim_replica
            report = reclaim_replica(rep)
        except Exception as e:  # noqa: BLE001 — reclaim is best-effort
            report = {"engine": rep.name, "ok": False, "error": repr(e)}
        self.reclaim_reports.append(report)
        self.events.append(("reclaim", report))

    # -- routing --------------------------------------------------------

    def pick_replica(self, exclude=()) -> int:
        """Healthy replica for a recovery resubmit (slot/load aware)."""
        pool = self.pool
        base = getattr(pool, "route_decode_indices", None)
        indices = base() if base is not None else None
        cands = [i for i in (indices if indices is not None
                             else range(len(pool)))
                 if pool.health(i) != "dead" and i not in exclude]
        if not cands:
            cands = [i for i in (indices if indices is not None
                                 else range(len(pool)))
                     if pool.health(i) != "dead"]
        if not cands:
            raise ReplicaCrash(
                f"no healthy replica left in pool "
                f"({len(pool)} total, all dead)")
        return pool.least_loaded_decode(cands)

    def repin(self, task, idx: int):
        """Move the sequence's replica affinity to `idx`."""
        from repro.core import primitives as P
        if task.prim.op not in P.LLM_OPS:
            return
        key = (task.ctx.qid, task.prim.config.get("sid", task.prim.pid))
        with self.sched._aff_lock:
            self.sched.affinity[key] = idx

    # -- prompt replay ---------------------------------------------------

    def rebuild_prompt(self, task, sid: str) -> str:
        """Reconstruct a sequence's full prompt from the query e-graph:
        the orchestrator resolved every prefill payload from the object
        store, so a dead replica's prompt is always recomputable. A
        prompt split by the causal-prefill pass (Pass 3) lives in TWO
        primitives — PartialPrefilling (early parts) + FullPrefilling
        (late parts) — so every matching piece is collected and joined
        in causal order; the whitespace tokenizer guarantees
        ``encode(a) + encode(b) == encode(a + " " + b)``, making the
        joined replay token-identical to the split original."""
        from repro.core.executors import rebuild_full_prompt
        ctx = task.ctx
        full = rebuild_full_prompt(task.prim.engine, ctx, sid)
        if full is not None:
            return full
        raise ReplicaCrash(
            f"cannot rebuild prompt for {sid}: no matching prefill "
            f"primitive in query {ctx.qid}")

    # -- watchdog --------------------------------------------------------

    def _watch(self):
        cfg = self.cfg
        while self._running:
            time.sleep(cfg.watchdog_period)
            now = time.time()
            with self._lock:
                handles = list(self._outstanding.values())
            if not handles:
                continue
            # 1) heartbeat: a loop with pending work whose run thread has
            #    not completed a pass recently is hung (suspect -> dead)
            for idx in {h.route["idx"] for h in handles if not h.settled}:
                self._check_heartbeat(idx, now)
            # 2) per-request deadlines + dead-replica sweep (covers hangs,
            #    where no per-sequence callback will ever fire)
            for h in handles:
                if h.settled:
                    continue
                if h.deadline is not None and now >= h.deadline:
                    h.expire()
                elif self.pool.health(h.route["idx"]) == "dead":
                    h.recover_stranded()

    def _check_heartbeat(self, idx: int, now: float):
        pool = self.pool
        if pool.health(idx) == "dead":
            return
        loop = getattr(pool[idx], "_decode_loop", None)
        if loop is None:
            return
        busy = loop.occupancy() > 0 or bool(loop.prefill_waiting)
        if not busy:
            return
        stale = now - getattr(loop, "last_pass", now)
        if stale > self.cfg.dead_after:
            self.mark_dead(idx, f"heartbeat stale {stale:.2f}s")
        elif stale > self.cfg.suspect_after:
            pool.mark_suspect(idx, f"heartbeat stale {stale:.2f}s")


# --------------------------------------------------------------------------
# per-task recovery handle


class TaskRecovery:
    """Fault-tolerance handle for one loop-dispatched LLM task. The
    executor binds its entries and resubmit/fail callbacks; per-sequence
    failures route through :meth:`recover`."""

    def __init__(self, mgr: RecoveryManager, task, route: dict, kind: str):
        self.mgr = mgr
        self.cfg = mgr.cfg
        self.task = task
        self.route = route          # {"idx": int, "tokens": int} — mutable
        self.kind = kind            # "decode" | "prefill"
        # unified deadline: the watchdog enforces whichever is tighter —
        # the per-task FT budget or the query-level deadline stamped by
        # the overload layer (they share one clock; see serving/overload)
        dls = []
        if self.cfg.request_deadline:
            dls.append(time.time() + self.cfg.request_deadline)
        qdl = getattr(task.ctx, "deadline", None)
        if qdl is not None:
            dls.append(float(qdl))
        self.deadline = min(dls) if dls else None
        self._lock = threading.Lock()
        self.cancelled = False
        self.settled = False
        self.attempts: Dict[int, int] = {}
        self._state: Dict[int, str] = {}     # j -> live|recovering|done
        self._handles: Dict[int, object] = {}  # j -> DecodeSeq|PrefillJob
        self._on: Dict[int, int] = {}        # j -> replica idx submitted on
        self._sids: List[str] = []
        self._resubmit: Optional[Callable] = None
        self._fail: Optional[Callable] = None

    # -- executor binding ------------------------------------------------

    def bind(self, sids: List[str], resubmit: Callable, fail: Callable):
        self._sids = list(sids)
        self._resubmit = resubmit
        self._fail = fail
        for j in range(len(sids)):
            self._state.setdefault(j, "live")
            self._on.setdefault(j, self.route["idx"])

    def note_submitted(self, j: int, handle):
        with self._lock:
            self._handles[j] = handle
            if self._state.get(j) != "done":
                self._state[j] = "live"

    def note_done(self, j: int):
        with self._lock:
            self._state[j] = "done"

    def settle(self):
        with self._lock:
            self.settled = True
        self.mgr.finish(self)

    @property
    def qid(self) -> str:
        return self.task.ctx.qid

    def prompt_for(self, sid: str) -> str:
        return self.mgr.rebuild_prompt(self.task, sid)

    def wrap(self, err) -> RequestError:
        """Structured terminal error for this task."""
        if isinstance(err, RequestError):
            return err
        attempts = max(self.attempts.values(), default=0)
        rep = self.mgr.pool[self.route["idx"]]
        out = RequestError(
            f"request {self.qid}:{self.task.prim.pid} failed after "
            f"{attempts} recovery attempt(s) "
            f"(last replica {getattr(rep, 'name', '?')}): {err}",
            qid=self.qid, sid=self._sids[0] if self._sids else "",
            reason=type(err).__name__, attempts=attempts,
            replica=getattr(rep, "name", ""))
        out.__cause__ = err
        return out

    # -- recovery ---------------------------------------------------------

    def recover(self, j: int, handle) -> bool:
        """Executor hook: entry `j` failed with ``handle.error``. Marks
        replica health, and returns True when a retry was scheduled (the
        executor must then NOT count the entry as finished)."""
        with self._lock:
            cur = self._handles.get(j)
            on = self._on.get(j, self.route["idx"])
        if cur is not None and handle is not cur:
            # late eviction from a submission this entry already left
            # (the watchdog re-queued it elsewhere and the abandoned
            # loop drained afterwards) — the failure belongs to the old
            # replica, not whichever one now runs the entry; charging it
            # to route["idx"] would cascade-kill healthy replicas
            self.mgr.events.append(
                ("stale_failure", self.qid,
                 self._sids[j] if j < len(self._sids) else j,
                 repr(handle.error)))
            return True
        err = handle.error
        self.mgr.note_failure(on, err)
        return self._schedule(j, handle, err)

    def recover_submit(self, j: int, err) -> bool:
        """Scheduler-thread hook: submitting entry `j` raised before any
        loop handle existed (e.g. the routed replica died between
        routing and admission). Marks health and schedules a replay on a
        healthy replica when policy allows."""
        with self._lock:
            on = self._on.get(j, self.route["idx"])
        self.mgr.note_failure(on, err)
        return self._schedule(j, None, err)

    def recover_stranded(self):
        """Watchdog path: the routed replica is dead and hung — its
        per-sequence callbacks will never fire. Replay every still-live
        entry elsewhere."""
        for j, st in list(self._state.items()):
            if st == "live":
                self._schedule(j, self._handles.get(j),
                               ReplicaCrash("replica died while hung"))

    def _schedule(self, j: int, handle, err) -> bool:
        with self._lock:
            if self.cancelled or self._state.get(j) in ("done", "recovering"):
                return True    # already handled elsewhere; swallow
            if not is_recoverable(err):
                return False
            a = self.attempts.get(j, 0)
            if a >= self.cfg.max_retries:
                return False
            if self.deadline is not None and time.time() >= self.deadline:
                return False
            self.attempts[j] = a + 1
            self._state[j] = "recovering"
        delay = self.cfg.backoff * (2 ** a)
        t = threading.Thread(target=self._retry, args=(j, handle, delay),
                             daemon=True, name=f"ft-retry:{self.qid}:{j}")
        t.start()
        return True

    def _retry(self, j: int, handle, delay: float):
        try:
            time.sleep(delay)
            with self._lock:
                if self.cancelled:
                    return
            with self._lock:
                old = self._on.get(j, self.route["idx"])
            new = self.mgr.pick_replica(
                exclude={old} if len(self.mgr.pool) > 1 else ())
            if new != old:
                # move the load-ledger charge with the task
                self.mgr.pool.note_decode_finished(old, self.route["tokens"])
                self.mgr.pool.note_decode_submitted(new, self.route["tokens"])
                self.route["idx"] = new
            with self._lock:
                self._on[j] = new
            self.mgr.repin(self.task, new)
            self.mgr.events.append(
                ("retry", self.qid, self._sids[j] if j < len(self._sids)
                 else j, self.mgr.pool[new].name, self.attempts.get(j, 0)))
            with self._lock:
                if self.cancelled:
                    return
                self._state[j] = "live"
            self._resubmit(j, self.mgr.pool[new], handle)
        except Exception as e:  # noqa: BLE001 — recovery itself failed
            self._terminal(e)

    def expire(self):
        """Deadline passed: fail the whole task loudly, exactly once."""
        with self._lock:
            if self.settled or self.cancelled:
                return
            self.cancelled = True
        attempts = max(self.attempts.values(), default=0)
        err = DeadlineExceeded(
            f"request {self.qid}:{self.task.prim.pid} exceeded its "
            f"deadline after {attempts} "
            f"recovery attempt(s); sequences: {self._sids}",
            qid=self.qid, sid=self._sids[0] if self._sids else "",
            reason="deadline", attempts=attempts,
            replica=getattr(self.mgr.pool[self.route['idx']], "name", ""))
        self.mgr.events.append(("deadline", self.qid, self._sids))
        self._terminal(err, wrapped=True)

    def _terminal(self, err, wrapped: bool = False):
        fail = self._fail
        try:
            if fail is not None:
                fail(err if wrapped else self.wrap(err))
        finally:
            self.settle()
