"""KV / recurrent-state cache management.

Cache layout mirrors the model's stage/pattern structure:

    cache = {
      'stages': [ [elem_cache, ...pattern elems], ...stages ],
    }

where each ``elem_cache`` is a dict of arrays with a leading ``repeat``
dim (stacked across the scanned layers of the stage):

  - full attention:     {'k': (R,B,T,K,hd), 'v': (R,B,T,K,hd)}
  - sliding window:     same, with T = min(window, max_len)  (ring buffer)
  - MLA:                {'ckv': (R,B,T,r), 'krope': (R,B,T,p)}
  - hybrid (attn+ssm):  attention k/v plus {'ssm_h': (R,B,dI,N),
                         'ssm_conv': (R,B,cw-1,dI)}
  - rwkv:               {'state': (R,B,H,dk,dv), 'sx_tm': (R,B,d),
                         'sx_cm': (R,B,d)}

Sequence length is tracked as a single dynamic scalar ``pos`` passed to the
model apply function (all layers advance in lockstep).

``init_cache(..., abstract=True)`` returns ShapeDtypeStructs — used by the
dry-run to build AOT inputs without allocating terabytes.
"""
from __future__ import annotations

import threading
import time
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, LayerSpec


def _mk(shape, dtype, abstract, sharding=None):
    if abstract:
        return jax.ShapeDtypeStruct(shape, dtype, sharding=sharding)
    return jnp.zeros(shape, dtype)


def elem_cache_shape(cfg: ModelConfig, spec: LayerSpec, repeat: int,
                     batch: int, max_len: int, chunk: int = 256):
    """Returns {name: (shape, dtype)} for one pattern element.

    Sliding-window layers get a ring buffer of `window + chunk` slots
    (capped at max_len): a chunked write of S tokens needs window+S-1
    live slots for every query in the chunk to see its full window. When
    the cap hits max_len the ring never wraps and degenerates to a linear
    cache — same code path, no memory lost."""
    out = {}
    hd = cfg.resolved_head_dim
    if spec.kind == "rwkv":
        s = cfg.ssm
        heads = cfg.d_model // s.head_dim
        out["state"] = ((repeat, batch, heads, s.head_dim, s.head_dim),
                        jnp.float32)
        out["sx_tm"] = ((repeat, batch, cfg.d_model), jnp.float32)
        out["sx_cm"] = ((repeat, batch, cfg.d_model), jnp.float32)
        return out
    # attention part ('attn' and 'hybrid'); ring size rounded up to 256
    # so the sequence axis stays shardable over the mesh
    if spec.window is None:
        T = max_len
    else:
        T = min(-(-(spec.window + chunk) // 256) * 256, max_len)
    if cfg.attention_kind == "mla":
        m = cfg.mla
        out["ckv"] = ((repeat, batch, T, m.kv_lora_rank), jnp.bfloat16)
        out["krope"] = ((repeat, batch, T, m.qk_rope_head_dim), jnp.bfloat16)
    else:
        out["k"] = ((repeat, batch, T, cfg.num_kv_heads, hd), jnp.bfloat16)
        out["v"] = ((repeat, batch, T, cfg.num_kv_heads, hd), jnp.bfloat16)
    if spec.kind == "hybrid":
        s = cfg.ssm
        d_inner = cfg.d_model
        out["ssm_h"] = ((repeat, batch, d_inner, s.state_dim), jnp.float32)
        out["ssm_conv"] = ((repeat, batch, s.conv_dim - 1, d_inner),
                           jnp.float32)
    return out


def init_cache(cfg: ModelConfig, batch: int, max_len: int, *,
               chunk: int = 256, abstract: bool = False, shardings=None):
    """shardings: optional matching pytree-of-NamedSharding builder fn
    f(name, shape) -> sharding, used for abstract dry-run inputs.
    chunk: largest prefill chunk the caller will write (sizes the
    sliding-window ring buffers)."""
    stages = []
    for st in cfg.stages:
        elems = []
        for spec in st.pattern:
            shapes = elem_cache_shape(cfg, spec, st.repeat, batch, max_len,
                                      chunk)
            elem = {}
            for name, (shape, dtype) in shapes.items():
                sh = shardings(name, shape) if shardings else None
                elem[name] = _mk(shape, dtype, abstract, sh)
            elems.append(elem)
        stages.append(elems)
    return {"stages": stages}


def cache_bytes(cfg: ModelConfig, batch: int, max_len: int,
                chunk: int = 256) -> int:
    total = 0
    for st in cfg.stages:
        for spec in st.pattern:
            for shape, dtype in elem_cache_shape(
                    cfg, spec, st.repeat, batch, max_len, chunk).values():
                total += int(np.prod(shape)) * jnp.dtype(dtype).itemsize
    return total


# ---------------------------------------------------------------------------
# Paged KV pool (block-granular cache with copy-on-write prefix sharing)
#
# Instead of one dense (R,B,max_len,...) cache per sequence, a replica owns
# ONE physical pool per cache array, carved into fixed-size token blocks:
#
#     k: (R, num_blocks, block_size, K, hd)
#
# Every sequence holds a *block table* — a host-side list of physical block
# ids covering its logical positions [0, pos) — instead of a private cache
# pytree. Admission/eviction never stacks or unstacks KV; forking a prefix
# state is O(table) refcount bumps (copy-on-write: a shared block is copied
# only when a writer appends into it). Blocks are refcounted and free-listed
# by BlockAllocator; block 0 is RESERVED as the batch-padding scratch block
# (padding rows write there, so it is never handed to a sequence).

PAD_BLOCK = 0


class OutOfBlocks(RuntimeError):
    """The paged KV pool has no free block (admission backpressure)."""


class BlockAllocator:
    """Refcounted free-list allocator over ``num_blocks`` fixed-size blocks.

    Block ``PAD_BLOCK`` (0) is reserved for batch-padding writes and is
    never allocated. All methods are thread-safe; ``wait_for_free`` blocks
    until at least ``n`` blocks are free (a ``decref`` to zero notifies),
    which is the prefill-side backpressure point when the pool is full.
    """

    def __init__(self, num_blocks: int):
        if num_blocks < 2:
            raise ValueError("need >= 2 blocks (block 0 is the pad block)")
        self.num_blocks = num_blocks
        self._refs = [0] * num_blocks
        self._free = list(range(num_blocks - 1, 0, -1))   # pop() -> low ids
        self._cv = threading.Condition()
        self._waiters = 0

    @property
    def capacity(self) -> int:
        """Allocatable blocks (excludes the reserved pad block)."""
        return self.num_blocks - 1

    def alloc(self) -> int:
        with self._cv:
            if not self._free:
                raise OutOfBlocks(
                    f"paged KV pool exhausted ({self.capacity} blocks)")
            b = self._free.pop()
            self._refs[b] = 1
            return b

    def incref(self, b: int):
        with self._cv:
            assert self._refs[b] > 0, f"incref on free block {b}"
            self._refs[b] += 1

    def decref(self, b: int):
        with self._cv:
            assert self._refs[b] > 0, f"decref on free block {b}"
            self._refs[b] -= 1
            if self._refs[b] == 0:
                self._free.append(b)
            if self._refs[b] <= 1:
                # 0: a block returned to the free list; 1: a block held
                # by a radix prefix cache lost its last live-sequence
                # reference and became EVICTABLE capacity — both improve
                # wait_for_free predicates that credit evictable blocks
                self._cv.notify_all()

    def refcount(self, b: int) -> int:
        with self._cv:
            return self._refs[b]

    def refs_snapshot(self) -> list:
        """Copy of the refcount array. Safe to call from a
        ``wait_for_free`` predicate: the condition's underlying lock is
        reentrant, so the waiting thread may re-enter here."""
        with self._cv:
            return list(self._refs)

    def notify_waiters(self):
        """Wake wait_for_free waiters whose predicate improved for a
        reason other than a decref — e.g. a decode RESERVATION was
        dropped (evicted sequence), freeing headroom without freeing a
        block."""
        with self._cv:
            self._cv.notify_all()

    def free_blocks(self) -> int:
        with self._cv:
            return len(self._free)

    def used_blocks(self) -> int:
        with self._cv:
            return self.capacity - len(self._free)

    def waiters(self) -> int:
        """Threads currently blocked in ``wait_for_free`` (diagnostics)."""
        with self._cv:
            return self._waiters

    def snapshot(self) -> dict:
        """Point-in-time allocator state for diagnostics messages."""
        with self._cv:
            return {"capacity": self.capacity,
                    "free": len(self._free),
                    "used": self.capacity - len(self._free),
                    "waiters": self._waiters}

    def audit(self) -> dict:
        """Conservation check: every block is either free (ref 0) or
        referenced; free-list and refcount array must agree exactly.
        Returns {"ok", "leaked", "free", "capacity", "bad_free"}."""
        with self._cv:
            free_set = set(self._free)
            bad_free = [b for b in free_set if self._refs[b] != 0]
            leaked = [b for b in range(1, self.num_blocks)
                      if self._refs[b] == 0 and b not in free_set]
            return {"ok": not bad_free and not leaked,
                    "leaked": len(leaked), "bad_free": len(bad_free),
                    "free": len(self._free), "capacity": self.capacity}

    def wait_for_free(self, n: int, timeout: float = 30.0,
                      reserved_fn=None) -> bool:
        """Block until ``n`` blocks are free beyond ``reserved_fn()``
        (blocks promised to admitted decodes). Returns False on timeout."""
        deadline = time.time() + timeout
        with self._cv:
            self._waiters += 1
            try:
                while True:
                    reserved = reserved_fn() if reserved_fn else 0
                    if len(self._free) - reserved >= n:
                        return True
                    remaining = deadline - time.time()
                    if remaining <= 0:
                        return False
                    self._cv.wait(timeout=remaining)
            finally:
                self._waiters -= 1


def blocks_for(pos_end: int, block_size: int) -> int:
    """Blocks needed to cover logical positions [0, pos_end)."""
    return -(-pos_end // block_size)


def trim_table(alloc: "BlockAllocator", table, pos_end: int,
               block_size: int) -> int:
    """Speculative-decode rollback: drop trailing block-table entries
    that cover ONLY positions >= pos_end (rejected draft tokens /
    overshoot), decref'ing each — a shared trailing block is released,
    an exclusively-owned one returns to the free list. Mutates ``table``
    in place and returns the number of entries dropped. Caller must hold
    the engine's paged lock."""
    keep = blocks_for(pos_end, block_size)
    dropped = 0
    while len(table) > keep:
        alloc.decref(table.pop())
        dropped += 1
    return dropped


# ---------------------------------------------------------------------------
# Global radix-tree prefix cache (cross-query / cross-tenant KV reuse)

class _RadixNode:
    """One radix-tree edge: a BLOCK-ALIGNED token run plus the physical
    blocks holding its KV. Children are keyed by the token tuple of
    their first block — two children of one node always differ within
    that first block (otherwise insert would have shared it), so the
    key is collision-free without per-token child maps."""
    __slots__ = ("tokens", "blocks", "children", "parent", "last_access")

    def __init__(self, tokens, blocks, parent):
        self.tokens = tuple(tokens)
        self.blocks = list(blocks)
        self.children: dict = {}
        self.parent = parent
        self.last_access = 0


def _common_len(a, b) -> int:
    n = min(len(a), len(b))
    for i in range(n):
        if a[i] != b[i]:
            return i
    return n


class RadixPrefixCache:
    """Radix tree over TOKEN SEQUENCES whose edges own refcounted paged
    block runs — the generalization of the instruction-prefix cache:
    ANY two requests (any query, any tenant) sharing a block-aligned
    token prefix share its KV blocks, not just prompts starting with a
    warmed instruction.

    Ownership model — the tree is just another block OWNER on the
    engine's ``BlockAllocator``:

      * ``insert`` increfs every newly adopted block (the tree holds
        exactly ONE reference per cached block, deduplicating repeat
        inserts of an already-cached path),
      * ``match_prefix`` increfs the matched run on the CALLER's behalf
        — the caller extends a sequence's block table with them exactly
        like ``fork_state``, and releases them through the normal
        table decref path,
      * ``evict`` walks LRU leaves and drops the tree's references; a
        block still referenced by a live sequence survives its leaf's
        eviction (refcount > 0), so eviction can never free live KV.

    Everything is block-granular: only WHOLE blocks are cached or
    matched (a partial tail block stays exclusively owned by the
    sequence that wrote it), so a matched sequence's first write lands
    on a fresh block and never COWs cached state.

    Thread safety: all tree mutation runs under one internal lock,
    taken BEFORE any allocator call (lock order: radix -> allocator —
    the allocator never calls back). ``_blocks``, the flat mirror of
    every cached block id, is REBOUND (never mutated in place) so
    lock-free readers — the engine's evictable-capacity snapshot in
    routing and wait predicates — can iterate a consistent list."""

    def __init__(self, alloc: BlockAllocator, block_size: int):
        self.alloc = alloc
        self.block_size = int(block_size)
        self._root = _RadixNode((), [], None)
        self._blocks: list = []         # flat mirror of all cached blocks
        self._clock = 0                 # LRU timestamps (monotone counter)
        self._lock = threading.Lock()
        self.stats = {"hits": 0, "misses": 0, "hit_tokens": 0,
                      "inserted_blocks": 0, "evicted_blocks": 0,
                      "freed_blocks": 0, "evictions": 0}

    # -- introspection ------------------------------------------------------
    def num_blocks(self) -> int:
        return len(self._blocks)

    def block_snapshot(self) -> list:
        """Current mirror list (lock-free: the list object is immutable
        once published; mutation rebinds)."""
        return self._blocks

    def num_nodes(self) -> int:
        with self._lock:
            n, stack = 0, [self._root]
            while stack:
                node = stack.pop()
                n += len(node.children)
                stack.extend(node.children.values())
            return n

    def evictable_blocks(self) -> int:
        """Cached blocks the tree is the SOLE owner of (refcount 1) —
        the capacity eviction could return to the free list."""
        with self._lock:
            return sum(1 for b in self._blocks
                       if self.alloc.refcount(b) == 1)

    def clear(self) -> int:
        """Drop EVERY cached reference and reset the tree (dead-replica
        reclamation). Returns the number of references released. Blocks
        still shared with live sequences survive until those release."""
        with self._lock:
            n, stack = 0, [self._root]
            while stack:
                node = stack.pop()
                stack.extend(node.children.values())
                for b in node.blocks:
                    self.alloc.decref(b)
                    n += 1
            self._root = _RadixNode((), [], None)
            self._blocks = []               # rebind, no mutate
            self.stats["evicted_blocks"] += n
            return n

    # -- match --------------------------------------------------------------
    def _match_locked(self, tokens, touch: bool):
        bs = self.block_size
        toks = tuple(tokens)
        node = self._root
        out, matched = [], 0
        if touch:
            self._clock += 1
            node.last_access = self._clock
        while len(toks) - matched >= bs:
            rest = toks[matched:]
            child = node.children.get(rest[:bs])
            if child is None:
                break
            take = (_common_len(child.tokens, rest) // bs) * bs
            if touch:
                child.last_access = self._clock
            out.extend(child.blocks[: take // bs])
            matched += take
            if take < len(child.tokens):
                break
            node = child
        return out, matched

    def match_prefix(self, tokens):
        """Longest cached block-aligned prefix of ``tokens`` ->
        (block_ids, matched_token_count). Every returned block is
        increfed on the CALLER's behalf: the caller owns a table
        reference (fork semantics) and releases it through the normal
        sequence-release decref path. Touches the matched path's LRU
        timestamps."""
        with self._lock:
            out, matched = self._match_locked(tokens, touch=True)
            for b in out:
                self.alloc.incref(b)
            if matched:
                self.stats["hits"] += 1
                self.stats["hit_tokens"] += matched
            else:
                self.stats["misses"] += 1
            return out, matched

    def match_len(self, tokens) -> int:
        """Read-only probe (router prefix affinity): matched token count
        without increfs or LRU touches."""
        with self._lock:
            return self._match_locked(tokens, touch=False)[1]

    # -- insert -------------------------------------------------------------
    def insert(self, tokens, table) -> int:
        """Cache a finished prompt's block-aligned prefix: walk the tree
        reusing already-cached nodes (a duplicate insert adopts
        nothing), split mid-edge at the last shared block boundary, and
        adopt the new suffix blocks from ``table`` with one tree incref
        each. Returns the number of newly cached blocks."""
        bs = self.block_size
        toks = tuple(tokens[: (len(tokens) // bs) * bs])
        if not toks:
            return 0
        with self._lock:
            self._clock += 1
            self._root.last_access = self._clock
            node, off, added = self._root, 0, 0
            while off < len(toks):
                rest = toks[off:]
                child = node.children.get(rest[:bs])
                if child is None:
                    nb = list(table[off // bs: off // bs + len(rest) // bs])
                    for b in nb:
                        self.alloc.incref(b)
                    new = _RadixNode(rest, nb, node)
                    new.last_access = self._clock
                    node.children[rest[:bs]] = new
                    self._blocks = self._blocks + nb     # rebind, no mutate
                    added = len(nb)
                    break
                take = (_common_len(child.tokens, rest) // bs) * bs
                child.last_access = self._clock
                if take < len(child.tokens):
                    self._split_locked(child, take)
                node = child
                off += take
            self.stats["inserted_blocks"] += added
            return added

    def _split_locked(self, node: _RadixNode, take: int):
        """Split an edge at block boundary ``take``: ``node`` keeps the
        first ``take`` tokens/blocks; a new child inherits the remainder
        and node's former children. No refcounts change — the tree's
        single reference per block just moves between nodes."""
        bs = self.block_size
        lower = _RadixNode(node.tokens[take:], node.blocks[take // bs:],
                           node)
        lower.children = node.children
        for ch in lower.children.values():
            ch.parent = lower
        lower.last_access = node.last_access
        node.tokens = node.tokens[:take]
        node.blocks = node.blocks[:take // bs]
        node.children = {lower.tokens[:bs]: lower}

    # -- eviction -----------------------------------------------------------
    def evict(self, want: int) -> int:
        """LRU leaf eviction under memory pressure: drop least-recently-
        matched leaves until ``want`` blocks have actually RETURNED to
        the free list, or nothing more can free. Leaves whose blocks are
        all still referenced by live sequences are skipped — dropping
        them frees nothing (refcounts keep live KV safe regardless) and
        ancestors of a fully-shared leaf are fully shared too, so
        skipping never strands freeable inner blocks. Evicting a leaf
        can expose its parent as the next LRU leaf (cascade). Returns
        blocks freed to the pool."""
        if want <= 0:
            return 0
        with self._lock:
            freed, evicted_any, skipped = 0, False, set()
            while freed < want:
                leaf = self._lru_leaf_locked(skipped)
                if leaf is None:
                    break
                if not any(self.alloc.refcount(b) == 1
                           for b in leaf.blocks):
                    skipped.add(id(leaf))
                    continue
                for b in leaf.blocks:
                    if self.alloc.refcount(b) == 1:
                        freed += 1
                    self.alloc.decref(b)
                del leaf.parent.children[leaf.tokens[:self.block_size]]
                self.stats["evicted_blocks"] += len(leaf.blocks)
                self.stats["evictions"] += 1
                evicted_any = True
            if evicted_any:
                self._rebuild_mirror_locked()
                self.stats["freed_blocks"] += freed
            return freed

    def _lru_leaf_locked(self, skipped):
        best, stack = None, [self._root]
        while stack:
            n = stack.pop()
            if n.children:
                stack.extend(n.children.values())
            elif n is not self._root and id(n) not in skipped:
                if best is None or n.last_access < best.last_access:
                    best = n
        return best

    def _rebuild_mirror_locked(self):
        blocks, stack = [], [self._root]
        while stack:
            n = stack.pop()
            blocks.extend(n.blocks)
            stack.extend(n.children.values())
        self._blocks = blocks                # rebind, no mutate


def reclaim_replica(engine, lock_timeout: float = 2.0) -> dict:
    """Release every sequence, cached prefix and decode reservation of a
    DEAD replica and audit its allocator for leaks (free-list / refcount
    conservation). Works on real and sim engines (sim has no allocator;
    only the sequence table is dropped).

    A replica that died while HUNG may hold its paged pool lock forever;
    rather than deadlocking the recovery path, its blocks are written
    off (``written_off=True``) — the pool is per-replica, so the leak is
    contained to memory the dead replica owned anyway."""
    report = {"engine": getattr(engine, "name", "?"), "released": 0,
              "radix_refs": 0, "prefix_refs": 0, "leaked": -1,
              "ok": True, "written_off": False}
    paged = bool(getattr(engine, "paged", False))
    plock = getattr(engine, "_paged_lock", None)
    if paged and plock is not None:
        # probe only: if a hung thread holds the pool lock, releasing
        # block tables would block forever — write the pool off instead.
        # (release() takes engine._lock before _paged_lock; holding the
        # paged lock across release() here would invert that order.)
        if not plock.acquire(timeout=lock_timeout):
            report["written_off"] = True
            report["ok"] = False
            return report
        plock.release()
    for sid in list(getattr(engine, "states", {})):
        try:
            engine.release(sid)
            report["released"] += 1
        except Exception:  # noqa: BLE001 — reclaim everything we can
            pass
    radix = getattr(engine, "radix", None)
    if radix is not None:
        report["radix_refs"] = radix.clear()
    pc = getattr(engine, "prefix_cache", None)
    alloc = getattr(engine, "alloc", None)
    if paged and isinstance(pc, dict) and alloc is not None:
        for st in pc.values():
            for b in getattr(st, "table", []) or []:
                alloc.decref(b)
                report["prefix_refs"] += 1
        pc.clear()
    resv = getattr(engine, "_decode_reserved", None)
    if resv is not None:
        resv.clear()
    if paged and alloc is not None:
        audit = alloc.audit()
        report["leaked"] = alloc.capacity - alloc.free_blocks()
        report["ok"] = audit["ok"] and report["leaked"] == 0
        alloc.notify_waiters()
    else:
        report["leaked"] = 0
    return report


def _paged_elem_shape(cfg: ModelConfig, spec: LayerSpec, repeat: int,
                      num_blocks: int, block_size: int):
    """Per-elem pool shapes: the token axis (T) of the dense layout becomes
    (num_blocks, block_size). Sliding-window layers are paged LINEARLY —
    the window is enforced by the position mask, not a ring buffer — so
    every attention elem pages identically. Recurrent state (rwkv /
    hybrid-SSM) is per-sequence, not per-token, and cannot be paged."""
    if spec.kind in ("rwkv", "hybrid"):
        raise ValueError(
            f"paged KV cache does not support '{spec.kind}' layers "
            "(recurrent state is per-sequence, not per-token)")
    out = {}
    hd = cfg.resolved_head_dim
    if cfg.attention_kind == "mla":
        m = cfg.mla
        out["ckv"] = ((repeat, num_blocks, block_size, m.kv_lora_rank),
                      jnp.bfloat16)
        out["krope"] = ((repeat, num_blocks, block_size, m.qk_rope_head_dim),
                        jnp.bfloat16)
    else:
        out["k"] = ((repeat, num_blocks, block_size, cfg.num_kv_heads, hd),
                    jnp.bfloat16)
        out["v"] = ((repeat, num_blocks, block_size, cfg.num_kv_heads, hd),
                    jnp.bfloat16)
    return out


def init_paged_pool(cfg: ModelConfig, num_blocks: int, block_size: int, *,
                    abstract: bool = False):
    """Physical block pool pytree (mirrors init_cache's stage structure,
    with the token axis carved into (num_blocks, block_size))."""
    stages = []
    for st in cfg.stages:
        elems = []
        for spec in st.pattern:
            shapes = _paged_elem_shape(cfg, spec, st.repeat, num_blocks,
                                       block_size)
            elems.append({name: _mk(shape, dtype, abstract)
                          for name, (shape, dtype) in shapes.items()})
        stages.append(elems)
    return {"stages": stages}


def paged_block_bytes(cfg: ModelConfig, block_size: int) -> int:
    """True memory of ONE pool block across all layers — the unit the
    block-based OccupancyMeter reports."""
    total = 0
    for st in cfg.stages:
        for spec in st.pattern:
            for shape, dtype in _paged_elem_shape(
                    cfg, spec, st.repeat, 1, block_size).values():
                total += int(np.prod(shape)) * jnp.dtype(dtype).itemsize
    return total


def _copy_blocks_jit(pool, srcs, dsts):
    """One gather/scatter for ALL pending COW pairs (block axis is axis
    1, after the scanned repeat axis); the pool buffer is donated, so on
    backends with donation this is an in-place block copy rather than a
    full-pool duplication per pair."""
    return jax.tree.map(lambda a: a.at[:, dsts].set(a[:, srcs]), pool)


_copy_blocks_jit = jax.jit(_copy_blocks_jit, donate_argnums=(0,))


def copy_pool_blocks(pool, srcs, dsts):
    """Copy-on-write realization: duplicate physical blocks ``srcs[i]``
    into ``dsts[i]`` across every pool array. CAUTION: the input pool's
    buffers are donated — callers must drop their reference in favor of
    the returned pool.

    Contract: this is a PURE DATA MOVE with no refcount side effects.
    The caller owns all ``BlockAllocator`` bookkeeping — ``dsts`` must
    already be allocated (refcounted) and any decref of ``srcs`` happens
    after the copy. ``migrate_blocks`` builds the cross-pool handoff on
    the same contract."""
    return _copy_blocks_jit(pool, jnp.asarray(srcs, jnp.int32),
                            jnp.asarray(dsts, jnp.int32))


def _gather_blocks_jit(pool, idx):
    """Stage blocks OUT of a pool (block axis is axis 1). The pool is
    NOT donated: the source keeps serving from its buffers while the
    staged copy travels to another pool."""
    return jax.tree.map(lambda a: a[:, idx], pool)


_gather_blocks_jit = jax.jit(_gather_blocks_jit)


def _scatter_blocks_jit(pool, stage, idx):
    """Land staged blocks into pool slots ``idx``. The destination pool
    is donated (in-place write where the backend supports donation)."""
    return jax.tree.map(lambda a, s: a.at[:, idx].set(s), pool, stage)


_scatter_blocks_jit = jax.jit(_scatter_blocks_jit, donate_argnums=(0,))


def gather_pool_blocks(pool, blocks):
    """Copy blocks out of ``pool`` into a free-standing staged pytree
    (same structure, block axis shrunk to ``len(blocks)``). No refcount
    side effects; the input pool stays valid."""
    return _gather_blocks_jit(pool, jnp.asarray(blocks, jnp.int32))


def scatter_pool_blocks(pool, stage, blocks):
    """Write a staged pytree (from ``gather_pool_blocks``) into slots
    ``blocks`` of ``pool``. CAUTION: ``pool``'s buffers are donated —
    callers must rebind to the returned pool. No refcount side effects."""
    return _scatter_blocks_jit(pool, stage, jnp.asarray(blocks, jnp.int32))


def reserve_blocks(alloc: "BlockAllocator", n: int) -> list:
    """All-or-nothing allocation of ``n`` blocks (each refcount 1). If
    the pool runs out mid-way, every block already taken is returned and
    ``OutOfBlocks`` propagates — the allocator is left exactly as found."""
    got: list = []
    try:
        for _ in range(n):
            got.append(alloc.alloc())
    except OutOfBlocks:
        for b in got:
            alloc.decref(b)
        raise
    return got


def migrate_blocks(src_alloc: "BlockAllocator", src_pool,
                   dst_alloc: "BlockAllocator", dst_pool,
                   table, *, dst_table=None):
    """Paged KV handoff: copy the blocks of one sequence's ``table`` from
    a source pool into blocks reserved in a DESTINATION pool (another
    replica), returning ``(dst_table, new_dst_pool)``. Built on the
    ``copy_pool_blocks`` contract: the data move itself has no refcount
    side effects, so this primitive owns the bookkeeping explicitly.

    Atomicity: destination capacity is secured FIRST (``reserve_blocks``,
    all-or-nothing); only after the staged copy lands is the source table
    decref'd — on reservation failure the source is untouched and
    ``OutOfBlocks`` propagates. Refcounts: each source entry loses exactly
    the sequence's OWN reference, so blocks shared with a radix prefix
    tree or a COW fork survive on the source, still owned there; every
    destination block is freshly allocated with refcount 1 — the migrated
    copy is sequence-private (it is NOT inserted into any prefix cache).
    The pad block is never migrated: tables never contain it (asserted).

    Pass ``dst_table`` to supply pre-reserved destination blocks (the
    engine path reserves under backpressure before staging). The caller
    must serialize access to each pool against its owner's step loop —
    the destination pool's buffers are donated; the source's are only
    read, and the staged copy is synchronized before this returns, so
    the source may resume donated steps immediately after."""
    table = list(table)
    assert PAD_BLOCK not in table, "pad block in a sequence block table"
    if not table:
        return [], dst_pool
    if dst_table is None:
        dst_table = reserve_blocks(dst_alloc, len(table))
    else:
        dst_table = list(dst_table)
        assert len(dst_table) == len(table)
    stage = gather_pool_blocks(src_pool, table)
    stage = jax.block_until_ready(stage)
    dst_pool = scatter_pool_blocks(dst_pool, stage, dst_table)
    for b in table:
        src_alloc.decref(b)
    return dst_table, dst_pool


# ---------------------------------------------------------------------------
# Occupancy accounting (engine-pool load routing)

def bytes_per_token(cfg: ModelConfig, chunk: int = 256) -> int:
    """Marginal KV bytes per resident token, amortized over a reference
    window (sliding-window / recurrent layers make the true cost
    sub-linear; a 1k-token reference captures the steady state)."""
    ref = 1024
    return max(1, cache_bytes(cfg, 1, ref, chunk) // ref)


class OccupancyMeter:
    """Per-replica ledger of resident sequence tokens and decode slots.

    Engines advance the token ledger on prefill/decode and clear entries
    on release; the pool router reads ``tokens()`` as the KV-occupancy
    component of a replica's load. Under run-to-completion decode the
    ledger advances once per batch (``advance(sid, max_new)`` up front);
    under continuous batching it advances PER ITERATION (one token per
    resident sequence per step), so occupancy tracks what is actually
    written to the KV cache.

    ``decode_slots`` adds ADMITTED-slot introspection for the continuous
    decode loop: the loop acquires a slot at admission and releases it at
    eviction, so ``slots_used()`` reports which sequences are actively
    stepping. Note the pool's slot-aware decode router consults the
    loop's own ``decode_slots_free()`` (which also counts sequences
    WAITING for a slot), not this meter.

    When bound to a ``BlockAllocator`` (paged engines), ``tokens()`` and
    ``bytes()`` report ALLOCATED BLOCKS — the true memory footprint,
    counting a shared prefix once and quantizing at block granularity —
    instead of the per-sid amortized token ledger. The per-sid ledger is
    still maintained for ``seqs()`` and slot introspection."""

    def __init__(self, bytes_per_tok: int = 0, decode_slots: int = 0, *,
                 allocator: "BlockAllocator" = None, block_size: int = 0,
                 block_bytes: int = 0):
        self.bytes_per_tok = bytes_per_tok
        self.decode_slots = decode_slots
        self.allocator = allocator
        self.block_size = block_size
        self.block_bytes = block_bytes
        self._tokens: Dict[str, int] = {}
        self._slot_sids: set = set()
        self._lock = threading.Lock()

    def advance(self, sid: str, n: int):
        with self._lock:
            self._tokens[sid] = self._tokens.get(sid, 0) + int(n)

    def release(self, sid: str):
        with self._lock:
            self._tokens.pop(sid, None)

    def tokens(self) -> int:
        if self.allocator is not None:
            return self.allocator.used_blocks() * self.block_size
        with self._lock:
            return sum(self._tokens.values())

    def bytes(self) -> int:
        if self.allocator is not None:
            return self.allocator.used_blocks() * self.block_bytes
        return self.tokens() * self.bytes_per_tok

    def blocks(self) -> int:
        """Allocated pool blocks (0 when not block-bound)."""
        return 0 if self.allocator is None else self.allocator.used_blocks()

    def seqs(self) -> int:
        with self._lock:
            return len(self._tokens)

    # -- decode-slot accounting (continuous batching) ----------------------
    def acquire_slot(self, sid: str):
        with self._lock:
            self._slot_sids.add(sid)

    def release_slot(self, sid: str):
        with self._lock:
            self._slot_sids.discard(sid)

    def slots_used(self) -> int:
        with self._lock:
            return len(self._slot_sids)

    def slots_free(self) -> int:
        with self._lock:
            return max(0, self.decode_slots - len(self._slot_sids))


# ---------------------------------------------------------------------------
# Ring-buffer position bookkeeping (sliding-window layers)

def batch_pos(pos, batch: int):
    """Normalize pos (python int / scalar / (B,) vector) to (B,) int32 —
    per-sequence positions enable continuous batching in the engines."""
    pos = jnp.asarray(pos, jnp.int32)
    return jnp.broadcast_to(pos, (batch,))


def write_linear(buf, chunk, pos):
    """buf (B,T,...), chunk (B,S,...), write at [pos_b, pos_b+S) per seq."""
    pos = batch_pos(pos, buf.shape[0])

    def one(b, c, p):
        start = (p,) + (0,) * (b.ndim - 1)
        return jax.lax.dynamic_update_slice(b, c.astype(b.dtype), start)

    return jax.vmap(one)(buf, chunk, pos)


def write_ring(buf, chunk, pos):
    """Ring-buffer write: absolute positions pos_b..pos_b+S-1 land at
    (pos_b+i) % W. Used by sliding-window layers."""
    W = buf.shape[1]
    S = chunk.shape[1]
    pos = batch_pos(pos, buf.shape[0])
    idx = (pos[:, None] + jnp.arange(S)[None, :]) % W     # (B,S)

    def one(b, c, ix):
        return b.at[ix].set(c.astype(b.dtype))

    return jax.vmap(one)(buf, chunk, idx)


def slot_positions_linear(T, length):
    """Absolute position held by each slot of a linear cache of size T given
    per-seq total length (B,); -1 for unwritten slots. Returns (B,T)."""
    slot = jnp.arange(T)[None, :]
    return jnp.where(slot < length[:, None], slot, -1)


def slot_positions_ring(W, length):
    """Absolute position held by each ring slot; -1 if unwritten.
    Slot i holds the largest p < length_b with p % W == i. Returns (B,W)."""
    i = jnp.arange(W)[None, :]
    L = length[:, None]
    p = (L - 1) - ((L - 1 - i) % W)
    return jnp.where((p >= 0) & (L > 0), p, -1)
