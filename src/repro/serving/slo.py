"""SLO-aware multi-tenant scheduling policy (Teola §7.2).

Module-level LLM servers see an undifferentiated token stream; the
orchestration layer KNOWS which requests sit on an interactive user's
critical path and which belong to a throughput-bound batch tenant
(PAPER §7.2 sketches exactly this application-supplied priority).  This
module is that knowledge turned into a scheduling policy — a small,
engine-agnostic object (`SLOPolicy`) that the continuous decode loop and
the engines consult at their existing decision points:

  * **priority admission** — waiting decode sequences and chunked-
    prefill jobs are ranked ``(class, -priority, -depth, arrival)``
    instead of FIFO.  ``interactive`` (TTFT/TBT-bound) ranks ahead of
    ``batch`` (throughput-bound); within a class the legacy
    ``QueryContext.priority`` knob orders (so the one knob now governs
    BOTH the legacy ``form_batch`` path and the continuous path);
    e-graph critical-path ``depth`` breaks ties so a query's downstream
    LLM ops inherit urgency.  An **aging bound** promotes a batch item
    to interactive rank after ``aging_s`` seconds so batch never
    starves.

  * **per-tenant fair share** — a `FairShareLedger` computes a weighted
    max-min allocation of decode slots / KV blocks over tenants with
    live demand.  Work-conserving by construction: a tenant may exceed
    its share whenever no OTHER tenant has unmet demand.

  * **paged preemption** — under pressure (an urgent waiter deferred
    while batch sequences are resident) the policy nominates a batch
    victim for evict-to-recompute: the engine frees its KV (paged:
    ``trim_table`` to position 0; dense: drop the per-seq cache), the
    loop re-queues the sequence, and on re-admission the engine rebuilds
    KV by re-prefilling ``prompt + emitted`` — causal attention over the
    same tokens is the same computation, so the continuation is
    token-identical to the unpreempted run (the same argument as PR-8's
    ``recover_decode`` teacher forcing).  A cooldown plus a per-sequence
    preemption cap provide hysteresis so preemption cannot thrash.

Everything is flag-gated: engines without an attached policy
(``engine.slo is None``) run the exact pre-existing FIFO code paths,
byte-identical.
"""
from __future__ import annotations

import threading
import time
from typing import Dict, Iterable, List, Optional

INTERACTIVE = "interactive"
BATCH = "batch"


class SLOTag:
    """Per-request scheduling metadata threaded from ``Runtime.submit``
    down to the engine's ``DecodeSeq`` / ``PrefillJob``.

    ``cls`` is the SLO class (`interactive` / `batch`), ``priority`` the
    legacy application priority knob (higher = sooner), ``tenant`` the
    isolation/accounting domain, ``depth`` the primitive's e-graph
    critical-path depth (more downstream work = more urgency),
    ``t_submit`` the query submit time (aging + TTFT baseline) and
    ``deadline`` the absolute query deadline stamped by the overload
    layer (None = no deadline — the pre-overload behavior).
    """

    __slots__ = ("cls", "priority", "tenant", "depth", "t_submit",
                 "deadline")

    def __init__(self, cls: str = BATCH, priority: int = 0,
                 tenant: str = "default", depth: int = 0,
                 t_submit: Optional[float] = None,
                 deadline: Optional[float] = None):
        if cls not in (INTERACTIVE, BATCH):
            raise ValueError(f"unknown SLO class {cls!r} "
                             f"(expected {INTERACTIVE!r} or {BATCH!r})")
        self.cls = cls
        self.priority = int(priority)
        self.tenant = str(tenant)
        self.depth = int(depth)
        self.t_submit = float(t_submit) if t_submit is not None \
            else time.time()
        self.deadline = float(deadline) if deadline is not None else None

    def __repr__(self):
        return (f"<SLOTag {self.cls} tenant={self.tenant} "
                f"prio={self.priority} depth={self.depth}>")


def derive_tag(*, slo: Optional[str] = None, priority: int = 0,
               tenant: str = "default", depth: int = 0,
               t_submit: Optional[float] = None,
               deadline: Optional[float] = None) -> SLOTag:
    """Build a tag from request metadata.  When no explicit SLO class is
    given the legacy ``priority`` knob decides: any positive priority
    means a user is waiting on it (interactive); priority 0 is
    throughput work (batch).  This is the satellite fix for the latent
    priority gap — the knob that already orders legacy ``form_batch``
    now also orders the continuous path, through the same tag."""
    cls = slo if slo is not None else \
        (INTERACTIVE if priority > 0 else BATCH)
    return SLOTag(cls=cls, priority=priority, tenant=tenant, depth=depth,
                  t_submit=t_submit, deadline=deadline)


# --------------------------------------------------------------------------
# fair share
# --------------------------------------------------------------------------
class FairShareLedger:
    """Weighted max-min fair allocator over tenants with live demand.

    ``shares(demand)`` is a pure function of the demand map: it fills
    one unit at a time, always to the unsatisfied tenant with the
    smallest ``(allocated + 1) / weight`` ratio (ties broken by tenant
    name for determinism) — weighted round-robin, the classic
    progressive-filling realization of weighted max-min fairness.  With
    equal weights this is EXACTLY the integer leximin optimum (tested
    against a brute-force oracle in ``tests/test_slo_sched.py``);
    weights skew the fill rate proportionally.  The stateful
    part (``acquire`` / ``release``) tracks what each tenant currently
    HOLDS so admission checks can compare holdings against shares.

    ``may_take`` is work-conserving: when no other tenant has unmet
    demand (demand above its holdings) the requesting tenant may take
    capacity freely — fairness never idles the machine.
    """

    def __init__(self, capacity: int,
                 weights: Optional[Dict[str, float]] = None):
        self.capacity = max(0, int(capacity))
        self.weights = dict(weights or {})
        self.usage: Dict[str, int] = {}
        self._lock = threading.Lock()

    def weight(self, tenant: str) -> float:
        w = float(self.weights.get(tenant, 1.0))
        return w if w > 0 else 1.0

    # -- stateful holdings -------------------------------------------------
    def acquire(self, tenant: str, n: int = 1):
        with self._lock:
            self.usage[tenant] = self.usage.get(tenant, 0) + int(n)

    def release(self, tenant: str, n: int = 1):
        with self._lock:
            left = self.usage.get(tenant, 0) - int(n)
            if left > 0:
                self.usage[tenant] = left
            else:
                self.usage.pop(tenant, None)

    def usage_of(self, tenant: str) -> int:
        with self._lock:
            return self.usage.get(tenant, 0)

    def snapshot(self) -> Dict[str, int]:
        with self._lock:
            return dict(self.usage)

    # -- pure allocation ---------------------------------------------------
    def shares(self, demand: Dict[str, int]) -> Dict[str, int]:
        """Weighted max-min shares for the given demand map (units)."""
        want = {t: int(d) for t, d in demand.items() if d > 0}
        share = {t: 0 for t in want}
        if not want or self.capacity <= 0:
            return share
        left = self.capacity
        unsat = sorted(want)
        while left > 0 and unsat:
            # progressive filling: one unit to the tenant whose next
            # unit costs the least weighted share
            t = min(unsat, key=lambda u: ((share[u] + 1) / self.weight(u),
                                          u))
            share[t] += 1
            left -= 1
            if share[t] >= want[t]:
                unsat.remove(t)
        return share

    def may_take(self, tenant: str, n: int = 1,
                 demand: Optional[Dict[str, int]] = None) -> bool:
        """Would granting ``tenant`` ``n`` more units respect its
        weighted max-min share under ``demand``?  Work-conserving: always
        True when no other tenant wants more than it holds."""
        n = int(n)
        with self._lock:
            held = self.usage.get(tenant, 0)
            d = {t: int(v) for t, v in (demand or {}).items()}
            d[tenant] = max(d.get(tenant, 0), held + n)
            others_unmet = any(
                t != tenant and v > self.usage.get(t, 0)
                for t, v in d.items())
        if not others_unmet:
            return True
        return held + n <= self.shares(d).get(tenant, 0)


# --------------------------------------------------------------------------
# per-tenant / per-class stats
# --------------------------------------------------------------------------
def _pct(xs: List[float], q: float) -> float:
    if not xs:
        return 0.0
    ys = sorted(xs)
    i = min(len(ys) - 1, max(0, int(round(q * (len(ys) - 1)))))
    return ys[i]


class TenantStats:
    """Counters + latency samples keyed by ``(tenant, cls)``."""

    FIELDS = ("submitted", "admitted", "preempted", "evicted", "done")

    def __init__(self):
        self._lock = threading.Lock()
        self._counts: Dict[tuple, Dict[str, int]] = {}
        self._ttft: Dict[tuple, List[float]] = {}
        self._tbt: Dict[tuple, List[float]] = {}

    def _key(self, tag: SLOTag) -> tuple:
        return (tag.tenant, tag.cls)

    def bump(self, tag: SLOTag, field: str, n: int = 1):
        with self._lock:
            row = self._counts.setdefault(
                self._key(tag), {f: 0 for f in self.FIELDS})
            row[field] = row.get(field, 0) + n

    def note_ttft(self, tag: SLOTag, dt: float):
        with self._lock:
            self._ttft.setdefault(self._key(tag), []).append(float(dt))

    def note_tbt(self, tag: SLOTag, dt: float):
        with self._lock:
            self._tbt.setdefault(self._key(tag), []).append(float(dt))

    def snapshot(self) -> Dict[str, dict]:
        with self._lock:
            keys = set(self._counts) | set(self._ttft) | set(self._tbt)
            out = {}
            for k in sorted(keys):
                row = dict(self._counts.get(
                    k, {f: 0 for f in self.FIELDS}))
                ttft, tbt = self._ttft.get(k, []), self._tbt.get(k, [])
                row["ttft_p50_ms"] = round(_pct(ttft, 0.50) * 1e3, 3)
                row["ttft_p99_ms"] = round(_pct(ttft, 0.99) * 1e3, 3)
                row["tbt_p50_ms"] = round(_pct(tbt, 0.50) * 1e3, 3)
                row["tbt_p99_ms"] = round(_pct(tbt, 0.99) * 1e3, 3)
                out[f"{k[0]}/{k[1]}"] = row
            return out

    def merge_into(self, out: Dict[str, dict]):
        """Accumulate this replica's snapshot into a pool-level dict."""
        for key, row in self.snapshot().items():
            dst = out.setdefault(key, {})
            for f, v in row.items():
                if f.endswith("_ms"):
                    # percentiles do not sum; keep the max across
                    # replicas (a conservative pool-level tail bound)
                    dst[f] = max(dst.get(f, 0.0), v)
                else:
                    dst[f] = dst.get(f, 0) + v
        return out


# --------------------------------------------------------------------------
# the policy object engines / loops consult
# --------------------------------------------------------------------------
class SLOPolicy:
    """Per-replica scheduling policy: ranking, fair share, preemption.

    Attached to an engine as ``engine.slo`` by :func:`attach_slo`; the
    continuous decode loop and the engine's ``try_admit`` consult it.
    ``slots`` / ``blocks`` are the replica's decode-slot and KV-block
    capacities (0 disables that ledger — e.g. dense engines have no
    block pool)."""

    def __init__(self, *, slots: int = 0, blocks: int = 0,
                 weights: Optional[Dict[str, float]] = None,
                 aging_s: float = 5.0, preempt_cooldown_s: float = 0.25,
                 max_preempts_per_seq: int = 2,
                 deadline_slack_s: float = 1.0):
        self.slots = FairShareLedger(slots, weights) if slots else None
        self.blocks = FairShareLedger(blocks, weights) if blocks else None
        self.aging_s = float(aging_s)
        self.deadline_slack_s = float(deadline_slack_s)
        self.preempt_cooldown_s = float(preempt_cooldown_s)
        self.max_preempts_per_seq = int(max_preempts_per_seq)
        self.stats = TenantStats()
        self._lock = threading.Lock()
        self._t_last_preempt = 0.0
        self._preempt_counts: Dict[str, int] = {}
        # tenants with unmet demand at the loop's last admission pass —
        # the engine-side block-share check uses this as the demand set
        self.live_tenants: frozenset = frozenset()

    # -- tagging / ranking -------------------------------------------------
    def tag_of(self, obj) -> SLOTag:
        """The object's SLO tag; untagged work gets a default batch tag
        stamped with its own submit time (so it still ages)."""
        tag = getattr(obj, "slo", None)
        if tag is None:
            tag = SLOTag(cls=BATCH, t_submit=getattr(
                obj, "t_submit", time.time()))
            try:
                obj.slo = tag
            except Exception:  # noqa: BLE001 — unsettable obj: tag anew
                pass
        return tag

    def is_urgent(self, obj, now: Optional[float] = None) -> bool:
        """Interactive class, batch promoted by the aging bound, or ANY
        class whose unified query deadline (overload layer) is within
        ``deadline_slack_s`` of expiring — urgency and the FT watchdog
        now read the same clock."""
        tag = self.tag_of(obj)
        now = time.time() if now is None else now
        if tag.cls == INTERACTIVE or \
                (self.aging_s > 0 and now - tag.t_submit >= self.aging_s):
            return True
        dl = getattr(tag, "deadline", None)
        return dl is not None and dl - now <= self.deadline_slack_s

    def rank_key(self, obj, now: Optional[float] = None) -> tuple:
        tag = self.tag_of(obj)
        now = time.time() if now is None else now
        return (0 if self.is_urgent(obj, now) else 1,
                -tag.priority, -tag.depth, tag.t_submit)

    def admission_order(self, waiting: Iterable, now: Optional[float]
                        = None) -> list:
        now = time.time() if now is None else now
        return sorted(waiting, key=lambda s: self.rank_key(s, now))

    # -- fair share --------------------------------------------------------
    def slot_demand(self, waiting: Iterable, active: Iterable) \
            -> Dict[str, int]:
        """Per-tenant decode-slot demand: resident + queued."""
        d: Dict[str, int] = {}
        for seq in list(waiting) + list(active):
            t = self.tag_of(seq).tenant
            d[t] = d.get(t, 0) + 1
        return d

    def may_take_slot(self, tag: SLOTag,
                      demand: Dict[str, int]) -> bool:
        if self.slots is None:
            return True
        if self.slots.usage_of(tag.tenant) == 0:
            # progress guarantee: integer shares can round a tenant to
            # ZERO when capacity < live tenants — a tenant holding
            # nothing may always take one free slot (off-by-one-unit
            # from exact max-min, and what keeps a preempted-for tenant
            # from losing the freed slot back to the victim's tenant)
            return True
        return self.slots.may_take(tag.tenant, 1, demand)

    def may_take_blocks(self, tenant: str, n: int) -> bool:
        """Engine-side KV-block share check (called from ``try_admit``).
        Demand set = tenants the loop saw with unmet demand last pass;
        each is assumed able to use its full share (prompt sizes are
        unknown ahead of admission), which degrades to weighted
        proportional shares — still max-min for the saturated case."""
        if self.blocks is None:
            return True
        if self.blocks.usage_of(tenant) == 0:
            # same progress guarantee as slots: a tenant holding no
            # blocks may always admit ONE sequence's worth (its share
            # could otherwise round below a single sequence's need and
            # wedge that tenant out entirely)
            return True
        demand = {t: self.blocks.capacity
                  for t in set(self.live_tenants) | {tenant}}
        return self.blocks.may_take(tenant, n, demand)

    def note_live(self, tenants: Iterable[str]):
        self.live_tenants = frozenset(tenants)

    # -- admission / eviction bookkeeping ---------------------------------
    def note_admit(self, seq):
        tag = self.tag_of(seq)
        if self.slots is not None:
            self.slots.acquire(tag.tenant, 1)
        seq._slo_slot_held = True
        self.stats.bump(tag, "admitted")

    def _drop_slot(self, seq, tag: SLOTag):
        # the held-flag (not t_admit) guards the release: a preempted
        # sequence keeps its t_admit but no longer holds a slot
        if self.slots is not None and getattr(seq, "_slo_slot_held",
                                              False):
            self.slots.release(tag.tenant, 1)
        seq._slo_slot_held = False

    def note_evict(self, seq, failed: bool = False):
        tag = self.tag_of(seq)
        self._drop_slot(seq, tag)
        self.stats.bump(tag, "evicted")
        if not failed:
            self.stats.bump(tag, "done")

    def note_tokens(self, seq, now: Optional[float] = None):
        """Per-pass latency sampling: first token → TTFT from the tag's
        submit time; subsequent tokens → TBT from the previous pass."""
        tag = self.tag_of(seq)
        now = time.time() if now is None else now
        last = getattr(seq, "_slo_t_last", None)
        if last is None:
            self.stats.note_ttft(tag, now - tag.t_submit)
        else:
            self.stats.note_tbt(tag, now - last)
        seq._slo_t_last = now

    # -- preemption governor ----------------------------------------------
    def plan_preemption(self, active: Iterable, now: Optional[float]
                        = None) -> list:
        """Nominate at most ONE batch victim for evict-to-recompute.
        Hysteresis: a cooldown between preemptions plus a per-sequence
        preemption cap — a sequence preempted ``max_preempts_per_seq``
        times runs to completion, so pressure cannot thrash the same
        work forever.  Victim choice: the non-urgent resident with the
        fewest emitted tokens (cheapest replay), ties to the most
        recently admitted (LIFO — longest-resident work is safest)."""
        now = time.time() if now is None else now
        with self._lock:
            if now - self._t_last_preempt < self.preempt_cooldown_s:
                return []
            cands = [s for s in active
                     if not self.is_urgent(s, now)
                     and self._preempt_counts.get(s.sid, 0)
                     < self.max_preempts_per_seq]
            if not cands:
                return []
            victim = min(cands, key=lambda s: (s.steps,
                                               -(s.t_admit or 0.0)))
            self._t_last_preempt = now
            self._preempt_counts[victim.sid] = \
                self._preempt_counts.get(victim.sid, 0) + 1
        return [victim]

    def note_preempted(self, seq):
        tag = self.tag_of(seq)
        self._drop_slot(seq, tag)
        self.stats.bump(tag, "preempted")

    # -- reporting ---------------------------------------------------------
    def tenant_stats(self) -> Dict[str, dict]:
        out = self.stats.snapshot()
        if self.blocks is not None:
            held = self.blocks.snapshot()
            for key in out:
                out[key]["kv_blocks_held"] = held.get(
                    key.split("/", 1)[0], 0)
        return out


# --------------------------------------------------------------------------
# wiring
# --------------------------------------------------------------------------
def _decode_replicas(obj) -> list:
    """Expand an engine-or-pool into its decode-capable replicas."""
    reps = getattr(obj, "replicas", None)
    if reps is None:
        reps = list(obj) if isinstance(obj, list) else [obj]
    return [r for r in reps if hasattr(r, "submit_decode")
            and hasattr(r, "max_batch")]


def attach_slo(engines, *, weights: Optional[Dict[str, float]] = None,
               aging_s: float = 5.0, preempt_cooldown_s: float = 0.25,
               max_preempts_per_seq: int = 2,
               deadline_slack_s: float = 1.0) -> list:
    """Arm SLO scheduling on every decode-capable replica in ``engines``
    (a name→engine/pool mapping, as built by ``apps.build_engines`` /
    ``build_sim_engines``).  Each replica gets its OWN policy — slot and
    block ledgers are per-replica resources.  Returns the policies."""
    policies = []
    seen = set()
    for obj in engines.values():
        for rep in _decode_replicas(obj):
            if id(rep) in seen:
                continue
            seen.add(id(rep))
            blocks = int(getattr(rep, "num_blocks", 0) or 0) \
                if getattr(rep, "paged", False) else 0
            pol = SLOPolicy(
                slots=int(getattr(rep, "max_batch", 0) or 0),
                blocks=blocks, weights=weights, aging_s=aging_s,
                preempt_cooldown_s=preempt_cooldown_s,
                max_preempts_per_seq=max_preempts_per_seq,
                deadline_slack_s=deadline_slack_s)
            rep.slo = pol
            policies.append(pol)
    return policies


def pool_tenant_stats(engines) -> Dict[str, dict]:
    """Merge per-replica tenant stats across a name→engine/pool mapping
    (counts sum; latency percentiles keep the per-replica max)."""
    out: Dict[str, dict] = {}
    for obj in engines.values():
        fn = getattr(obj, "tenant_stats", None)
        if fn is None:
            continue
        for key, row in fn().items():
            dst = out.setdefault(key, {})
            for f, v in row.items():
                if f.endswith("_ms"):
                    dst[f] = max(dst.get(f, 0.0), v)
                else:
                    dst[f] = dst.get(f, 0) + v
    return out
