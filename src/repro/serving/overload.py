"""Overload control & graceful degradation for the Teola runtime.

When offered load exceeds capacity, per-module servers can only time out
whole queries. With the e-graph in hand the orchestrator can do better —
this module implements four cooperating mechanisms, all flag-gated and
byte-identical to the unarmed runtime when idle:

1. **Deadline propagation** — a single per-query deadline (unifying the
   fault-tolerance ``request_deadline`` watchdog and ``SLOTag`` urgency)
   is decomposed along the e-graph into per-primitive latest-finish
   budgets using the same critical-path structure ``passes.py`` already
   computes, so every dispatched task knows its slack.
2. **Admission control / load shedding** — a front-door controller
   estimates pool queue delay from ``EnginePool`` load signals plus its
   own in-flight ledger and rejects new queries with a structured
   :class:`Overloaded` error before they consume capacity. The
   interactive class is protected by a configurable headroom factor.
3. **Hedged dispatch** — for idempotent non-LLM primitives (embed,
   rerank, search) the pooled scheduler issues a backup request to a
   second healthy replica after a latency-percentile trigger;
   first-result-wins, the loser is discarded, and a hedge failure is
   never double-counted as a replica failure.
4. **Degraded-mode execution** — per-node degradation annotations
   (skippable rerank, shrinkable ``top_k``, shrinkable ``max_new``,
   prefill chunk caps) are activated stepwise by a brown-out ladder with
   hysteresis whenever measured slack goes negative, with per-query
   attribution in stats.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple

from repro.serving.faults import RequestError
from repro.serving.slo import BATCH, INTERACTIVE


class Overloaded(RequestError):
    """Structured front-door rejection: the query was shed at admission
    because the estimated queue delay exceeded its slack."""

    def __init__(self, msg: str, *, qid: str = "", cls: str = BATCH,
                 outstanding: float = 0.0,
                 est_delay_s: Optional[float] = None):
        super().__init__(msg, qid=qid, reason="overloaded")
        self.cls = cls
        self.outstanding = outstanding
        self.est_delay_s = est_delay_s


def query_class(slo: Optional[str], priority: int) -> str:
    """Same class derivation as ``slo.derive_tag`` (kept in sync)."""
    if slo is not None:
        return slo
    return INTERACTIVE if priority > 0 else BATCH


# Idempotent, sequence-state-free primitive ops that are safe to hedge:
# running them twice produces identical store writes.
HEDGEABLE_OPS = ("Embedding", "Reranking", "Searching", "SearchAPI")


@dataclass
class OverloadConfig:
    """Knobs for the overload-control layer. Every mechanism is off (or
    inert) by default; arming with defaults and zero pressure must be
    token-identical to running without the layer."""
    # -- deadlines (seconds of slack granted at submit; None = no deadline)
    deadline_s: Optional[float] = None
    interactive_deadline_s: Optional[float] = None   # falls back to deadline_s
    batch_deadline_s: Optional[float] = None         # falls back to deadline_s
    # -- admission control / shedding
    shed: bool = False
    max_queue_tokens: float = 4096.0   # shed batch class beyond this backlog
    interactive_factor: float = 3.0    # interactive headroom multiplier
    ewma_alpha: float = 0.2            # service-rate smoothing
    # -- hedged dispatch
    hedge: bool = False
    hedge_after_s: Optional[float] = None  # fixed trigger (deterministic tests)
    hedge_quantile: float = 0.95           # else: latency percentile trigger
    hedge_min_samples: int = 16            # samples before percentile arms
    # -- degradation ladder
    degrade: bool = False
    degrade_after: int = 2     # consecutive negative-slack samples per step up
    recover_after: int = 4     # consecutive positive-slack samples per step down
    cooldown_s: float = 0.5    # min seconds between ladder moves (hysteresis)
    max_level: int = 3


def decompose_deadline(graph) -> Dict[str, float]:
    """Per-primitive latest-finish fractions along the e-graph.

    For each primitive ``p`` let ``cost(p)`` be its estimated token work
    and ``D(p)`` the downstream critical cost — the heaviest
    ``cost + D`` over its children. With ``T`` the total critical-path
    cost, primitive ``p`` must finish by fraction ``(T - D(p)) / T`` of
    the query's total slack for the critical path to stay on schedule.
    Sinks map to 1.0; earlier primitives to proportionally smaller
    fractions. Returns ``{pid: fraction in (0, 1]}``.
    """
    from repro.core.engine_pool import estimate_tokens

    nodes = graph.nodes
    cost = {pid: float(max(1, estimate_tokens(n))) for pid, n in nodes.items()}
    down: Dict[str, float] = {}
    for n in reversed(graph.topo_order()):           # children before parents
        d = 0.0
        for cpid in n.children:
            d = max(d, cost[cpid] + down[cpid])
        down[n.pid] = d
    total = max((cost[pid] + down[pid] for pid in nodes), default=0.0)
    if total <= 0.0:
        return {pid: 1.0 for pid in nodes}
    return {pid: (total - down[pid]) / total for pid in nodes}


def query_token_estimate(graph) -> float:
    """Total estimated token work of a query's e-graph (admission ledger
    unit; control-flow primitives are free)."""
    from repro.core.engine_pool import estimate_tokens
    from repro.core.primitives import CONTROL_OPS

    return float(sum(estimate_tokens(n) for n in graph.nodes.values()
                     if n.op not in CONTROL_OPS))


class AdmissionController:
    """Front-door load shedding.

    The backlog signal is the max of (a) the controller's own in-flight
    token ledger (admitted queries not yet done) and (b) the registered
    ``EnginePool`` load signals (queued + in-flight + discounted-resident
    tokens). A batch-class query is shed when the backlog exceeds
    ``max_queue_tokens`` — or, once a service rate has been observed and
    the query carries a deadline, when the estimated queue delay exceeds
    its slack. Interactive queries get ``interactive_factor`` times the
    headroom; a query whose deadline is already unmeetable is shed
    regardless of class.
    """

    def __init__(self, cfg: OverloadConfig):
        self.cfg = cfg
        self.pools: List[Any] = []
        self._live: List[Tuple[Any, float]] = []     # (ctx, tokens)
        self._rate: Optional[float] = None           # tokens / second
        self._lock = threading.Lock()
        self.counts = {INTERACTIVE: {"admitted": 0, "shed": 0},
                       BATCH: {"admitted": 0, "shed": 0}}

    def register_pool(self, pool) -> None:
        with self._lock:
            if pool not in self.pools:
                self.pools.append(pool)

    # -- signals ----------------------------------------------------------
    def outstanding_tokens(self) -> float:
        with self._lock:
            self._live = [(c, t) for (c, t) in self._live
                          if not c.done.is_set()]
            own = sum(t for _, t in self._live)
            pools = list(self.pools)
        sig = own
        for p in pools:
            try:
                sig = max(sig, p.outstanding_tokens())
            except Exception:  # noqa: BLE001 - a dying pool never blocks admit
                pass
        return float(sig)

    def note_done(self, tokens: float, elapsed_s: float) -> None:
        """Feed one completed query into the EWMA service-rate estimate."""
        if elapsed_s <= 0 or tokens <= 0:
            return
        inst = tokens / elapsed_s
        with self._lock:
            a = self.cfg.ewma_alpha
            self._rate = inst if self._rate is None else (
                a * inst + (1.0 - a) * self._rate)

    @property
    def service_rate(self) -> Optional[float]:
        return self._rate

    def queue_delay_s(self) -> Optional[float]:
        r = self._rate
        if not r:
            return None
        return self.outstanding_tokens() / r

    # -- decisions --------------------------------------------------------
    def decide(self, cls: str, slack_s: Optional[float] = None,
               ) -> Tuple[bool, float, Optional[float]]:
        """Returns ``(admit, outstanding_tokens, est_delay_s)``."""
        out = self.outstanding_tokens()
        rate = self._rate
        delay = (out / rate) if rate else None
        if slack_s is not None and slack_s <= 0.0:
            return False, out, delay   # unmeetable deadline: any class
        allow = self.cfg.max_queue_tokens
        if rate and slack_s is not None:
            # a tight deadline sheds earlier than the static threshold
            allow = min(allow, rate * slack_s)
        if cls == INTERACTIVE:
            allow *= self.cfg.interactive_factor
        return out <= allow, out, delay

    def admit(self, ctx, cls: str, tokens: float,
              slack_s: Optional[float] = None) -> Optional[Overloaded]:
        """Admit (ledger the query, return None) or shed (return the
        structured error without touching the ledger)."""
        if not self.cfg.shed:
            with self._lock:
                self._live.append((ctx, tokens))
                self.counts[cls]["admitted"] += 1
            return None
        ok, out, delay = self.decide(cls, slack_s)
        with self._lock:
            if ok:
                self._live.append((ctx, tokens))
                self.counts[cls]["admitted"] += 1
                return None
            self.counts[cls]["shed"] += 1
        d = f", est delay {delay:.2f}s" if delay is not None else ""
        return Overloaded(
            f"query {ctx.qid} shed at admission: {out:.0f} tokens "
            f"outstanding{d}", qid=ctx.qid, cls=cls, outstanding=out,
            est_delay_s=delay)

    def snapshot(self) -> Dict[str, Any]:
        with self._lock:
            snap = {c: dict(v) for c, v in self.counts.items()}
            snap["service_rate_tps"] = self._rate
        return snap


class HedgePolicy:
    """Latency tracker + trigger + counters for hedged dispatch."""

    def __init__(self, cfg: OverloadConfig):
        self.cfg = cfg
        self._lat: Dict[str, deque] = {}
        self._lock = threading.Lock()
        self.counts = {"issued": 0, "wins": 0, "losses": 0,
                       "rescues": 0, "backup_failures": 0}

    def note_latency(self, op: str, dt: float) -> None:
        with self._lock:
            self._lat.setdefault(op, deque(maxlen=256)).append(dt)

    def trigger_delay(self, op: str) -> Optional[float]:
        """Seconds to wait before issuing the backup, or None to not
        hedge. A fixed ``hedge_after_s`` takes precedence (deterministic
        schedules); otherwise the configured latency quantile, once
        enough samples exist."""
        if not self.cfg.hedge:
            return None
        if self.cfg.hedge_after_s is not None:
            return self.cfg.hedge_after_s
        with self._lock:
            lat = self._lat.get(op)
            if lat is None or len(lat) < self.cfg.hedge_min_samples:
                return None
            xs = sorted(lat)
        i = min(len(xs) - 1, int(self.cfg.hedge_quantile * len(xs)))
        return xs[i]

    def _bump(self, key: str) -> None:
        with self._lock:
            self.counts[key] += 1

    def note_issued(self) -> None:
        self._bump("issued")

    def note_win(self) -> None:
        self._bump("wins")

    def note_loss(self) -> None:
        self._bump("losses")

    def note_rescue(self) -> None:
        """Primary failed but the hedge completed the batch."""
        self._bump("rescues")

    def note_backup_failure(self) -> None:
        """Hedge failed; never counted against the replica or the task."""
        self._bump("backup_failures")

    def snapshot(self) -> Dict[str, int]:
        with self._lock:
            return dict(self.counts)


class DegradationPolicy:
    """Brown-out ladder with hysteresis.

    ``note_slack`` feeds measured per-primitive slack; ``degrade_after``
    consecutive negative samples step the ladder up one level,
    ``recover_after`` consecutive positive samples step it down, and no
    move happens within ``cooldown_s`` of the previous one. Level 0 is
    token-identical to the unarmed runtime.

    Ladder semantics (given a node's ``degrade`` annotation):
      L1  shrink ``top_k`` toward ``min_top_k`` (search / rerank)
      L2  skip a ``skippable`` rerank (unscored passthrough truncation)
      L3  halve decode ``max_new`` toward ``min_new``; cap prefill
          chunks at ``chunk_cap``
    """

    def __init__(self, cfg: OverloadConfig):
        self.cfg = cfg
        self.level = 0
        self._neg = 0
        self._pos = 0
        self._t_move = 0.0
        self._lock = threading.Lock()
        self.step_counts: Dict[str, int] = {}
        self._by_query: Dict[str, set] = {}

    def note_slack(self, slack_s: float, now: Optional[float] = None) -> int:
        """Feed one slack sample; returns the (possibly updated) level."""
        now = time.time() if now is None else now
        with self._lock:
            if slack_s < 0.0:
                self._neg += 1
                self._pos = 0
                if (self._neg >= self.cfg.degrade_after
                        and self.level < self.cfg.max_level
                        and now - self._t_move >= self.cfg.cooldown_s):
                    self.level += 1
                    self._neg = 0
                    self._t_move = now
            else:
                self._pos += 1
                self._neg = 0
                if (self._pos >= self.cfg.recover_after
                        and self.level > 0
                        and now - self._t_move >= self.cfg.cooldown_s):
                    self.level -= 1
                    self._pos = 0
                    self._t_move = now
            return self.level

    def plan(self, ann: Optional[Dict[str, Any]],
             config: Dict[str, Any],
             level: Optional[int] = None) -> Optional[Dict[str, Any]]:
        """Pure function: overrides for one primitive at one ladder level
        (None when nothing fires — the token-identical case)."""
        lvl = self.level if level is None else level
        if lvl <= 0 or not ann:
            return None
        out: Dict[str, Any] = {}
        if lvl >= 1 and "min_top_k" in ann and "top_k" in config:
            tk = int(config["top_k"])
            new = max(int(ann["min_top_k"]), (tk + 1) // 2)
            if new < tk:
                out["top_k"] = new
        if lvl >= 2 and ann.get("skippable"):
            out["skip"] = True
        if lvl >= 3:
            if "min_new" in ann and "max_new" in config:
                mn = int(config["max_new"])
                new = max(int(ann["min_new"]), mn // 2)
                if new < mn:
                    out["max_new"] = new
            if "chunk_cap" in ann:
                out["chunk_cap"] = int(ann["chunk_cap"])
        return out or None

    def attribute(self, qid: str, steps) -> None:
        """Per-query attribution: record which steps fired for ``qid``."""
        with self._lock:
            got = self._by_query.setdefault(qid, set())
            for s in steps:
                if s not in got:
                    got.add(s)
                    self.step_counts[s] = self.step_counts.get(s, 0) + 1

    def degraded_queries(self) -> Dict[str, set]:
        with self._lock:
            return {q: set(s) for q, s in self._by_query.items()}

    def snapshot(self) -> Dict[str, Any]:
        with self._lock:
            return {"level": self.level,
                    "steps": dict(self.step_counts),
                    "queries_degraded": len(self._by_query)}


class OverloadManager:
    """Bundles config + controllers; one instance per Runtime.

    The runtime stamps admitted queries (``ctx.deadline``,
    ``ctx.budget_frac``, ``ctx.overload``) so downstream layers — the
    executors' degradation hooks, the FT watchdog's unified deadline,
    the SLO urgency test — all read the same clock.
    """

    def __init__(self, cfg: Optional[OverloadConfig] = None):
        self.cfg = cfg or OverloadConfig()
        self.admission = AdmissionController(self.cfg)
        self.hedge = HedgePolicy(self.cfg)
        self.degrade = DegradationPolicy(self.cfg)

    # -- deadline propagation --------------------------------------------
    def deadline_for(self, cls: str) -> Optional[float]:
        if cls == INTERACTIVE and self.cfg.interactive_deadline_s is not None:
            return self.cfg.interactive_deadline_s
        if cls == BATCH and self.cfg.batch_deadline_s is not None:
            return self.cfg.batch_deadline_s
        return self.cfg.deadline_s

    def stamp(self, ctx, graph, cls: str) -> None:
        """Attach deadline + per-primitive budgets to an incoming query."""
        ctx.overload = self
        ctx.slo_cls = cls
        ctx.ov_tokens = query_token_estimate(graph)
        dl = self.deadline_for(cls)
        if dl is not None:
            ctx.deadline = ctx.t_submit + dl
            ctx.budget_frac = decompose_deadline(graph)

    def admit(self, ctx, cls: str) -> Optional[Overloaded]:
        slack = None
        if getattr(ctx, "deadline", None) is not None:
            slack = ctx.deadline - time.time()
        return self.admission.admit(ctx, cls, getattr(ctx, "ov_tokens", 0.0),
                                    slack)

    def task_slack(self, prim, ctx, now: Optional[float] = None,
                   ) -> Optional[float]:
        """Seconds until this primitive's latest-finish budget expires
        (negative = behind schedule), or None without a deadline."""
        dl = getattr(ctx, "deadline", None)
        if dl is None:
            return None
        frac = getattr(ctx, "budget_frac", {}).get(prim.pid, 1.0)
        node_dl = ctx.t_submit + (dl - ctx.t_submit) * frac
        return node_dl - (time.time() if now is None else now)

    # -- degradation hook (called from the executors, per primitive) -----
    def degrade_plan(self, prim, ctx) -> Optional[Dict[str, Any]]:
        if not self.cfg.degrade:
            return None
        slack = self.task_slack(prim, ctx)
        if slack is not None:
            self.degrade.note_slack(slack)
        ann = prim.config.get("degrade")
        plan = self.degrade.plan(ann, prim.config)
        if plan:
            steps = sorted(plan.keys())
            self.degrade.attribute(ctx.qid, steps)
            try:
                ctx.degraded_steps = (
                    getattr(ctx, "degraded_steps", set()) | set(steps))
            except Exception:  # noqa: BLE001
                pass
        return plan

    # -- completion feedback ---------------------------------------------
    def note_query_done(self, ctx) -> None:
        tokens = getattr(ctx, "ov_tokens", 0.0)
        if ctx.t_done is not None and tokens > 0:
            self.admission.note_done(tokens, ctx.t_done - ctx.t_submit)

    def snapshot(self) -> Dict[str, Any]:
        return {"admission": self.admission.snapshot(),
                "hedge": self.hedge.snapshot(),
                "degrade": self.degrade.snapshot()}
