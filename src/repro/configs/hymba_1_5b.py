"""Hymba-1.5B [arXiv:2411.13676].

Hybrid-head architecture: every layer runs attention heads and Mamba
(SSM) heads in PARALLEL on the same input, outputs normalized and fused.
32L, d_model=1600, 25 attention heads (GQA kv=5, head_dim=64), d_ff=5504,
vocab=32001, ssm_state=16. Hymba uses sliding-window attention on all but
three layers; we model all layers with a 2048-token window (simplification
recorded in DESIGN.md), which is what makes long_500k decode tractable.
"""
from repro.configs.base import (LayerSpec, ModelConfig, SSMConfig, Stage,
                                register)

CONFIG = register(ModelConfig(
    name="hymba-1.5b",
    family="hybrid",
    d_model=1600,
    num_heads=25,
    num_kv_heads=5,
    head_dim=64,
    d_ff=5504,
    vocab_size=32001,
    stages=(Stage(pattern=(LayerSpec(kind="hybrid", window=2048),),
                  repeat=32),),
    attention_kind="gqa",
    rope_kind="neox",
    rope_theta=10000.0,
    act="silu",
    ssm=SSMConfig(kind="mamba", state_dim=16, dt_rank=32, conv_dim=4),
    norm_eps=1e-5,
    citation="arXiv:2411.13676",
))
