"""InternVL2-26B language backbone (InternLM2-20B) [arXiv:2404.16821].

48L, d_model=6144, 48 heads (GQA kv=8), d_ff=16384, vocab=92553.
The InternViT-6B vision encoder + MLP projector is the modality frontend
and is stubbed: input_specs() provides precomputed patch embeddings
interleaved with text tokens (see DESIGN.md carve-out).
"""
from repro.configs.base import LayerSpec, ModelConfig, Stage, register

CONFIG = register(ModelConfig(
    name="internvl2-26b",
    family="vlm",
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=92553,
    stages=(Stage(pattern=(LayerSpec(kind="attn"),), repeat=48),),
    attention_kind="gqa",
    rope_kind="neox",
    rope_theta=1000000.0,
    act="silu",
    norm_eps=1e-5,
    embed_stub="vision",
    citation="arXiv:2404.16821",
))
