"""DeepSeek 67B [arXiv:2401.02954].

Llama-architecture dense model: 95L, d_model=8192, 64 heads (GQA kv=8),
d_ff=22016, vocab=102400.
"""
from repro.configs.base import LayerSpec, ModelConfig, Stage, register

CONFIG = register(ModelConfig(
    name="deepseek-67b",
    family="dense",
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=22016,
    vocab_size=102400,
    stages=(Stage(pattern=(LayerSpec(kind="attn"),), repeat=95),),
    attention_kind="gqa",
    rope_kind="neox",
    rope_theta=10000.0,
    act="silu",
    norm_eps=1e-6,
    citation="arXiv:2401.02954",
))
