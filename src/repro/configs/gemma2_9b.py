"""Gemma-2 9B [arXiv:2408.00118].

42L, d_model=3584, 16 heads (GQA kv=8), head_dim=256, d_ff=14336,
vocab=256000. Alternating local (4096-token sliding window) and global
attention layers; attention-logit softcap 50, final-logit softcap 30;
query scale 1/sqrt(query_pre_attn_scalar=256); sqrt(d) embedding scaling.
"""
from repro.configs.base import LayerSpec, ModelConfig, Stage, register

CONFIG = register(ModelConfig(
    name="gemma2-9b",
    family="dense",
    d_model=3584,
    num_heads=16,
    num_kv_heads=8,
    head_dim=256,
    d_ff=14336,
    vocab_size=256000,
    stages=(Stage(pattern=(LayerSpec(kind="attn", window=4096),
                           LayerSpec(kind="attn")), repeat=21),),
    attention_kind="gqa",
    rope_kind="neox",
    rope_theta=10000.0,
    attn_logit_softcap=50.0,
    final_logit_softcap=30.0,
    attn_scale=256 ** -0.5,
    embed_scale=True,
    act="gelu",
    tie_embeddings=True,
    citation="arXiv:2408.00118",
))
