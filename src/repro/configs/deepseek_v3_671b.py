"""DeepSeek-V3 671B [arXiv:2412.19437].

61L, d_model=7168, 128 heads with Multi-head Latent Attention (MLA:
q_lora 1536, kv_lora 512, nope 128 + rope 64 head dims, v 128),
vocab=129280. First 3 layers dense FFN (d_ff=18432); remaining 58 are MoE
with 1 shared + 256 routed experts (top-8), expert dim 2048.
MTP (multi-token prediction) is a training-objective add-on orthogonal to
the orchestration technique; see DESIGN.md §Arch-applicability.
"""
from repro.configs.base import (LayerSpec, MLAConfig, MoEConfig, ModelConfig,
                                Stage, register)

CONFIG = register(ModelConfig(
    name="deepseek-v3-671b",
    family="moe",
    d_model=7168,
    num_heads=128,
    num_kv_heads=128,
    head_dim=128,
    d_ff=18432,                    # dense layers; experts use moe.d_expert
    vocab_size=129280,
    stages=(
        Stage(pattern=(LayerSpec(kind="attn", moe=False),), repeat=3),
        Stage(pattern=(LayerSpec(kind="attn", moe=True),), repeat=58),
    ),
    attention_kind="mla",
    mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512,
                  qk_nope_head_dim=128, qk_rope_head_dim=64,
                  v_head_dim=128),
    moe=MoEConfig(num_experts=256, top_k=8, d_expert=2048,
                  num_shared_experts=1, d_shared=2048,
                  capacity_factor=1.25, norm_topk_prob=True),
    rope_kind="neox",
    rope_theta=10000.0,
    act="silu",
    citation="arXiv:2412.19437",
))
