"""Qwen1.5-MoE-A2.7B [hf:Qwen/Qwen1.5-MoE-A2.7B].

24L, d_model=2048, 16 heads (MHA: kv=16), vocab=151936. Every layer MoE:
60 routed experts top-4 (expert dim 1408) + 4 shared experts
(shared intermediate 5632 total). Gate probs not re-normalized after top-k.
"""
from repro.configs.base import (LayerSpec, MoEConfig, ModelConfig, Stage,
                                register)

CONFIG = register(ModelConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=5632,                     # shared-expert path width
    vocab_size=151936,
    stages=(Stage(pattern=(LayerSpec(kind="attn", moe=True),), repeat=24),),
    attention_kind="gqa",
    rope_kind="neox",
    rope_theta=1000000.0,
    qkv_bias=True,
    moe=MoEConfig(num_experts=60, top_k=4, d_expert=1408,
                  num_shared_experts=4, d_shared=5632,
                  capacity_factor=1.25, norm_topk_prob=False),
    act="silu",
    citation="hf:Qwen/Qwen1.5-MoE-A2.7B",
))
