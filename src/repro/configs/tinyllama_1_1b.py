"""TinyLlama 1.1B [arXiv:2401.02385].

Llama-2 architecture at small scale: 22L, d_model=2048, 32 heads
(GQA kv=4), d_ff=5632, vocab=32000.
"""
from repro.configs.base import LayerSpec, ModelConfig, Stage, register

CONFIG = register(ModelConfig(
    name="tinyllama-1.1b",
    family="dense",
    d_model=2048,
    num_heads=32,
    num_kv_heads=4,
    head_dim=64,
    d_ff=5632,
    vocab_size=32000,
    stages=(Stage(pattern=(LayerSpec(kind="attn"),), repeat=22),),
    attention_kind="gqa",
    rope_kind="neox",
    rope_theta=10000.0,
    act="silu",
    norm_eps=1e-5,
    citation="arXiv:2401.02385",
))
