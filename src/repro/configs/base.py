"""Model configuration system.

Every assigned architecture is expressed as a ModelConfig: a sequence of
*stages*, each stage being a repeating *pattern* of LayerSpecs. Stages are
scanned over their repeat count with stacked weights so HLO size is
independent of layer count; heterogeneous layouts (e.g. Gemma-2's
local/global alternation, DeepSeek-V3's leading dense layers) are expressed
as multi-element patterns or multiple stages.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Sub-configs


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_expert: int                 # per-expert FFN hidden dim
    num_shared_experts: int = 0
    d_shared: int = 0             # total shared-expert FFN hidden dim
    capacity_factor: float = 1.25
    router_noise: float = 0.0
    # normalize top-k gate weights (deepseek-v3 style) vs plain softmax probs
    norm_topk_prob: bool = True


@dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-V3 multi-head latent attention."""
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class SSMConfig:
    kind: str = "mamba"           # "mamba" | "rwkv6"
    state_dim: int = 16           # N for mamba; head_dim implied for rwkv6
    head_dim: int = 64            # rwkv6 per-head k/v dim
    dt_rank: int = 32
    lora_rank: int = 32           # rwkv6 data-dependent decay LoRA rank
    conv_dim: int = 4             # mamba local conv width


@dataclass(frozen=True)
class LayerSpec:
    """One layer's shape. kind:
    - 'attn':   norm -> attention -> residual, norm -> ffn -> residual
    - 'rwkv':   norm -> rwkv time-mix -> residual, norm -> channel-mix -> res
    - 'hybrid': norm -> (attention || ssm heads, fused) -> residual, ffn
    """
    kind: str = "attn"
    window: Optional[int] = None   # sliding window (tokens); None = full attn
    moe: bool = False              # FFN is mixture-of-experts


@dataclass(frozen=True)
class Stage:
    pattern: Tuple[LayerSpec, ...]
    repeat: int

    @property
    def num_layers(self) -> int:
        return len(self.pattern) * self.repeat


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | vlm | audio
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    stages: Tuple[Stage, ...]
    head_dim: Optional[int] = None          # default d_model // num_heads
    # attention details
    attention_kind: str = "gqa"             # gqa | mla | none
    rope_kind: str = "neox"                 # neox | half | none
    rope_theta: float = 10000.0
    attn_logit_softcap: Optional[float] = None
    final_logit_softcap: Optional[float] = None
    attn_scale: Optional[float] = None      # override 1/sqrt(head_dim)
    embed_scale: bool = False               # gemma-style sqrt(d) embed scaling
    qkv_bias: bool = False                  # chatglm3 uses qkv bias
    # ffn
    act: str = "silu"                       # silu | gelu
    # sub-configs
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    # misc
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    # modality frontend stub: inputs are precomputed embeddings, not tokens
    embed_stub: Optional[str] = None        # None | 'audio' | 'vision'
    citation: str = ""

    @property
    def num_layers(self) -> int:
        return sum(s.num_layers for s in self.stages)

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim is not None:
            return self.head_dim
        return self.d_model // self.num_heads

    def param_count(self) -> int:
        """Approximate parameter count N (for MODEL_FLOPS = 6*N*D)."""
        d, hd = self.d_model, self.resolved_head_dim
        n = self.vocab_size * d  # embed
        if not self.tie_embeddings:
            n += self.vocab_size * d
        for st in self.stages:
            for spec in st.pattern:
                ln = 2 * d
                if spec.kind == "rwkv":
                    s = self.ssm
                    # time-mix: r,k,v,g,o projections + decay lora + ffn
                    tm = 5 * d * d + 2 * s.lora_rank * d * 6
                    cm = 2 * d * self.d_ff + d * self.d_ff
                    n += st.repeat * (tm + cm + ln)
                    continue
                # attention params
                if self.attention_kind == "mla":
                    m = self.mla
                    qk_hd = m.qk_nope_head_dim + m.qk_rope_head_dim
                    attn = (d * m.q_lora_rank
                            + m.q_lora_rank * self.num_heads * qk_hd
                            + d * (m.kv_lora_rank + m.qk_rope_head_dim)
                            + m.kv_lora_rank * self.num_heads
                            * (m.qk_nope_head_dim + m.v_head_dim)
                            + self.num_heads * m.v_head_dim * d)
                else:
                    attn = (d * self.num_heads * hd
                            + 2 * d * self.num_kv_heads * hd
                            + self.num_heads * hd * d)
                if spec.kind == "hybrid":
                    s = self.ssm
                    attn += 2 * d * d + 2 * d * s.state_dim * 2  # ssm branch
                # ffn params
                if spec.moe:
                    mo = self.moe
                    ffn = mo.num_experts * 3 * d * mo.d_expert + d * mo.num_experts
                    if mo.num_shared_experts:
                        ffn += 3 * d * mo.d_shared
                else:
                    ffn = 3 * d * self.d_ff
                n += st.repeat * (attn + ffn + ln)
        return n

    def active_param_count(self) -> int:
        """Active params per token (MoE: only routed top-k experts count)."""
        if self.moe is None:
            return self.param_count()
        n = self.param_count()
        mo = self.moe
        d = self.d_model
        for st in self.stages:
            for spec in st.pattern:
                if spec.moe:
                    dead = (mo.num_experts - mo.top_k) * 3 * d * mo.d_expert
                    n -= st.repeat * dead
        return n

    def reduced(self, **overrides) -> "ModelConfig":
        """A smoke-test-sized variant of the same family: 2 layers,
        d_model<=512, <=4 experts, tiny vocab."""
        d = min(self.d_model, 256)
        hd = 64
        nh = max(2, min(4, self.num_heads))
        nkv = max(1, min(nh, self.num_kv_heads if self.num_kv_heads else nh))
        while nh % nkv:
            nkv -= 1
        moe = self.moe
        if moe is not None:
            moe = dataclasses.replace(
                moe, num_experts=4, top_k=min(2, moe.top_k),
                d_expert=128, d_shared=128 if moe.num_shared_experts else 0,
                num_shared_experts=min(1, moe.num_shared_experts))
        mla = self.mla
        if mla is not None:
            mla = MLAConfig(q_lora_rank=64, kv_lora_rank=32,
                            qk_nope_head_dim=32, qk_rope_head_dim=16,
                            v_head_dim=32)
        ssm = self.ssm
        if ssm is not None:
            ssm = dataclasses.replace(ssm, state_dim=8, head_dim=32,
                                      dt_rank=8, lora_rank=8)
        # keep each distinct pattern once, repeat 1 (>=2 layers if pattern>=2)
        stages = []
        seen = set()
        for st in self.stages:
            key = tuple((sp.kind, sp.window is not None, sp.moe)
                        for sp in st.pattern)
            if key in seen:
                continue
            seen.add(key)
            pat = tuple(dataclasses.replace(
                sp, window=min(sp.window, 64) if sp.window else None)
                for sp in st.pattern)
            stages.append(Stage(pattern=pat, repeat=1))
        if sum(s.num_layers for s in stages) < 2:
            stages = [Stage(pattern=stages[0].pattern, repeat=2)]
        kw = dict(
            name=self.name + "-smoke", family=self.family,
            d_model=d, num_heads=nh, num_kv_heads=nkv, head_dim=hd,
            d_ff=min(self.d_ff, 512), vocab_size=min(self.vocab_size, 1024),
            stages=tuple(stages),
            attention_kind=self.attention_kind, rope_kind=self.rope_kind,
            rope_theta=self.rope_theta,
            attn_logit_softcap=self.attn_logit_softcap,
            final_logit_softcap=self.final_logit_softcap,
            attn_scale=None, embed_scale=self.embed_scale,
            qkv_bias=self.qkv_bias, act=self.act,
            moe=moe, mla=mla, ssm=ssm, norm_eps=self.norm_eps,
            tie_embeddings=self.tie_embeddings, embed_stub=self.embed_stub,
            citation=self.citation,
        )
        kw.update(overrides)
        return ModelConfig(**kw)


# ---------------------------------------------------------------------------
# Input shapes (assigned)

@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    mode: str                      # 'train' | 'prefill' | 'decode'


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}


# ---------------------------------------------------------------------------
# Registry

_REGISTRY: dict = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    if not _REGISTRY:
        load_all()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_configs():
    if not _REGISTRY:
        load_all()
    return sorted(_REGISTRY)


ARCH_MODULES = [
    "musicgen_medium", "gemma2_9b", "chatglm3_6b", "tinyllama_1_1b",
    "internvl2_26b", "hymba_1_5b", "deepseek_v3_671b", "qwen2_moe_a2_7b",
    "deepseek_67b", "rwkv6_3b", "engines_tiny",
]


def load_all():
    import importlib
    for m in ARCH_MODULES:
        importlib.import_module(f"repro.configs.{m}")
