"""MusicGen-medium decoder backbone [arXiv:2306.05284].

Decoder-only transformer over EnCodec tokens. 48L, d_model=1536, 24 heads
(GQA kv=24, i.e. MHA), d_ff=6144, vocab=2048 (one EnCodec codebook's
cardinality). The EnCodec conv codec + delay-pattern interleaving is the
modality frontend and is stubbed: input_specs() provides precomputed frame
embeddings (see DESIGN.md carve-out).
"""
from repro.configs.base import LayerSpec, ModelConfig, Stage, register

CONFIG = register(ModelConfig(
    name="musicgen-medium",
    family="audio",
    d_model=1536,
    num_heads=24,
    num_kv_heads=24,
    head_dim=64,
    d_ff=6144,
    vocab_size=2048,
    stages=(Stage(pattern=(LayerSpec(kind="attn"),), repeat=48),),
    attention_kind="gqa",
    rope_kind="none",            # musicgen uses learned/sinusoidal pos-emb
    act="gelu",
    norm_eps=1e-5,
    embed_stub="audio",
    citation="arXiv:2306.05284",
))
