"""Tiny engine-scale model configs used by the Teola runtime on CPU.

These power the *runnable* examples and benchmarks (the paper's workflows
executed end-to-end in this container). The assigned full-scale archs are
exercised via the AOT dry-run instead.
"""
from repro.configs.base import LayerSpec, ModelConfig, Stage, register

# Core LLM engine model (llama-style, ~12M params)
CORE_LLM = register(ModelConfig(
    name="tiny-core-llm",
    family="dense",
    d_model=256,
    num_heads=4,
    num_kv_heads=2,
    head_dim=64,
    d_ff=704,
    vocab_size=4096,
    stages=(Stage(pattern=(LayerSpec(kind="attn"),), repeat=4),),
    attention_kind="gqa",
    rope_kind="neox",
    act="silu",
    citation="(engine-scale stand-in for llama-2-7B/13B/30B core LLMs)",
))

# Lightweight contextualizer LLM (gemma-2-2B stand-in)
LITE_LLM = register(ModelConfig(
    name="tiny-lite-llm",
    family="dense",
    d_model=128,
    num_heads=2,
    num_kv_heads=1,
    head_dim=64,
    d_ff=384,
    vocab_size=4096,
    stages=(Stage(pattern=(LayerSpec(kind="attn", window=64),
                           LayerSpec(kind="attn")), repeat=1),),
    attention_kind="gqa",
    rope_kind="neox",
    attn_logit_softcap=50.0,
    final_logit_softcap=30.0,
    act="gelu",
    citation="(engine-scale stand-in for gemma-2-2B contextualizer)",
))
