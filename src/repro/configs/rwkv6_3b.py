"""RWKV-6 (Finch) 3B [arXiv:2404.05892].

Attention-free RNN with data-dependent decay (dynamic recurrence).
32L, d_model=2560 (40 heads x 64), channel-mix d_ff=8960, vocab=65536.
Decode is O(1) in sequence length (per-layer matrix state), which is why
this arch runs the long_500k shape.
"""
from repro.configs.base import (LayerSpec, ModelConfig, SSMConfig, Stage,
                                register)

CONFIG = register(ModelConfig(
    name="rwkv6-3b",
    family="ssm",
    d_model=2560,
    num_heads=40,
    num_kv_heads=40,
    head_dim=64,
    d_ff=8960,
    vocab_size=65536,
    stages=(Stage(pattern=(LayerSpec(kind="rwkv"),), repeat=32),),
    attention_kind="none",
    rope_kind="none",
    ssm=SSMConfig(kind="rwkv6", head_dim=64, lora_rank=32),
    act="silu",
    norm_eps=1e-5,
    citation="arXiv:2404.05892",
))
