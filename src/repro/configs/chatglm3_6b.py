"""ChatGLM3-6B [arXiv:2406.12793].

28L, d_model=4096, 32 heads (GQA kv=2), d_ff=13696, vocab=65024.
2D/partial RoPE (rotary applied to half the head dims), QKV bias.
"""
from repro.configs.base import LayerSpec, ModelConfig, Stage, register

CONFIG = register(ModelConfig(
    name="chatglm3-6b",
    family="dense",
    d_model=4096,
    num_heads=32,
    num_kv_heads=2,
    head_dim=128,
    d_ff=13696,
    vocab_size=65024,
    stages=(Stage(pattern=(LayerSpec(kind="attn"),), repeat=28),),
    attention_kind="gqa",
    rope_kind="half",
    rope_theta=10000.0,
    qkv_bias=True,
    act="silu",
    norm_eps=1e-5,
    citation="arXiv:2406.12793",
))
