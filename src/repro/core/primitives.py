"""Task primitives — the basic unit of Teola's fine-grained orchestration
(paper §4.1, Table 2).

Each primitive is a symbolic node with a metadata profile: its op, target
engine, the data keys it consumes/produces, originating component, and
scheduling attributes (topological depth, associated request count).
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Any, Dict, List, Set

# Primitive ops (Table 2). White = common engine ops, blue = decomposed
# LLM ops, gray = control flow.
EMBEDDING = "Embedding"
INGESTION = "Ingestion"
SEARCHING = "Searching"
RERANKING = "Reranking"
CHUNKING = "Chunking"
SEARCH_API = "SearchAPI"
PREFILL = "Prefilling"
DECODE = "Decoding"
PARTIAL_PREFILL = "PartialPrefilling"
FULL_PREFILL = "FullPrefilling"
PARTIAL_DECODE = "PartialDecoding"
CONDITION = "Condition"
AGGREGATE = "Aggregate"

LLM_OPS = {PREFILL, DECODE, PARTIAL_PREFILL, FULL_PREFILL, PARTIAL_DECODE}
CONTROL_OPS = {CONDITION, AGGREGATE}

_counter = itertools.count()


@dataclass
class Primitive:
    op: str
    engine: str
    component: str
    query_id: str = ""
    pid: str = ""
    # dataflow metadata: keys read from / written to the query object store
    consumes: Set[str] = field(default_factory=set)
    produces: Set[str] = field(default_factory=set)
    # op-specific metadata (prompt parts, batch items, seq/state ids, ...)
    config: Dict[str, Any] = field(default_factory=dict)
    # graph links (pids)
    parents: Set[str] = field(default_factory=set)
    children: Set[str] = field(default_factory=set)
    # annotations inherited from the component
    batchable: bool = False
    splittable: bool = False
    # scheduling metadata
    depth: int = 0
    num_requests: int = 1
    # explicit ordering edges that must survive Pass 1 (e.g.
    # Ingestion -> Searching consistency barrier)
    barrier: bool = False

    def __post_init__(self):
        if not self.pid:
            self.pid = f"{self.op}_{next(_counter)}"

    def __repr__(self):
        return (f"<{self.pid} eng={self.engine} comp={self.component} "
                f"depth={self.depth}>")


@dataclass
class Graph:
    """A primitive-level dataflow graph (p-graph or e-graph)."""
    nodes: Dict[str, Primitive] = field(default_factory=dict)
    query_id: str = ""

    def add(self, prim: Primitive) -> Primitive:
        prim.query_id = self.query_id
        self.nodes[prim.pid] = prim
        return prim

    def edge(self, a: Primitive, b: Primitive):
        a.children.add(b.pid)
        b.parents.add(a.pid)

    def unedge(self, a: Primitive, b: Primitive):
        a.children.discard(b.pid)
        b.parents.discard(a.pid)

    def remove(self, prim: Primitive):
        for p in list(prim.parents):
            self.nodes[p].children.discard(prim.pid)
        for c in list(prim.children):
            self.nodes[c].parents.discard(prim.pid)
        del self.nodes[prim.pid]

    def roots(self) -> List[Primitive]:
        return [n for n in self.nodes.values() if not n.parents]

    def topo_order(self) -> List[Primitive]:
        indeg = {p: len(n.parents) for p, n in self.nodes.items()}
        ready = [p for p, d in indeg.items() if d == 0]
        out = []
        while ready:
            pid = ready.pop()
            out.append(self.nodes[pid])
            for c in self.nodes[pid].children:
                indeg[c] -= 1
                if indeg[c] == 0:
                    ready.append(c)
        if len(out) != len(self.nodes):
            raise ValueError("cycle in primitive graph")
        return out

    def assign_depths(self):
        """Reverse-topological depth (Algorithm 2, Event 1): output nodes
        have depth 0; a parent's depth is max(child)+1."""
        order = self.topo_order()
        for n in self.nodes.values():
            n.depth = 0
        for n in reversed(order):
            for ppid in n.parents:
                p = self.nodes[ppid]
                p.depth = max(p.depth, n.depth + 1)

    def validate(self):
        for pid, n in self.nodes.items():
            assert n.pid == pid
            for c in n.children:
                assert pid in self.nodes[c].parents, (pid, c)
            for p in n.parents:
                assert pid in self.nodes[p].children, (pid, p)
        self.topo_order()  # raises on cycles
        return True
