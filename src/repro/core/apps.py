"""The paper's application workflows (Figure 2) built on the template API,
plus a shared engine pool factory.

(a) search engine-empowered generation   (judge LLM -> search -> core LLM)
(c) document QA with naive RAG           (index ∥ query-embed -> search ->
                                          tree-mode synthesis)
(d) document QA with advanced RAG        (+ query expansion, rerank,
                                          refine-mode synthesis)
(e) contextual retrieval (Anthropic)     (chunk contextualization before
                                          indexing + rerank)
"""
from __future__ import annotations


from repro.configs.base import get_config
from repro.core.workflow import APP, EngineSpec, Node
from repro.engines.encoder_engines import EmbeddingEngine, RerankEngine
from repro.engines.llm_engine import LLMEngine
from repro.engines.model_free import (ChunkerEngine, SearchAPIEngine,
                                      VectorDBEngine)


def build_engines(*, seed: int = 0, llm_max_batch: int = 4,
                  emb_max_batch: int = 16, paged_kv: bool = False,
                  kv_block_size: int = 16, chunked_prefill: bool = False,
                  prefill_chunk: int = 128, token_budget=None,
                  prefix_cache: str = "none"):
    """One shared pool (the paper co-locates apps on shared engines).
    ``paged_kv`` switches the LLM engines to the block-paged KV cache
    (copy-on-write prefix sharing, block-based occupancy/backpressure);
    ``chunked_prefill`` streams prompts through each LLM replica's
    continuous loop as budget-bounded chunks mixed with decode
    iterations (stall-free prefill); ``prefix_cache="radix"`` adds the
    global radix-tree prefix cache (any shared block-aligned prompt
    prefix reuses cached KV across queries; requires paged_kv)."""
    return {
        "core_llm": LLMEngine("core_llm", get_config("tiny-core-llm"),
                              seed=seed, max_batch=llm_max_batch,
                              paged=paged_kv, block_size=kv_block_size,
                              chunked_prefill=chunked_prefill,
                              prefill_chunk=prefill_chunk,
                              token_budget=token_budget,
                              prefix_cache=prefix_cache),
        "lite_llm": LLMEngine("lite_llm", get_config("tiny-lite-llm"),
                              seed=seed + 1, max_batch=llm_max_batch * 2,
                              paged=paged_kv, block_size=kv_block_size,
                              chunked_prefill=chunked_prefill,
                              prefill_chunk=prefill_chunk,
                              token_budget=token_budget,
                              prefix_cache=prefix_cache),
        "embedding": EmbeddingEngine(max_batch=emb_max_batch),
        "rerank": RerankEngine(max_batch=emb_max_batch),
        "vectordb": VectorDBEngine(),
        "chunker": ChunkerEngine(),
        "search_api": SearchAPIEngine(),
    }


def _register_common(app: APP, engines):
    for name, eng in engines.items():
        app.register_engine(EngineSpec.from_engine(name, eng))
    app.register_engine(EngineSpec(name="control", kind="control",
                                   max_batch=1 << 30))


def naive_rag(engines, *, num_chunks: int = 32, top_k: int = 3,
              tree_k: int = 3) -> APP:
    app = APP.init("doc_qa_naive_rag")
    _register_common(app, engines)
    chunk = Node("chunk", "chunker")
    index = Node("index", "embedding", name="indexing",
                 anno="batchable", config={"num_chunks": num_chunks})
    qemb = Node("query_embed", "embedding", name="query_embedding")
    search = Node("vector_search", "vectordb",
                  config={"top_k": top_k, "num_queries": 1})
    gen = Node("llm_generate", "core_llm", name="synthesize",
               config={"mode": "tree", "num_context": tree_k,
                       "context_key": "retrieved",
                       "degrade": {"min_new": 8}})
    chunk >> index >> qemb >> search >> gen
    app.update_template([chunk, index, qemb, search, gen])
    return app


def advanced_rag(engines, *, num_chunks: int = 32, num_expanded: int = 3,
                 search_k: int = 8, top_k: int = 3) -> APP:
    app = APP.init("doc_qa_advanced_rag")
    _register_common(app, engines)
    chunk = Node("chunk", "chunker")
    index = Node("index", "embedding", name="indexing",
                 anno="batchable", config={"num_chunks": num_chunks})
    expand = Node("llm_expand", "core_llm", name="query_expansion",
                  anno="splittable", config={"num_expanded": num_expanded,
                                             "max_new": 24})
    qemb = Node("query_embed", "embedding", name="query_embedding",
                config={"in_key": "expanded_queries",
                        "num_queries": num_expanded})
    search = Node("vector_search", "vectordb",
                  config={"top_k": search_k, "num_queries": num_expanded,
                          "degrade": {"min_top_k": 2}})
    rerank = Node("rerank", "rerank",
                  config={"top_k": top_k,
                          "num_candidates": search_k * num_expanded,
                          "degrade": {"skippable": True, "min_top_k": 1}})
    gen = Node("llm_generate", "core_llm", name="synthesize",
               config={"mode": "refine", "num_context": top_k,
                       "context_key": "top_chunks",
                       "degrade": {"min_new": 8, "chunk_cap": 64}})
    chunk >> index >> expand >> qemb >> search >> rerank >> gen
    app.update_template([chunk, index, expand, qemb, search, rerank, gen])
    return app


def search_gen(engines, *, web_k: int = 4) -> APP:
    app = APP.init("search_engine_generation")
    _register_common(app, engines)
    judge = Node("llm_judge", "lite_llm", name="proxy_judge",
                 config={"max_new": 8})
    sapi = Node("search_api", "search_api", config={"top_k": web_k})
    gen = Node("llm_generate", "core_llm", name="synthesize",
               config={"mode": "oneshot", "context_key": "web_results",
                       "max_new": 32, "degrade": {"min_new": 8}})
    judge >> sapi >> gen
    app.update_template([judge, sapi, gen])
    return app


def contextual_retrieval(engines, *, num_chunks: int = 32, search_k: int = 8,
                         top_k: int = 3) -> APP:
    app = APP.init("contextual_retrieval")
    _register_common(app, engines)
    chunk = Node("chunk", "chunker")
    ctx = Node("contextualize", "lite_llm", anno="batchable",
               config={"num_chunks": num_chunks, "max_new": 8})
    index = Node("index", "embedding", name="indexing", anno="batchable",
                 config={"num_chunks": num_chunks, "in_key": "ctx_chunks"})
    qemb = Node("query_embed", "embedding", name="query_embedding")
    search = Node("vector_search", "vectordb",
                  config={"top_k": search_k, "num_queries": 1})
    rerank = Node("rerank", "rerank",
                  config={"top_k": top_k, "num_candidates": search_k,
                          "degrade": {"skippable": True, "min_top_k": 1}})
    gen = Node("llm_generate", "core_llm", name="synthesize",
               config={"mode": "oneshot", "context_key": "top_chunks",
                       "degrade": {"min_new": 8}})
    chunk >> ctx >> index >> qemb >> search >> rerank >> gen
    app.update_template([chunk, ctx, index, qemb, search, rerank, gen])
    return app


ALL_APPS = {
    "naive_rag": naive_rag,
    "advanced_rag": advanced_rag,
    "search_gen": search_gen,
    "contextual_retrieval": contextual_retrieval,
}
