"""Graph optimization passes (paper §4.2, Algorithm 1's GraphOpt).

Pass 1  Dependency pruning      — rebuild edges from data dependencies only
Pass 2  Stage decomposition     — split oversized batchable primitives into
                                  pipelined micro-stages (+ Aggregate)
Pass 3  LLM prefilling split    — Partial/Full Prefilling for prompt parts
                                  available before retrieval completes
Pass 4  LLM decoding pipelining — splittable decodes become chained
                                  Partial Decodings feeding per-item clones
                                  of downstream batchable primitives

The optimizer applies passes to a p-graph to produce the e-graph; each
pass is a standalone, individually-testable transformation.
"""
from __future__ import annotations

import itertools
import math
from typing import Dict, Optional

from repro.core import primitives as P
from repro.core.primitives import Graph, Primitive

_uid = itertools.count()


# ---------------------------------------------------------------------------
# Pass 1

def pass1_prune_dependencies(g: Graph) -> Graph:
    """Remaining edges represent data dependencies ONLY: an edge (a, b)
    survives iff b consumes a key a produces. Template-order edges that
    carry no data are pruned, detaching independent branches (e.g. the
    indexing pipeline from query expansion)."""
    producers: Dict[str, str] = {}
    for n in g.nodes.values():
        for k in n.produces:
            producers[k] = n.pid
    for n in list(g.nodes.values()):
        for cpid in list(n.children):
            c = g.nodes[cpid]
            if not (n.produces & c.consumes):
                g.unedge(n, c)
    # add any missing data edges (consumer of k -> producer of k)
    for n in g.nodes.values():
        for k in n.consumes:
            ppid = producers.get(k)
            if ppid is not None and ppid != n.pid:
                g.edge(g.nodes[ppid], n)
    return g


# ---------------------------------------------------------------------------
# Pass 2

def pass2_stage_decompose(g: Graph, engines) -> Graph:
    """Batchable primitives whose request count exceeds the engine's
    max-efficient batch are split into pipelined stages. A directly-chained
    batchable consumer with the same item count is split stage-wise too
    (embedding -> ingestion; contextualize prefill -> decode); an Aggregate
    primitive re-joins the final keys."""
    for n in list(g.nodes.values()):
        if not (n.batchable and "items_key" in n.config):
            continue
        if n.pid not in g.nodes:        # already replaced as a chained pair
            continue
        eng = engines.get(n.engine)
        maxb = getattr(eng, "max_batch", 8) if eng else 8
        if n.num_requests <= maxb:
            continue
        _split_stages(g, n, maxb, engines)
    return g


def _chained_partner(g: Graph, n: Primitive) -> Optional[Primitive]:
    if len(n.children) != 1:
        return None
    c = g.nodes[next(iter(n.children))]
    if (c.batchable and c.num_requests == n.num_requests
            and len(c.parents) == 1 and "items_key" in c.config):
        return c
    return None


def _split_stages(g: Graph, n: Primitive, maxb: int, engines):
    stages = math.ceil(n.num_requests / maxb)
    partner = _chained_partner(g, n)
    chain = [n] if partner is None else [n, partner]

    made = {}  # (prim, stage) -> clone
    for prim in chain:
        pkey = next(iter(prim.produces))
        clones = []
        for s in range(stages):
            lo, hi = s * maxb, min((s + 1) * maxb, prim.num_requests)
            c = Primitive(
                op=prim.op, engine=prim.engine, component=prim.component,
                consumes=set(prim.consumes), produces={f"{pkey}#s{s}"},
                batchable=True, num_requests=hi - lo,
                splittable=prim.splittable,
                config={**prim.config, "item_range": (lo, hi),
                        "stage": s, "stage_of": prim.pid})
            g.add(c)
            clones.append(c)
            made[(prim.pid, s)] = c
        made[prim.pid] = clones

    # wire: stage s of chain[i] -> stage s of chain[i+1]
    for i in range(len(chain) - 1):
        up_key = next(iter(chain[i].produces))
        for s in range(stages):
            a, b = made[(chain[i].pid, s)], made[(chain[i + 1].pid, s)]
            b.consumes = (b.consumes - {up_key}) | {f"{up_key}#s{s}"}
            g.edge(a, b)

    # parents of the head feed all head stages
    for ppid in list(chain[0].parents):
        for s in range(stages):
            g.edge(g.nodes[ppid], made[(chain[0].pid, s)])

    # Aggregate joins the tail stages and emits the original key(s)
    tail = chain[-1]
    agg = g.add(Primitive(
        op=P.AGGREGATE, engine="control", component=tail.component,
        consumes={f"{next(iter(tail.produces))}#s{s}" for s in range(stages)},
        produces=set(tail.produces),
        config={"concat_of": next(iter(tail.produces))}))
    for s in range(stages):
        g.edge(made[(tail.pid, s)], agg)
    for cpid in list(tail.children):
        g.edge(agg, g.nodes[cpid])

    for prim in chain:
        g.remove(prim)


# ---------------------------------------------------------------------------
# Pass 3

def pass3_prefill_split(g: Graph) -> Graph:
    """Causal prefilling: prompt parts available at query arrival
    (instruction / question / earlier drafts already produced) can be
    prefilled before late parts (retrieved context). Split Prefilling into
    PartialPrefilling (early parts) + FullPrefilling (late parts)."""
    producers = {k: n.pid for n in g.nodes.values() for k in n.produces}
    for n in list(g.nodes.values()):
        if n.op != P.PREFILL or n.config.get("per_item_seq"):
            continue
        parts = n.config.get("parts") or []
        early = [p for p in parts if p[1] is None
                 or producers.get(p[1]) is None]
        late = [p for p in parts if not (p[1] is None
                                         or producers.get(p[1]) is None)]
        # keep prompt order causal: early parts must be a prefix
        n_early = 0
        for name, key in parts:
            if key is None or producers.get(key) is None:
                n_early += 1
            else:
                break
        early = parts[:n_early]
        late = parts[n_early:]
        if not early or not late:
            continue
        sid = n.config["sid"]
        pp = g.add(Primitive(
            op=P.PARTIAL_PREFILL, engine=n.engine, component=n.component,
            consumes={k for _, k in early if k is not None},
            produces={f"state:{sid}:0p"},
            config={**n.config, "parts": early, "partial": True}))
        fp = g.add(Primitive(
            op=P.FULL_PREFILL, engine=n.engine, component=n.component,
            consumes=({k for _, k in late if k is not None}
                      | {f"state:{sid}:0p"}),
            produces=set(n.produces),
            config={**n.config, "parts": late, "continue_partial": True}))
        g.edge(pp, fp)
        for ppid in list(n.parents):
            parent = g.nodes[ppid]
            if parent.produces & pp.consumes:
                g.edge(parent, pp)
            if parent.produces & fp.consumes:
                g.edge(parent, fp)
        for cpid in list(n.children):
            g.edge(fp, g.nodes[cpid])
        g.remove(n)
    return g


# ---------------------------------------------------------------------------
# Pass 4

def pass4_decode_pipeline(g: Graph) -> Graph:
    """Splittable decodes stream semantically-complete items: Decoding is
    replaced by a chain of Partial Decodings (each continues the same
    sequence for one item's tokens) and downstream *itemizable* primitives
    are cloned per item, so item 0's embedding/search runs while item 1 is
    still decoding."""
    for n in list(g.nodes.values()):
        if n.op != P.DECODE or not n.splittable:
            continue
        k = int(n.config.get("num_items", 1))
        if k <= 1:
            continue
        out_key = n.config["out_key"]
        sid = n.config["sid"]
        v = n.config.get("state_v", 2)
        per_item_new = max(1, n.config.get("max_new", 24) // k)

        pds = []
        prev = None
        for i in range(k):
            pd = Primitive(
                op=P.PARTIAL_DECODE, engine=n.engine, component=n.component,
                consumes=(set(n.consumes) if i == 0
                          else {f"state:{sid}:{v}p{i - 1}"}),
                produces={f"{out_key}#{i}", f"state:{sid}:{v}p{i}"},
                config={**n.config, "item": i, "max_new": per_item_new,
                        "out_key": f"{out_key}#{i}"})
            g.add(pd)
            if prev is not None:
                g.edge(prev, pd)
            pds.append(pd)
            prev = pd
        # the final PD also publishes the aggregate key for non-itemizable
        # consumers
        pds[-1].produces.add(out_key)
        pds[-1].config["also_aggregate"] = out_key

        for ppid in list(n.parents):
            parent = g.nodes[ppid]
            if parent.produces & pds[0].consumes:
                g.edge(parent, pds[0])
        # clone itemizable consumers per item
        for cpid in list(n.children):
            child = g.nodes[cpid]
            if child.config.get("itemizable") and out_key in child.consumes:
                _itemize_chain(g, child, out_key, pds, k)
            else:
                g.edge(pds[-1], child)
        g.remove(n)
    return g


def _itemize_chain(g: Graph, node: Primitive, key: str, producers, k: int):
    """Clone `node` (and recursively its itemizable single-consumer chain)
    per item i, rewiring item i's clone to producers[i]."""
    clones = []
    for i in range(k):
        cfg = {**node.config, "item": i}
        if cfg.get("items_key") == key:
            cfg["items_key"] = f"{key}#{i}"
        c = Primitive(
            op=node.op, engine=node.engine, component=node.component,
            consumes={(f"{key}#{i}" if x == key else x)
                      for x in node.consumes},
            produces={f"{x}#{i}" for x in node.produces},
            batchable=node.batchable, num_requests=1,
            config=cfg)
        g.add(c)
        g.edge(producers[i], c)
        # non-key parents (e.g. index_ready) feed every clone
        for ppid in node.parents:
            parent = g.nodes[ppid]
            if parent.produces & c.consumes:
                g.edge(parent, c)
        clones.append(c)

    for cpid in list(node.children):
        child = g.nodes[cpid]
        child_key = next(iter(node.produces & child.consumes), None)
        if child.config.get("itemizable") and child_key:
            _itemize_chain(g, child, child_key, clones, k)
        else:
            # non-itemizable consumer (e.g. rerank) reads all item keys
            if child_key:
                child.consumes.discard(child_key)
                child.consumes |= {f"{child_key}#{i}" for i in range(k)}
            for c in clones:
                g.edge(c, child)
    g.remove(node)


# ---------------------------------------------------------------------------

ALL_PASSES = ("prune", "stage", "prefill_split", "decode_pipeline")


def graph_opt(g: Graph, engines, passes=ALL_PASSES) -> Graph:
    """GraphOpt (Algorithm 1): apply optimization passes; the result is the
    e-graph handed to the runtime. Depths are assigned per Algorithm 2."""
    if "prune" in passes:
        pass1_prune_dependencies(g)
    if "stage" in passes:
        pass2_stage_decompose(g, engines)
    if "prefill_split" in passes:
        pass3_prefill_split(g)
    if "decode_pipeline" in passes:
        pass4_decode_pipeline(g)
    g.validate()
    g.assign_depths()
    return g
