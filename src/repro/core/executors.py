"""Primitive executors: resolve a batch of NodeTasks against the per-query
object stores, invoke the engine op, and write outputs back."""
from __future__ import annotations

import inspect
import threading
from typing import List

import numpy as np

from repro.core import primitives as P
from repro.core.streams import TokenStream, resolve


def _textify(v) -> str:
    if v is None:
        return ""
    if isinstance(v, TokenStream):
        # stream-unaware consumer: block until the decode completes
        return v.wait_text()
    if isinstance(v, str):
        return v
    if isinstance(v, dict):
        return v.get("text", str(v))
    if isinstance(v, (list, tuple)):
        return " ".join(_textify(x) for x in v)
    return str(v)


def _items(store, prim):
    data = resolve(store[prim.config["items_key"]])
    rng = prim.config.get("item_range")
    if rng:
        data = data[rng[0]:rng[1]]
    return data


def _out_key(prim):
    # primary produced key (excluding state keys and per-slot keys)
    cands = [k for k in prim.produces if not k.startswith("state:")]
    plain = [k for k in cands if "#" not in k]
    if plain:
        return plain[0]
    return cands[0] if cands else next(iter(prim.produces))


def _write_slots(store, prim, main_key, result_list):
    """Publish per-slot keys 'main#i' a downstream consumer asked for."""
    for k in prim.produces:
        if k.startswith(main_key + "#") and "#s" not in k:
            i = int(k.rsplit("#", 1)[1])
            if result_list:
                store[k] = [result_list[min(i, len(result_list) - 1)]]
            else:
                store[k] = []


def _sid(prim, ctx, item=None):
    base = f"{ctx.qid}:{prim.config['sid']}" if "sid" in prim.config \
        else f"{ctx.qid}:{prim.pid}"
    sid = base if item is None else f"{base}:{item}"
    ctx.sids.add(sid)
    return sid


def _prompt_text(prim, store) -> str:
    pieces = []
    for name, key in prim.config.get("parts", []):
        if key is None:
            pieces.append(prim.config.get("instruction", ""))
        else:
            pieces.append(_textify(store.get(key)))
    return " ".join(x for x in pieces if x)


def _overload_plan(prim, ctx):
    """Degradation overrides for one primitive of one query — None on
    every off path (no overload manager / degradation disabled / ladder
    at level 0 / no annotation), which keeps execution token-identical.
    Cached per (query, pid): the brown-out ladder may move between
    calls, but one primitive must see ONE consistent decision."""
    ov = getattr(ctx, "overload", None)
    if ov is None:
        return None
    plans = getattr(ctx, "_ov_plans", None)
    if plans is None:
        plans = ctx._ov_plans = {}
    if prim.pid not in plans:
        plans[prim.pid] = ov.degrade_plan(prim, ctx)
    return plans[prim.pid]


def _degraded_max_new(prim, ctx, default: int) -> int:
    plan = _overload_plan(prim, ctx)
    if plan and "max_new" in plan:
        return plan["max_new"]
    return default


def _degraded_top_k(prim, ctx, default: int) -> int:
    plan = _overload_plan(prim, ctx)
    if plan and "top_k" in plan:
        return plan["top_k"]
    return default


def decode_entries(prim, ctx) -> List[tuple]:
    """(sid, max_new) per sequence of one decode task — shared by the
    loop dispatch below and the scheduler's disaggregated handoff (which
    must enumerate exactly the sids ``submit_decode_task`` will submit,
    to migrate them first)."""
    entries = []
    if prim.config.get("per_item_seq"):
        rng = prim.config.get("item_range")
        lo = rng[0] if rng else 0
        mn = _degraded_max_new(prim, ctx, prim.config.get("max_new", 12))
        for i in range(prim.num_requests):
            entries.append((_sid(prim, ctx, lo + i), mn))
    else:
        entries.append((_sid(prim, ctx),
                        _degraded_max_new(prim, ctx,
                                          prim.config.get("max_new", 24))))
    return entries


def _prefill_payload(prim, ctx) -> List[dict]:
    """Per-sequence prefill payload dicts for one task — shared by the
    batch executor and the chunked-loop dispatch so the sid/text
    construction can never diverge between the two paths."""
    store = ctx.store
    if prim.config.get("per_item_seq"):
        rng = prim.config.get("item_range", (0, 0))
        return [{"sid": _sid(prim, ctx, rng[0] + i),
                 "text": (prim.config.get("instruction", "") + " "
                          + _textify(it_))}
                for i, it_ in enumerate(_items(store, prim))]
    return [{"sid": _sid(prim, ctx), "text": _prompt_text(prim, store)}]


def _slo_tag(task, engine):
    """SLO tag for one task's sequences — built only when the routed
    engine has an armed policy (``engine.slo``), so flag-off call sites
    are byte-identical (no extra kwarg reaches the engine). The tag
    carries the query's SLO class / legacy priority / tenant plus the
    PRIMITIVE's e-graph depth: a deep decode has more downstream work
    hanging off it, so it ranks ahead of a shallow one of the same
    class (critical-path slack from ``depth()``)."""
    if getattr(engine, "slo", None) is None:
        return None
    from repro.serving.slo import derive_tag
    ctx = task.ctx
    return derive_tag(slo=getattr(ctx, "slo", None),
                      priority=getattr(ctx, "priority", 0),
                      tenant=getattr(ctx, "tenant", "default"),
                      depth=task.prim.depth,
                      t_submit=ctx.t_submit,
                      deadline=getattr(ctx, "deadline", None))


def rebuild_full_prompt(engine_name: str, ctx, sid: str):
    """Reconstruct a sequence's WHOLE prompt from the query e-graph. A
    prompt split by the causal-prefill pass lives in two primitives —
    PartialPrefilling (early parts) + FullPrefilling (late parts) — so
    every matching piece is collected and joined in causal order; the
    whitespace tokenizer guarantees ``encode(a) + encode(b) ==
    encode(a + " " + b)``, making the joined replay token-identical to
    the split original. Returns None when no prefill primitive of this
    engine produced the sequence."""
    pieces = {}                             # op -> payload text
    for prim in ctx.graph.nodes.values():
        if prim.op not in (P.PREFILL, P.PARTIAL_PREFILL, P.FULL_PREFILL):
            continue
        if prim.engine != engine_name:
            continue
        try:
            for p in _prefill_payload(prim, ctx):
                if p["sid"] == sid:
                    pieces[prim.op] = p["text"]
        except Exception:  # noqa: BLE001 — unresolved sibling payloads
            continue
    if not pieces:
        return None
    order = (P.PREFILL, P.PARTIAL_PREFILL, P.FULL_PREFILL)
    return " ".join(pieces[o] for o in order if o in pieces and pieces[o])


def _continuation_payload(prim, ctx, engine, items):
    """A FullPrefilling continuation rerouted off a dead replica: the
    partial state it would extend died with that replica, so prefill the
    WHOLE rebuilt prompt (early + late parts) on the fresh state —
    silently prefilling only the late parts would decode from a wrong
    prefix. No-op (and allocation-free) on the healthy path, where the
    partial state is resident on the routed engine."""
    if not prim.config.get("continue_partial"):
        return items
    states = getattr(engine, "states", {})
    out = []
    for p in items:
        if p["sid"] not in states:
            full = rebuild_full_prompt(prim.engine, ctx, p["sid"])
            if full is not None:
                p = {**p, "text": full}
        out.append(p)
    return out


# ---------------------------------------------------------------------------

def execute_batch(engine, tasks: List):
    op = tasks[0].prim.op
    kind = getattr(engine, "kind", "")
    if op == P.CHUNKING:
        payload = [{"docs": t.ctx.store["docs"],
                    "chunk_size": t.prim.config.get("chunk_size", 48),
                    "overlap": t.prim.config.get("overlap", 8)}
                   for t in tasks]
        res = engine.op_chunk(payload)
        for t, r in zip(tasks, res):
            t.ctx.store[_out_key(t.prim)] = r
        return

    if op == P.EMBEDDING:
        payload = []
        for t in tasks:
            items = _items(t.ctx.store, t.prim)
            if isinstance(items, (str, dict)):
                items = [items]
            payload.append({"texts": [_textify(x) for x in items],
                            "_items": items})
        res = engine.op_embed(payload)
        for t, r, pl in zip(tasks, res, payload):
            t.ctx.store[_out_key(t.prim)] = {
                "vectors": r, "meta": [x if isinstance(x, dict)
                                       else {"text": _textify(x)}
                                       for x in pl["_items"]]}
        return

    if op == P.INGESTION:
        payload = []
        for t in tasks:
            src = t.ctx.store[next(iter(t.prim.consumes))]
            payload.append({"collection": t.ctx.qid,
                            "vectors": src["vectors"], "meta": src["meta"]})
        engine.op_ingest(payload)
        for t in tasks:
            t.ctx.store[_out_key(t.prim)] = True
        return

    if op == P.SEARCHING:
        payload, spans = [], []
        for t in tasks:
            qsrc = t.ctx.store[t.prim.config["items_key"]
                               if t.prim.config.get("items_key") in
                               t.ctx.store else
                               next(k for k in t.prim.consumes
                                    if k.startswith("query_vecs"))]
            vecs = qsrc["vectors"] if isinstance(qsrc, dict) else qsrc
            vecs = np.atleast_2d(np.asarray(vecs))
            spans.append((len(payload), len(payload) + len(vecs)))
            top_k = _degraded_top_k(t.prim, t.ctx,
                                    t.prim.config.get("top_k", 3))
            for v in vecs:
                payload.append({"collection": t.ctx.qid, "query_vec": v,
                                "top_k": top_k})
        res = engine.op_search(payload)
        for t, (a, b) in zip(tasks, spans):
            hits = [h for r in res[a:b] for h in r]
            main = _out_key(t.prim)
            t.ctx.store[main] = hits
            _write_slots(t.ctx.store, t.prim, main, hits)
        return

    if op == P.RERANKING:
        payload, ranked = [], []
        for t in tasks:
            cands = []
            for k in t.prim.consumes:
                if k.startswith("retrieved"):
                    cands.extend(t.ctx.store.get(k) or [])
            # dedup by text
            seen, uniq = set(), []
            for c in cands:
                if c["text"] not in seen:
                    seen.add(c["text"])
                    uniq.append(c)
            plan = _overload_plan(t.prim, t.ctx) or {}
            top_k = plan.get("top_k", t.prim.config.get("top_k", 3))
            if plan.get("skip"):
                # degraded passthrough: forward the first top_k deduped
                # candidates unscored — graph shape and store layout are
                # preserved, only the scoring pass is shed
                r = uniq[:top_k]
                main = _out_key(t.prim)
                t.ctx.store[main] = r
                _write_slots(t.ctx.store, t.prim, main, r)
                continue
            ranked.append(t)
            payload.append({"question": t.ctx.store.get("question", ""),
                            "candidates": uniq,
                            "top_k": top_k})
        res = engine.op_rerank(payload) if payload else []
        for t, r in zip(ranked, res):
            main = _out_key(t.prim)
            t.ctx.store[main] = r
            _write_slots(t.ctx.store, t.prim, main, r)
        return

    if op == P.SEARCH_API:
        payload = [{"question": t.ctx.store.get("question", ""),
                    "top_k": t.prim.config.get("top_k", 4)}
                   for t in tasks
                   if t.ctx.store.get("need_search", True)]
        res = engine.op_search(payload) if payload else []
        it = iter(res)
        for t in tasks:
            if t.ctx.store.get("need_search", True):
                t.ctx.store[_out_key(t.prim)] = next(it)
            else:
                t.ctx.store[_out_key(t.prim)] = []
        return

    if op in (P.PREFILL, P.PARTIAL_PREFILL, P.FULL_PREFILL):
        payload = []
        for t in tasks:
            items = _prefill_payload(t.prim, t.ctx)
            payload.extend(_continuation_payload(t.prim, t.ctx, engine,
                                                 items))
        engine.op_prefill(payload)
        for t in tasks:
            for k in t.prim.produces:
                t.ctx.store[k] = True
        return

    if op in (P.DECODE, P.PARTIAL_DECODE):
        payload, spans = [], []
        slot_streams = {}       # payload slot -> TokenStream
        for t in tasks:
            prim, store = t.prim, t.ctx.store
            if prim.config.get("per_item_seq"):
                # items decoded on their own sequences (contextualize)
                src_prefill_range = prim.config.get("item_range")
                n_items = prim.num_requests
                lo = src_prefill_range[0] if src_prefill_range else 0
                spans.append((len(payload), len(payload) + n_items))
                mn = _degraded_max_new(prim, t.ctx,
                                       prim.config.get("max_new", 12))
                for i in range(n_items):
                    payload.append({"sid": _sid(prim, t.ctx, lo + i),
                                    "max_new": mn})
            else:
                spans.append((len(payload), len(payload) + 1))
                payload.append({"sid": _sid(prim, t.ctx),
                                "max_new": _degraded_max_new(
                                    prim, t.ctx,
                                    prim.config.get("max_new", 24))})
                if t.stream is not None:
                    slot_streams[len(payload) - 1] = t.stream
        if slot_streams and "on_chunk" in inspect.signature(
                engine.op_decode).parameters:
            def on_chunk(i, text_so_far):
                s = slot_streams.get(i)
                if s is not None:
                    s.put(text_so_far)
            res = engine.op_decode(payload, on_chunk=on_chunk)
        else:
            res = engine.op_decode(payload)
        for t, (a, b) in zip(tasks, spans):
            _write_decode_outputs(t, res[a:b])
        return

    raise ValueError(f"no executor for op {op} on engine kind {kind}")


def _write_decode_outputs(t, texts: List[str]):
    """Publish a decode task's final texts into the query store (shared by
    the batch executor and the continuous-batching submit path)."""
    prim, store = t.prim, t.ctx.store
    key = prim.config.get("out_key", _out_key(prim))
    if prim.config.get("per_item_seq"):
        store[key] = [{"text": x} for x in texts]
    elif prim.op == P.DECODE and prim.config.get("num_items", 1) > 1:
        # unsplit decode of a multi-item output: divide evenly
        words = texts[0].split()
        k = prim.config["num_items"]
        per = max(1, len(words) // k)
        store[key] = [" ".join(words[i * per:(i + 1) * per])
                      for i in range(k)]
    else:
        if t.stream is not None:
            # seal the channel, then restore the plain-text store
            # layout (late consumers never see the stream object)
            t.stream.close(texts[0])
        store[key] = texts[0]
    if prim.config.get("also_aggregate"):
        agg = prim.config["also_aggregate"]
        parts = [store.get(f"{agg}#{i}", "")
                 for i in range(prim.config.get("num_items", 1))]
        store[agg] = [p for p in parts]
    for k2 in prim.produces:
        if k2.startswith("state:"):
            store[k2] = True


def submit_prefill_task(engine, task, done, on_fail=None, ft=None):
    """Chunked-prefill dispatch of ONE prefill NodeTask: every sequence
    of the task is queued into the engine's continuous loop as a
    resumable PrefillJob (``submit_prefill``) — the loop lands
    budget-bounded chunks BETWEEN decode iterations instead of running
    one monolithic whole-prompt forward that would head-of-line-block
    every co-resident decode. The scheduler thread returns immediately;
    when the task's LAST job completes, the store is written exactly as
    the batch executor writes it and ``done(task)`` fires on the loop
    thread. On a job error the query is failed like ``_fail_batch`` and
    ``on_fail(task)``, if given, runs cleanup.

    ``ft`` (optional) is a ``faults.TaskRecovery`` handle: a failed job
    is offered for recovery (resubmission on a healthy replica) before
    being counted as a failure, duplicate completions of a recovered
    job are dropped, and terminal errors are wrapped structurally."""
    prim, ctx = task.prim, task.ctx
    store = ctx.store
    payload = _prefill_payload(prim, ctx)

    if not payload:                      # zero-item prefill: parity with
        for k in prim.produces:          # the batch path's empty span
            store[k] = True
        if ft is not None:
            ft.settle()
        done(task)
        return

    lock = threading.Lock()
    remaining = [len(payload)]
    errors: List = []
    completed = [False] * len(payload)

    def fail(err):
        if ft is not None:
            err = ft.wrap(err)
        if task.stream is not None:
            task.stream.close()
        if ctx.error is None:    # first error wins (root cause)
            ctx.error = err
        ctx.done.set()
        if on_fail is not None:
            on_fail(task)
        if ft is not None:
            ft.settle()

    def job_done(j, job):
        if ft is not None and ft.cancelled:
            return                       # deadline already failed the task
        with lock:
            if completed[j]:
                return                   # duplicate (job was recovered)
        if job.error is not None and ft is not None and ft.recover(j, job):
            return                       # retry scheduled elsewhere
        with lock:
            if completed[j]:
                return
            completed[j] = True
            if job.error is not None:
                errors.append(job.error)
            remaining[0] -= 1
            last = remaining[0] == 0
        if ft is not None:
            ft.note_done(j)
        if not last:
            return
        if errors:
            fail(errors[0])
            return
        try:
            for k in prim.produces:
                store[k] = True
        except Exception as e:  # noqa: BLE001
            fail(e)
            return
        if ft is not None:
            ft.settle()
        done(task)

    def _submit(j, eng, prev):
        p = _continuation_payload(prim, ctx, eng, [payload[j]])[0]
        tag = _slo_tag(task, eng)
        if tag is not None:
            p = {**p, "slo": tag}
        job = eng.submit_prefill(p,
                                 on_done=lambda job, j=j: job_done(j, job))
        plan = _overload_plan(prim, ctx)
        if plan and plan.get("chunk_cap"):
            # degraded mode: the loop lands smaller chunks for this job
            # (best-effort — a chunk already taken stays at full size)
            job.chunk_cap = int(plan["chunk_cap"])
        if ft is not None:
            ft.note_submitted(j, job)

    if ft is not None:
        ft.bind([p["sid"] for p in payload], _submit, fail)
    for j in range(len(payload)):
        try:
            _submit(j, engine, None)
        except Exception as e:  # noqa: BLE001 — count the failed job so
            if ft is not None and ft.recover_submit(j, e):
                continue        # replay scheduled on a healthy replica
            with lock:          # the task still completes (as a failure)
                completed[j] = True
                errors.append(e)
                remaining[0] -= 1
                last = remaining[0] == 0
            if last:
                fail(errors[0])


def submit_decode_task(engine, task, done, on_fail=None, ft=None):
    """Continuous-batching dispatch of ONE decode NodeTask: every sequence
    of the task is admitted into the engine's persistent decode loop
    (``submit_decode``) instead of a blocking run-to-completion batch. The
    scheduler thread returns immediately; when the task's LAST sequence is
    evicted from the loop, the store is written exactly as the batch path
    writes it and ``done(task)`` fires on the loop thread. On a sequence
    error the query is failed like ``_fail_batch`` (done is NOT called)
    and ``on_fail(task)``, if given, runs cleanup (e.g. releasing the
    pool's in-flight ledger).

    ``ft`` (optional) is a ``faults.TaskRecovery`` handle. With it, a
    failed sequence is offered for recovery before being counted: the
    handle resubmits on a healthy replica through ``recover_decode``
    (prompt replayed from the e-graph, emitted tokens teacher-forced —
    token-identical resume). A sequence routed to an engine that does
    not hold its state (its pinned replica died between prefill and
    decode) takes the same replay path. Duplicate completions — a hung
    replica finishing a sequence that was already recovered elsewhere —
    are dropped, and terminal errors are wrapped structurally."""
    prim, ctx = task.prim, task.ctx
    entries = decode_entries(prim, ctx)  # (sid, max_new) per sequence

    if not entries:                      # zero-item decode: parity with
        _write_decode_outputs(task, [])  # the batch path's empty span
        if ft is not None:
            ft.settle()
        done(task)
        return

    lock = threading.Lock()
    remaining = [len(entries)]
    results: List = [None] * len(entries)
    errors: List = []
    completed = [False] * len(entries)

    def fail(err):
        if ft is not None:
            err = ft.wrap(err)
        if task.stream is not None:
            task.stream.close()
        if ctx.error is None:    # first error wins (root cause)
            ctx.error = err
        ctx.done.set()
        if on_fail is not None:
            on_fail(task)
        if ft is not None:
            ft.settle()

    def finish():
        if errors:
            fail(errors[0])
            return
        try:
            _write_decode_outputs(task, results)
        except Exception as e:  # noqa: BLE001
            fail(e)
            return
        if ft is not None:
            ft.settle()
        done(task)

    def seq_done(j, seq):
        if ft is not None and ft.cancelled:
            return                       # deadline already failed the task
        with lock:
            if completed[j]:
                return                   # duplicate (seq was recovered)
        if seq.error is not None and ft is not None and ft.recover(j, seq):
            return                       # retry scheduled elsewhere
        with lock:
            if completed[j]:
                return
            completed[j] = True
            if seq.error is not None:
                errors.append(seq.error)
            results[j] = seq.result
            remaining[0] -= 1
            last = remaining[0] == 0
        if ft is not None:
            ft.note_done(j)
        if last:
            # a completion-path failure (done -> graph bookkeeping) must
            # fail the query, not strand it; the ledger was already
            # released by done's own wrapper at that point
            try:
                finish()
            except Exception as e:  # noqa: BLE001
                if task.stream is not None:
                    task.stream.close()
                if ctx.error is None:
                    ctx.error = e
                ctx.done.set()

    on_text = task.stream.put if (task.stream is not None
                                  and len(entries) == 1) else None

    def _submit(j, eng, prev):
        sid, max_new = entries[j]
        cb = lambda seq, j=j: seq_done(j, seq)   # noqa: E731
        tag = _slo_tag(task, eng)
        extra = {} if tag is None else {"slo": tag}
        if ft is not None and (prev is not None or
                               sid not in getattr(eng, "states", {})):
            seq = eng.recover_decode(sid, ft.prompt_for(sid), max_new,
                                     prev, on_text=on_text, on_done=cb,
                                     **extra)
        else:
            seq = eng.submit_decode(sid, max_new, on_text=on_text,
                                    on_done=cb, **extra)
        if ft is not None:
            ft.note_submitted(j, seq)

    if ft is not None:
        ft.bind([sid for sid, _ in entries], _submit, fail)
    for j in range(len(entries)):
        try:
            _submit(j, engine, None)
        except Exception as e:  # noqa: BLE001 — admission failed (e.g.
            if ft is None:      # the routed replica just died): offer
                raise           # recovery before failing the task
            if not ft.recover_submit(j, e):
                fail(e)
                return


# ---------------------------------------------------------------------------

def run_control(prim, ctx):
    store = ctx.store
    if prim.op == P.CONDITION:
        pred = prim.config.get("predicate", "always_true")
        if pred == "always_true":
            val = True
        elif pred == "never":
            val = False
        elif callable(pred):
            val = bool(pred(store))
        else:
            val = True
        store[_out_key(prim)] = val
        return
    if prim.op == P.AGGREGATE:
        out = _out_key(prim)
        if "concat_of" in prim.config:
            keys = sorted((k for k in prim.consumes),
                          key=lambda s: int(s.rsplit("#s", 1)[1])
                          if "#s" in s else 0)
            vals = [resolve(store.get(k)) for k in keys]
            if all(isinstance(v, dict) and "vectors" in v for v in vals):
                store[out] = {
                    "vectors": np.concatenate([v["vectors"] for v in vals]),
                    "meta": sum((v["meta"] for v in vals), [])}
            elif all(isinstance(v, list) for v in vals):
                store[out] = sum(vals, [])
            elif all(v is True for v in vals):
                store[out] = True
            else:
                store[out] = vals
        else:
            store[out] = [resolve(store.get(k))
                          for k in sorted(prim.consumes)]
        return
    raise ValueError(f"unknown control op {prim.op}")
