"""p-Graph construction: GraphTransform (Algorithm 1).

Decomposes each template component, with query-specific configuration,
into symbolic primitives with explicit data dependencies, then links
components along the template edges (tail primitive -> head primitive).
Data keys are unique per producer; LLM sequence state is threaded through
versioned `state:{sid}:{v}` keys so Pass 1's dataflow-only edge rebuild
preserves prefill/decode ordering for free.
"""
from __future__ import annotations

import itertools
from typing import Dict

from repro.core import primitives as P
from repro.core.primitives import Graph, Primitive
from repro.core.prompts import INSTRUCTIONS
from repro.core.workflow import APP, Node

_sid = itertools.count()


def _llm_seq(g: Graph, comp: Node, *, parts, out_key, max_new, num_items=1,
             splittable=False, consumes_extra=(), instruction=None,
             degrade=None):
    """Prefill + Decode primitive pair for one LLM sequence.
    parts: ordered list of (part_name, data_key_or_None) — None means the
    part is static text available at query arrival (instruction etc.).
    ``degrade`` (optional dict) annotates both primitives with their
    graceful-degradation contract (overload layer: min_new, chunk_cap)."""
    sid = f"s{next(_sid)}"
    pf_consumes = {k for _, k in parts if k is not None}
    pf = g.add(Primitive(
        op=P.PREFILL, engine=comp.engine, component=comp.name,
        consumes=pf_consumes | set(consumes_extra),
        produces={f"state:{sid}:1"},
        config={"parts": list(parts), "sid": sid, "state_v": 1,
                "instruction": instruction}))
    dc = g.add(Primitive(
        op=P.DECODE, engine=comp.engine, component=comp.name,
        consumes={f"state:{sid}:1"},
        produces={out_key, f"state:{sid}:2"},
        splittable=splittable,
        config={"sid": sid, "state_v": 2, "out_key": out_key,
                "max_new": max_new, "num_items": num_items}))
    if degrade:
        pf.config["degrade"] = dict(degrade)
        dc.config["degrade"] = dict(degrade)
    g.edge(pf, dc)
    return pf, dc


def decompose_component(g: Graph, comp: Node, C: dict,
                        produced_by: Dict[str, str]):
    """Appends this component's primitives to g; returns (head, tail)."""
    kind = comp.kind
    cc = {**comp.config, **C.get(comp.name, {})}

    if kind == "chunk":
        n = g.add(Primitive(
            op=P.CHUNKING, engine=comp.engine, component=comp.name,
            consumes={"docs"}, produces={"chunks"},
            config={"chunk_size": cc.get("chunk_size", 48),
                    "overlap": cc.get("overlap", 8)}))
        return n, n

    if kind == "index":
        nreq = cc.get("num_chunks", 32)
        emb = g.add(Primitive(
            op=P.EMBEDDING, engine=comp.engine, component=comp.name,
            consumes={cc.get("in_key", "chunks")}, produces={"chunk_vecs"},
            batchable=True, num_requests=nreq,
            config={"items_key": cc.get("in_key", "chunks")}))
        ing = g.add(Primitive(
            op=P.INGESTION, engine=cc.get("db_engine", "vectordb"),
            component=comp.name, consumes={"chunk_vecs"},
            produces={"index_ready"}, batchable=True, num_requests=nreq,
            config={"items_key": "chunk_vecs"}))
        g.edge(emb, ing)
        return emb, ing

    if kind == "query_embed":
        in_key = cc.get("in_key", "question")
        n = g.add(Primitive(
            op=P.EMBEDDING, engine=comp.engine, component=comp.name,
            consumes={in_key}, produces={"query_vecs"},
            batchable=True, num_requests=cc.get("num_queries", 1),
            config={"items_key": in_key, "itemizable": True}))
        return n, n

    if kind == "vector_search":
        n = g.add(Primitive(
            op=P.SEARCHING, engine=comp.engine, component=comp.name,
            consumes={"query_vecs", "index_ready"}, produces={"retrieved"},
            batchable=True, num_requests=cc.get("num_queries", 1),
            config={"top_k": cc.get("top_k", 3), "items_key": "query_vecs",
                    "itemizable": True}))
        if cc.get("degrade"):
            n.config["degrade"] = dict(cc["degrade"])
        return n, n

    if kind == "rerank":
        n = g.add(Primitive(
            op=P.RERANKING, engine=comp.engine, component=comp.name,
            consumes={"retrieved", "question"}, produces={"top_chunks"},
            batchable=True, num_requests=cc.get("num_candidates", 16),
            config={"top_k": cc.get("top_k", 3)}))
        if cc.get("degrade"):
            n.config["degrade"] = dict(cc["degrade"])
        return n, n

    if kind == "llm_expand":
        k = cc.get("num_expanded", 3)
        pf, dc = _llm_seq(
            g, comp,
            parts=[("instruction", None), ("question", "question")],
            out_key="expanded_queries", max_new=cc.get("max_new", 24),
            num_items=k, splittable=(comp.anno == "splittable"),
            instruction=cc.get("instruction", INSTRUCTIONS["expand"]),
            degrade=cc.get("degrade"))
        return pf, dc

    if kind == "llm_judge":
        pf, dc = _llm_seq(
            g, comp,
            parts=[("instruction", None), ("question", "question")],
            out_key="judge_out", max_new=cc.get("max_new", 8),
            instruction=cc.get("instruction", INSTRUCTIONS["judge"]))
        cond = g.add(Primitive(
            op=P.CONDITION, engine="control", component=comp.name,
            consumes={"judge_out"}, produces={"need_search"},
            config={"predicate": cc.get("predicate", "always_true")}))
        g.edge(dc, cond)
        return pf, cond

    if kind == "search_api":
        n = g.add(Primitive(
            op=P.SEARCH_API, engine=comp.engine, component=comp.name,
            consumes={"question", "need_search"}, produces={"web_results"},
            config={"top_k": cc.get("top_k", 4)}))
        return n, n

    if kind == "contextualize":
        nreq = cc.get("num_chunks", 32)
        sid = f"ctx{next(_sid)}"
        pf = g.add(Primitive(
            op=P.PREFILL, engine=comp.engine, component=comp.name,
            consumes={"chunks"}, produces={"ctx_state"},
            batchable=True, num_requests=nreq,
            config={"parts": [("instruction", None), ("chunk", "chunks")],
                    "items_key": "chunks", "per_item_seq": True, "sid": sid,
                    "instruction": cc.get("instruction",
                                          INSTRUCTIONS["contextualize"])}))
        dc = g.add(Primitive(
            op=P.DECODE, engine=comp.engine, component=comp.name,
            consumes={"ctx_state"}, produces={"ctx_chunks"},
            batchable=True, num_requests=nreq,
            config={"out_key": "ctx_chunks", "per_item_seq": True,
                    "sid": sid, "max_new": cc.get("max_new", 12),
                    "items_key": "ctx_state"}))
        g.edge(pf, dc)
        return pf, dc

    if kind == "llm_generate":
        mode = cc.get("mode", "oneshot")
        ctx_key = cc.get("context_key", "top_chunks")
        k = cc.get("num_context", 3)
        if mode == "oneshot":
            pf, dc = _llm_seq(
                g, comp,
                parts=[("instruction", None), ("question", "question"),
                       ("context", ctx_key)],
                out_key="answer", max_new=cc.get("max_new", 32),
                instruction=cc.get("instruction", INSTRUCTIONS["oneshot"]),
                degrade=cc.get("degrade"))
            return pf, dc
        if mode == "refine":
            head = None
            prev_dc = None
            for i in range(k):
                parts = [("instruction", None),
                         ("question", "question"),
                         ("context", f"{ctx_key}#{i}" if k > 1 else ctx_key)]
                if prev_dc is not None:
                    parts.insert(2, ("draft", f"answer@{i - 1}"))
                pf, dc = _llm_seq(
                    g, comp, parts=parts,
                    out_key="answer" if i == k - 1 else f"answer@{i}",
                    max_new=cc.get("max_new", 32),
                    instruction=cc.get("instruction", INSTRUCTIONS["refine"]),
                    degrade=cc.get("degrade"))
                if head is None:
                    head = pf
                if prev_dc is not None:
                    g.edge(prev_dc, pf)
                prev_dc = dc
            return head, prev_dc
        if mode == "tree":
            # k parallel leaf calls + aggregating final call
            leaves = []
            for i in range(k):
                pf, dc = _llm_seq(
                    g, comp,
                    parts=[("instruction", None), ("question", "question"),
                           ("context", f"{ctx_key}#{i}" if k > 1 else
                            ctx_key)],
                    out_key=f"leaf_answer@{i}",
                    max_new=cc.get("max_new", 24),
                    instruction=cc.get("instruction", INSTRUCTIONS["tree"]),
                    degrade=cc.get("degrade"))
                leaves.append((pf, dc))
            agg = g.add(Primitive(
                op=P.AGGREGATE, engine="control", component=comp.name,
                consumes={f"leaf_answer@{i}" for i in range(k)},
                produces={"leaf_answers"}, config={}))
            for _, dc in leaves:
                g.edge(dc, agg)
            pf, dc = _llm_seq(
                g, comp,
                parts=[("instruction", None), ("question", "question"),
                       ("drafts", "leaf_answers")],
                out_key="answer", max_new=cc.get("max_new", 32),
                instruction=cc.get("instruction", INSTRUCTIONS["combine"]),
                degrade=cc.get("degrade"))
            g.edge(agg, pf)
            return leaves[0][0], dc
        raise ValueError(f"unknown llm_generate mode {mode}")

    raise ValueError(f"unknown component kind {kind!r}")


def graph_transform(app: APP, query: dict, C: dict | None = None) -> Graph:
    """Algorithm 1 GraphTransform: template + query config -> p-graph."""
    C = dict(C or {})
    # query-specific sizing: the chunk count drives batchable primitive
    # request counts (paper: p-graph reflects the query's input data)
    if "docs" in query:
        from repro.engines.model_free import ChunkerEngine
        chunk_comps = [c for c in app.template if c.kind == "chunk"]
        cs = chunk_comps[0].config.get("chunk_size", 48) if chunk_comps \
            else 48
        ov = chunk_comps[0].config.get("overlap", 8) if chunk_comps else 8
        n_chunks = ChunkerEngine.count_chunks(query["docs"], cs, ov)
        for comp in app.template:
            if comp.kind in ("index", "contextualize"):
                C.setdefault(comp.name, {}).setdefault("num_chunks",
                                                       max(1, n_chunks))
    g = Graph(query_id=query.get("id", "q0"))
    # split context keys for multi-context synthesis: rerank publishes
    # top_chunks#i per context slot when the generator consumes them
    bounds: Dict[Node, tuple] = {}
    for comp in app.template:
        head, tail = decompose_component(g, comp, C, {})
        bounds[comp] = (head, tail)
    for a, b in app.template_edges():
        g.edge(bounds[a][1], bounds[b][0])
    # rerank -> refine/tree: expose per-slot context keys
    _split_context_keys(g)
    g.validate()
    return g


def _split_context_keys(g: Graph):
    """If a consumer reads a per-slot key 'base#i' of a key 'base' that a
    single node produces (e.g. tree/refine synthesis reading
    top_chunks#i / retrieved#i), that producer advertises the slot keys
    too — it writes them all at completion."""
    producers = {}
    for n in g.nodes.values():
        for k in n.produces:
            producers[k] = n
    for n in g.nodes.values():
        for k in n.consumes:
            if "#" in k and k not in producers:
                base = k.split("#")[0]
                if base in producers:
                    producers[base].produces.add(k)
                    producers[base].config.setdefault("slot_keys",
                                                      []).append(k)
