"""Streaming decode channels (decode -> downstream pipelining).

A ``TokenStream`` is the value a streaming decode primitive publishes into
the query object store *while it is still decoding*: an append-only,
thread-safe text channel. Chunks of newly decoded text are ``put`` by the
engine executor as they are produced; the runtime early-releases the
decode's graph children on the first chunk, so a downstream primitive
(rerank, condition, aggregate, ...) is dispatched — and can start
consuming — before sequence completion.

Consumers that need the complete text call ``wait_text()`` (blocks until
``close``); incremental consumers iterate the stream or poll
``snapshot()``. After ``close(final)`` the runtime overwrites the store
key with the plain final string, so late consumers never see the channel
object and the non-streaming store layout is restored byte-for-byte.
"""
from __future__ import annotations

import threading
import time
from typing import Callable, List, Optional


class TokenStream:
    def __init__(self, key: str = ""):
        self.key = key
        self._text = ""
        self._chunks: List[str] = []       # deltas, in arrival order
        self.chunk_times: List[float] = []  # wall time of each delta
        self._closed = False
        self._cv = threading.Condition()
        # runtime hook: fired exactly once, on the first chunk (or on
        # close if the decode produced everything in one shot). MUST NOT
        # block: it is invoked from the engine executor thread mid-decode.
        self.on_first: Optional[Callable[[], None]] = None
        self._first_fired = False

    # -- producer side ------------------------------------------------------
    def put(self, text_so_far: str):
        """Advance the stream to `text_so_far` (snapshot-replace: engines
        report cumulative decoded text; the delta is recorded as a chunk)."""
        fire = None
        with self._cv:
            if self._closed:
                return
            delta = text_so_far[len(self._text):]
            if not delta:
                return
            self._text = text_so_far
            self._chunks.append(delta)
            self.chunk_times.append(time.time())
            if not self._first_fired:
                self._first_fired = True
                fire = self.on_first
            self._cv.notify_all()
        if fire is not None:
            fire()

    def close(self, final_text: Optional[str] = None):
        fire = None
        with self._cv:
            if self._closed:
                return
            if final_text is not None and final_text != self._text:
                delta = final_text[len(self._text):]
                if delta:
                    self._chunks.append(delta)
                    self.chunk_times.append(time.time())
                self._text = final_text
            self._closed = True
            if not self._first_fired:
                self._first_fired = True
                fire = self.on_first
            self._cv.notify_all()
        if fire is not None:
            fire()

    # -- consumer side ------------------------------------------------------
    @property
    def closed(self) -> bool:
        with self._cv:
            return self._closed

    def snapshot(self) -> str:
        """Text decoded so far (non-blocking)."""
        with self._cv:
            return self._text

    def wait_text(self, timeout: float = 300) -> str:
        """Block until the stream closes; return the complete text."""
        with self._cv:
            self._cv.wait_for(lambda: self._closed, timeout)
            return self._text

    def __iter__(self):
        """Yield text deltas as they arrive; terminates at close."""
        i = 0
        while True:
            with self._cv:
                self._cv.wait_for(
                    lambda: len(self._chunks) > i or self._closed, 300)
                chunks = self._chunks[i:]
                i = len(self._chunks)
                closed = self._closed
            for c in chunks:
                yield c
            if closed and i == len(self._chunks):
                return

    def __repr__(self):
        return (f"<TokenStream {self.key} chunks={len(self._chunks)} "
                f"closed={self._closed}>")


def resolve(value, timeout: float = 300):
    """Collapse a possibly-streaming store value to its final form."""
    if isinstance(value, TokenStream):
        return value.wait_text(timeout)
    return value
