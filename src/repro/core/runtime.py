"""Two-tier runtime (paper §5).

Upper tier — GraphScheduler: tracks each query's e-graph, dispatches
primitives whose in-degree reaches zero to the per-engine schedulers, and
manages the per-query object store.

Lower tier — one scheduler per engine *pool*:
  EngineScheduler        single-instance engines: one thread that fuses
                         primitive requests from concurrent queries into
                         engine batches.
  PooledEngineScheduler  EnginePool engines: the same batch-formation
                         policies over one shared queue, then a LOAD-AWARE
                         ROUTER dispatches each fused batch to the
                         least-loaded replica (outstanding tokens + KV
                         occupancy — see core/engine_pool.py), with
                         sequence->replica affinity for LLM ops since a
                         sequence's KV state lives on one replica.

Batching policies (both schedulers):
  'po'   per-invocation oriented — one query's bundle at a time (baseline)
  'to'   throughput oriented    — FIFO dynamic batching to max batch
  'topo' topology-aware batching — Algorithm 2: bucket by query, order by
         reverse-topological depth, earliest-arrival buckets first.

Streaming decode pipelining (partial-result emission): when the Runtime
is constructed with ``streaming=True``, an eligible Decoding primitive
publishes a TokenStream into the query store at dispatch time and the
engine emits decoded chunks into it as they are produced. On the FIRST
chunk the runtime early-releases the decode's graph children, so
downstream primitives (rerank, condition, aggregate, ...) are dispatched
— and can begin consuming via the stream — before sequence completion.
At completion the store key is overwritten with the plain final text, so
the final store is byte-identical to the non-streaming layout.

Control primitives (Condition/Aggregate) run inline on the graph
scheduler thread. Dependent pre-scheduling (§6, communication mitigation)
is modeled by resolving payloads lazily at execution time from the shared
object store, so a parent's output is visible to its pre-issued child
without an extra scheduler round-trip.
"""
from __future__ import annotations

import itertools
import queue
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

from repro.core import primitives as P
from repro.core.engine_pool import (DisaggregatedEnginePool, EnginePool,
                                    estimate_tokens, replicas_of)
from repro.core.primitives import Graph, Primitive
from repro.core.streams import TokenStream

_qid = itertools.count()


class QueryContext:
    def __init__(self, graph: Graph, inputs: Dict[str, Any],
                 output_key: str = "answer", priority: int = 0,
                 slo: Optional[str] = None, tenant: str = "default"):
        self.qid = f"q{next(_qid)}"
        self.graph = graph
        self.store: Dict[str, Any] = dict(inputs)
        self.output_key = output_key
        self.priority = priority    # higher = served first (paper §7.2)
        # SLO class ("interactive" | "batch" | None) and tenant identity
        # for the serving/slo policy layer; None defers to priority
        self.slo = slo
        self.tenant = tenant
        self.done = threading.Event()
        self.t_submit = time.time()
        self.t_done: Optional[float] = None
        # absolute query deadline (stamped by the overload layer; None =
        # no deadline). The FT watchdog, SLO urgency test and the
        # degradation ladder all derive their clocks from this one value.
        self.deadline: Optional[float] = None
        self.node_spans: Dict[str, tuple] = {}     # pid -> (t0, t1)
        self.sids: set = set()
        self.lock = threading.Lock()
        self.error: Optional[Exception] = None
        # streaming: (parent_pid, child_pid) edges already released early
        self.early_edges: set = set()

    @property
    def latency(self):
        return (self.t_done or time.time()) - self.t_submit

    def result(self, timeout=120):
        self.done.wait(timeout)
        if self.error:
            raise self.error
        return self.store.get(self.output_key)


@dataclass
class NodeTask:
    prim: Primitive
    ctx: QueryContext
    t_arrival: float = field(default_factory=time.time)
    managed: bool = True     # False: baseline orchestrators drive progress
    stream: Optional[TokenStream] = None   # set for streaming decodes

    @property
    def depth(self):
        return self.prim.depth


def _fail_batch(batch: List[NodeTask], e: Exception):
    for t in batch:
        if t.stream is not None:
            t.stream.close()
        if t.ctx.error is None:
            # first error wins: a cascade failure (e.g. later submits
            # bouncing off an already-dead replica) must not overwrite
            # the structured root-cause error already recorded
            t.ctx.error = e
        t.ctx.done.set()


# ---------------------------------------------------------------------------
# Batch formation — shared by the single-instance and pooled schedulers.

def form_batch(pending: List[NodeTask], policy: str,
               max_bs: int) -> List[NodeTask]:
    if not pending:
        return []
    if policy == "po":
        # bundle = same (query, component) as the head task, FIFO
        head = min(pending, key=lambda t: t.t_arrival)
        bundle = [t for t in pending
                  if t.ctx is head.ctx
                  and t.prim.component == head.prim.component
                  and t.prim.op == head.prim.op]
        return bundle[:max_bs]
    if policy == "to":
        pending.sort(key=lambda t: t.t_arrival)
        op = pending[0].prim.op
        batch, slots = [], max_bs
        for t in pending:
            if t.prim.op != op:
                continue
            if t.prim.num_requests > slots and batch:
                break
            batch.append(t)
            slots -= t.prim.num_requests
            if slots <= 0:
                break
        return batch
    # 'topo' — Algorithm 2: bucket pending nodes by query; buckets
    # ordered by (priority desc, earliest arrival); round-robin over
    # buckets taking the HIGHEST-DEPTH node of each bucket per round
    # (Fig. 7 batches the most graph-advancing primitive of each
    # query together). Priority implements the paper's §7.2
    # app-priority discussion as primitive metadata.
    buckets: Dict[str, List[NodeTask]] = {}
    for t in pending:
        buckets.setdefault(t.ctx.qid, []).append(t)
    ordered = sorted(buckets.values(),
                     key=lambda b: (-max(t.ctx.priority for t in b),
                                    min(t.t_arrival for t in b)))
    for b in ordered:
        b.sort(key=lambda t: -t.prim.depth)
    batch, slots, op = [], max_bs, None
    while slots > 0:
        took = False
        for b in ordered:
            if slots <= 0:
                break
            for t in b:
                if op is not None and t.prim.op != op:
                    continue
                if t.prim.num_requests > slots and batch:
                    continue
                op = op or t.prim.op
                batch.append(t)
                b.remove(t)
                slots -= t.prim.num_requests
                took = True
                break
        if not took:
            break
    return batch


# ---------------------------------------------------------------------------

# ops dispatched into the persistent decode loop under continuous batching
CONTINUOUS_OPS = (P.DECODE, P.PARTIAL_DECODE)
# prefill ops additionally loop-dispatched when the engine has CHUNKED
# prefill enabled (prompts stream through mixed prefill/decode passes)
PREFILL_OPS = (P.PREFILL, P.PARTIAL_PREFILL, P.FULL_PREFILL)


def take_continuous(pending: List[NodeTask],
                    include_prefill: bool = False) -> List[NodeTask]:
    """Pull loop-destined tasks out of a pending list (caller holds the
    scheduler's condition lock): decodes always; prefills too when the
    engine runs chunked prefill inside the loop."""
    ops = CONTINUOUS_OPS + PREFILL_OPS if include_prefill \
        else CONTINUOUS_OPS
    cont = [t for t in pending if t.prim.op in ops]
    for t in cont:
        pending.remove(t)
    return cont


def chunked_prefill_enabled(engine) -> bool:
    """True when prefill primitives should bypass batch formation and be
    queued as chunked PrefillJobs in the engine's continuous loop."""
    return bool(getattr(engine, "chunked_prefill", False)) and \
        hasattr(engine, "submit_prefill")


class EngineScheduler(threading.Thread):
    """Lower-tier scheduler for a SINGLE engine instance.

    With ``continuous=True`` (and an engine exposing ``submit_decode``)
    decode primitives bypass batch formation: they are submitted straight
    into the engine's persistent decode loop — the decode-slot dispatch
    mode — so the scheduler thread never blocks an engine for a whole
    decode batch and newly-arrived decodes join mid-flight. When the
    engine additionally runs CHUNKED prefill, prefill primitives are
    loop-dispatched the same way (``submit_prefill_task``): the prompt
    advances in budget-bounded chunks between decode iterations instead
    of head-of-line-blocking them."""

    def __init__(self, engine, executor, policy: str = "topo",
                 period: float = 0.002, continuous: bool = False):
        super().__init__(daemon=True)
        self.engine = engine
        self.executor = executor
        self.policy = policy
        self.period = period
        self.continuous = continuous and hasattr(engine, "submit_decode")
        self.chunked = self.continuous and chunked_prefill_enabled(engine)
        self.pending: List[NodeTask] = []
        self.cv = threading.Condition()
        self.running = True
        self.on_complete = None        # set by Runtime
        self.batches = []              # (size_requests, op) log
        self.decode_submits = []       # (num_requests, op) loop submissions

    def submit(self, task: NodeTask):
        with self.cv:
            self.pending.append(task)
            self.cv.notify()

    def stop(self):
        self.running = False
        with self.cv:
            self.cv.notify()

    def _form_batch(self) -> List[NodeTask]:
        max_bs = getattr(self.engine, "max_batch", 8)
        return form_batch(self.pending, self.policy, max_bs)

    def _submit_continuous(self, tasks: List[NodeTask]):
        from repro.core.executors import (submit_decode_task,
                                          submit_prefill_task)
        for t in tasks:
            self.decode_submits.append((t.prim.num_requests, t.prim.op))
            submit = submit_prefill_task if t.prim.op in PREFILL_OPS \
                else submit_decode_task
            try:
                submit(self.engine, t, self.on_complete)
            except Exception as e:  # noqa: BLE001
                _fail_batch([t], e)

    def run(self):
        while self.running:
            with self.cv:
                if not self.pending:
                    self.cv.wait(timeout=0.1)
                    continue
                cont = take_continuous(self.pending, self.chunked) \
                    if self.continuous else []
                batch = self._form_batch()
                for t in batch:
                    self.pending.remove(t)
            self._submit_continuous(cont)
            if not batch:
                if not cont:
                    time.sleep(self.period)
                continue
            self.batches.append((sum(t.prim.num_requests for t in batch),
                                 batch[0].prim.op))
            try:
                self.executor(self.engine, batch)
            except Exception as e:  # noqa: BLE001
                _fail_batch(batch, e)
                continue
            for t in batch:
                self.on_complete(t)


# ---------------------------------------------------------------------------

class _ReplicaWorker(threading.Thread):
    """Executes routed batches on one pool replica; maintains the pool's
    in-flight token ledger around each execution."""

    def __init__(self, sched: "PooledEngineScheduler", idx: int):
        super().__init__(daemon=True)
        self.sched = sched
        self.idx = idx
        self.engine = sched.pool[idx]
        self.q: "queue.Queue" = queue.Queue()

    def run(self):
        pool = self.sched.pool
        while True:
            item = self.q.get()
            if item is None:
                return
            batch, tokens = item
            pool.note_started(self.idx, tokens)
            try:
                fire = self.sched._execute_routed(self, batch, tokens)
            except Exception as e:  # noqa: BLE001
                if not self.sched._retry_routed(self, batch, tokens, e):
                    _fail_batch(batch,
                                self.sched._wrap_batch_error(self, batch,
                                                             e))
                continue
            finally:
                pool.note_finished(self.idx, tokens)
            if not fire:
                continue   # hedge machinery already fired completions
            for t in batch:
                try:
                    self.sched.on_complete(t)
                except Exception as e:  # noqa: BLE001
                    # a completion-hook failure must fail THAT task, not
                    # silently kill this worker thread
                    _fail_batch([t], e)


def _seq_key(task: NodeTask) -> Optional[tuple]:
    """Replica-affinity key: LLM ops act on a named sequence whose KV
    state lives on exactly one replica."""
    if task.prim.op not in P.LLM_OPS:
        return None
    return (task.ctx.qid, task.prim.config.get("sid", task.prim.pid))


class PooledEngineScheduler(threading.Thread):
    """Lower-tier scheduler for an EnginePool: forms fused batches from
    one shared queue under the same policies, then routes each batch to a
    replica. Routing is load-aware (least outstanding tokens, including
    KV occupancy) with sequence affinity: once a sequence's prefill lands
    on a replica, every later op of that sequence follows it. A fused
    batch that spans sequences pinned to different replicas is partitioned
    into per-replica sub-batches.

    With ``continuous=True``, decode primitives skip the replica worker
    queues: each is routed (affinity first, then SLOT-AWARE least-load —
    a replica with a free decode slot beats a loaded one) and submitted
    into that replica's persistent decode loop. With chunked prefill
    enabled on the replicas, prefill primitives are loop-dispatched the
    same way — affinity binds a partially prefilled sequence to the
    replica holding its KV; fresh prompts go to the least-loaded replica
    (block-exhausted paged replicas demoted), whose loop then lands the
    chunks between its decode iterations."""

    def __init__(self, pool: EnginePool, executor, policy: str = "topo",
                 period: float = 0.002, continuous: bool = False,
                 fault_tolerance=None, overload=None):
        super().__init__(daemon=True)
        self.pool = pool
        self.engine = pool[0]          # profile source (max_batch, kind)
        self.executor = executor
        self.policy = policy
        self.period = period
        # overload layer (OverloadManager): hedged dispatch for
        # idempotent non-LLM routed batches. None (the default) keeps
        # _execute_routed a plain executor call — byte-identical.
        self.overload = overload
        self.continuous = continuous and hasattr(pool[0], "submit_decode")
        self.chunked = self.continuous and chunked_prefill_enabled(pool[0])
        # fault tolerance (FTConfig): a RecoveryManager owns replica
        # health marking, block reclamation, watchdog hang/deadline
        # detection and per-task recovery handles. None (the default)
        # leaves every dispatch path byte-identical.
        self.ftmgr = None
        if fault_tolerance is not None and \
                hasattr(pool[0], "submit_decode"):
            from repro.serving.faults import RecoveryManager
            self.ftmgr = RecoveryManager(self, fault_tolerance)
            self.ftmgr.start()
        # disaggregated prefill/decode dispatch: prefill ops see only the
        # prefill-specialist replicas, decodes only the decode side (with
        # a KV migration when the sequence was prefilled elsewhere). For
        # plain pools both index sets stay None — every routing call
        # below is byte-identical to the pre-role scheduler.
        self.disagg = isinstance(pool, DisaggregatedEnginePool) and \
            self.continuous
        self._prefill_idx = pool.prefill_indices if self.disagg else None
        self._decode_idx = pool.decode_indices if self.disagg else None
        # graceful degradation: with replicas dead, the pool's route_*
        # views exclude them (demoting to colocated mode when one whole
        # role is gone). All-healthy they equal the static partitions.
        # prefix-aware prefill routing: only when some replica carries a
        # radix prefix cache — flag off keeps routing byte-identical
        self.prefix_aware = any(
            getattr(r, "prefix_cache_mode", "none") == "radix"
            for r in pool)
        self.pending: List[NodeTask] = []
        self.cv = threading.Condition()
        self.running = True
        self.on_complete = None
        self.batches = []              # (size_requests, op) log
        self.decode_submits = []       # (num_requests, op) loop submissions
        self.routes = []               # (replica_idx, op, n_requests, tokens)
        self.affinity: Dict[tuple, int] = {}
        self._aff_lock = threading.Lock()
        self.workers = [_ReplicaWorker(self, i) for i in range(len(pool))]
        for w in self.workers:
            w.start()

    def submit(self, task: NodeTask):
        with self.cv:
            self.pending.append(task)
            self.cv.notify()

    def stop(self):
        self.running = False
        if self.ftmgr is not None:
            self.ftmgr.stop()
        with self.cv:
            self.cv.notify()
        for w in self.workers:
            w.q.put(None)

    def _pf_idx(self):
        return self.pool.route_prefill_indices() if self.disagg else None

    def _dc_idx(self):
        return self.pool.route_decode_indices() if self.disagg else None

    def _slo_tenant(self, t: NodeTask):
        """Tenant identity for decode routing — only when the replicas
        carry an armed SLO policy (None keeps routing byte-identical)."""
        if getattr(self.pool[0], "slo", None) is None:
            return None
        return getattr(t.ctx, "tenant", "default")

    def forget(self, qid: str):
        """Drop a finished query's sequence-affinity entries."""
        with self._aff_lock:
            for k in [k for k in self.affinity if k[0] == qid]:
                del self.affinity[k]

    def _form_batch(self) -> List[NodeTask]:
        max_bs = getattr(self.engine, "max_batch", 8)
        return form_batch(self.pending, self.policy, max_bs)

    def _prefix_route(self, t: NodeTask):
        """Radix prefix-affinity probe for an UNPINNED prefill: the
        replica whose tree holds the longest cached prefix of the
        task's prompt (None -> no replica beats a cold prefill; caller
        falls back to least-loaded). Best-effort: payload construction
        needs upstream store values, and any surprise there must route,
        not raise."""
        if not self.prefix_aware or t.prim.op not in PREFILL_OPS:
            return None
        from repro.core.executors import _prefill_payload
        from repro.core.streams import TokenStream

        def has_stream(v):
            if isinstance(v, TokenStream):
                return True
            if isinstance(v, (list, tuple)):
                return any(has_stream(x) for x in v)
            if isinstance(v, dict):
                return any(has_stream(x) for x in v.values())
            return False

        store = t.ctx.store
        keys = [k for _, k in t.prim.config.get("parts", [])
                if k is not None]
        if "items_key" in t.prim.config:
            keys.append(t.prim.config["items_key"])
        if any(has_stream(store.get(k)) for k in keys):
            # a streaming part would BLOCK payload construction until
            # the upstream decode finishes — never stall the router
            return None
        try:
            payload = _prefill_payload(t.prim, t.ctx)
            if not payload:
                return None
            return self.pool.best_prefix_replica(payload[0]["text"],
                                                 self._pf_idx())
        except Exception:  # noqa: BLE001
            return None

    def _submit_continuous(self, tasks: List[NodeTask]):
        """Route each loop-destined task to a replica (KV affinity
        binds; otherwise decodes go slot-aware least-load, prefill
        chunks block-aware least-load) and admit it into that replica's
        loop."""
        from repro.core.executors import (submit_decode_task,
                                          submit_prefill_task)
        for t in tasks:
            is_prefill = t.prim.op in PREFILL_OPS
            key = _seq_key(t)
            with self._aff_lock:
                idx = self.affinity.get(key) if key is not None else None
                if idx is not None and self.ftmgr is not None and \
                        self.pool.health(idx) == "dead":
                    # pinned replica died since the last op: drop the pin
                    # and re-route; the executor replays the sequence via
                    # recover_decode on the fresh replica
                    del self.affinity[key]
                    idx = None
                if idx is None:
                    if is_prefill:
                        # prefix affinity first: the replica with the
                        # longest radix-cached prefix skips that much
                        # prefill compute
                        idx = self._prefix_route(t)
                        if idx is None:
                            idx = self.pool.least_loaded(self._pf_idx())
                    else:
                        idx = self.pool.least_loaded_decode(
                            self._dc_idx(), tenant=self._slo_tenant(t))
                    if key is not None:
                        self.affinity[key] = idx
            if self.disagg and not is_prefill and \
                    idx < self.pool.n_prefill:
                # two-stage dispatch: the sequence finished prefill on a
                # prefill specialist — migrate its KV to a decode
                # specialist before loop admission
                try:
                    idx = self._handoff(t, idx)
                except Exception as e:  # noqa: BLE001
                    _fail_batch([t], e)
                    continue
            tokens = estimate_tokens(t.prim)
            self.pool.note_decode_submitted(idx, tokens)
            self.routes.append((idx, t.prim.op, t.prim.num_requests,
                                tokens))
            self.decode_submits.append((t.prim.num_requests, t.prim.op))
            # route is MUTABLE: recovery re-routes a task's sequences to
            # another replica mid-flight and updates route["idx"], so the
            # ledger release lands on the replica that actually ran it
            route = {"idx": idx, "tokens": tokens}

            def _done(task, route=route):
                self.pool.note_decode_finished(route["idx"],
                                               route["tokens"])
                self.on_complete(task)

            def _fail(task, route=route):
                # release the ledger even when the task errors (done is
                # not called on the error path)
                self.pool.note_decode_finished(route["idx"],
                                               route["tokens"])

            ft = None
            if self.ftmgr is not None:
                ft = self.ftmgr.handle(
                    t, route, "prefill" if is_prefill else "decode")
            submit = submit_prefill_task if is_prefill \
                else submit_decode_task
            try:
                submit(self.pool[idx], t, _done, on_fail=_fail, ft=ft)
            except Exception as e:  # noqa: BLE001
                if ft is not None:
                    e = ft.wrap(e)   # structured error, not a bare crash
                    ft.settle()
                self.pool.note_decode_finished(route["idx"],
                                               route["tokens"])
                _fail_batch([t], e)

    def _handoff(self, t: NodeTask, src_idx: int) -> int:
        """Second dispatch stage (disaggregated pools): the sequence(s)
        of a decode task were prefilled on prefill replica ``src_idx`` —
        pick the slot/block-aware best decode replica, migrate each
        sequence's KV there (``export_seq`` -> ``import_seq``: blocks
        staged out of the source pool into freshly reserved destination
        blocks, source released atomically) and re-pin affinity so every
        later op of the sequence follows the decode replica. Runs on the
        scheduler thread: the staging copy overlaps the destination
        loop's iteration cadence — resident decodes never stop ticking
        while a handoff is in flight."""
        from repro.core.executors import decode_entries
        dst_idx = self.pool.least_loaded_decode(
            self._dc_idx(), tenant=self._slo_tenant(t))
        if dst_idx == src_idx:
            # degraded pool: the whole decode side is dead and routing
            # demoted to colocated mode — the KV already lives here
            return src_idx
        src, dst = self.pool[src_idx], self.pool[dst_idx]
        try:
            for sid, _ in decode_entries(t.prim, t.ctx):
                if sid in getattr(src, "states", {}):
                    dst.import_seq(src.export_seq(sid))
                    self.pool.note_migration(sid, src_idx, dst_idx)
        except Exception as e:  # noqa: BLE001
            if self.ftmgr is None:
                raise
            # transfer fault: mark the destination and decode colocated
            # on the prefill replica instead. Sequences whose state was
            # already moved off src are replayed there by the executor's
            # recover_decode path (their KV is simply missing on src).
            self.ftmgr.note_failure(dst_idx, e)
            self.ftmgr.events.append(
                ("handoff_fallback", t.ctx.qid, src_idx, dst_idx,
                 repr(e)))
            key = _seq_key(t)
            if key is not None:
                with self._aff_lock:
                    self.affinity[key] = src_idx
            return src_idx
        key = _seq_key(t)
        if key is not None:
            with self._aff_lock:
                self.affinity[key] = dst_idx
        return dst_idx

    # -- the replica router -------------------------------------------------
    def _route(self, batch: List[NodeTask]):
        """Partition a fused batch by sequence affinity; everything
        unpinned goes — as one fused sub-batch — to the least-loaded
        replica and pins its sequences there."""
        groups: Dict[int, List[NodeTask]] = {}
        unpinned: List[NodeTask] = []
        with self._aff_lock:
            for t in batch:
                key = _seq_key(t)
                idx = self.affinity.get(key) if key is not None else None
                if idx is None:
                    unpinned.append(t)
                else:
                    groups.setdefault(idx, []).append(t)
            if unpinned:
                # disaggregated pools: routed batches are prefill work
                # (decodes go through _submit_continuous) — keep them on
                # the prefill specialists
                idx = self.pool.least_loaded(self._pf_idx())
                for t in unpinned:
                    # radix prefix affinity can split a task off the
                    # fused sub-batch — reusing a long cached prefix
                    # beats batching a cold prefill (prefix_aware off:
                    # pidx is always None, one fused sub-batch as before)
                    pidx = self._prefix_route(t)
                    tidx = pidx if pidx is not None else idx
                    groups.setdefault(tidx, []).append(t)
                    key = _seq_key(t)
                    if key is not None:
                        self.affinity[key] = tidx
        for idx, tasks in groups.items():
            tokens = sum(estimate_tokens(t.prim) for t in tasks)
            self.pool.note_queued(idx, tokens)
            self.routes.append((idx, tasks[0].prim.op,
                                sum(t.prim.num_requests for t in tasks),
                                tokens))
            self.workers[idx].q.put((tasks, tokens))

    # -- hedged execution of routed batches ---------------------------------
    def _hedge_delay(self, batch: List[NodeTask]):
        """Backup-issue delay for a routed batch, or None not to hedge:
        requires an armed overload manager, an idempotent op, >1 healthy
        replica and an armed trigger (fixed or percentile)."""
        ov = self.overload
        if ov is None or len(self.pool) < 2:
            return None
        from repro.serving.overload import HEDGEABLE_OPS
        if batch[0].prim.op not in HEDGEABLE_OPS:
            return None
        return ov.hedge.trigger_delay(batch[0].prim.op)

    def _execute_routed(self, worker, batch: List[NodeTask],
                        tokens: int) -> bool:
        """Run one routed batch on its replica, optionally hedged.
        Returns True when the CALLER should fire the completion hooks
        (plain path / primary won), False when the hedge machinery
        already fired them (backup won)."""
        op = batch[0].prim.op
        ov = self.overload
        delay = self._hedge_delay(batch)
        if delay is None:
            t0 = time.time()
            self.executor(worker.engine, batch)
            if ov is not None:
                from repro.serving.overload import HEDGEABLE_OPS
                if op in HEDGEABLE_OPS:
                    ov.hedge.note_latency(op, time.time() - t0)
            return True
        # hedged: first-result-wins. Both executions write identical
        # values into the query store (the ops are deterministic and
        # idempotent), so the "winner" decides only WHO fires the
        # completion hooks — exactly once, guarded by `st`.
        st = {"winner": None, "launched": False}
        lock = threading.Lock()
        primary_done = threading.Event()

        def _fire():
            for t in batch:
                try:
                    self.on_complete(t)
                except Exception as e:  # noqa: BLE001
                    _fail_batch([t], e)

        def _backup():
            if primary_done.wait(delay):
                return                      # primary beat the trigger
            cands = [i for i in self.pool.healthy_indices()
                     if i != worker.idx]
            if not cands:
                return
            bidx = self.pool.least_loaded(cands)
            with lock:
                if st["winner"] is not None:
                    return
                st["launched"] = True
            ov.hedge.note_issued()
            self.pool.note_queued(bidx, tokens)
            self.pool.note_started(bidx, tokens)
            try:
                self.executor(self.pool[bidx], batch)
            except Exception:  # noqa: BLE001
                # a hedge failure is NEVER double-counted: no health
                # mark, no retry charge — the primary path stands alone
                ov.hedge.note_backup_failure()
                return
            finally:
                self.pool.note_finished(bidx, tokens)
            with lock:
                if st["winner"] is not None:
                    ov.hedge.note_loss()    # primary already won
                    return
                st["winner"] = "backup"
            ov.hedge.note_win()
            _fire()

        th = threading.Thread(target=_backup, daemon=True,
                              name=f"hedge:{batch[0].ctx.qid}:{op}")
        th.start()
        t0 = time.time()
        try:
            self.executor(worker.engine, batch)
        except Exception:
            primary_done.set()
            with lock:
                launched = st["launched"]
            if launched:
                # the backup may still rescue the batch — wait for its
                # verdict before failing the tasks
                th.join(timeout=120)
                with lock:
                    if st["winner"] == "backup":
                        ov.hedge.note_rescue()
                        return False   # hedge completed the batch
            raise
        primary_done.set()
        ov.hedge.note_latency(op, time.time() - t0)
        with lock:
            if st["winner"] is not None:
                ov.hedge.note_loss()       # backup beat us; discard ours
                return False
            st["winner"] = "primary"
        return True

    def _retry_routed(self, worker, batch: List[NodeTask], tokens: int,
                      err: Exception) -> bool:
        """A routed (run-to-completion) batch blew up on a replica.
        With fault tolerance on and the error recoverable, mark the
        replica, unpin the batch's sequences from it, and re-route the
        whole batch — capped by cfg.max_retries attempts per task."""
        mgr = self.ftmgr
        if mgr is None:
            return False
        from repro.serving.faults import is_recoverable
        mgr.note_failure(worker.idx, err)
        if not is_recoverable(err):
            return False
        for t in batch:
            a = getattr(t, "ft_attempts", 0)
            if a >= mgr.cfg.max_retries:
                return False
            t.ft_attempts = a + 1
        time.sleep(mgr.cfg.backoff)
        with self._aff_lock:
            for k in [k for k, v in self.affinity.items()
                      if v == worker.idx]:
                del self.affinity[k]
        mgr.events.append(("retry_batch", worker.idx, len(batch),
                           repr(err)))
        self._route(batch)
        return True

    def _wrap_batch_error(self, worker, batch: List[NodeTask],
                          err: Exception) -> Exception:
        """Structured terminal error for a batch-path failure when fault
        tolerance is on (parity with ``TaskRecovery.wrap`` — a request
        must never fail with a bare replica exception)."""
        from repro.serving.faults import RequestError
        if self.ftmgr is None or isinstance(err, RequestError):
            return err
        rep = self.pool[worker.idx]
        t = batch[0]
        out = RequestError(
            f"request {t.ctx.qid}:{t.prim.pid} failed after "
            f"{getattr(t, 'ft_attempts', 0)} recovery attempt(s) "
            f"(replica {getattr(rep, 'name', '?')}): {err}",
            qid=t.ctx.qid, reason=type(err).__name__,
            attempts=getattr(t, "ft_attempts", 0),
            replica=getattr(rep, "name", ""))
        out.__cause__ = err
        return out

    def run(self):
        while self.running:
            with self.cv:
                if not self.pending:
                    self.cv.wait(timeout=0.1)
                    continue
                cont = take_continuous(self.pending, self.chunked) \
                    if self.continuous else []
                batch = self._form_batch()
                for t in batch:
                    self.pending.remove(t)
            self._submit_continuous(cont)
            if not batch:
                if not cont:
                    time.sleep(self.period)
                continue
            self.batches.append((sum(t.prim.num_requests for t in batch),
                                 batch[0].prim.op))
            self._route(batch)


# ---------------------------------------------------------------------------

# ops whose output can be streamed chunk-wise to downstream consumers
STREAMABLE_OPS = {P.DECODE, P.PARTIAL_DECODE}


def stream_eligible(prim: Primitive) -> bool:
    """A decode can stream when it emits ONE plain-text value (per-item
    sequences and multi-item splits post-process the final text)."""
    return (prim.op in STREAMABLE_OPS
            and not prim.config.get("per_item_seq")
            and prim.config.get("num_items", 1) <= 1
            and not prim.config.get("also_aggregate")
            and prim.config.get("stream", True))


class Runtime:
    """Graph scheduler + one lower-tier scheduler per engine pool.
    An engines-dict value may be a bare engine, an EnginePool, or a
    legacy list of replicas (wrapped into an EnginePool when len > 1).
    ``streaming=True`` enables decode->downstream chunk pipelining.
    ``continuous_batching=True`` enables the decode-slot dispatch mode:
    decode primitives are admitted into each LLM replica's persistent
    decode loop (iteration-level continuous batching) instead of being
    executed as blocking run-to-completion batches."""

    def __init__(self, engines: Dict[str, Any], policy: str = "topo",
                 streaming: bool = False,
                 continuous_batching: bool = False,
                 fault_tolerance=None, overload=None):
        from repro.core.executors import execute_batch
        self.engines = engines
        self.policy = policy
        self.streaming = streaming
        self.continuous_batching = continuous_batching
        self.fault_tolerance = fault_tolerance
        # overload layer (serving/overload.OverloadManager): front-door
        # admission control + deadline stamping here, hedged dispatch in
        # the pooled schedulers, degradation hooks in the executors.
        # None (the default) keeps every path byte-identical.
        self.overload = overload
        self.scheds: Dict[str, Any] = {}
        for name, eng in engines.items():
            if isinstance(eng, list):
                eng = EnginePool(eng, name=name) if len(eng) > 1 else eng[0]
            if isinstance(eng, EnginePool):
                s = PooledEngineScheduler(eng, execute_batch, policy,
                                          continuous=continuous_batching,
                                          fault_tolerance=fault_tolerance,
                                          overload=overload)
                if overload is not None and \
                        hasattr(eng[0], "submit_decode"):
                    # LLM pools feed the admission controller's queue-
                    # delay estimate (non-LLM pools are never the
                    # capacity bottleneck the front door guards)
                    overload.admission.register_pool(eng)
            else:
                s = EngineScheduler(eng, execute_batch, policy,
                                    continuous=continuous_batching)
            s.on_complete = self._on_complete
            s.start()
            self.scheds[name] = s
        self.queries: List[QueryContext] = []
        self._lock = threading.Lock()

    def submit(self, graph: Graph, inputs: Dict[str, Any],
               output_key: str = "answer", priority: int = 0,
               slo: Optional[str] = None,
               tenant: str = "default") -> QueryContext:
        ctx = QueryContext(graph, inputs, output_key, priority=priority,
                           slo=slo, tenant=tenant)
        with self._lock:
            self.queries.append(ctx)
        if self.overload is not None:
            from repro.serving.overload import query_class
            cls = query_class(slo, priority)
            self.overload.stamp(ctx, graph, cls)
            err = self.overload.admit(ctx, cls)
            if err is not None:
                # load shed at the front door: the query never consumes
                # engine capacity — structured error, done immediately
                ctx.indegree = {}
                ctx.error = err
                ctx.t_done = time.time()
                ctx.done.set()
                return ctx
        ctx.indegree = {pid: len(n.parents)
                        for pid, n in graph.nodes.items()}
        for n in graph.roots():
            self._dispatch(n, ctx)
        if not graph.nodes:
            self._finish(ctx)
        return ctx

    def _dispatch(self, prim: Primitive, ctx: QueryContext):
        ctx.node_spans.setdefault(prim.pid, (time.time(), None))
        if prim.engine == "control":
            self._run_control(prim, ctx)
            self._complete_node(prim, ctx)
            return
        task = NodeTask(prim, ctx)
        if self.streaming and stream_eligible(prim):
            task.stream = self._open_stream(prim, ctx)
        self.scheds[prim.engine].submit(task)

    def _open_stream(self, prim: Primitive, ctx: QueryContext):
        """Partial-result emission path: publish a TokenStream under the
        decode's output key and arm the first-chunk early-release hook."""
        from repro.core.executors import _out_key
        key = prim.config.get("out_key", _out_key(prim))
        stream = TokenStream(key)
        stream.on_first = lambda: self._stream_ready(prim, ctx)
        ctx.store[key] = stream
        return stream

    def _stream_ready(self, prim: Primitive, ctx: QueryContext):
        """First decoded chunk is out: release the decode's children
        early. Runs on the engine executor thread MID-DECODE, so children
        are dispatched from fresh threads — a control primitive that
        blocks on the stream must not stall the decode loop."""
        ready = []
        with ctx.lock:
            for cpid in prim.children:
                edge = (prim.pid, cpid)
                if edge in ctx.early_edges:
                    continue
                ctx.early_edges.add(edge)
                ctx.indegree[cpid] -= 1
                if ctx.indegree[cpid] == 0:
                    ready.append(ctx.graph.nodes[cpid])
        for n in ready:
            threading.Thread(target=self._dispatch, args=(n, ctx),
                             daemon=True).start()

    def _run_control(self, prim: Primitive, ctx: QueryContext):
        from repro.core.executors import run_control
        run_control(prim, ctx)

    def _on_complete(self, task: NodeTask):
        if not task.managed:
            t0 = task.ctx.node_spans.get(task.prim.pid,
                                         (task.t_arrival, None))[0]
            task.ctx.node_spans[task.prim.pid] = (t0, time.time())
            return
        self._complete_node(task.prim, task.ctx)

    def _complete_node(self, prim: Primitive, ctx: QueryContext):
        t0 = ctx.node_spans.get(prim.pid, (time.time(), None))[0]
        ctx.node_spans[prim.pid] = (t0, time.time())
        ready = []
        with ctx.lock:
            for cpid in prim.children:
                if (prim.pid, cpid) in ctx.early_edges:
                    continue        # already released on first chunk
                ctx.indegree[cpid] -= 1
                if ctx.indegree[cpid] == 0:
                    ready.append(ctx.graph.nodes[cpid])
        for n in ready:
            self._dispatch(n, ctx)
        # finished when every node has been completed
        if all(v <= 0 for v in ctx.indegree.values()) and \
                all(ctx.node_spans.get(pid, (0, None))[1] is not None
                    for pid in ctx.graph.nodes):
            self._finish(ctx)

    def _finish(self, ctx: QueryContext):
        if ctx.done.is_set():
            return
        ctx.t_done = time.time()
        ctx.done.set()
        if self.overload is not None:
            # feed the admission controller's service-rate estimate
            self.overload.note_query_done(ctx)
        # release LLM sequence state on every replica of every pool
        for name, eng in self.engines.items():
            for inst in replicas_of(eng):
                if hasattr(inst, "release"):
                    for sid in ctx.sids:
                        inst.release(sid)
                if hasattr(inst, "drop"):
                    inst.drop(ctx.qid)
        for s in self.scheds.values():
            if isinstance(s, PooledEngineScheduler):
                s.forget(ctx.qid)

    def shutdown(self):
        for s in self.scheds.values():
            s.stop()
        for eng in self.engines.values():
            for inst in replicas_of(eng):
                if hasattr(inst, "stop_decode_loop"):
                    inst.stop_decode_loop()
