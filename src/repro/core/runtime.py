"""Two-tier runtime (paper §5).

Upper tier — GraphScheduler: tracks each query's e-graph, dispatches
primitives whose in-degree reaches zero to the per-engine schedulers, and
manages the per-query object store.

Lower tier — EngineScheduler (one thread per engine): fuses primitive
requests from concurrent queries into engine batches under one of three
policies:
  'po'   per-invocation oriented — one query's bundle at a time (baseline)
  'to'   throughput oriented    — FIFO dynamic batching to max batch
  'topo' topology-aware batching — Algorithm 2: bucket by query, order by
         reverse-topological depth, earliest-arrival buckets first.

Control primitives (Condition/Aggregate) run inline on the graph
scheduler thread. Dependent pre-scheduling (§6, communication mitigation)
is modeled by resolving payloads lazily at execution time from the shared
object store, so a parent's output is visible to its pre-issued child
without an extra scheduler round-trip.
"""
from __future__ import annotations

import itertools
import queue
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import numpy as np

from repro.core import primitives as P
from repro.core.primitives import Graph, Primitive

_qid = itertools.count()


class QueryContext:
    def __init__(self, graph: Graph, inputs: Dict[str, Any],
                 output_key: str = "answer", priority: int = 0):
        self.qid = f"q{next(_qid)}"
        self.graph = graph
        self.store: Dict[str, Any] = dict(inputs)
        self.output_key = output_key
        self.priority = priority    # higher = served first (paper §7.2)
        self.done = threading.Event()
        self.t_submit = time.time()
        self.t_done: Optional[float] = None
        self.node_spans: Dict[str, tuple] = {}     # pid -> (t0, t1)
        self.sids: set = set()
        self.lock = threading.Lock()
        self.error: Optional[Exception] = None

    @property
    def latency(self):
        return (self.t_done or time.time()) - self.t_submit

    def result(self, timeout=120):
        self.done.wait(timeout)
        if self.error:
            raise self.error
        return self.store.get(self.output_key)


@dataclass
class NodeTask:
    prim: Primitive
    ctx: QueryContext
    t_arrival: float = field(default_factory=time.time)
    managed: bool = True     # False: baseline orchestrators drive progress

    @property
    def depth(self):
        return self.prim.depth


# ---------------------------------------------------------------------------

class EngineScheduler(threading.Thread):
    def __init__(self, engine, executor, policy: str = "topo",
                 period: float = 0.002):
        super().__init__(daemon=True)
        self.engine = engine
        self.executor = executor
        self.policy = policy
        self.period = period
        self.pending: List[NodeTask] = []
        self.cv = threading.Condition()
        self.running = True
        self.on_complete = None        # set by Runtime
        self.batches = []              # (size_requests, op) log

    def submit(self, task: NodeTask):
        with self.cv:
            self.pending.append(task)
            self.cv.notify()

    def stop(self):
        self.running = False
        with self.cv:
            self.cv.notify()

    # -- batch formation ----------------------------------------------------
    def _form_batch(self) -> List[NodeTask]:
        if not self.pending:
            return []
        max_bs = getattr(self.engine, "max_batch", 8)
        if self.policy == "po":
            # bundle = same (query, component) as the head task, FIFO
            head = min(self.pending, key=lambda t: t.t_arrival)
            bundle = [t for t in self.pending
                      if t.ctx is head.ctx
                      and t.prim.component == head.prim.component
                      and t.prim.op == head.prim.op]
            return bundle[:max_bs]
        if self.policy == "to":
            self.pending.sort(key=lambda t: t.t_arrival)
            op = self.pending[0].prim.op
            batch, slots = [], max_bs
            for t in self.pending:
                if t.prim.op != op:
                    continue
                if t.prim.num_requests > slots and batch:
                    break
                batch.append(t)
                slots -= t.prim.num_requests
                if slots <= 0:
                    break
            return batch
        # 'topo' — Algorithm 2: bucket pending nodes by query; buckets
        # ordered by (priority desc, earliest arrival); round-robin over
        # buckets taking the HIGHEST-DEPTH node of each bucket per round
        # (Fig. 7 batches the most graph-advancing primitive of each
        # query together). Priority implements the paper's §7.2
        # app-priority discussion as primitive metadata.
        buckets: Dict[str, List[NodeTask]] = {}
        for t in self.pending:
            buckets.setdefault(t.ctx.qid, []).append(t)
        ordered = sorted(buckets.values(),
                         key=lambda b: (-max(t.ctx.priority for t in b),
                                        min(t.t_arrival for t in b)))
        for b in ordered:
            b.sort(key=lambda t: -t.prim.depth)
        batch, slots, op = [], max_bs, None
        while slots > 0:
            took = False
            for b in ordered:
                if slots <= 0:
                    break
                for t in b:
                    if op is not None and t.prim.op != op:
                        continue
                    if t.prim.num_requests > slots and batch:
                        continue
                    op = op or t.prim.op
                    batch.append(t)
                    b.remove(t)
                    slots -= t.prim.num_requests
                    took = True
                    break
            if not took:
                break
        return batch

    def run(self):
        while self.running:
            with self.cv:
                if not self.pending:
                    self.cv.wait(timeout=0.1)
                    continue
                batch = self._form_batch()
                for t in batch:
                    self.pending.remove(t)
            if not batch:
                time.sleep(self.period)
                continue
            self.batches.append((sum(t.prim.num_requests for t in batch),
                                 batch[0].prim.op))
            try:
                self.executor(self.engine, batch)
            except Exception as e:  # noqa: BLE001
                for t in batch:
                    t.ctx.error = e
                    t.ctx.done.set()
                continue
            for t in batch:
                self.on_complete(t)


# ---------------------------------------------------------------------------

class EngineGroup:
    """Multiple instances of one engine behind a load-balancing router
    (paper §6/§7.1: each LLM provisioned with two instances; load metric
    = outstanding requests, with sequence->instance AFFINITY for LLM ops
    since the KV state lives on one instance)."""

    def __init__(self, scheds: List[EngineScheduler]):
        self.scheds = scheds
        self.affinity: Dict[tuple, EngineScheduler] = {}
        self._lock = threading.Lock()

    def _load(self, s: EngineScheduler) -> int:
        with s.cv:
            return sum(t.prim.num_requests for t in s.pending)

    def submit(self, task: NodeTask):
        sid = task.prim.config.get("sid")
        if sid is not None:
            key = (task.ctx.qid, sid)
            with self._lock:
                s = self.affinity.get(key)
                if s is None:
                    s = min(self.scheds, key=self._load)
                    self.affinity[key] = s
        else:
            s = min(self.scheds, key=self._load)
        s.submit(task)

    @property
    def batches(self):
        return [b for s in self.scheds for b in s.batches]

    def stop(self):
        for s in self.scheds:
            s.stop()


class Runtime:
    """Graph scheduler + engine scheduler pool over a set of engines.
    An engines-dict value may be a LIST of replicas -> EngineGroup."""

    def __init__(self, engines: Dict[str, Any], policy: str = "topo"):
        from repro.core.executors import execute_batch
        self.engines = engines
        self.policy = policy
        self.scheds: Dict[str, Any] = {}
        for name, eng in engines.items():
            replicas = eng if isinstance(eng, list) else [eng]
            group = []
            for inst in replicas:
                s = EngineScheduler(inst, execute_batch, policy)
                s.on_complete = self._on_complete
                group.append(s)
                s.start()
            self.scheds[name] = (EngineGroup(group) if len(group) > 1
                                 else group[0])
        self.queries: List[QueryContext] = []
        self._lock = threading.Lock()

    def submit(self, graph: Graph, inputs: Dict[str, Any],
               output_key: str = "answer",
               priority: int = 0) -> QueryContext:
        ctx = QueryContext(graph, inputs, output_key, priority=priority)
        with self._lock:
            self.queries.append(ctx)
        ctx.indegree = {pid: len(n.parents)
                        for pid, n in graph.nodes.items()}
        for n in graph.roots():
            self._dispatch(n, ctx)
        if not graph.nodes:
            self._finish(ctx)
        return ctx

    def _dispatch(self, prim: Primitive, ctx: QueryContext):
        ctx.node_spans.setdefault(prim.pid, (time.time(), None))
        if prim.engine == "control":
            self._run_control(prim, ctx)
            self._complete_node(prim, ctx)
            return
        self.scheds[prim.engine].submit(NodeTask(prim, ctx))

    def _run_control(self, prim: Primitive, ctx: QueryContext):
        from repro.core.executors import run_control
        run_control(prim, ctx)

    def _on_complete(self, task: NodeTask):
        if not task.managed:
            t0 = task.ctx.node_spans.get(task.prim.pid,
                                         (task.t_arrival, None))[0]
            task.ctx.node_spans[task.prim.pid] = (t0, time.time())
            return
        self._complete_node(task.prim, task.ctx)

    def _complete_node(self, prim: Primitive, ctx: QueryContext):
        t0 = ctx.node_spans.get(prim.pid, (time.time(), None))[0]
        ctx.node_spans[prim.pid] = (t0, time.time())
        ready = []
        with ctx.lock:
            for cpid in prim.children:
                ctx.indegree[cpid] -= 1
                if ctx.indegree[cpid] == 0:
                    ready.append(ctx.graph.nodes[cpid])
            remaining = sum(1 for v in ctx.indegree.values() if v > 0)
        for n in ready:
            self._dispatch(n, ctx)
        # finished when every node has been completed
        if all(v <= 0 for v in ctx.indegree.values()) and \
                all(ctx.node_spans.get(pid, (0, None))[1] is not None
                    for pid in ctx.graph.nodes):
            self._finish(ctx)

    def _finish(self, ctx: QueryContext):
        if ctx.done.is_set():
            return
        ctx.t_done = time.time()
        ctx.done.set()
        # release LLM sequence state on every instance
        for name, eng in self.engines.items():
            for inst in (eng if isinstance(eng, list) else [eng]):
                if hasattr(inst, "release"):
                    for sid in ctx.sids:
                        inst.release(sid)
                if hasattr(inst, "drop"):
                    inst.drop(ctx.qid)

    def shutdown(self):
        for s in self.scheds.values():
            s.stop()
