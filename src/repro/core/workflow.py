"""Developer-facing workflow template API (paper §3.2, Listing 1).

Developers register execution engines, declare components (`Node`) with
engines/roles/IO and optimization annotations, and chain them with `>>`.
The template is coarse-grained — per-query decomposition into primitives
happens in pgraph.GraphTransform.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional


@dataclass
class EngineSpec:
    """Registered execution engine + latency/batching profile."""
    name: str
    kind: str                      # 'llm' | 'embedding' | 'rerank' |
    #                                'vectordb' | 'chunker' | 'search_api'
    max_batch: int = 8             # max efficient batch (profiled)
    max_tokens: int = 1024         # LLM: max efficient batched token count
    instances: int = 1             # pool size (EnginePool replicas)
    resource: Dict[str, int] = field(default_factory=dict)
    config: Dict[str, Any] = field(default_factory=dict)

    @classmethod
    def from_engine(cls, name: str, eng) -> "EngineSpec":
        """Pool-aware registration: `eng` may be a bare engine, a list of
        replicas, or an EnginePool; the profile comes from the primary
        replica and `instances` reflects the pool size."""
        from repro.core.engine_pool import pool_size, primary_of
        inst = primary_of(eng)
        return cls(name=name, kind=getattr(inst, "kind", "misc"),
                   max_batch=getattr(inst, "max_batch", 8),
                   max_tokens=getattr(inst, "max_tokens", 1024),
                   instances=pool_size(eng))


class Node:
    """A workflow template component.

    config may carry a ``degrade`` annotation — the component's graceful-
    degradation contract, activated stepwise by the overload layer's
    brown-out ladder (serving/overload.py) and ignored otherwise:
      ``{"min_top_k": k}``   retrieval/rerank top_k may shrink to k (L1)
      ``{"skippable": True}`` the component may be skipped outright (L2,
                             rerank: unscored candidate passthrough)
      ``{"min_new": m}``      generation max_new may halve down to m (L3)
      ``{"chunk_cap": c}``    chunked prefill capped to c tokens/pass (L3)
    """

    def __init__(self, kind: str, engine: str, name: Optional[str] = None,
                 anno: Optional[str] = None, config: Optional[dict] = None):
        self.kind = kind
        self.engine = engine
        self.name = name or kind
        self.anno = anno or ""            # 'batchable' | 'splittable' | ''
        self.config = dict(config or {})
        self.downstream: List["Node"] = []

    def __rshift__(self, other: "Node") -> "Node":
        self.downstream.append(other)
        return other

    def __repr__(self):
        return f"Node({self.name}:{self.kind}@{self.engine})"


class APP:
    """An application: engines + workflow template."""

    def __init__(self, name: str):
        self.name = name
        self.engines: Dict[str, EngineSpec] = {}
        self.template: List[Node] = []

    @classmethod
    def init(cls, name: str = "app") -> "APP":
        return cls(name)

    def register_engine(self, spec: EngineSpec):
        self.engines[spec.name] = spec
        return spec

    def update_template(self, nodes: List[Node]):
        self.template = list(nodes)
        for n in nodes:
            if n.engine not in self.engines:
                raise ValueError(f"{n}: engine {n.engine!r} not registered")
        return self

    def template_edges(self):
        edges = []
        for n in self.template:
            for d in n.downstream:
                edges.append((n, d))
        return edges
