"""Engine pools: N replicas of one engine behind a load-aware router.

The paper's testbed provisions two instances per LLM (§7.1) and its
instance-scaling / colocation results depend on dispatching work across
replicas. An ``EnginePool`` owns the replicas (built by ``replicate`` via
each engine's ``clone()`` — model weights are shared, per-replica state
such as the KV store is not) plus the per-replica load ledger the
lower-tier router consults.

The load metric is tokens, not queue length: for each replica it sums
  queued    — token estimate of batches routed to the replica but not
              yet executing,
  inflight  — token estimate of the batch currently executing,
  resident  — KV-cache occupancy (tokens held by live sequences on that
              replica, reported by the engine's ``kv_occupancy()``).
A queue-length metric would treat a 2000-token prefill and an 8-token
judge decode as equal work; token accounting is what makes colocated
heterogeneous apps balance (Fig. 9).
"""
from __future__ import annotations

import threading
from typing import Any, Dict, List

from repro.core import primitives as P

# Resident KV tokens cost less than tokens that still need compute: they
# occupy memory and lengthen future attention, but are not queued work.
RESIDENT_WEIGHT = 0.25


def estimate_tokens(prim) -> int:
    """Token-work estimate for routing. Decode work scales with max_new;
    prefill with the (profiled) prompt length; encoder/model-free ops with
    their request count."""
    cfg = prim.config
    if prim.op in (P.DECODE, P.PARTIAL_DECODE):
        return prim.num_requests * int(cfg.get("max_new", 24))
    if prim.op in (P.PREFILL, P.PARTIAL_PREFILL, P.FULL_PREFILL):
        return prim.num_requests * int(cfg.get("est_prompt_tokens", 64))
    return prim.num_requests * 8


class _ReplicaLoad:
    __slots__ = ("queued", "inflight")

    def __init__(self):
        self.queued = 0
        self.inflight = 0


class EnginePool:
    """Replica container + load ledger. The pool is engine-kind agnostic:
    anything exposing the op_* executor interface and (optionally)
    ``clone()`` / ``kv_occupancy()`` can be pooled."""

    def __init__(self, replicas: List[Any], name: str = "",
                 role: str = "unified"):
        if not replicas:
            raise ValueError("EnginePool needs at least one replica")
        if role not in ("prefill", "decode", "unified", "disaggregated"):
            raise ValueError(f"unknown pool role {role!r}")
        self.replicas = list(replicas)
        self.name = name or getattr(replicas[0], "name", "pool")
        # role specialization: "unified" replicas serve both phases (the
        # default — byte-identical to the pre-role pool); "prefill" /
        # "decode" pools serve one phase; DisaggregatedEnginePool mixes
        # both behind one engine name with a migration handoff between
        # them. Every replica is stamped for introspection.
        self.role = role
        for r in self.replicas:
            setattr(r, "pool_role", role)
        self._loads = [_ReplicaLoad() for _ in self.replicas]
        self._lock = threading.Lock()
        # per-replica health (fault tolerance): healthy -> suspect ->
        # dead. All-healthy is the steady state and every health check
        # below reduces to a no-op then — flag-off routing is identical.
        self._health = ["healthy"] * len(self.replicas)
        self._health_reasons: Dict[int, str] = {}

    @classmethod
    def replicate(cls, engine, n: int, name: str = "") -> "EnginePool":
        """Build a pool of `n` replicas from a prototype engine via its
        ``clone()`` (shared weights, fresh per-replica state). The
        prototype itself is replica 0."""
        reps = [engine]
        for i in range(1, n):
            if not hasattr(engine, "clone"):
                raise TypeError(
                    f"{type(engine).__name__} has no clone(); cannot build "
                    f"a pool of {n}")
            reps.append(engine.clone(i))
        return cls(reps, name=name or getattr(engine, "name", ""))

    # -- container protocol -------------------------------------------------
    def __len__(self):
        return len(self.replicas)

    def __iter__(self):
        return iter(self.replicas)

    def __getitem__(self, i):
        return self.replicas[i]

    # -- load ledger (token units) ------------------------------------------
    def note_queued(self, i: int, tokens: int):
        with self._lock:
            self._loads[i].queued += tokens

    def note_started(self, i: int, tokens: int):
        with self._lock:
            self._loads[i].queued -= tokens
            self._loads[i].inflight += tokens

    def note_finished(self, i: int, tokens: int):
        with self._lock:
            self._loads[i].inflight -= tokens

    # Continuous-batching decodes skip the routed-batch queue: work goes
    # straight into the replica's decode loop, so it is in-flight from
    # submission until the sequence is evicted.
    def note_decode_submitted(self, i: int, tokens: int):
        with self._lock:
            self._loads[i].inflight += tokens

    def note_decode_finished(self, i: int, tokens: int):
        with self._lock:
            self._loads[i].inflight -= tokens

    def load(self, i: int) -> float:
        """Outstanding token-work of replica i (queued + in-flight +
        discounted resident KV occupancy). Paged replicas report
        occupancy in ALLOCATED BLOCKS (block-quantized tokens, shared
        prefixes counted once) — true memory, not amortized tokens."""
        resident = getattr(self.replicas[i], "kv_occupancy", lambda: 0)()
        with self._lock:
            l = self._loads[i]
            return l.queued + l.inflight + RESIDENT_WEIGHT * resident

    def kv_free_blocks(self, i: int):
        """Free (unreserved) paged-KV blocks of replica i; None when the
        replica has no block pool."""
        fn = getattr(self.replicas[i], "kv_free_blocks", None)
        return fn() if fn is not None else None

    # -- replica health (fault tolerance) -----------------------------------
    _HEALTH_ORDER = {"healthy": 0, "suspect": 1, "dead": 2}

    def health(self, i: int) -> str:
        """Effective health of replica i: the worse of the pool's mark
        (detection-side) and the engine's own ``health`` attribute
        (set when its decode loop dies or a crash is injected)."""
        eng = getattr(self.replicas[i], "health", "healthy")
        mine = self._health[i]
        return eng if self._HEALTH_ORDER.get(eng, 0) > \
            self._HEALTH_ORDER.get(mine, 0) else mine

    def health_reason(self, i: int) -> str:
        return self._health_reasons.get(i, "")

    def mark_suspect(self, i: int, reason: str = ""):
        """Quarantine-light: a suspect replica only receives work when
        no healthy candidate remains (demoted in every routing key)."""
        with self._lock:
            if self._health[i] == "healthy":
                self._health[i] = "suspect"
                self._health_reasons[i] = reason

    def mark_dead(self, i: int, reason: str = "") -> bool:
        """Quarantine: a dead replica is excluded from routing entirely.
        Returns True on the healthy/suspect -> dead transition (callers
        reclaim its blocks exactly once)."""
        with self._lock:
            was = self._health[i]
            self._health[i] = "dead"
            if was != "dead":
                # keep the FIRST death reason — later marks are echoes
                self._health_reasons[i] = reason
            return was != "dead"

    def mark_healthy(self, i: int):
        """Re-admit a replica (operator action / tests)."""
        with self._lock:
            self._health[i] = "healthy"
            self._health_reasons.pop(i, None)

    def healthy_indices(self, indices=None) -> list:
        """Candidate set with dead replicas excluded. Falls back to the
        unfiltered set when EVERY candidate is dead — routing then fails
        at submit time with the replica's own error rather than silently
        picking nothing."""
        base = list(indices if indices is not None
                    else range(len(self.replicas)))
        alive = [i for i in base if self.health(i) != "dead"]
        return alive or base

    def _suspect_rank(self, i: int) -> int:
        return 0 if self.health(i) == "healthy" else 1

    def least_loaded(self, indices=None) -> int:
        """Replica for routed batch work. A replica whose paged-KV pool
        is EXHAUSTED only receives work when every replica is exhausted
        (admission backpressure at the routing tier). ``indices``
        restricts the candidate set (role-specialized dispatch); None —
        the default — considers every replica, byte-identical to the
        pre-role router."""
        def key(i):
            free = self.kv_free_blocks(i)
            return (self._suspect_rank(i),
                    0 if (free is None or free > 0) else 1, self.load(i))
        return min(self.healthy_indices(indices), key=key)

    # -- prefix-aware routing (radix prefix cache) --------------------------
    def prefix_match_len(self, i: int, text: str) -> int:
        """Radix-cached prefix length of ``text`` on replica i (0 when
        the replica has no radix cache). Read-only probe."""
        fn = getattr(self.replicas[i], "prefix_match_len", None)
        return fn(text) if fn is not None else 0

    def best_prefix_replica(self, text: str, indices=None):
        """Replica whose radix tree holds the LONGEST cached prefix of
        ``text`` — prefill there reuses the most KV. Exhausted pools are
        demoted exactly like least_loaded; ties (including the common
        no-match-anywhere case) return None so the caller falls back to
        block-aware least-loaded routing. ``indices`` restricts the
        candidate set (role-specialized dispatch)."""
        best_i, best_m = None, 0
        for i in self.healthy_indices(indices):
            if self.health(i) == "dead":
                continue          # all-dead fallback set: no prefix reuse
            free = self.kv_free_blocks(i)
            if free is not None and free <= 0:
                continue
            m = self.prefix_match_len(i, text)
            if m > best_m:
                best_i, best_m = i, m
        return best_i

    # -- slot-aware decode routing (continuous batching) --------------------
    def decode_slots_free(self, i: int):
        """Free decode-loop slots of replica i; None when the replica
        does not expose slot accounting."""
        fn = getattr(self.replicas[i], "decode_slots_free", None)
        return fn() if fn is not None else None

    def _tenant_slots_held(self, i: int, tenant) -> int:
        """Decode slots ``tenant`` currently holds on replica i (0 when
        the replica has no armed SLO policy / slot ledger)."""
        pol = getattr(self.replicas[i], "slo", None)
        if tenant is None or pol is None or \
                getattr(pol, "slots", None) is None:
            return 0
        return pol.slots.usage_of(tenant)

    def least_loaded_decode(self, indices=None, tenant=None) -> int:
        """Replica for a new continuous-batching decode: a replica with a
        free decode slot starts the sequence NEXT iteration, while a full
        loop queues it behind a whole sequence — so free-slot replicas
        win outright; a block-exhausted paged pool demotes a replica the
        same way (its loop would defer admission); ties fall back to
        token load. ``indices`` restricts the candidate set
        (role-specialized dispatch). ``tenant`` (SLO scheduling) spreads
        one tenant's sequences across replicas: among equally-free
        replicas, the one where the tenant holds the fewest decode slots
        wins — per-replica fair-share ledgers then see balanced holdings
        instead of one replica absorbing the whole tenant. ``tenant``
        None (flag off) keeps the key byte-identical."""
        def key(i):
            slots = self.decode_slots_free(i)
            blocks = self.kv_free_blocks(i)
            has_free = (slots is None or slots > 0) and \
                (blocks is None or blocks > 0)
            return (self._suspect_rank(i), 0 if has_free else 1,
                    self._tenant_slots_held(i, tenant), self.load(i))
        return min(self.healthy_indices(indices), key=key)

    def tenant_stats(self) -> Dict[str, dict]:
        """Pool-level per-tenant/per-class stats: replica snapshots
        merged (counts sum; latency percentiles keep the max — a
        conservative pool tail bound). Empty when no replica has an
        armed SLO policy."""
        out: Dict[str, dict] = {}
        for r in self.replicas:
            fn = getattr(r, "tenant_stats", None)
            if fn is None:
                continue
            for key, row in fn().items():
                dst = out.setdefault(key, {})
                for f, v in row.items():
                    if f.endswith("_ms"):
                        dst[f] = max(dst.get(f, 0.0), v)
                    else:
                        dst[f] = dst.get(f, 0) + v
        return out

    def loads(self) -> List[float]:
        return [self.load(i) for i in range(len(self.replicas))]

    def outstanding_tokens(self) -> float:
        """Total outstanding token-work across the pool (queued +
        in-flight + discounted resident) — the queue-backlog signal the
        overload layer's admission controller reads at the front door."""
        return float(sum(self.loads()))

    def __repr__(self):
        return f"<EnginePool {self.name} x{len(self.replicas)}>"


class DisaggregatedEnginePool(EnginePool):
    """Role-specialized pool: replicas [0, n_prefill) are PREFILL
    specialists, the rest DECODE specialists, behind one engine name.

    Prefill replicas run (chunked or monolithic) prefill at full token
    budget with no co-resident decodes to time-slice against; decode
    replicas run the continuous decode loop with no prompt chunks
    stealing budget. The scheduler's two-stage dispatch routes PREFILL
    ops to the prefill side (prefix-aware, block-aware least-loaded as
    in a unified pool, restricted to ``prefill_indices``) and, when the
    first decode op of a sequence arrives, migrates the sequence's paged
    KV blocks to the chosen decode replica (``export_seq``/``import_seq``
    — the ``migrate_blocks`` handoff) before admitting it into that
    replica's loop. Everything EnginePool provides (load ledger, container
    protocol, registry helpers) applies unchanged — the subclass only
    partitions the candidate sets and records handoffs."""

    def __init__(self, replicas: List[Any], n_prefill: int, name: str = ""):
        if not 1 <= n_prefill < len(replicas):
            raise ValueError(
                f"disaggregated pool needs >=1 prefill and >=1 decode "
                f"replica (got n_prefill={n_prefill} of "
                f"{len(replicas)} replicas)")
        super().__init__(replicas, name=name, role="disaggregated")
        self.n_prefill = n_prefill
        for i, r in enumerate(self.replicas):
            setattr(r, "pool_role",
                    "prefill" if i < n_prefill else "decode")
        self.migrations: List[tuple] = []   # (sid, src_idx, dst_idx)

    @classmethod
    def disaggregate(cls, engine, n_prefill: int, n_decode: int,
                     name: str = "") -> "DisaggregatedEnginePool":
        """Build a prefill/decode-specialized pool from one prototype
        engine (replica 0 is the prototype, a prefill specialist) —
        clones share weights, per-replica KV pools are private exactly
        as in ``replicate``."""
        if n_prefill < 1 or n_decode < 1:
            raise ValueError(
                f"need >=1 prefill and >=1 decode replica, got "
                f"{n_prefill}/{n_decode}")
        if not hasattr(engine, "clone"):
            raise TypeError(
                f"{type(engine).__name__} has no clone(); cannot "
                f"disaggregate")
        reps = [engine] + [engine.clone(i)
                           for i in range(1, n_prefill + n_decode)]
        return cls(reps, n_prefill,
                   name=name or getattr(engine, "name", ""))

    @property
    def prefill_indices(self) -> tuple:
        return tuple(range(self.n_prefill))

    @property
    def decode_indices(self) -> tuple:
        return tuple(range(self.n_prefill, len(self.replicas)))

    def role_of(self, i: int) -> str:
        return "prefill" if i < self.n_prefill else "decode"

    # -- graceful degradation (fault tolerance) -----------------------------
    # When every replica of one role is dead, the pool DEMOTES to
    # colocated mode on the surviving role's replicas: a dead decode
    # side sends decodes to the prefill specialists (and vice versa)
    # rather than stranding the request. All-healthy, these return the
    # static role partitions — flag-off routing is identical.

    def route_prefill_indices(self) -> tuple:
        alive = tuple(i for i in self.prefill_indices
                      if self.health(i) != "dead")
        if alive:
            return alive
        fallback = tuple(i for i in self.decode_indices
                         if self.health(i) != "dead")
        return fallback or self.prefill_indices

    def route_decode_indices(self) -> tuple:
        alive = tuple(i for i in self.decode_indices
                      if self.health(i) != "dead")
        if alive:
            return alive
        fallback = tuple(i for i in self.prefill_indices
                         if self.health(i) != "dead")
        return fallback or self.decode_indices

    def degraded(self) -> bool:
        """True when one whole role is dead and the pool runs colocated."""
        return (all(self.health(i) == "dead" for i in self.decode_indices)
                or all(self.health(i) == "dead"
                       for i in self.prefill_indices))

    def note_migration(self, sid: str, src_idx: int, dst_idx: int):
        with self._lock:
            self.migrations.append((sid, src_idx, dst_idx))

    def __repr__(self):
        return (f"<DisaggregatedEnginePool {self.name} "
                f"{self.n_prefill}p+{len(self.replicas) - self.n_prefill}d>")


# ---------------------------------------------------------------------------
# Registry helpers — an engines-dict value may be a bare engine, a list of
# replicas (legacy), or an EnginePool.

def replicas_of(eng) -> list:
    if isinstance(eng, EnginePool):
        return list(eng.replicas)
    if isinstance(eng, list):
        return list(eng)
    return [eng]


def pool_size(eng) -> int:
    return len(replicas_of(eng))


def primary_of(eng):
    """Representative replica (profile source for EngineSpec)."""
    return replicas_of(eng)[0]


def pair_replicas(target, draft) -> List[tuple]:
    """Draft/target placement for speculative decoding: pair replica i of
    the target pool with replica ``i % len(draft)`` of the draft pool —
    the index-aligned co-location the paper's shared app pool already
    provides (core_llm replica i sits next to lite_llm replica i), cycled
    when the pools are sized differently. Works on bare engines, legacy
    replica lists, and EnginePools."""
    t, d = replicas_of(target), replicas_of(draft)
    return [(t[i], d[i % len(d)]) for i in range(len(t))]


def build_pools(engines: Dict[str, Any],
                sizes: Dict[str, int]) -> Dict[str, Any]:
    """Replace selected engines with pools: sizes maps engine name -> n.
    Engines absent from `sizes` (or with n == 1) pass through untouched."""
    out = dict(engines)
    for name, n in sizes.items():
        if n > 1 and name in out and not isinstance(out[name], EnginePool):
            out[name] = EnginePool.replicate(out[name], n, name=name)
    return out


def disaggregate_pools(engines: Dict[str, Any], names,
                       n_prefill: int, n_decode: int) -> Dict[str, Any]:
    """Replace the named engines with disaggregated prefill/decode pools
    (``--disaggregate`` wiring). Engines already pooled or absent pass
    through untouched."""
    out = dict(engines)
    for name in names:
        if name in out and not isinstance(out[name], EnginePool):
            out[name] = DisaggregatedEnginePool.disaggregate(
                out[name], n_prefill, n_decode, name=name)
    return out
