"""Teola facade: parse query -> p-graph -> optimize -> e-graph -> schedule.

Also hosts the baseline orchestrators used in the paper's evaluation:
  - LlamaDist      module-chain execution (coarse orchestration)
  - LlamaDistPC    + manual module parallelization + instruction KV reuse
  - AutoGenLike    agent-grouped sequential execution
All baselines share the same engines and runtime; only orchestration
granularity (and the engine scheduling policy) differs.
"""
from __future__ import annotations

import threading
import time
from typing import Dict, List, Optional

from repro.core.engine_pool import replicas_of
from repro.core.passes import ALL_PASSES, graph_opt
from repro.core.pgraph import graph_transform
from repro.core.primitives import Graph
from repro.core.runtime import QueryContext, Runtime
from repro.core.workflow import APP


class Teola:
    def __init__(self, app: APP, engines: Dict, *, policy: str = "topo",
                 passes=ALL_PASSES, streaming: bool = False,
                 continuous_batching: bool = False,
                 fault_tolerance=None, overload=None):
        self.app = app
        self.engines = engines
        self.passes = passes
        self.runtime = Runtime(engines, policy=policy, streaming=streaming,
                               continuous_batching=continuous_batching,
                               fault_tolerance=fault_tolerance,
                               overload=overload)
        self._egraph_cache: Dict[str, Graph] = {}

    def _cache_key(self, query: dict):
        """e-graph structure depends only on the query's SIZE parameters
        (paper §4.2: cache and reuse optimized subgraphs)."""
        if "docs" not in query:
            return (self.app.name, 0)
        from repro.engines.model_free import ChunkerEngine
        chunk = next((n for n in self.app.template if n.kind == "chunk"),
                     None)
        cs = chunk.config.get("chunk_size", 48) if chunk else 48
        ov = chunk.config.get("overlap", 8) if chunk else 8
        return (self.app.name,
                ChunkerEngine.count_chunks(query["docs"], cs, ov))

    def build_egraph(self, query: dict, C: Optional[dict] = None,
                     use_cache: bool = True) -> Graph:
        key = self._cache_key(query) if (use_cache and C is None) else None
        if key is not None and key in self._egraph_cache:
            return self._egraph_cache[key]
        g = graph_transform(self.app, query, C)
        g = graph_opt(g, self.app.engines, self.passes)
        if key is not None:
            self._egraph_cache[key] = g
        return g

    def submit(self, query: dict, C: Optional[dict] = None,
               priority: int = 0, slo: Optional[str] = None,
               tenant: str = "default") -> QueryContext:
        g = self.build_egraph(query, C)
        inputs = {k: v for k, v in query.items() if k != "id"}
        return self.runtime.submit(g, inputs, priority=priority,
                                   slo=slo, tenant=tenant)

    def query(self, query: dict, C: Optional[dict] = None, timeout=120,
              priority: int = 0, slo: Optional[str] = None,
              tenant: str = "default"):
        ctx = self.submit(query, C, priority=priority, slo=slo,
                          tenant=tenant)
        out = ctx.result(timeout)
        return out, ctx

    def shutdown(self):
        self.runtime.shutdown()


# ---------------------------------------------------------------------------
# Baselines

class _ModuleChain:
    """Shared machinery: execute the workflow one component-group at a
    time; each group is the unoptimized primitive sub-graph of its
    components (no cross-group overlap — the module boundary is a
    barrier)."""
    PASSES = ()                     # no graph optimization

    def __init__(self, app: APP, engines: Dict, *, policy: str = "to"):
        self.app = app
        self.engines = engines
        self.runtime = Runtime(engines, policy=policy)

    def groups(self) -> List[List[str]]:
        # one group per component (LlamaDist)
        return [[n.name] for n in self.app.template]

    def parallel_groups(self) -> List[List[List[str]]]:
        """Phases of groups that may run concurrently (LlamaDistPC)."""
        return [[g] for g in self.groups()]

    def _build(self, query):
        g = graph_transform(self.app, query, None)
        # keep template edges (module barrier); only assign depths
        g.assign_depths()
        return g

    def submit(self, query: dict, C=None) -> QueryContext:
        g = self._build(query)
        inputs = {k: v for k, v in query.items() if k != "id"}
        ctx = QueryContext(g, inputs)
        ctx.indegree = {pid: len(n.parents) for pid, n in g.nodes.items()}
        t = threading.Thread(target=self._run, args=(g, ctx), daemon=True)
        t.start()
        return ctx

    def _run(self, g: Graph, ctx: QueryContext):
        try:
            produced = set()
            for n in g.nodes.values():
                produced |= set(n.produces)
            for phase in self.parallel_groups():
                threads = []
                for group in phase:
                    th = threading.Thread(
                        target=self._run_group,
                        args=(g, ctx, group, produced))
                    th.start()
                    threads.append(th)
                for th in threads:
                    th.join()
            ctx.t_done = time.time()
        except Exception as e:  # noqa: BLE001
            ctx.error = e
        finally:
            ctx.done.set()
            for eng in self.engines.values():
                for inst in replicas_of(eng):
                    if hasattr(inst, "release"):
                        for sid in ctx.sids:
                            inst.release(sid)
                    if hasattr(inst, "drop"):
                        inst.drop(ctx.qid)

    def _run_group(self, g: Graph, ctx: QueryContext, group: List[str],
                   produced=frozenset()):
        """Run the primitives of these components, respecting intra-group
        dependencies, blocking until all complete. A failure is recorded
        on the context (thread exceptions would otherwise vanish and a
        sibling group waiting on this group's outputs would spin)."""
        nodes = [n for n in g.topo_order() if n.component in group]
        try:
            for n in nodes:
                self._exec_node(n, ctx, produced)
        except Exception as e:  # noqa: BLE001
            if ctx.error is None:
                ctx.error = e

    def _exec_node(self, prim, ctx, produced=frozenset()):
        from repro.core.executors import run_control
        from repro.core.runtime import NodeTask
        # payloads are resolved lazily from the store on the engine
        # scheduler thread, so inputs produced by ANOTHER group running
        # in the same phase must be present before submission (the
        # managed path gets this ordering from in-degree tracking)
        deps = [k for k in prim.consumes if k in produced]
        while not all(k in ctx.store for k in deps):
            if ctx.error:
                raise ctx.error
            time.sleep(0.001)
        if prim.engine == "control":
            run_control(prim, ctx)
            return
        sched = self.runtime.scheds[prim.engine]
        ctx.node_spans.setdefault(prim.pid, (time.time(), None))
        sched.submit(NodeTask(prim, ctx, managed=False))
        # wait on per-task completion via polling the store keys
        while True:
            if ctx.error:
                raise ctx.error
            if all(k in ctx.store for k in prim.produces):
                return
            time.sleep(0.001)

    def query(self, query: dict, C=None, timeout=120):
        ctx = self.submit(query, C)
        out = ctx.result(timeout)
        return out, ctx

    def shutdown(self):
        self.runtime.shutdown()


class LlamaDist(_ModuleChain):
    """Ray-based distributed LlamaIndex stand-in: strict module chain."""


class LlamaDistPC(_ModuleChain):
    """LlamaDist + manual parallelization of independent modules +
    instruction-prefix KV cache reuse."""

    def __init__(self, app, engines, *, policy: str = "to"):
        super().__init__(app, engines, policy=policy)
        self._warm_prefix_cache()

    def _warm_prefix_cache(self):
        # pre-compute instruction KV prefixes on the LLM engines
        from repro.core.prompts import INSTRUCTIONS
        defaults = {"llm_expand": INSTRUCTIONS["expand"],
                    "llm_judge": INSTRUCTIONS["judge"],
                    "contextualize": INSTRUCTIONS["contextualize"]}
        gen_defaults = {"oneshot": INSTRUCTIONS["oneshot"],
                        "refine": INSTRUCTIONS["refine"],
                        "tree": INSTRUCTIONS["tree"]}
        for n in self.app.template:
            instr = n.config.get("instruction") or defaults.get(n.kind) \
                or gen_defaults.get(n.config.get("mode", ""))
            eng = self.engines.get(n.engine)
            for inst in replicas_of(eng):
                if hasattr(inst, "get_prefix_state"):
                    inst.use_prefix_cache = True
                    if instr:
                        inst.get_prefix_state(instr)

    def parallel_groups(self):
        """Manually parallelize known-independent modules: the indexing
        pipeline runs concurrently with query expansion / judging."""
        names = [n.name for n in self.app.template]
        phases: List[List[List[str]]] = []
        done = set()

        def take(*keys):
            return [k for k in keys if k in names and k not in done]

        # phase 1: chunking (everything depends on chunks)
        p1 = take("chunk", "contextualize")
        if p1:
            phases.append([[x] for x in p1])
            done.update(p1)
        # phase 2: indexing ∥ (query expansion | judge)
        par = []
        for grp in (take("indexing"), take("query_expansion"),
                    take("proxy_judge"), take("query_embedding")
                    if "query_expansion" not in names else []):
            if grp:
                par.append(grp)
        if par:
            phases.append(par)
            done.update(x for g in par for x in g)
        # remaining components sequentially
        for n in names:
            if n not in done:
                phases.append([[n]])
                done.add(n)
        return phases


class AutoGenLike(_ModuleChain):
    """Agent-grouped orchestration: consecutive components sharing a broad
    role are fused into one agent; agents run sequentially."""

    ROLE_OF = {
        "chunk": "retrieval", "indexing": "retrieval",
        "query_embedding": "retrieval", "vector_search": "retrieval",
        "contextualize": "retrieval",
        "query_expansion": "expansion", "rerank": "rerank",
        "proxy_judge": "judge", "search_api": "judge",
        "synthesize": "synthesize",
    }

    def groups(self):
        """Merge CONSECUTIVE template components sharing an agent role
        (an agent handles several system modules, paper §7 baselines) —
        contiguity preserves the workflow's dataflow order."""
        out, cur, cur_role = [], [], None
        for n in self.app.template:
            role = self.ROLE_OF.get(n.name, n.name)
            if role == cur_role:
                cur.append(n.name)
            else:
                if cur:
                    out.append(cur)
                cur, cur_role = [n.name], role
        if cur:
            out.append(cur)
        return out
