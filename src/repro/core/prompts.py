"""Instruction templates (realistic ~50-token system prompts, matching the
paper's setting where partial prefilling of instructions is worthwhile)."""

_GUIDELINES = ("You must answer faithfully using only the provided "
               "material, cite the supporting fragment for every claim, "
               "refuse speculation, keep the answer concise and structured, "
               "and preserve any numeric values exactly as written in the "
               "source text without rounding or reformatting them.")

INSTRUCTIONS = {
    "expand": "Rewrite the user question into several diverse standalone "
              "search queries that cover different phrasings and aspects "
              "of the information need. " + _GUIDELINES,
    "judge": "Draft a short candidate answer from parametric knowledge and "
             "output the token SEARCH if external evidence is required to "
             "answer reliably. " + _GUIDELINES,
    "contextualize": "Write a short situating context for the following "
                     "document chunk so it can be understood in isolation. "
                     + _GUIDELINES,
    "oneshot": "Answer the user question using the retrieved context "
               "passages below. " + _GUIDELINES,
    "refine": "Refine the existing candidate answer given one additional "
              "retrieved context passage. " + _GUIDELINES,
    "tree": "Answer the user question using this single retrieved context "
            "passage. " + _GUIDELINES,
    "combine": "Combine the candidate answers into one final answer. "
               + _GUIDELINES,
}
