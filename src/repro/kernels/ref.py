"""Pure-jnp oracles for every Pallas kernel (independent, naive
implementations used by the allclose test sweeps)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def flash_prefill_ref(q, k, v, *, prefix_len=0, window=None, cap=None,
                      scale=None, total_len=None):
    """q (B,Sq,H,hd); k,v (B,T,K,hd). Naive masked attention."""
    B, Sq, H, hd = q.shape
    T, K = k.shape[1], k.shape[2]
    G = H // K
    if scale is None:
        scale = hd ** -0.5
    if total_len is None:
        total_len = prefix_len + Sq
    kr = jnp.repeat(k, G, axis=2).astype(jnp.float32)   # (B,T,H,hd)
    vr = jnp.repeat(v, G, axis=2).astype(jnp.float32)
    s = jnp.einsum("bshd,bthd->bhst", q.astype(jnp.float32), kr) * scale
    if cap is not None:
        s = cap * jnp.tanh(s / cap)
    q_pos = prefix_len + jnp.arange(Sq)
    k_pos = jnp.arange(T)
    mask = (k_pos[None, :] <= q_pos[:, None]) & (k_pos[None, :] < total_len)
    if window is not None:
        mask &= k_pos[None, :] > q_pos[:, None] - window
    s = jnp.where(mask[None, None], s, -2.0e38)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhst,bthd->bshd", p, vr)
    return o.astype(q.dtype)


def decode_attention_ref(q, k, v, length, *, window=None, cap=None,
                         scale=None):
    """q (B,H,hd); k,v (B,T,K,hd); length (B,) valid cache lengths
    (the new token's KV must already be written at length-1)."""
    B, H, hd = q.shape
    T, K = k.shape[1], k.shape[2]
    G = H // K
    if scale is None:
        scale = hd ** -0.5
    kr = jnp.repeat(k, G, axis=2).astype(jnp.float32)
    vr = jnp.repeat(v, G, axis=2).astype(jnp.float32)
    s = jnp.einsum("bhd,bthd->bht", q.astype(jnp.float32), kr) * scale
    if cap is not None:
        s = cap * jnp.tanh(s / cap)
    k_pos = jnp.arange(T)[None, :]                       # (1,T)
    mask = k_pos < length[:, None]
    if window is not None:
        mask &= k_pos > (length[:, None] - 1 - window)
    s = jnp.where(mask[:, None, :], s, -2.0e38)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bht,bthd->bhd", p, vr).astype(q.dtype)


def paged_decode_attention_ref(q, k_pool, v_pool, block_tables, length, *,
                               window=None, cap=None, scale=None):
    """XLA `take`-based paged decode path (also the CPU serving path):
    gather each sequence's blocks into a contiguous linear view through
    its block table, then run dense masked decode attention. k_pool/v_pool
    (num_blocks, block_size, K, hd); block_tables (B, maxblk) int32."""
    B, maxblk = block_tables.shape
    bs = k_pool.shape[1]
    k = jnp.take(k_pool, block_tables, axis=0).reshape(
        B, maxblk * bs, *k_pool.shape[2:])
    v = jnp.take(v_pool, block_tables, axis=0).reshape(
        B, maxblk * bs, *v_pool.shape[2:])
    return decode_attention_ref(q, k, v, length, window=window, cap=cap,
                                scale=scale)


def verify_attention_ref(q, k_pool, v_pool, block_tables, length, *,
                         window=None, cap=None, scale=None):
    """XLA `take`-based speculative-verification path (also the CPU
    serving path): gather each sequence's paged blocks into a contiguous
    view, then run multi-query masked attention with the chunk's queries
    at absolute positions length - Sq + i (causal intra-chunk mask).
    q (B,Sq,H,hd); k_pool/v_pool (num_blocks, block_size, K, hd);
    block_tables (B, maxblk) int32; length (B,) int32 total valid length
    INCLUDING the Sq chunk positions."""
    B, Sq, H, hd = q.shape
    maxblk = block_tables.shape[1]
    bs, K = k_pool.shape[1], k_pool.shape[2]
    G = H // K
    if scale is None:
        scale = hd ** -0.5
    k = jnp.take(k_pool, block_tables, axis=0).reshape(
        B, maxblk * bs, *k_pool.shape[2:])
    v = jnp.take(v_pool, block_tables, axis=0).reshape(
        B, maxblk * bs, *v_pool.shape[2:])
    kr = jnp.repeat(k, G, axis=2).astype(jnp.float32)    # (B,T,H,hd)
    vr = jnp.repeat(v, G, axis=2).astype(jnp.float32)
    s = jnp.einsum("bshd,bthd->bhst", q.astype(jnp.float32), kr) * scale
    if cap is not None:
        s = cap * jnp.tanh(s / cap)
    T = maxblk * bs
    q_pos = length[:, None] - Sq + jnp.arange(Sq)[None, :]      # (B,Sq)
    k_pos = jnp.arange(T)
    mask = k_pos[None, None, :] <= q_pos[:, :, None]            # (B,Sq,T)
    if window is not None:
        mask &= k_pos[None, None, :] > (q_pos[:, :, None] - window)
    s = jnp.where(mask[:, None], s, -2.0e38)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhst,bthd->bshd", p, vr).astype(q.dtype)


def chunk_prefill_attention_ref(q, k_pool, v_pool, block_tables, start, *,
                                window=None, cap=None, scale=None):
    """XLA `take`-based chunked-prefill path (also the CPU serving path):
    gather each sequence's paged blocks into a contiguous view, then run
    masked attention with the chunk's queries at absolute positions
    ``start[b] + i`` — causal over the resident prefix AND inside the
    chunk. q (B,Sq,H,hd); k_pool/v_pool (num_blocks, block_size, K, hd);
    block_tables (B, maxblk) int32; start (B,) int32 chunk-start
    positions (tokens resident before the chunk; the chunk's own KV is
    already scattered into the pool). Equivalent to the verify oracle at
    total length ``start + Sq``."""
    Sq = q.shape[1]
    return verify_attention_ref(q, k_pool, v_pool, block_tables,
                                start + Sq, window=window, cap=cap,
                                scale=scale)


def rwkv6_scan_ref(r, k, v, w, u, state0):
    """r,k,v,w (B,S,H,hd); u (H,hd); state0 (B,H,hd,hd) fp32.
    Sequential reference recurrence:
      y_t = r_t . (S_{t-1} + diag(u) k_t v_t^T);  S_t = diag(w_t) S + k v^T
    Returns (y (B,S,H,hd) fp32, final state)."""
    rf, kf, vf, wf = [a.astype(jnp.float32) for a in (r, k, v, w)]
    uf = u.astype(jnp.float32)

    def step(st, xs):
        r_t, k_t, v_t, w_t = xs
        kv = k_t[..., :, None] * v_t[..., None, :]
        y = jnp.einsum("bhk,bhkv->bhv", r_t, st + uf[..., None] * kv)
        st = w_t[..., None] * st + kv
        return st, y

    xs = tuple(jnp.moveaxis(a, 1, 0) for a in (rf, kf, vf, wf))
    state, y = jax.lax.scan(step, state0.astype(jnp.float32), xs)
    return jnp.moveaxis(y, 0, 1), state
