"""Pallas TPU kernel: chunked prefill attention over a PAGED KV prefix.

This is the paged-pool generalization of ``flash_prefill``: a prompt
chunk of Sq tokens is prefilled against the sequence's existing prefix,
but the prefix (and the chunk's own just-written KV) live in the SHARED
block pool (num_blocks, block_size, K, hd) and are addressed through a
per-sequence block table — the serving layout of the copy-on-write
prefix-sharing cache. The scalar-prefetched table drives the KV
BlockSpec index maps exactly as in ``paged_decode_attention``: grid step
(b, i, j) DMAs physical block ``table[b, j]`` straight from the pool, so
no gathered per-sequence copy of the KV is ever materialized.

Per-sequence chunk-start positions are the second scalar-prefetch
operand: query row i of sequence b sits at absolute position
``start[b] + i``, which yields the causal mask over the prefix AND
inside the chunk from positions alone (the intra-chunk mask that makes
chunked prefill token-identical to monolithic prefill). Sliding windows
and logit softcap are supported like the other serving kernels.

Tiling: grid (B, Sq/bq, maxblk) with j innermost so the online-softmax
scratch accumulates over KV blocks per (b, i). The softmax state lives
in the GQA-grouped (K, bq*G, ·) row layout shared with
``verify_attention`` — row ``r*G + g`` of kv-group ``k`` is query row
``r`` of head ``k*G + g`` — so score/value matmuls batch over the K axis
with no per-block transposes. Causal block skipping: KV block j is
skipped when ``j*bs`` lies beyond the q block's last position (prefix
blocks stream, future blocks never load).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.0e38


def _chunk_kernel(tbl_ref, start_ref, q_ref, k_ref, v_ref, o_ref, acc_ref,
                  m_ref, l_ref, *, scale, window, cap, bs, bq, G):
    b = pl.program_id(0)
    i = pl.program_id(1)
    j = pl.program_id(2)
    start = start_ref[b]

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # causal block skip: the q block covers absolute positions
    # [start + i*bq, start + i*bq + bq); KV block j holds logical
    # positions [j*bs, (j+1)*bs) — skip blocks entirely past the last
    # query position (the chunk's KV is already scattered into the pool,
    # so every key at k_pos <= q_pos is valid data)
    @pl.when(j * bs <= start + i * bq + bq - 1)
    def _compute():
        q = q_ref[0].astype(jnp.float32)                  # (bq, H, hd)
        kf = k_ref[0].astype(jnp.float32)                 # (K, bs, hd)
        vf = v_ref[0].astype(jnp.float32)
        hd = q.shape[2]
        K = kf.shape[0]
        qg = jnp.moveaxis(q.reshape(bq, K, G, hd), 0, 1)  # (K, bq, G, hd)
        qg = qg.reshape(K, bq * G, hd)
        s = jax.lax.dot_general(
            qg, kf, (((2,), (2,)), ((0,), (0,))),
            preferred_element_type=jnp.float32) * scale    # (K, bq*G, bs)
        if cap is not None:
            s = cap * jnp.tanh(s / cap)
        k_pos = j * bs + jax.lax.broadcasted_iota(
            jnp.int32, (K, bq * G, bs), 2)
        q_pos = start + i * bq + jax.lax.broadcasted_iota(
            jnp.int32, (K, bq * G, bs), 1) // G
        mask = k_pos <= q_pos
        if window is not None:
            mask &= k_pos > (q_pos - window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]                               # (K, bq*G, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=2, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=2, keepdims=True)
        m_ref[...] = m_new
        pv = jax.lax.dot_general(
            p, vf, (((2,), (1,)), ((0,), (0,))),
            preferred_element_type=jnp.float32)           # (K, bq*G, hd)
        acc_ref[...] = acc_ref[...] * alpha + pv

    @pl.when(j == pl.num_programs(2) - 1)
    def _finish():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o = acc_ref[...] / denom                          # (K, bq*G, hd)
        K, _, hd = o.shape
        o = jnp.moveaxis(o.reshape(K, bq, G, hd), 0, 1)   # (bq, K, G, hd)
        o_ref[0] = o.reshape(bq, K * G, hd).astype(o_ref.dtype)


def chunk_prefill_attention(q, k_pool, v_pool, block_tables, start, *,
                            window=None, cap=None, scale=None, bq: int = 128,
                            interpret: bool = True):
    """Chunked-prefill attention over the paged block pool.

    q (B, Sq, H, hd): the prompt chunk's queries, row i of sequence b at
    absolute position ``start[b] + i``; k_pool, v_pool
    (num_blocks, block_size, K, hd); block_tables (B, maxblk) int32
    physical block per logical block; start (B,) int32 chunk-start
    positions (= tokens already resident before this chunk). The chunk's
    own KV must already be scattered into the pool. Returns
    (B, Sq, H, hd). Sq == 1 with start = length - 1 reduces to
    ``paged_decode_attention``.
    """
    B, Sq, H, hd = q.shape
    bs, K = k_pool.shape[1], k_pool.shape[2]
    G = H // K
    maxblk = block_tables.shape[1]
    if scale is None:
        scale = hd ** -0.5
    bq = min(bq, Sq)
    if Sq % bq != 0:
        bq = Sq                      # engine buckets divide evenly; odd
    #                                  test shapes fall back to one block
    kh = jnp.moveaxis(k_pool, 2, 1)     # (nb, K, bs, hd)
    vh = jnp.moveaxis(v_pool, 2, 1)
    grid = (B, Sq // bq, maxblk)
    kernel = functools.partial(_chunk_kernel, scale=scale, window=window,
                               cap=cap, bs=bs, bq=bq, G=G)
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, bq, H, hd), lambda b, i, j, tbl, s:
                             (b, i, 0, 0)),
                pl.BlockSpec((1, K, bs, hd),
                             lambda b, i, j, tbl, s: (tbl[b, j], 0, 0, 0)),
                pl.BlockSpec((1, K, bs, hd),
                             lambda b, i, j, tbl, s: (tbl[b, j], 0, 0, 0)),
            ],
            out_specs=pl.BlockSpec((1, bq, H, hd), lambda b, i, j, tbl, s:
                                   (b, i, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((K, bq * G, hd), jnp.float32),
                pltpu.VMEM((K, bq * G, 1), jnp.float32),
                pltpu.VMEM((K, bq * G, 1), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, Sq, H, hd), q.dtype),
        interpret=interpret,
    )(block_tables.astype(jnp.int32), start.astype(jnp.int32), q, kh, vh)
    return out
