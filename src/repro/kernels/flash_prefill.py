"""Pallas TPU kernel: chunked-prefill flash attention with KV prefix.

This is the TPU-native form of Teola's Partial/Full Prefilling (paper §4.2
Pass 3): a prompt chunk of Sq tokens is prefilled *against an existing KV
prefix* of `prefix_len` tokens already resident in the cache, with causal
masking inside the chunk. GQA is handled natively in the index map (no KV
head repetition), sliding windows and Gemma-2-style logit softcap are
supported.

Tiling: grid (B, H, Sq/bq, T/bk), q/o blocks (bq, hd) and kv blocks
(bk, hd) in VMEM; fp32 running-softmax accumulator scratch. bq/bk default
128 to align with the MXU; hd is the lane dim (128/256).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.0e38


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            scale, prefix_len, window, cap, bq, bk, total_len):
    i = pl.program_id(2)
    j = pl.program_id(3)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_pos = prefix_len + i * bq + jax.lax.broadcasted_iota(
        jnp.int32, (bq, bk), 0)
    k_pos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = (k_pos <= q_pos) & (k_pos < total_len)
    if window is not None:
        mask &= k_pos > q_pos - window

    # skip fully-masked kv blocks (causal block skipping)
    block_needed = j * bk <= prefix_len + i * bq + bq - 1

    @pl.when(block_needed)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)             # (bq, hd)
        k = k_ref[0, 0].astype(jnp.float32)             # (bk, hd)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if cap is not None:
            s = cap * jnp.tanh(s / cap)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]                              # (bq, 1)
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)                  # (bq, 1)
        l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=1, keepdims=True)
        m_ref[...] = m_new
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(j == pl.num_programs(3) - 1)
    def _finish():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / denom).astype(o_ref.dtype)


def flash_prefill(q, k, v, *, prefix_len: int = 0, window=None, cap=None,
                  scale=None, total_len=None, bq: int = 128, bk: int = 128,
                  interpret: bool = True):
    """q (B, Sq, H, hd); k, v (B, T, K, hd) — the cache buffer with the
    chunk already written at [prefix_len, prefix_len+Sq).
    Returns o (B, Sq, H, hd).
    prefix_len is static (serving engines bucket chunk offsets)."""
    B, Sq, H, hd = q.shape
    T, K = k.shape[1], k.shape[2]
    G = H // K
    if scale is None:
        scale = hd ** -0.5
    if total_len is None:
        total_len = prefix_len + Sq
    bq = min(bq, Sq)
    bk = min(bk, T)
    assert Sq % bq == 0 and T % bk == 0, (Sq, bq, T, bk)

    # head-major layouts so blocks are (rows, lanes) 2-D tiles
    qh = jnp.moveaxis(q, 2, 1).reshape(B, H, Sq, hd)
    kh = jnp.moveaxis(k, 2, 1).reshape(B, K, T, hd)
    vh = jnp.moveaxis(v, 2, 1).reshape(B, K, T, hd)

    grid = (B, H, Sq // bq, T // bk)
    kernel = functools.partial(
        _kernel, scale=scale, prefix_len=prefix_len, window=window, cap=cap,
        bq=bq, bk=bk, total_len=total_len)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda b, h, i, j, G=G: (b, h // G, j, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda b, h, i, j, G=G: (b, h // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd),
                               lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, hd), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
        ],
        interpret=interpret,
    )(qh, kh, vh)
    return jnp.moveaxis(out, 1, 2)  # (B, Sq, H, hd)
