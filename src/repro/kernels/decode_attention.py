"""Pallas TPU kernels: batched GQA decode attention (flash-decode style),
dense and PAGED.

Dense: one new token per sequence attends to a KV cache of up to T tokens
with a *dynamic* per-batch valid length (scalar-prefetched, so block index
maps could skip past-the-end blocks on real hardware). GQA native: all H
query heads for a sequence stay resident in VMEM while KV blocks stream by.
Grid (B, T/bk); scratch: fp32 accumulator (H, hd) + running max/denom.

Paged: K/V live in a SHARED block pool (num_blocks, block_size, K, hd) and
each sequence addresses it through a block table — the scalar-prefetched
table drives the KV BlockSpec index maps, so the j-th grid step DMAs
physical block ``table[b, j]`` straight from the pool (no gathered copy of
the sequence's KV is ever materialized). This is the decode path for the
copy-on-write prefix-sharing cache in serving/kv_cache.py.

Verify: ``verify_attention`` generalizes the paged decode kernel from
q_len=1 to q_len=Sq (the speculative-decoding verification forward: the
target model scores a drafted chunk of k tokens plus the committed last
token in ONE pass). Queries sit at absolute positions
``length - Sq + i``; a causal intra-chunk mask keeps draft token i blind
to drafts > i while every query still streams the sequence's full paged
history via the same scalar-prefetched block-table index maps. Sq == 1
reduces exactly to ``paged_decode_attention``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.0e38


def _flash_init(acc_ref, m_ref, l_ref):
    acc_ref[...] = jnp.zeros_like(acc_ref)
    m_ref[...] = jnp.full_like(m_ref, NEG_INF)
    l_ref[...] = jnp.zeros_like(l_ref)


def _flash_block(q_ref, k_ref, v_ref, acc_ref, m_ref, l_ref, *,
                 block_start, length, scale, window, cap, bk, G):
    """One KV block of online-softmax accumulation — the numerically
    delicate core shared by the dense and paged decode kernels. The KV
    refs hold the block's data; ``block_start`` is its LOGICAL position
    (dense: j*bk into the sequence's cache; paged: j*bs, with the
    physical block already resolved by the BlockSpec index map)."""
    q = q_ref[0].astype(jnp.float32)                  # (H, hd)
    kf = k_ref[0].astype(jnp.float32)                 # (K, bk, hd)
    vf = v_ref[0].astype(jnp.float32)
    H, hd = q.shape
    K = kf.shape[0]
    qg = q.reshape(K, G, hd)
    s = jax.lax.dot_general(
        qg, kf, (((2,), (2,)), ((0,), (0,))),
        preferred_element_type=jnp.float32) * scale    # (K, G, bk)
    if cap is not None:
        s = cap * jnp.tanh(s / cap)
    k_pos = block_start + jax.lax.broadcasted_iota(jnp.int32, (K, G, bk), 2)
    mask = k_pos < length
    if window is not None:
        mask &= k_pos > (length - 1 - window)
    s = jnp.where(mask, s, NEG_INF)

    sh = s.reshape(H, bk)
    m_prev = m_ref[...]                               # (H,1)
    m_new = jnp.maximum(m_prev, jnp.max(sh, axis=1, keepdims=True))
    p = jnp.exp(sh - m_new)                           # (H, bk)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=1, keepdims=True)
    m_ref[...] = m_new
    pv = jax.lax.dot_general(
        p.reshape(K, G, bk), vf, (((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32)           # (K, G, hd)
    acc_ref[...] = acc_ref[...] * alpha + pv.reshape(H, hd)


def _flash_finish(o_ref, acc_ref, l_ref):
    denom = jnp.maximum(l_ref[...], 1e-30)
    o_ref[0] = (acc_ref[...] / denom).astype(o_ref.dtype)


def _kernel(len_ref, q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            scale, window, cap, bk, G):
    b = pl.program_id(0)
    j = pl.program_id(1)
    length = len_ref[b]

    @pl.when(j == 0)
    def _init():
        _flash_init(acc_ref, m_ref, l_ref)

    @pl.when(j * bk < length)
    def _compute():
        _flash_block(q_ref, k_ref, v_ref, acc_ref, m_ref, l_ref,
                     block_start=j * bk, length=length, scale=scale,
                     window=window, cap=cap, bk=bk, G=G)

    @pl.when(j == pl.num_programs(1) - 1)
    def _finish():
        _flash_finish(o_ref, acc_ref, l_ref)


def decode_attention(q, k, v, length, *, window=None, cap=None, scale=None,
                     bk: int = 128, interpret: bool = True):
    """q (B,H,hd); k,v (B,T,K,hd); length (B,) int32 valid lengths.
    Returns (B,H,hd)."""
    B, H, hd = q.shape
    T, K = k.shape[1], k.shape[2]
    G = H // K
    if scale is None:
        scale = hd ** -0.5
    bk = min(bk, T)
    assert T % bk == 0

    kh = jnp.moveaxis(k, 2, 1)      # (B,K,T,hd)
    vh = jnp.moveaxis(v, 2, 1)
    grid = (B, T // bk)
    kernel = functools.partial(_kernel, scale=scale, window=window, cap=cap,
                               bk=bk, G=G)
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, H, hd), lambda b, j, L: (b, 0, 0)),
                pl.BlockSpec((1, K, bk, hd), lambda b, j, L: (b, 0, j, 0)),
                pl.BlockSpec((1, K, bk, hd), lambda b, j, L: (b, 0, j, 0)),
            ],
            out_specs=pl.BlockSpec((1, H, hd), lambda b, j, L: (b, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((H, hd), jnp.float32),
                pltpu.VMEM((H, 1), jnp.float32),
                pltpu.VMEM((H, 1), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, H, hd), q.dtype),
        interpret=interpret,
    )(length.astype(jnp.int32), q, kh, vh)
    return out


def _paged_kernel(tbl_ref, len_ref, q_ref, k_ref, v_ref, o_ref, acc_ref,
                  m_ref, l_ref, *, scale, window, cap, bs, G):
    """Same flash accumulation as ``_kernel`` (shared ``_flash_block``);
    the KV refs already hold physical block ``tbl[b, j]`` (the BlockSpec
    index maps consumed the prefetched table), so the body only needs
    the LOGICAL position ``j * bs + i`` for masking."""
    b = pl.program_id(0)
    j = pl.program_id(1)
    length = len_ref[b]

    @pl.when(j == 0)
    def _init():
        _flash_init(acc_ref, m_ref, l_ref)

    @pl.when(j * bs < length)
    def _compute():
        _flash_block(q_ref, k_ref, v_ref, acc_ref, m_ref, l_ref,
                     block_start=j * bs, length=length, scale=scale,
                     window=window, cap=cap, bk=bs, G=G)

    @pl.when(j == pl.num_programs(1) - 1)
    def _finish():
        _flash_finish(o_ref, acc_ref, l_ref)


def _verify_kernel(tbl_ref, len_ref, q_ref, k_ref, v_ref, o_ref, acc_ref,
                   m_ref, l_ref, *, scale, window, cap, bs, Sq, G):
    """Multi-token (q_len=Sq) paged flash accumulation. The online-softmax
    state lives in the GQA-grouped row layout (K, Sq*G, ·) — row
    ``s*G + g`` of kv-group ``k`` is query position ``s`` of head
    ``k*G + g`` — so score/value matmuls batch over the K axis with no
    per-block transposes; the single relayout to (Sq, H, hd) happens once
    at finish. Query ``s`` sits at absolute position ``length - Sq + s``,
    giving the causal intra-chunk mask for free from positions alone."""
    b = pl.program_id(0)
    j = pl.program_id(1)
    length = len_ref[b]

    @pl.when(j == 0)
    def _init():
        _flash_init(acc_ref, m_ref, l_ref)

    @pl.when(j * bs < length)
    def _compute():
        q = q_ref[0].astype(jnp.float32)                  # (Sq, H, hd)
        kf = k_ref[0].astype(jnp.float32)                 # (K, bs, hd)
        vf = v_ref[0].astype(jnp.float32)
        hd = q.shape[2]
        K = kf.shape[0]
        qg = jnp.moveaxis(q.reshape(Sq, K, G, hd), 0, 1)  # (K, Sq, G, hd)
        qg = qg.reshape(K, Sq * G, hd)
        s = jax.lax.dot_general(
            qg, kf, (((2,), (2,)), ((0,), (0,))),
            preferred_element_type=jnp.float32) * scale    # (K, Sq*G, bs)
        if cap is not None:
            s = cap * jnp.tanh(s / cap)
        k_pos = j * bs + jax.lax.broadcasted_iota(jnp.int32, (K, Sq * G, bs),
                                                  2)
        q_pos = length - Sq + jax.lax.broadcasted_iota(
            jnp.int32, (K, Sq * G, bs), 1) // G
        mask = k_pos <= q_pos
        if window is not None:
            mask &= k_pos > (q_pos - window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]                               # (K, Sq*G, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=2, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=2, keepdims=True)
        m_ref[...] = m_new
        pv = jax.lax.dot_general(
            p, vf, (((2,), (1,)), ((0,), (0,))),
            preferred_element_type=jnp.float32)           # (K, Sq*G, hd)
        acc_ref[...] = acc_ref[...] * alpha + pv

    @pl.when(j == pl.num_programs(1) - 1)
    def _finish():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o = acc_ref[...] / denom                          # (K, Sq*G, hd)
        K, _, hd = o.shape
        o = jnp.moveaxis(o.reshape(K, Sq, G, hd), 0, 1)   # (Sq, K, G, hd)
        o_ref[0] = o.reshape(Sq, K * G, hd).astype(o_ref.dtype)


def verify_attention(q, k_pool, v_pool, block_tables, length, *,
                     window=None, cap=None, scale=None,
                     interpret: bool = True):
    """Speculative-verification attention over the paged pool.

    q (B, Sq, H, hd): the drafted chunk's queries (Sq = draft_k + 1);
    k_pool, v_pool (num_blocks, block_size, K, hd); block_tables
    (B, maxblk) int32; length (B,) int32 TOTAL valid length including the
    Sq chunk positions (query i sits at ``length - Sq + i``; its KV must
    already be scattered into the pool). Returns (B, Sq, H, hd)."""
    B, Sq, H, hd = q.shape
    bs, K = k_pool.shape[1], k_pool.shape[2]
    G = H // K
    maxblk = block_tables.shape[1]
    if scale is None:
        scale = hd ** -0.5

    kh = jnp.moveaxis(k_pool, 2, 1)     # (nb, K, bs, hd)
    vh = jnp.moveaxis(v_pool, 2, 1)
    grid = (B, maxblk)
    kernel = functools.partial(_verify_kernel, scale=scale, window=window,
                               cap=cap, bs=bs, Sq=Sq, G=G)
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, Sq, H, hd), lambda b, j, tbl, L:
                             (b, 0, 0, 0)),
                pl.BlockSpec((1, K, bs, hd),
                             lambda b, j, tbl, L: (tbl[b, j], 0, 0, 0)),
                pl.BlockSpec((1, K, bs, hd),
                             lambda b, j, tbl, L: (tbl[b, j], 0, 0, 0)),
            ],
            out_specs=pl.BlockSpec((1, Sq, H, hd), lambda b, j, tbl, L:
                                   (b, 0, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((K, Sq * G, hd), jnp.float32),
                pltpu.VMEM((K, Sq * G, 1), jnp.float32),
                pltpu.VMEM((K, Sq * G, 1), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, Sq, H, hd), q.dtype),
        interpret=interpret,
    )(block_tables.astype(jnp.int32), length.astype(jnp.int32), q, kh, vh)
    return out


def paged_decode_attention(q, k_pool, v_pool, block_tables, length, *,
                           window=None, cap=None, scale=None,
                           interpret: bool = True):
    """q (B,H,hd); k_pool,v_pool (num_blocks, block_size, K, hd) shared
    pools; block_tables (B, maxblk) int32 physical block ids per logical
    block; length (B,) int32 valid lengths. Returns (B,H,hd)."""
    B, H, hd = q.shape
    nb, bs, K = k_pool.shape[0], k_pool.shape[1], k_pool.shape[2]
    G = H // K
    maxblk = block_tables.shape[1]
    if scale is None:
        scale = hd ** -0.5

    kh = jnp.moveaxis(k_pool, 2, 1)     # (nb, K, bs, hd)
    vh = jnp.moveaxis(v_pool, 2, 1)
    grid = (B, maxblk)
    kernel = functools.partial(_paged_kernel, scale=scale, window=window,
                               cap=cap, bs=bs, G=G)
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, H, hd), lambda b, j, tbl, L: (b, 0, 0)),
                pl.BlockSpec((1, K, bs, hd),
                             lambda b, j, tbl, L: (tbl[b, j], 0, 0, 0)),
                pl.BlockSpec((1, K, bs, hd),
                             lambda b, j, tbl, L: (tbl[b, j], 0, 0, 0)),
            ],
            out_specs=pl.BlockSpec((1, H, hd), lambda b, j, tbl, L:
                                   (b, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((H, hd), jnp.float32),
                pltpu.VMEM((H, 1), jnp.float32),
                pltpu.VMEM((H, 1), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, H, hd), q.dtype),
        interpret=interpret,
    )(block_tables.astype(jnp.int32), length.astype(jnp.int32), q, kh, vh)
    return out
