"""Pallas TPU kernel: RWKV-6 chunked WKV scan.

The per-head matrix state S (hd x hd, fp32) stays resident in VMEM scratch
across the sequential chunk grid dimension — the TPU-native adaptation of
the CUDA wkv6 kernel (which keeps state in registers/shared memory): on
TPU the state never round-trips to HBM between timesteps, only r/k/v/w
chunk blocks stream HBM->VMEM.

Grid (B, H, S/chunk); the chunk axis is innermost and TPU grid execution
is sequential, so scratch carries state between chunks of the same (b, h)
— chunk must therefore be the LAST grid dim and (b, h) outer.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref, y_ref, sf_ref,
            st_ref, *, chunk):
    c = pl.program_id(2)

    @pl.when(c == 0)
    def _load_state():
        st_ref[...] = s0_ref[0, 0].astype(jnp.float32)

    u = u_ref[0].astype(jnp.float32)                      # (hd,)

    def step(t, _):
        r_t = r_ref[0, t, 0, :].astype(jnp.float32)       # (hd,)
        k_t = k_ref[0, t, 0, :].astype(jnp.float32)
        v_t = v_ref[0, t, 0, :].astype(jnp.float32)
        w_t = w_ref[0, t, 0, :].astype(jnp.float32)
        st = st_ref[...]
        kv = k_t[:, None] * v_t[None, :]                  # (hd, hd)
        y = jnp.einsum("k,kv->v", r_t, st + u[:, None] * kv)
        st_ref[...] = w_t[:, None] * st + kv
        y_ref[0, t, 0, :] = y.astype(y_ref.dtype)
        return ()

    jax.lax.fori_loop(0, chunk, step, ())

    @pl.when(c == pl.num_programs(2) - 1)
    def _store_state():
        sf_ref[0, 0] = st_ref[...]


def rwkv6_scan(r, k, v, w, u, state0, *, chunk: int = 64,
               interpret: bool = True):
    """r,k,v,w (B,S,H,hd); u (H,hd); state0 (B,H,hd,hd) fp32.
    Returns (y (B,S,H,hd) fp32, final_state (B,H,hd,hd) fp32)."""
    B, S, H, hd = r.shape
    chunk = min(chunk, S)
    assert S % chunk == 0

    grid = (B, H, S // chunk)
    io_spec = pl.BlockSpec((1, chunk, 1, hd), lambda b, h, c: (b, c, h, 0))
    kernel = functools.partial(_kernel, chunk=chunk)
    y, sf = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            io_spec, io_spec, io_spec, io_spec,
            pl.BlockSpec((1, hd), lambda b, h, c: (h, 0)),
            pl.BlockSpec((1, 1, hd, hd), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_specs=[
            io_spec,
            pl.BlockSpec((1, 1, hd, hd), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, H, hd), jnp.float32),
            jax.ShapeDtypeStruct((B, H, hd, hd), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((hd, hd), jnp.float32)],
        interpret=interpret,
    )(r, k, v, w, u, state0)
    return y, sf
