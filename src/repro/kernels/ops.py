"""Jit'd public wrappers around the Pallas kernels.

On this CPU container the kernels run in interpret mode (the Pallas body
executed in Python for correctness validation); on TPU set
``REPRO_PALLAS_INTERPRET=0`` (or pass interpret=False) to compile the real
Mosaic kernels.
"""
from __future__ import annotations

import os
from functools import partial

import jax

from repro.kernels.chunk_prefill import \
    chunk_prefill_attention as _chunk_prefill
from repro.kernels.decode_attention import decode_attention as _decode
from repro.kernels.decode_attention import \
    paged_decode_attention as _paged_decode
from repro.kernels.decode_attention import verify_attention as _verify
from repro.kernels.flash_prefill import flash_prefill as _prefill
from repro.kernels.rwkv6_scan import rwkv6_scan as _rwkv


def default_interpret() -> bool:
    env = os.environ.get("REPRO_PALLAS_INTERPRET")
    if env is not None:
        return env not in ("0", "false", "False")
    return jax.default_backend() != "tpu"


@partial(jax.jit, static_argnames=("prefix_len", "window", "cap", "scale",
                                   "total_len", "bq", "bk", "interpret"))
def flash_prefill(q, k, v, *, prefix_len=0, window=None, cap=None,
                  scale=None, total_len=None, bq=128, bk=128,
                  interpret=None):
    interpret = default_interpret() if interpret is None else interpret
    return _prefill(q, k, v, prefix_len=prefix_len, window=window, cap=cap,
                    scale=scale, total_len=total_len, bq=bq, bk=bk,
                    interpret=interpret)


@partial(jax.jit, static_argnames=("window", "cap", "scale", "bk",
                                   "interpret"))
def decode_attention(q, k, v, length, *, window=None, cap=None, scale=None,
                     bk=128, interpret=None):
    interpret = default_interpret() if interpret is None else interpret
    return _decode(q, k, v, length, window=window, cap=cap, scale=scale,
                   bk=bk, interpret=interpret)


@partial(jax.jit, static_argnames=("window", "cap", "scale", "interpret"))
def paged_decode_attention(q, k_pool, v_pool, block_tables, length, *,
                           window=None, cap=None, scale=None,
                           interpret=None):
    interpret = default_interpret() if interpret is None else interpret
    return _paged_decode(q, k_pool, v_pool, block_tables, length,
                         window=window, cap=cap, scale=scale,
                         interpret=interpret)


@partial(jax.jit, static_argnames=("window", "cap", "scale", "interpret"))
def verify_attention(q, k_pool, v_pool, block_tables, length, *,
                     window=None, cap=None, scale=None, interpret=None):
    """Speculative-verification attention (paged, q_len = draft_k + 1);
    q_len == 1 reduces to paged_decode_attention."""
    interpret = default_interpret() if interpret is None else interpret
    return _verify(q, k_pool, v_pool, block_tables, length, window=window,
                   cap=cap, scale=scale, interpret=interpret)


@partial(jax.jit, static_argnames=("window", "cap", "scale", "bq",
                                   "interpret"))
def chunk_prefill_attention(q, k_pool, v_pool, block_tables, start, *,
                            window=None, cap=None, scale=None, bq=128,
                            interpret=None):
    """Chunked-prefill attention over the paged pool (q at absolute
    positions start[b] + i); Sq == 1 at start = length - 1 reduces to
    paged_decode_attention."""
    interpret = default_interpret() if interpret is None else interpret
    return _chunk_prefill(q, k_pool, v_pool, block_tables, start,
                          window=window, cap=cap, scale=scale, bq=bq,
                          interpret=interpret)


@partial(jax.jit, static_argnames=("chunk", "interpret"))
def rwkv6_scan(r, k, v, w, u, state0, *, chunk=64, interpret=None):
    interpret = default_interpret() if interpret is None else interpret
    return _rwkv(r, k, v, w, u, state0, chunk=chunk, interpret=interpret)
