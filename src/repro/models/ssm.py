"""Recurrent sequence mixers: RWKV-6 (Finch) time/channel mix and a
Mamba-style selective SSM branch (used by Hymba's hybrid heads).

Both use the same chunked-scan execution strategy: an outer lax.scan over
fixed-size chunks carrying the recurrent state, with a checkpointed inner
sequential scan, so the backward pass only stores chunk-boundary states
(Mamba-2-style chunking; the Pallas `rwkv6_scan` kernel implements the
intra-chunk part with VMEM-resident state on TPU).

Decode (S==1) is a single O(1) state update — this is what makes the
long_500k shape tractable for these families.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import dense_init, group_norm_heads, split_keys

CHUNK = 128


def _chunked_scan(step_fn, state, xs, chunk=CHUNK):
    """xs: pytree of (B, S, ...) arrays. step_fn(state, x_t) -> (state, y_t)
    with x_t (B, ...). Returns (state, ys (B,S,...))."""
    S = jax.tree_util.tree_leaves(xs)[0].shape[1]

    def scan_time(state, xs_c):
        # xs_c: (B, C, ...) -> time-major scan
        xs_t = jax.tree.map(lambda a: jnp.moveaxis(a, 1, 0), xs_c)
        state, ys = jax.lax.scan(step_fn, state, xs_t)
        return state, jax.tree.map(lambda a: jnp.moveaxis(a, 0, 1), ys)

    if S <= chunk or S % chunk != 0:
        return scan_time(state, xs)

    nc = S // chunk
    xs_chunks = jax.tree.map(
        lambda a: jnp.moveaxis(
            a.reshape(a.shape[0], nc, chunk, *a.shape[2:]), 1, 0), xs)
    inner = jax.checkpoint(scan_time)
    state, ys = jax.lax.scan(inner, state, xs_chunks)
    ys = jax.tree.map(
        lambda a: jnp.moveaxis(a, 0, 1).reshape(
            a.shape[1], nc * chunk, *a.shape[3:]), ys)
    return state, ys


def _token_shift(x, sx):
    """x (B,S,d), sx (B,d) last token of previous chunk -> previous-token
    tensor (B,S,d) and new sx."""
    prev = jnp.concatenate([sx[:, None, :], x[:, :-1, :]], axis=1)
    return prev, x[:, -1, :]


# ===========================================================================
# RWKV-6

def init_rwkv_params(key, cfg, dtype):
    d = cfg.d_model
    s = cfg.ssm
    H = d // s.head_dim
    L = s.lora_rank
    ks = split_keys(key, 12)
    return {
        "tm": {
            "mix_base": (jax.random.uniform(ks[0], (5, d), jnp.float32)
                         ).astype(dtype),
            "mix_w1": dense_init(ks[1], (d, 5 * L), dtype),
            "mix_w2": dense_init(ks[2], (5, L, d), dtype, scale=0.1),
            "wr": dense_init(ks[3], (d, d), dtype),
            "wk": dense_init(ks[4], (d, d), dtype),
            "wv": dense_init(ks[5], (d, d), dtype),
            "wg": dense_init(ks[6], (d, d), dtype),
            "wo": dense_init(ks[7], (d, d), dtype),
            "w_base": jnp.full((d,), -4.0, jnp.float32),
            "w_w1": dense_init(ks[8], (d, L), dtype),
            "w_w2": dense_init(ks[9], (L, d), dtype, scale=0.1),
            "u": jnp.zeros((H, s.head_dim), jnp.float32),
            "gn_scale": jnp.ones((H, s.head_dim), jnp.float32),
        },
        "cm": {
            "mix_k": jnp.full((d,), 0.5, dtype),
            "mix_r": jnp.full((d,), 0.5, dtype),
            "wk": dense_init(ks[10], (d, cfg.d_ff), dtype),
            "wv": dense_init(ks[11], (cfg.d_ff, d), dtype),
            "wr": dense_init(ks[0], (d, d), dtype),
        },
    }


def rwkv_time_mix(cfg, p, x, state, sx):
    """x (B,S,d); state (B,H,hd,hd) fp32; sx (B,d) previous token.
    Returns (out, new_state, new_sx)."""
    s = cfg.ssm
    B, S, d = x.shape
    H, hd = d // s.head_dim, s.head_dim

    prev, new_sx = _token_shift(x, sx.astype(x.dtype))
    dx = prev - x
    # data-dependent token-shift mixing (ddlerp)
    xxx = x + dx * p["mix_base"][0].astype(x.dtype)
    t = jnp.tanh(xxx @ p["mix_w1"]).reshape(B, S, 5, -1)
    mix = p["mix_base"].astype(jnp.float32) + jnp.einsum(
        "bsfl,fld->bsfd", t.astype(jnp.float32),
        p["mix_w2"].astype(jnp.float32))
    xs = x[:, :, None, :] + dx[:, :, None, :] * mix.astype(x.dtype)
    x_w, x_k, x_v, x_r, x_g = [xs[:, :, i] for i in range(5)]

    r = (x_r @ p["wr"]).reshape(B, S, H, hd)
    k = (x_k @ p["wk"]).reshape(B, S, H, hd)
    v = (x_v @ p["wv"]).reshape(B, S, H, hd)
    g = jax.nn.silu(x_g @ p["wg"])
    # data-dependent decay in (0, 1)
    ww = p["w_base"] + (jnp.tanh(x_w @ p["w_w1"]) @ p["w_w2"]).astype(
        jnp.float32)
    w = jnp.exp(-jnp.exp(ww.astype(jnp.float32))).reshape(B, S, H, hd)

    u = p["u"].astype(jnp.float32)

    def step(st, inp):
        r_t, k_t, v_t, w_t = [a.astype(jnp.float32) for a in inp]
        # st (B,H,hd,hd): k-index × v-index
        kv = k_t[..., :, None] * v_t[..., None, :]          # (B,H,hd,hd)
        y = jnp.einsum("bhk,bhkv->bhv", r_t, st + u[..., None] * kv)
        st = w_t[..., None] * st + kv
        return st, y

    state, y = _chunked_scan(step, state, (r, k, v, w))
    y = group_norm_heads(y.astype(x.dtype), p["gn_scale"], eps=64e-5)
    out = (y.reshape(B, S, d) * g) @ p["wo"]
    return out, state, new_sx


def rwkv_channel_mix(cfg, p, x, sx):
    prev, new_sx = _token_shift(x, sx.astype(x.dtype))
    dx = prev - x
    x_k = x + dx * p["mix_k"]
    x_r = x + dx * p["mix_r"]
    k = jnp.square(jax.nn.relu(x_k @ p["wk"]))
    kv = k @ p["wv"]
    return jax.nn.sigmoid(x_r @ p["wr"]) * kv, new_sx


# ===========================================================================
# Mamba-style selective SSM (Hymba's parallel SSM heads)

def init_mamba_params(key, cfg, dtype):
    s = cfg.ssm
    d = cfg.d_model
    dI = d                       # inner dim == d_model (parallel-head design)
    N, R = s.state_dim, s.dt_rank
    ks = split_keys(key, 6)
    return {
        "w_in": dense_init(ks[0], (d, 2 * dI), dtype),
        "conv_w": dense_init(ks[1], (s.conv_dim, dI), dtype),
        "conv_b": jnp.zeros((dI,), dtype),
        "w_x": dense_init(ks[2], (dI, R + 2 * N), dtype),
        "w_dt": dense_init(ks[3], (R, dI), dtype),
        "dt_bias": jnp.full((dI,), -4.6, jnp.float32),   # softplus ~= 0.01
        "A_log": jnp.log(jnp.tile(jnp.arange(1, N + 1, dtype=jnp.float32),
                                  (dI, 1))),
        "D": jnp.ones((dI,), jnp.float32),
        "out_proj": dense_init(ks[4], (dI, d), dtype),
    }


def mamba_branch(cfg, p, x, h_state, conv_state):
    """x (B,S,d); h_state (B,dI,N) fp32; conv_state (B,cw-1,dI).
    Returns (out (B,S,d), h_state, conv_state)."""
    s = cfg.ssm
    B, S, d = x.shape
    N, cw = s.state_dim, s.conv_dim
    dI = d

    xz = x @ p["w_in"]
    x_in, z = xz[..., :dI], xz[..., dI:]

    # causal depthwise conv with carried state
    ctx = jnp.concatenate([conv_state.astype(x.dtype), x_in], axis=1)
    new_conv_state = ctx[:, -(cw - 1):, :].astype(jnp.float32)
    wins = jnp.stack([ctx[:, i:i + S, :] for i in range(cw)], axis=2)
    x_c = jax.nn.silu(jnp.einsum("bswd,wd->bsd", wins, p["conv_w"])
                      + p["conv_b"])

    xdb = x_c @ p["w_x"]
    R = s.dt_rank
    dt = jax.nn.softplus((xdb[..., :R] @ p["w_dt"]).astype(jnp.float32)
                         + p["dt_bias"])                    # (B,S,dI)
    Bc = xdb[..., R:R + N].astype(jnp.float32)              # (B,S,N)
    Cc = xdb[..., R + N:].astype(jnp.float32)               # (B,S,N)
    A = -jnp.exp(p["A_log"])                                # (dI,N)

    def step(h, inp):
        x_t, dt_t, b_t, c_t = inp
        # h (B,dI,N)
        da = jnp.exp(dt_t[..., None] * A)                   # (B,dI,N)
        h = da * h + (dt_t * x_t)[..., None] * b_t[:, None, :]
        y = jnp.einsum("bdn,bn->bd", h, c_t)
        return h, y

    h_state, y = _chunked_scan(
        step, h_state,
        (x_c.astype(jnp.float32), dt, Bc, Cc))
    y = y + p["D"] * x_c.astype(jnp.float32)
    out = (y.astype(x.dtype) * jax.nn.silu(z)) @ p["out_proj"]
    return out, h_state, new_conv_state
