"""Attention: GQA (grouped-query) and MLA (multi-head latent), with
position-mask unified handling of train / chunked-prefill / decode and
linear / ring-buffer caches.

The mask is derived purely from absolute positions:
    valid(i, j) = k_pos[j] >= 0  and  k_pos[j] <= q_pos[i]
                  and (window is None or k_pos[j] > q_pos[i] - window)
which makes full causal, prefix-cache chunked prefill (Teola's Partial/Full
Prefilling), sliding windows and ring buffers all the same code path.

Long sequences are processed blockwise over the query axis (lax.map over
checkpointed blocks) so peak memory is O(q_block * Skv), not O(S^2).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import apply_rope, dense_init, softcap, split_keys
from repro.models.sharding import hint, active_mesh
from repro.serving import kv_cache as kvc

NEG_INF = -2.0e38


def _model_axis_size():
    mesh = active_mesh()
    if mesh is None:
        return 1
    try:
        return mesh.shape["model"]
    except (KeyError, TypeError):
        return 1


def _maybe_model(n: int):
    """'model' if the dim is divisible by the TP axis, else None (avoid
    GSPMD padding waste on awkward head counts like Hymba's 25)."""
    from repro.launch import optflags
    if optflags.has("flat_dp"):            # model axis belongs to batch
        return None
    m = _model_axis_size()
    return "model" if (m > 1 and n % m == 0) else None


def position_mask(q_pos, k_pos, window):
    """q_pos (B,Sq), k_pos (B,Skv) -> (B,Sq,Skv)."""
    kp = k_pos[:, None, :]
    qp = q_pos[:, :, None]
    m = (kp >= 0) & (kp <= qp)
    if window is not None:
        m &= kp > (qp - window)
    return m


# ---------------------------------------------------------------------------
# GQA core

def _gqa_core(q, k, v, q_pos, k_pos, scale, window, cap):
    """q (B,Sq,H,hd); k,v (B,Skv,K,hd); grouped einsum (no KV repeat)."""
    B, Sq, H, hd = q.shape
    K = k.shape[2]
    G = H // K
    qh = q.reshape(B, Sq, K, G, hd).astype(jnp.float32)
    s = jnp.einsum("bskgh,btkh->bkgst", qh, k.astype(jnp.float32)) * scale
    s = softcap(s, cap)
    mask = position_mask(q_pos, k_pos, window)              # (B,Sq,Skv)
    s = jnp.where(mask[:, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgst,btkh->bskgh", p, v.astype(jnp.float32))
    return o.reshape(B, Sq, H, hd).astype(q.dtype)


def blockwise_over_q(core, q, q_pos, q_block):
    """Run `core(q_blk, q_pos_blk)` over query blocks via lax.map with
    rematerialization, keeping peak memory at one block of scores.
    q_pos: (B, Sq)."""
    B, Sq = q.shape[0], q.shape[1]
    if Sq <= q_block or Sq % q_block != 0:
        return core(q, q_pos)
    nb = Sq // q_block
    qb = jnp.moveaxis(q.reshape(B, nb, q_block, *q.shape[2:]), 1, 0)
    pb = jnp.moveaxis(q_pos.reshape(B, nb, q_block), 1, 0)
    fn = jax.checkpoint(lambda args: core(*args))
    ob = jax.lax.map(fn, (qb, pb))
    return jnp.moveaxis(ob, 0, 1).reshape(B, Sq, *ob.shape[3:])


def gqa_attention(q, k, v, q_pos, k_pos, *, scale, window=None, cap=None,
                  q_block=512, causal_skip=False):
    if causal_skip:
        return _gqa_causal_skip(q, k, v, q_pos, k_pos, scale, window, cap,
                                q_block)
    core = lambda qq, pp: _gqa_core(qq, k, v, pp, k_pos, scale, window, cap)
    return blockwise_over_q(core, q, q_pos, q_block)


def _gqa_causal_skip(q, k, v, q_pos, k_pos, scale, window, cap, q_block):
    """Causal block skipping (perf iteration, optflag 'causal_skip'):
    unrolled q-block loop where block i only attends KV[: (i+1)*q_block]
    — halves attention FLOPs for full causal self-attention. Requires
    q_pos == k_pos == contiguous (training / full prefill)."""
    B, Sq, H, hd = q.shape
    if Sq <= q_block or Sq % q_block != 0:
        return _gqa_core(q, k, v, q_pos, k_pos, scale, window, cap)
    nb = Sq // q_block
    outs = []
    for i in range(nb):
        hi = (i + 1) * q_block
        lo = 0
        if window is not None:            # also clip from the left
            lo = max(0, (i * q_block - window) // q_block * q_block)
        blk = jax.checkpoint(
            lambda qq, pp, kk, vv, kp: _gqa_core(qq, kk, vv, pp, kp, scale,
                                                 window, cap))
        outs.append(blk(q[:, i * q_block:hi], q_pos[:, i * q_block:hi],
                        k[:, lo:hi], v[:, lo:hi], k_pos[:, lo:hi]))
    return jnp.concatenate(outs, axis=1)


# ---------------------------------------------------------------------------
# GQA layer (projections + cache handling)

def init_gqa_params(key, cfg, dtype):
    d, hd = cfg.d_model, cfg.resolved_head_dim
    H, K = cfg.num_heads, cfg.num_kv_heads
    ks = split_keys(key, 4)
    p = {
        "wq": dense_init(ks[0], (d, H * hd), dtype),
        "wk": dense_init(ks[1], (d, K * hd), dtype),
        "wv": dense_init(ks[2], (d, K * hd), dtype),
        "wo": dense_init(ks[3], (H * hd, d), dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H * hd,), dtype)
        p["bk"] = jnp.zeros((K * hd,), dtype)
        p["bv"] = jnp.zeros((K * hd,), dtype)
    return p


def paged_write(pool, chunk, block_tables, positions):
    """Scatter a (B,S,...) chunk into a (nb,bs,...) pool through per-seq
    block tables: token (b,i) at absolute position p = positions[b,i]
    lands in physical block block_tables[b, p // bs] at offset p % bs.
    Distinct sequences own distinct blocks, so batch scatters never
    collide (padding rows all target the reserved pad block — last write
    wins on scratch data)."""
    bs = pool.shape[1]
    bid = jnp.take_along_axis(block_tables, positions // bs, axis=1)
    return pool.at[bid, positions % bs].set(chunk.astype(pool.dtype))


def paged_gather(pool, block_tables):
    """Gather a sequence-contiguous (B, maxblk*bs, ...) linear view of the
    pool through the block tables (the XLA `take` path; the Pallas kernel
    streams blocks by table instead of materializing this view)."""
    B, maxblk = block_tables.shape
    g = jnp.take(pool, block_tables, axis=0)      # (B, maxblk, bs, ...)
    return g.reshape(B, maxblk * pool.shape[1], *pool.shape[2:])


def gqa_layer(cfg, spec, p, x, cache, pos, q_block=512, block_tables=None):
    """x (B,S,d). cache: elem dict or None (train). pos: dynamic scalar
    (tokens already in cache; 0 for train). With ``block_tables``
    (B,maxblk), cache elems are PAGED POOLS (nb,bs,K,hd) shared across
    sequences: writes scatter through the table, reads gather a linear
    view, and sliding windows are enforced by the position mask alone
    (no ring buffer). Returns (out, new_cache)."""
    B, S, d = x.shape
    hd = cfg.resolved_head_dim
    H, K = cfg.num_heads, cfg.num_kv_heads
    scale = cfg.attn_scale if cfg.attn_scale is not None else hd ** -0.5

    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, S, H, hd)
    k = k.reshape(B, S, K, hd)
    v = v.reshape(B, S, K, hd)

    pos = kvc.batch_pos(pos, B)
    positions = pos[:, None] + jnp.arange(S)[None, :]      # (B,S)
    q = apply_rope(q, positions, cfg.rope_theta, cfg.rope_kind)
    k = apply_rope(k, positions, cfg.rope_theta, cfg.rope_kind)
    q = hint(q, "batch", None, _maybe_model(H), None)

    if cache is None:
        from repro.launch import optflags
        k_pos = positions
        o = gqa_attention(q, k, v, positions, k_pos, scale=scale,
                          window=spec.window, cap=cfg.attn_logit_softcap,
                          q_block=q_block,
                          causal_skip=optflags.has("causal_skip"))
        new_cache = None
    elif block_tables is not None:
        kp = paged_write(cache["k"], k, block_tables, positions)
        vp = paged_write(cache["v"], v, block_tables, positions)
        new_cache = {"k": kp, "v": vp}
        from repro.launch import optflags
        if S > 1 and optflags.has("pallas_chunk_prefill"):
            # chunked-prefill serving path: the prompt chunk's queries
            # (absolute positions pos + i) attend to the paged prefix and
            # to the chunk itself through the scalar-prefetched
            # block-table index maps, q tiled in bq blocks — no gathered
            # per-sequence KV view. Read at TRACE time like the other
            # kernel flags: set before building jitted steps.
            from repro.kernels import ops as kops
            o = kops.chunk_prefill_attention(
                q, kp, vp, block_tables, pos, window=spec.window,
                cap=cfg.attn_logit_softcap, scale=scale).astype(q.dtype)
        elif optflags.has("pallas_paged_attn"):
            # accelerator serving path: stream physical blocks through the
            # scalar-prefetched table index maps instead of materializing
            # the gathered view. verify_attention covers decode (S=1) and
            # speculative multi-token verification (S=k+1) alike — the
            # chunk's queries sit at positions (pos+S) - S + i. The flag
            # is read at TRACE time: set it before building jitted steps.
            from repro.kernels import ops as kops
            o = kops.verify_attention(
                q, kp, vp, block_tables, pos + S, window=spec.window,
                cap=cfg.attn_logit_softcap, scale=scale).astype(q.dtype)
        else:
            kb = paged_gather(kp, block_tables)
            vb = paged_gather(vp, block_tables)
            k_pos = kvc.slot_positions_linear(kb.shape[1], pos + S)
            o = gqa_attention(q, kb.astype(x.dtype), vb.astype(x.dtype),
                              positions, k_pos, scale=scale,
                              window=spec.window,
                              cap=cfg.attn_logit_softcap, q_block=q_block)
    else:
        kb, vb = cache["k"], cache["v"]
        T = kb.shape[1]
        if spec.window is not None:
            # ring buffer (degenerates to linear while pos+S <= T); the
            # window itself is enforced by the position mask. Correctness
            # needs T >= window+S-1 once the ring wraps — init_cache
            # sizes the buffer accordingly.
            kb = kvc.write_ring(kb, k, pos)
            vb = kvc.write_ring(vb, v, pos)
            k_pos = kvc.slot_positions_ring(T, pos + S)     # (B,T)
        else:
            kb = kvc.write_linear(kb, k, pos)
            vb = kvc.write_linear(vb, v, pos)
            k_pos = kvc.slot_positions_linear(T, pos + S)   # (B,T)
        o = gqa_attention(q, kb.astype(x.dtype), vb.astype(x.dtype),
                          positions, k_pos, scale=scale, window=spec.window,
                          cap=cfg.attn_logit_softcap, q_block=q_block)
        new_cache = {"k": kb, "v": vb}
    out = o.reshape(B, S, H * hd) @ p["wo"]
    return out, new_cache


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V3): absorbed formulation throughout.
#
# Absorbed attention never materializes per-head expanded K/V over the
# context: scores are computed in the compressed kv_lora space
#   q_eff = q_nope @ W_kv_b[k-part]      (B,S,H,r)
#   s     = q_eff . ckv + q_rope . k_rope
#   ctx   = softmax(s) . ckv             (B,S,H,r)
#   out_h = ctx @ W_kv_b[v-part]
# This is the production decode path (the KV cache stays compressed); we
# use it for prefill/train as well — it trades ~2.7x score FLOPs for O(r)
# cache reads, recorded in DESIGN.md / EXPERIMENTS.md.

def init_mla_params(key, cfg, dtype):
    m = cfg.mla
    d, H = cfg.d_model, cfg.num_heads
    qk_hd = m.qk_nope_head_dim + m.qk_rope_head_dim
    ks = split_keys(key, 5)
    return {
        "wq_a": dense_init(ks[0], (d, m.q_lora_rank), dtype),
        "q_norm": jnp.zeros((m.q_lora_rank,), dtype),
        "wq_b": dense_init(ks[1], (m.q_lora_rank, H * qk_hd), dtype),
        "wkv_a": dense_init(ks[2], (d, m.kv_lora_rank + m.qk_rope_head_dim),
                            dtype),
        "kv_norm": jnp.zeros((m.kv_lora_rank,), dtype),
        "wkv_b": dense_init(ks[3], (m.kv_lora_rank,
                                    H * (m.qk_nope_head_dim + m.v_head_dim)),
                            dtype),
        "wo": dense_init(ks[4], (H * m.v_head_dim, d), dtype),
    }


def _mla_core(q_eff, q_rope, ckv, krope, q_pos, k_pos, scale, window):
    """q_eff (B,Sq,H,r); q_rope (B,Sq,H,p); ckv (B,T,r); krope (B,T,p)."""
    s = (jnp.einsum("bshr,btr->bhst", q_eff.astype(jnp.float32),
                    ckv.astype(jnp.float32))
         + jnp.einsum("bshp,btp->bhst", q_rope.astype(jnp.float32),
                      krope.astype(jnp.float32))) * scale
    mask = position_mask(q_pos, k_pos, window)              # (B,Sq,Skv)
    s = jnp.where(mask[:, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    ctx = jnp.einsum("bhst,btr->bshr", p, ckv.astype(jnp.float32))
    return ctx.astype(q_eff.dtype)


def mla_layer(cfg, spec, p, x, cache, pos, q_block=512, block_tables=None):
    m = cfg.mla
    B, S, d = x.shape
    H = cfg.num_heads
    from repro.models.common import rms_norm
    scale = (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5

    cq = rms_norm(x @ p["wq_a"], p["q_norm"], cfg.norm_eps)
    q = (cq @ p["wq_b"]).reshape(B, S, H,
                                 m.qk_nope_head_dim + m.qk_rope_head_dim)
    q_nope, q_rope = q[..., :m.qk_nope_head_dim], q[..., m.qk_nope_head_dim:]
    pos = kvc.batch_pos(pos, B)
    positions = pos[:, None] + jnp.arange(S)[None, :]      # (B,S)
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta, "neox")

    kv_a = x @ p["wkv_a"]
    ckv_new = rms_norm(kv_a[..., :m.kv_lora_rank], p["kv_norm"], cfg.norm_eps)
    krope_new = apply_rope(kv_a[..., m.kv_lora_rank:], positions,
                           cfg.rope_theta, "neox")

    wkv_b = p["wkv_b"].reshape(m.kv_lora_rank, H,
                               m.qk_nope_head_dim + m.v_head_dim)
    wk = wkv_b[..., :m.qk_nope_head_dim]          # (r, H, nope)
    wv = wkv_b[..., m.qk_nope_head_dim:]          # (r, H, v)
    q_eff = jnp.einsum("bshn,rhn->bshr", q_nope, wk)
    q_eff = hint(q_eff, "batch", None, _maybe_model(H), None)

    if cache is None:
        ckv, krope = ckv_new, krope_new
        k_pos = positions
        new_cache = None
    elif block_tables is not None:
        cp = paged_write(cache["ckv"], ckv_new, block_tables, positions)
        kp = paged_write(cache["krope"], krope_new, block_tables, positions)
        new_cache = {"ckv": cp, "krope": kp}
        ckv = paged_gather(cp, block_tables).astype(x.dtype)
        krope = paged_gather(kp, block_tables).astype(x.dtype)
        k_pos = kvc.slot_positions_linear(ckv.shape[1], pos + S)
    else:
        ckv = kvc.write_linear(cache["ckv"], ckv_new, pos)
        krope = kvc.write_linear(cache["krope"], krope_new, pos)
        k_pos = kvc.slot_positions_linear(ckv.shape[1], pos + S)
        new_cache = {"ckv": ckv, "krope": krope}
        ckv = ckv.astype(x.dtype)
        krope = krope.astype(x.dtype)

    # blockwise over q on the pair (q_eff, q_rope)
    Sq = q_eff.shape[1]
    if Sq <= q_block or Sq % q_block != 0:
        ctx = _mla_core(q_eff, q_rope, ckv, krope, positions, k_pos, scale,
                        spec.window)
    else:
        nb = Sq // q_block
        qe = jnp.moveaxis(q_eff.reshape(B, nb, q_block, H, -1), 1, 0)
        qr = jnp.moveaxis(q_rope.reshape(B, nb, q_block, H, -1), 1, 0)
        pb = jnp.moveaxis(positions.reshape(B, nb, q_block), 1, 0)
        fn = jax.checkpoint(lambda a: _mla_core(a[0], a[1], ckv, krope, a[2],
                                                k_pos, scale, spec.window))
        ctx = jax.lax.map(fn, (qe, qr, pb))
        ctx = jnp.moveaxis(ctx, 0, 1).reshape(B, Sq, H, -1)

    out_h = jnp.einsum("bshr,rhv->bshv", ctx, wv)
    out = out_h.reshape(B, S, H * m.v_head_dim) @ p["wo"]
    return out, new_cache
