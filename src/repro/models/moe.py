"""Mixture-of-Experts FFN.

Two semantically-equivalent implementations of the routed path:

1. ``routed_dense`` — capacity-free masked compute; every expert weight is
   used for its assigned tokens via scatter/gather on a single device.
   Used for smoke tests and the Teola CPU engines.

2. ``routed_ep`` — expert-parallel shard_map for the production mesh:
   tokens are sequence-sharded over the 'model' axis; each model shard
   owns E/TP experts; dispatch/combine go through explicit
   ``all_to_all`` collectives with per-expert capacity (GShard-style
   token dropping at capacity_factor). Expert weights are additionally
   FSDP-sharded over 'data' and all-gathered per layer.

Shared experts are a plain dense FFN (tensor-parallel over 'model'),
computed outside the shard_map and added to the routed output — this is
the DeepSeek-V3 / Qwen-MoE shared-expert structure.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.common import act_fn, dense_init, split_keys
from repro.models.sharding import active_mesh, hint

if hasattr(jax, "shard_map"):                     # jax >= 0.6
    def _shard_map(f, *, mesh, in_specs, out_specs):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
else:                                             # jax 0.4/0.5
    from jax.experimental.shard_map import shard_map as _sm_legacy

    def _shard_map(f, *, mesh, in_specs, out_specs):
        return _sm_legacy(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_rep=False)


def init_moe_params(key, cfg, dtype):
    mo = cfg.moe
    d = cfg.d_model
    ks = split_keys(key, 5)
    p = {
        "router": dense_init(ks[0], (d, mo.num_experts), jnp.float32),
        # stacked expert weights: (E, d, f) / (E, f, d)
        "w_gate": dense_init(ks[1], (mo.num_experts, d, mo.d_expert), dtype),
        "w_up": dense_init(ks[2], (mo.num_experts, d, mo.d_expert), dtype),
        "w_down": dense_init(ks[3], (mo.num_experts, mo.d_expert, d), dtype),
    }
    if mo.num_shared_experts:
        ks2 = split_keys(ks[4], 3)
        p["shared"] = {
            "w_gate": dense_init(ks2[0], (d, mo.d_shared), dtype),
            "w_up": dense_init(ks2[1], (d, mo.d_shared), dtype),
            "w_down": dense_init(ks2[2], (mo.d_shared, d), dtype),
        }
    return p


def router_probs(cfg, router_w, x2d):
    """x2d (T, d) -> (gates (T,k), idx (T,k)) with optional top-k renorm."""
    mo = cfg.moe
    logits = x2d.astype(jnp.float32) @ router_w  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, mo.top_k)
    if mo.norm_topk_prob:
        gates = gates / (jnp.sum(gates, axis=-1, keepdims=True) + 1e-9)
    return gates, idx, logits


def aux_load_balance_loss(cfg, logits, idx):
    """Switch-style load-balance auxiliary loss (mean fraction * mean prob)."""
    mo = cfg.moe
    E = mo.num_experts
    probs = jax.nn.softmax(logits, axis=-1)
    me = jnp.mean(probs, axis=0)                        # (E,)
    counts = jnp.zeros((E,), jnp.float32).at[idx.reshape(-1)].add(1.0)
    ce = counts / (idx.size + 1e-9)
    return E * jnp.sum(me * ce)


def _expert_ffn(act, xg, w_gate, w_up, w_down):
    """xg (E, C, d); weights (E, d, f)/(E, f, d)."""
    h = act(jnp.einsum("ecd,edf->ecf", xg, w_gate)) * \
        jnp.einsum("ecd,edf->ecf", xg, w_up)
    return jnp.einsum("ecf,efd->ecd", h, w_down)


# ---------------------------------------------------------------------------
# 1. dense/local routed path

def routed_dense(cfg, p, x2d):
    """Exact top-k MoE without capacity dropping (single device)."""
    mo = cfg.moe
    act = act_fn(cfg.act)
    gates, idx, logits = router_probs(cfg, p["router"], x2d)
    T, d = x2d.shape
    out = jnp.zeros_like(x2d)
    # one-hot combine: y = sum_e mask_e * gate_e * ffn_e(x)
    # computed expert-major to keep weights stacked.
    oh = jax.nn.one_hot(idx, mo.num_experts, dtype=x2d.dtype)   # (T,k,E)
    combine = jnp.einsum("tk,tke->te", gates.astype(x2d.dtype), oh)  # (T,E)
    h = act(jnp.einsum("td,edf->tef", x2d, p["w_gate"])) * \
        jnp.einsum("td,edf->tef", x2d, p["w_up"])
    y = jnp.einsum("tef,efd->ted", h, p["w_down"])
    out = jnp.einsum("ted,te->td", y, combine)
    return out, aux_load_balance_loss(cfg, logits, idx)


# ---------------------------------------------------------------------------
# 2. expert-parallel shard_map path

def _ep_local(cfg, act, x_local, router_w, w_gate, w_up, w_down, *,
              tp_size: int, ep, fsdp: tuple = ("data",)):
    """Runs per-device inside shard_map. x_local (Tl, d).
    Expert weights arrive expert-sharded over 'model' (E_local = E_pad/tp
    each; experts padded up to a multiple of tp — padded experts receive no
    tokens) and FSDP-sharded over 'data' on the d axis; the d axis is
    all-gathered here (explicit FSDP weight gather, overlappable by XLA)."""
    mo = cfg.moe
    El = w_gate.shape[0]                   # local (padded) experts per shard
    E_pad = El * tp_size
    Tl, d = x_local.shape

    if fsdp:
        wg = jax.lax.all_gather(w_gate, fsdp, axis=1, tiled=True)
        wu = jax.lax.all_gather(w_up, fsdp, axis=1, tiled=True)
        wd = jax.lax.all_gather(w_down, fsdp, axis=2, tiled=True)
    else:                                  # resident expert weights
        wg, wu, wd = w_gate, w_up, w_down

    gates, idx, logits = router_probs(cfg, router_w, x_local)
    k = mo.top_k
    # per-sender capacity per expert (based on the REAL expert count)
    cap = max(1, int(Tl * k / mo.num_experts * mo.capacity_factor))

    # slot assignment: flat (Tl*k,) expert ids -> position within expert
    eid = idx.reshape(-1)                                  # (Tl*k,)
    gat = gates.reshape(-1).astype(x_local.dtype)
    onehot = jax.nn.one_hot(eid, E_pad, dtype=jnp.int32)    # (Tl*k, E_pad)
    pos_in_e = jnp.cumsum(onehot, axis=0) - onehot          # exclusive
    pos = jnp.sum(pos_in_e * onehot, axis=-1)               # (Tl*k,)
    keep = pos < cap
    slot = eid * cap + jnp.minimum(pos, cap - 1)            # (Tl*k,)

    # scatter tokens into the send buffer (E_pad*cap, d)
    tok = jnp.repeat(x_local, k, axis=0)                    # (Tl*k, d)
    send = jnp.zeros((E_pad * cap, d), x_local.dtype)
    send = send.at[slot].add(jnp.where(keep[:, None], tok, 0))

    # all_to_all over the expert-parallel axes: shard j receives its
    # experts' tokens
    send = send.reshape(tp_size, El * cap, d)
    recv = jax.lax.all_to_all(send, ep, split_axis=0, concat_axis=0,
                              tiled=True)                   # (tp*El*cap, d)
    recv = recv.reshape(tp_size, El, cap, d)
    recv = jnp.moveaxis(recv, 1, 0).reshape(El, tp_size * cap, d)

    # local experts (already this shard's E_local slice)
    y = _expert_ffn(act, recv, wg, wu, wd)                  # (El, tp*cap, d)

    # route back
    y = jnp.moveaxis(y.reshape(El, tp_size, cap, d), 1, 0)
    y = y.reshape(tp_size, El * cap, d)
    back = jax.lax.all_to_all(y, ep, split_axis=0, concat_axis=0,
                              tiled=True)
    back = back.reshape(E_pad * cap, d)                     # sender layout

    # combine: gather each assignment's slot, weight by gate
    yk = back[slot] * jnp.where(keep, gat, 0.0)[:, None]    # (Tl*k, d)
    out = jnp.sum(yk.reshape(Tl, k, d), axis=1)
    return out, aux_load_balance_loss(cfg, logits, idx)


def routed_ep(cfg, p, x2d, mesh):
    """x2d (T, d), T divisible by the full device count; tokens are
    sharded over all mesh axes so every device routes a disjoint slice
    (true expert parallelism; all_to_all runs along the EP axes —
    'model' by default, ('model','data') under the ep_all_axes flag)."""
    from repro.launch.shard_rules import ep_axes, fsdp_axes
    act = act_fn(cfg.act)
    ep = ep_axes(mesh)
    tp = 1
    for a in ep:
        tp *= mesh.shape[a]
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    fsdp = tuple(a for a in fsdp_axes(mesh) if a not in ep)
    ep_sp = ep if len(ep) > 1 else ep[0]
    fsdp_sp = (fsdp if len(fsdp) > 1 else fsdp[0]) if fsdp else None
    tok_spec = P(batch_axes + ("model",), None)

    # pad expert count up to a multiple of the TP axis (padded experts are
    # never routed to; GSPMD stores the uneven original padded anyway)
    E = cfg.moe.num_experts
    E_pad = -(-E // tp) * tp
    w_gate, w_up, w_down = p["w_gate"], p["w_up"], p["w_down"]
    if E_pad != E:
        w_gate = jnp.pad(w_gate, ((0, E_pad - E), (0, 0), (0, 0)))
        w_up = jnp.pad(w_up, ((0, E_pad - E), (0, 0), (0, 0)))
        w_down = jnp.pad(w_down, ((0, E_pad - E), (0, 0), (0, 0)))

    def body(x_l, rw, wg, wu, wd):
        out, aux = _ep_local(cfg, act, x_l, rw, wg, wu, wd, tp_size=tp,
                             ep=ep, fsdp=fsdp)
        for ax in ("model",) + batch_axes:
            aux = jax.lax.pmean(aux, ax)
        return out, aux

    w_specs = (P(ep_sp, fsdp_sp, None), P(ep_sp, fsdp_sp, None),
               P(ep_sp, None, fsdp_sp))
    out, aux = _shard_map(
        body, mesh=mesh,
        in_specs=(tok_spec, P(None, None)) + w_specs,
        out_specs=(tok_spec, P()),
    )(x2d, p["router"], w_gate, w_up, w_down)
    return out, aux


# ---------------------------------------------------------------------------

def shared_expert_ffn(cfg, p, x):
    act = act_fn(cfg.act)
    sp = p["shared"]
    h = act(x @ sp["w_gate"]) * (x @ sp["w_up"])
    h = hint(h, "batch", None, "model")
    return h @ sp["w_down"]


def moe_ffn(cfg, p, x):
    """x (B,S,d) -> (out, aux_loss). Chooses EP when a mesh is active and
    expert count divides the TP axis; otherwise the dense path."""
    mo = cfg.moe
    mesh = active_mesh()
    B, S, d = x.shape
    use_ep = (
        mesh is not None
        and "model" in mesh.axis_names
        and mesh.shape["model"] > 1
    )
    if use_ep:
        # flat-token layout, padded up to the device count so shard_map
        # divides evenly (decode steps have few tokens)
        T = B * S
        shards = _total_batch_shards(mesh) * mesh.shape["model"]
        Tp = -(-T // shards) * shards
        x2d = x.reshape(T, d)
        if Tp != T:
            x2d = jnp.pad(x2d, ((0, Tp - T), (0, 0)))
        out2d, aux = routed_ep(cfg, p, x2d, mesh)
        out = out2d[:T].reshape(B, S, d)
    else:
        out2d, aux = routed_dense(cfg, p, x.reshape(B * S, d))
        out = out2d.reshape(B, S, d)
    if mo.num_shared_experts:
        out = out + shared_expert_ffn(cfg, p, x)
    return out, aux


def _total_batch_shards(mesh):
    n = 1
    for a in ("pod", "data"):
        if a in mesh.axis_names:
            n *= mesh.shape[a]
    return n
