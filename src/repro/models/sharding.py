"""Trace-time sharding hints.

Model code calls ``hint(x, 'batch', None, 'model', None)`` on activations.
When a mesh context is active (set by the launcher / dry-run before
tracing), this becomes ``with_sharding_constraint``; otherwise it is a
no-op, so the same model code runs untouched on a single CPU device in
tests and in the Teola engines.

Logical axes:
  'batch'  -> all batch-ish mesh axes present: ('pod', 'data')
  'model'  -> tensor-parallel axis 'model'
  None     -> unsharded
"""
from __future__ import annotations

import contextlib
import threading

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

_state = threading.local()


def _axes():
    return getattr(_state, "axes", None)


def _mesh():
    return getattr(_state, "mesh", None)


@contextlib.contextmanager
def mesh_context(mesh):
    """Activate sharding hints for model code traced inside this block."""
    prev_axes, prev_mesh = _axes(), _mesh()
    _state.axes = tuple(mesh.axis_names)
    _state.mesh = mesh
    try:
        yield
    finally:
        _state.axes = prev_axes
        _state.mesh = prev_mesh


def logical_to_spec(logical, axes=None) -> P:
    axes = axes if axes is not None else _axes()
    parts = []
    for l in logical:
        if l is None or axes is None:
            parts.append(None)
        elif l == "batch":
            from repro.launch import optflags
            names = (("pod", "data", "model")
                     if optflags.has("flat_dp") else ("pod", "data"))
            have = tuple(a for a in names if a in axes)
            parts.append(have if have else None)
        elif l == "model":
            from repro.launch import optflags
            if optflags.has("flat_dp"):    # model axis belongs to batch
                parts.append(None)
            else:
                parts.append("model" if "model" in axes else None)
        else:
            raise ValueError(f"unknown logical axis {l!r}")
    return P(*parts)


def hint(x, *logical):
    """Apply a sharding constraint if a mesh context is active."""
    mesh = _mesh()
    if mesh is None:
        return x
    spec = logical_to_spec(logical)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def active_mesh():
    return _mesh()


def axis_present(name: str) -> bool:
    axes = _axes()
    return axes is not None and name in axes
