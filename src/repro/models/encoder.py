"""Bidirectional encoder models for the embedding and reranking engines
(BERT-family stand-ins for bge-large-en / bge-reranker-large)."""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.models.common import dense_init, embed_init, rms_norm, split_keys


@dataclass(frozen=True)
class EncoderConfig:
    name: str = "tiny-embedder"
    vocab_size: int = 4096
    d_model: int = 128
    num_heads: int = 4
    d_ff: int = 384
    num_layers: int = 2
    max_len: int = 512
    out_dim: int = 128          # embedding dim (embedder) / 1 (reranker)
    pooling: str = "mean"       # mean | cls_score
    norm_eps: float = 1e-6


EMBEDDER = EncoderConfig(name="tiny-embedder", out_dim=128, pooling="mean")
RERANKER = EncoderConfig(name="tiny-reranker", out_dim=1,
                         pooling="cls_score")


def init_encoder_params(cfg: EncoderConfig, key, dtype=jnp.float32):
    ks = split_keys(key, 3 + cfg.num_layers)
    params = {
        "embed": embed_init(ks[0], (cfg.vocab_size, cfg.d_model), dtype),
        "pos_embed": embed_init(ks[1], (cfg.max_len, cfg.d_model), dtype),
        "final_norm": jnp.zeros((cfg.d_model,), dtype),
        "head": dense_init(ks[2], (cfg.d_model, cfg.out_dim), dtype),
        "layers": [],
    }
    hd = cfg.d_model // cfg.num_heads
    for i in range(cfg.num_layers):
        lk = split_keys(ks[3 + i], 7)
        params["layers"].append({
            "ln1": jnp.zeros((cfg.d_model,), dtype),
            "ln2": jnp.zeros((cfg.d_model,), dtype),
            "wq": dense_init(lk[0], (cfg.d_model, cfg.d_model), dtype),
            "wk": dense_init(lk[1], (cfg.d_model, cfg.d_model), dtype),
            "wv": dense_init(lk[2], (cfg.d_model, cfg.d_model), dtype),
            "wo": dense_init(lk[3], (cfg.d_model, cfg.d_model), dtype),
            "w1": dense_init(lk[4], (cfg.d_model, cfg.d_ff), dtype),
            "w2": dense_init(lk[5], (cfg.d_ff, cfg.d_model), dtype),
        })
    return params


def apply_encoder(cfg: EncoderConfig, params, tokens, mask=None):
    """tokens (B,S) int32; mask (B,S) 1=real, 0=pad. Returns:
    pooling=='mean': L2-normalized embeddings (B, out_dim)
    pooling=='cls_score': relevance scores (B,)"""
    B, S = tokens.shape
    if mask is None:
        mask = jnp.ones((B, S), jnp.float32)
    mask = mask.astype(jnp.float32)
    x = params["embed"][tokens] + params["pos_embed"][:S]
    hd = cfg.d_model // cfg.num_heads
    for lp in params["layers"]:
        h = rms_norm(x, lp["ln1"], cfg.norm_eps)
        q = (h @ lp["wq"]).reshape(B, S, cfg.num_heads, hd)
        k = (h @ lp["wk"]).reshape(B, S, cfg.num_heads, hd)
        v = (h @ lp["wv"]).reshape(B, S, cfg.num_heads, hd)
        s = jnp.einsum("bshd,bthd->bhst", q, k) * hd ** -0.5
        s = jnp.where(mask[:, None, None, :] > 0, s, -1e30)
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bhst,bthd->bshd", p, v).reshape(B, S, cfg.d_model)
        x = x + o @ lp["wo"]
        h = rms_norm(x, lp["ln2"], cfg.norm_eps)
        x = x + jax.nn.gelu(h @ lp["w1"]) @ lp["w2"]
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    if cfg.pooling == "mean":
        pooled = jnp.sum(x * mask[..., None], axis=1) / (
            jnp.sum(mask, axis=1, keepdims=True) + 1e-6)
        emb = pooled @ params["head"]
        return emb / (jnp.linalg.norm(emb, axis=-1, keepdims=True) + 1e-6)
    # reranker: score from first token
    return (x[:, 0] @ params["head"]).squeeze(-1)
