"""Composable decoder model: init + apply for every assigned architecture.

A model is a sequence of *stages*; each stage scans a stacked repeating
*pattern* of layers (see configs.base). The same `apply_model` serves
training (cache=None, full causal), chunked prefill (cache + pos offset —
Teola's Partial/Full Prefilling), and decode (S==1).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, LayerSpec
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.common import (act_fn, dense_init, embed_init, rms_norm,
                                 softcap, split_keys)
from repro.models.sharding import hint


# ---------------------------------------------------------------------------
# init

def init_mlp_params(key, cfg, dtype):
    ks = split_keys(key, 3)
    d, f = cfg.d_model, cfg.d_ff
    return {
        "w_gate": dense_init(ks[0], (d, f), dtype),
        "w_up": dense_init(ks[1], (d, f), dtype),
        "w_down": dense_init(ks[2], (f, d), dtype),
    }


def init_layer_elem(key, cfg: ModelConfig, spec: LayerSpec, dtype):
    ks = split_keys(key, 4)
    p = {"ln1": jnp.zeros((cfg.d_model,), dtype),
         "ln2": jnp.zeros((cfg.d_model,), dtype)}
    if spec.kind == "rwkv":
        p.update(ssm_mod.init_rwkv_params(ks[0], cfg, dtype))
        return p
    if cfg.attention_kind == "mla":
        p["attn"] = attn.init_mla_params(ks[0], cfg, dtype)
    else:
        p["attn"] = attn.init_gqa_params(ks[0], cfg, dtype)
    if spec.kind == "hybrid":
        p["mamba"] = ssm_mod.init_mamba_params(ks[1], cfg, dtype)
        p["fuse_na"] = jnp.zeros((cfg.d_model,), dtype)
        p["fuse_ns"] = jnp.zeros((cfg.d_model,), dtype)
    if spec.moe:
        p["moe"] = moe_mod.init_moe_params(ks[2], cfg, dtype)
    else:
        p["mlp"] = init_mlp_params(ks[2], cfg, dtype)
    return p


def init_params(cfg: ModelConfig, key, dtype=jnp.float32):
    ks = split_keys(key, 3 + len(cfg.stages))
    params = {"embed": embed_init(ks[0], (cfg.vocab_size, cfg.d_model),
                                  dtype),
              "final_norm": jnp.zeros((cfg.d_model,), dtype)}
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(ks[1], (cfg.d_model, cfg.vocab_size),
                                       dtype)
    stages = []
    for si, st in enumerate(cfg.stages):
        elem_keys = split_keys(ks[2 + si], len(st.pattern))
        elems = []
        for spec, ek in zip(st.pattern, elem_keys):
            rep_keys = jnp.stack(split_keys(ek, st.repeat))
            elems.append(jax.vmap(
                lambda k, spec=spec: init_layer_elem(k, cfg, spec, dtype)
            )(rep_keys))
        stages.append(elems)
    params["stages"] = stages
    return params


def param_shapes(cfg: ModelConfig, dtype=jnp.bfloat16):
    """Abstract param tree (no allocation) — used by the dry-run."""
    return jax.eval_shape(
        lambda k: init_params(cfg, k, dtype), jax.random.key(0))


# ---------------------------------------------------------------------------
# apply

def _ffn(cfg, p, x):
    act = act_fn(cfg.act)
    h = act(x @ p["w_gate"]) * (x @ p["w_up"])
    h = hint(h, "batch", None, "model")
    return h @ p["w_down"]


def apply_layer(cfg, spec, p, x, ce, pos, q_block, block_tables=None):
    """One transformer layer. ce: cache elem dict or None (paged pool
    elems when ``block_tables`` is given). Returns
    (x, new_cache_elem, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    B, S, d = x.shape

    if spec.kind == "rwkv":
        if ce is None:
            s = cfg.ssm
            H = d // s.head_dim
            state = jnp.zeros((B, H, s.head_dim, s.head_dim), jnp.float32)
            sx_tm = jnp.zeros((B, d), jnp.float32)
            sx_cm = jnp.zeros((B, d), jnp.float32)
        else:
            state, sx_tm, sx_cm = ce["state"], ce["sx_tm"], ce["sx_cm"]
        h = rms_norm(x, p["ln1"], cfg.norm_eps)
        out, state, sx_tm = ssm_mod.rwkv_time_mix(cfg, p["tm"], h, state,
                                                  sx_tm)
        x = x + out
        h = rms_norm(x, p["ln2"], cfg.norm_eps)
        out, sx_cm = ssm_mod.rwkv_channel_mix(cfg, p["cm"], h, sx_cm)
        x = x + out
        nc = None if ce is None else {
            "state": state, "sx_tm": sx_tm.astype(jnp.float32),
            "sx_cm": sx_cm.astype(jnp.float32)}
        return x, nc, aux

    # --- attention (+ optional parallel SSM heads) ---
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    attn_cache_in = None
    if ce is not None:
        attn_cache_in = {k: v for k, v in ce.items()
                         if k in ("k", "v", "ckv", "krope")}
    if cfg.attention_kind == "mla":
        a_out, a_cache = attn.mla_layer(cfg, spec, p["attn"], h,
                                        attn_cache_in, pos, q_block,
                                        block_tables)
    else:
        a_out, a_cache = attn.gqa_layer(cfg, spec, p["attn"], h,
                                        attn_cache_in, pos, q_block,
                                        block_tables)
    nc = dict(a_cache) if a_cache is not None else None

    if spec.kind == "hybrid":
        if ce is None:
            s = cfg.ssm
            h_state = jnp.zeros((B, d, s.state_dim), jnp.float32)
            conv_state = jnp.zeros((B, s.conv_dim - 1, d), jnp.float32)
        else:
            h_state, conv_state = ce["ssm_h"], ce["ssm_conv"]
        s_out, h_state, conv_state = ssm_mod.mamba_branch(
            cfg, p["mamba"], h, h_state, conv_state)
        mixed = 0.5 * (rms_norm(a_out, p["fuse_na"], cfg.norm_eps)
                       + rms_norm(s_out, p["fuse_ns"], cfg.norm_eps))
        x = x + mixed
        if nc is not None:
            nc["ssm_h"] = h_state
            nc["ssm_conv"] = conv_state
    else:
        x = x + a_out

    # --- FFN ---
    h = rms_norm(x, p["ln2"], cfg.norm_eps)
    if spec.moe:
        f_out, aux = moe_mod.moe_ffn(cfg, p["moe"], h)
    else:
        f_out = _ffn(cfg, p["mlp"], h)
    x = x + f_out
    return x, nc, aux


def apply_model(cfg: ModelConfig, params, inputs, cache=None, pos=0, *,
                q_block=512, remat=True, logits_slice=None,
                block_tables=None, logits_at=None):
    """inputs: int tokens (B,S) or float embeddings (B,S,d) for
    modality-frontend-stub archs. Returns (logits, new_cache, aux_loss).

    cache/pos implement chunked (partial) prefill and decode; cache=None is
    training/eval over the full sequence. ``block_tables`` (B,maxblk)
    switches the cache to the PAGED layout (shared block pools indexed per
    sequence — see serving/kv_cache.py). ``logits_at`` (B,) computes the
    head only at one per-sequence chunk index (exact last-token logits
    under right-padded bucketed prefill), returning (B,1,vocab).
    """
    if jnp.issubdtype(inputs.dtype, jnp.integer):
        x = params["embed"][inputs]
    else:
        x = inputs.astype(params["embed"].dtype)
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    x = hint(x, "batch", None, None)
    pos = jnp.asarray(pos, jnp.int32)

    aux_total = jnp.zeros((), jnp.float32)
    new_cache = {"stages": []} if cache is not None else None

    for si, st in enumerate(cfg.stages):
        stacked = params["stages"][si]
        cache_st = cache["stages"][si] if cache is not None else None

        def body(x, xs, st=st, cache_present=cache_st is not None):
            elems = xs[0]
            caches = xs[1] if cache_present else [None] * len(st.pattern)
            new_elems = []
            aux = jnp.zeros((), jnp.float32)
            for spec, pe, ce in zip(st.pattern, elems, caches):
                x, nce, a = apply_layer(cfg, spec, pe, x, ce, pos, q_block,
                                        block_tables)
                aux = aux + a
                if cache_present:
                    new_elems.append(nce)
            return x, (new_elems, aux) if cache_present else aux

        if remat:
            body = jax.checkpoint(
                body, policy=jax.checkpoint_policies.nothing_saveable)

        if cache_st is not None:
            x, (nc_st, auxs) = jax.lax.scan(body, x, (stacked, cache_st))
            new_cache["stages"].append(nc_st)
        else:
            x, auxs = jax.lax.scan(body, x, (stacked,))
        aux_total = aux_total + jnp.sum(auxs)

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    if logits_at is not None:
        x = x[jnp.arange(x.shape[0]), logits_at][:, None, :]
    elif logits_slice is not None:
        x = x[:, -logits_slice:, :]
    head = (params["embed"].T if cfg.tie_embeddings
            else params["lm_head"])
    logits = x @ head.astype(x.dtype)
    logits = softcap(logits, cfg.final_logit_softcap)
    logits = hint(logits, "batch", None, "model")
    return logits, new_cache, aux_total
