"""Shared building blocks: norms, activations, RoPE, initializers."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rms_norm(x, scale, eps: float = 1e-6):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(dtype)


def layer_norm(x, scale, bias, eps: float = 1e-5):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dtype)


def group_norm_heads(x, scale, eps: float = 1e-5):
    """Per-head group norm over the last dim. x: (..., H, hd)."""
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32)).astype(dtype)


def act_fn(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu}[name]


def softcap(x, cap):
    if cap is None:
        return x
    return cap * jnp.tanh(x / cap)


# ---------------------------------------------------------------------------
# RoPE

def rope_freqs(head_dim: int, theta: float, rotary_dim: int | None = None):
    rd = rotary_dim if rotary_dim is not None else head_dim
    inv = 1.0 / (theta ** (jnp.arange(0, rd, 2, dtype=jnp.float32) / rd))
    return inv  # (rd/2,)


def apply_rope(x, positions, theta: float, kind: str = "neox"):
    """x: (B, S, H, hd) or (B, S, hd); positions: (S,) or (B, S) int32.

    kind: 'neox' rotates the full head dim (half-split layout),
          'half' rotates only the first half of head dims (ChatGLM 2D RoPE),
          'none' is identity.
    """
    if kind == "none":
        return x
    hd = x.shape[-1]
    rd = hd if kind == "neox" else hd // 2
    inv = rope_freqs(hd, theta, rd)
    positions = jnp.asarray(positions)
    if positions.ndim == 1:
        positions = positions[None]     # (1, S)
    ang = positions.astype(jnp.float32)[..., None] * inv  # (B|1, S, rd/2)
    if x.ndim == 4:                     # head axis present
        ang = ang[..., None, :]         # (B|1, S, 1, rd/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    rot, rest = x[..., :rd], x[..., rd:]
    x1, x2 = rot[..., : rd // 2], rot[..., rd // 2:]
    r1 = x1 * cos - x2 * sin
    r2 = x2 * cos + x1 * sin
    return jnp.concatenate([r1.astype(x.dtype), r2.astype(x.dtype), rest],
                           axis=-1)


# ---------------------------------------------------------------------------
# Init helpers

def dense_init(key, shape, dtype, scale: float | None = None):
    fan_in = shape[0] if len(shape) >= 2 else shape[-1]
    std = (scale if scale is not None else 1.0) / (fan_in ** 0.5)
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


def embed_init(key, shape, dtype):
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


def split_keys(key, n):
    return list(jax.random.split(key, n))
