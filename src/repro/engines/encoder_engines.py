"""Embedding and reranking engines wrapping the JAX encoder models."""
from __future__ import annotations

import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.engines.tokenizer import HashTokenizer
from repro.models.encoder import (EMBEDDER, RERANKER, EncoderConfig,
                                  apply_encoder, init_encoder_params)

_BUCKETS_B = (1, 2, 4, 8, 16, 32)
_BUCKETS_S = (16, 32, 64)


def _bucket(n, bs):
    for b in bs:
        if n <= b:
            return b
    return bs[-1]


class _EncoderEngine:
    def __init__(self, name, cfg: EncoderConfig, max_batch: int, seed=0):
        self.name = name
        self.cfg = cfg
        self.max_batch = max_batch
        self.tok = HashTokenizer(cfg.vocab_size)
        self.params = init_encoder_params(cfg, jax.random.key(seed))
        self._fwd = jax.jit(lambda p, t, m: apply_encoder(cfg, p, t, m))
        self.stats = {"requests": 0, "calls": 0, "busy_s": 0.0}

    def clone(self, idx: int = 1):
        """Pool replica: shared weights/tokenizer/jitted forward, fresh
        stats (encoders are stateless across requests)."""
        c = type(self).__new__(type(self))
        c.name = f"{self.name}.r{idx}"
        c.cfg = self.cfg
        c.max_batch = self.max_batch
        c.tok = self.tok
        c.params = self.params
        c._fwd = self._fwd
        c.stats = {"requests": 0, "calls": 0, "busy_s": 0.0}
        return c

    def _encode_batch(self, texts: List[str]):
        t0 = time.time()
        B = _bucket(len(texts), _BUCKETS_B)
        S = _bucket(max(1, max(len(t.split()) for t in texts)), _BUCKETS_S)
        toks = np.zeros((B, S), np.int32)
        mask = np.zeros((B, S), np.float32)
        for i, t in enumerate(texts):
            ids = self.tok.encode(t)[:S]
            toks[i, :len(ids)] = ids
            mask[i, :len(ids)] = 1.0
        out = np.asarray(self._fwd(self.params, jnp.asarray(toks),
                                   jnp.asarray(mask)))
        self.stats["requests"] += len(texts)
        self.stats["calls"] += 1
        self.stats["busy_s"] += time.time() - t0
        return out[:len(texts)]


class EmbeddingEngine(_EncoderEngine):
    kind = "embedding"

    def __init__(self, name="embedding", max_batch=16, seed=0):
        super().__init__(name, EMBEDDER, max_batch, seed)

    def op_embed(self, tasks):
        """tasks: list of {'texts': [...]} -> list of vector arrays."""
        flat, spans = [], []
        for t in tasks:
            spans.append((len(flat), len(flat) + len(t["texts"])))
            flat.extend(t["texts"])
        vecs = self._encode_batch(flat) if flat else np.zeros((0, 1))
        return [vecs[a:b] for a, b in spans]


class RerankEngine(_EncoderEngine):
    kind = "rerank"

    def __init__(self, name="rerank", max_batch=16, seed=1):
        super().__init__(name, RERANKER, max_batch, seed)

    def op_rerank(self, tasks):
        """tasks: {'question', 'candidates': [{'text',...}], 'top_k'}."""
        out = []
        for t in tasks:
            cands = t["candidates"]
            if not cands:
                out.append([])
                continue
            pairs = [f"{t['question']} [SEP] {c['text']}" for c in cands]
            scores = self._encode_batch(pairs)          # (n,) cls scores
            order = np.argsort(-scores)[: t.get("top_k", 3)]
            out.append([{**cands[i], "rerank_score": float(scores[i])}
                        for i in order])
        return out
