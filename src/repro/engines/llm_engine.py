"""LLM execution engine.

Runs a real (engine-scale) JAX decoder with:
  - per-sequence KV-cache state store (continuous batching across queries
    with per-sequence positions),
  - decomposed ops: prefill / partial_prefill / full_prefill (chunked
    prefill against the sequence's existing KV prefix — Teola Pass 3) and
    decode / partial_decode (n-token continuation — Teola Pass 4),
  - bucketed jit shapes (batch, chunk length) so engine calls reuse
    compiled programs,
  - an instruction prefix cache (LlamaDistPC baseline's cache-reuse).

On TPU the attention inside apply_model would route to the Pallas
flash_prefill / decode_attention kernels; on CPU the XLA path is used.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.engines.decode_loop import (ContinuousDecodeLoop, DecodeLoopMixin,
                                       DecodeSeq)
from repro.engines.tokenizer import HashTokenizer
from repro.models.transformer import apply_model, init_params
from repro.serving import kv_cache as kvc

BUCKETS_B = (1, 2, 4, 8, 16)
BUCKETS_S = (8, 16, 32, 64, 128, 256, 384, 512)


def _bucket(n, buckets):
    for b in buckets:
        if n <= b:
            return b
    return buckets[-1]


@dataclass
class SeqState:
    cache: object               # single-sequence cache pytree (B=1)
    pos: int = 0
    last_token: int = 1         # BOS


class LLMEngine(DecodeLoopMixin):
    kind = "llm"

    def __init__(self, name: str, cfg: ModelConfig, *, max_len: int = 512,
                 seed: int = 0, max_batch: int = 8, max_tokens: int = 1024,
                 dtype=jnp.float32, stream_chunk: int = 4):
        self.name = name
        self.cfg = cfg
        self.max_len = max_len
        self.max_batch = max_batch
        self.max_tokens = max_tokens
        self.stream_chunk = stream_chunk   # decode tokens per emitted chunk
        self.tok = HashTokenizer(cfg.vocab_size)
        self.params = init_params(cfg, jax.random.key(seed), dtype)
        self.states: Dict[str, SeqState] = {}
        self.prefix_cache: Dict[str, SeqState] = {}
        self._lock = threading.Lock()
        self._step = self._build_step()
        self.meter = kvc.OccupancyMeter(kvc.bytes_per_token(cfg),
                                        decode_slots=max_batch)
        self.stats = {"prefill_tokens": 0, "decode_tokens": 0, "calls": 0,
                      "decode_iters": 0, "busy_s": 0.0}
        # decode_iteration (loop thread) and prefill/decode batches
        # (scheduler thread) update stats concurrently
        self._stats_lock = threading.Lock()
        self._decode_loop: Optional[ContinuousDecodeLoop] = None
        self._pads: List[SeqState] = []   # reusable batch-padding states
        self._reset_batch_cache()

    def clone(self, idx: int = 1) -> "LLMEngine":
        """Pool replica: SHARED weights, tokenizer, compiled step and
        instruction-prefix cache; PER-REPLICA sequence/KV store, lock,
        occupancy meter and stats."""
        c = LLMEngine.__new__(LLMEngine)
        c.name = f"{self.name}.r{idx}"
        c.cfg = self.cfg
        c.max_len = self.max_len
        c.max_batch = self.max_batch
        c.max_tokens = self.max_tokens
        c.stream_chunk = self.stream_chunk
        c.tok = self.tok
        c.params = self.params
        c.states = {}
        c.prefix_cache = self.prefix_cache
        c._lock = threading.Lock()
        c._step = self._step
        c.meter = kvc.OccupancyMeter(self.meter.bytes_per_tok,
                                     decode_slots=c.max_batch)
        c.stats = {"prefill_tokens": 0, "decode_tokens": 0, "calls": 0,
                   "decode_iters": 0, "busy_s": 0.0}
        c._stats_lock = threading.Lock()
        c._decode_loop = None            # per-replica decode loop
        c._pads = []
        c._reset_batch_cache()
        return c

    def kv_occupancy(self) -> int:
        """Resident KV tokens on this replica (pool-router load input)."""
        return self.meter.tokens()

    # -- jitted batched step: write chunk, return logits of last position
    def _build_step(self):
        cfg = self.cfg

        def step(params, tokens, cache, pos):
            logits, cache, _ = apply_model(cfg, params, tokens, cache, pos,
                                           q_block=256, remat=False,
                                           logits_slice=1)
            return logits[:, -1], cache

        return jax.jit(step)

    def new_state(self) -> SeqState:
        return SeqState(cache=kvc.init_cache(self.cfg, 1, self.max_len))

    def fork_state(self, st: SeqState) -> SeqState:
        return SeqState(cache=jax.tree.map(lambda a: a, st.cache),
                        pos=st.pos, last_token=st.last_token)

    # -- batched execution -------------------------------------------------
    def _stack_states(self, states: List[SeqState]):
        cache = jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=1),
                             *[s.cache for s in states])
        pos = jnp.array([s.pos for s in states], jnp.int32)
        return cache, pos

    def _unstack(self, cache, states: List[SeqState]):
        n = len(states)
        for i, s in enumerate(states):
            s.cache = jax.tree.map(lambda a, i=i: a[:, i:i + 1], cache)

    def prefill_batch(self, items):
        """items: list of (state, token_list). Pads to a (B,S) bucket and
        runs one chunked-prefill step per sequence position offset."""
        t0 = time.time()
        B = _bucket(len(items), BUCKETS_B)
        S = _bucket(max(len(t) for _, t in items), BUCKETS_S)
        states = [s for s, _ in items]
        pad_states = states + [self.new_state()
                               for _ in range(B - len(states))]
        toks = np.zeros((B, S), np.int32)
        for i, (_, t) in enumerate(items):
            toks[i, :len(t)] = t[:S]
        cache, pos = self._stack_states(pad_states)
        logits, cache = self._step(self.params, jnp.asarray(toks), cache,
                                   pos)
        self._unstack(cache, pad_states)
        for i, (s, t) in enumerate(items):
            s.pos += len(t)
            # note: last VALID logit belongs to position len(t)-1; with
            # right-padding the final-position logit is only exact when
            # len(t)==S, so keep last_token from argmax over the padded
            # tail — acceptable for the engine-scale demo.
            s.last_token = int(jnp.argmax(logits[i]))
        with self._stats_lock:
            self.stats["prefill_tokens"] += sum(len(t) for _, t in items)
            self.stats["calls"] += 1
            self.stats["busy_s"] += time.time() - t0

    def decode_batch(self, items, on_chunk=None):
        """items: list of (state, n_tokens). Greedy continuous decode; all
        sequences step together for max(n) steps (finished ones keep
        writing into their own slots but results are truncated).
        on_chunk(i, token_ids_so_far): called every `stream_chunk` steps
        per live item — the streaming-decode emission point."""
        t0 = time.time()
        n_max = max(n for _, n in items)
        B = _bucket(len(items), BUCKETS_B)
        states = [s for s, _ in items]
        pad_states = states + [self.new_state()
                               for _ in range(B - len(states))]
        cache, pos = self._stack_states(pad_states)
        cur = jnp.array([[s.last_token] for s in pad_states], jnp.int32)
        outs = [[] for _ in pad_states]
        emitted = [0] * len(items)
        for t in range(n_max):
            logits, cache = self._step(self.params, cur, cache, pos)
            nxt = jnp.argmax(logits, axis=-1)
            for i in range(len(pad_states)):
                outs[i].append(int(nxt[i]))
            cur = nxt[:, None].astype(jnp.int32)
            pos = pos + 1
            if on_chunk and ((t + 1) % self.stream_chunk == 0
                             or t + 1 == n_max):
                for i, (_, n) in enumerate(items):
                    m = min(t + 1, n)
                    if m > emitted[i]:
                        emitted[i] = m
                        on_chunk(i, outs[i][:m])
        self._unstack(cache, pad_states)
        results = []
        for i, (s, n) in enumerate(items):
            s.pos = int(pos[i]) - (n_max - n)
            s.last_token = outs[i][n - 1]
            results.append(outs[i][:n])
        with self._stats_lock:
            self.stats["decode_tokens"] += sum(n for _, n in items)
            self.stats["calls"] += 1
            self.stats["busy_s"] += time.time() - t0
        return results

    # -- iteration-level continuous batching --------------------------------
    # (loop lifecycle — start/stop/slots — comes from DecodeLoopMixin)
    def submit_decode(self, sid: str, max_new: int, on_text=None,
                      on_done=None) -> DecodeSeq:
        """Admit sequence `sid` into the continuous decode loop for
        `max_new` tokens. on_text(text_so_far) fires every iteration;
        on_done(seq) fires at eviction. Returns the DecodeSeq handle."""
        st = self.states[sid]
        seq = DecodeSeq(sid, st, max_new,
                        text_fn=lambda s: self.tok.decode(s.tokens),
                        on_text=on_text, on_done=on_done)
        return self.start_decode_loop().submit(seq)

    def note_slot_acquired(self, seq: DecodeSeq):
        self.meter.acquire_slot(seq.sid)

    def note_slot_released(self, seq: DecodeSeq):
        # an evicted sequence's KV must be current in its own state
        # before the slot is reused (its sid may decode again later)
        self._flush_batch_cache()
        self.meter.release_slot(seq.sid)

    def _pad_states(self, k: int) -> List[SeqState]:
        while len(self._pads) < k:
            self._pads.append(self.new_state())
        return self._pads[:k]

    def _reset_batch_cache(self):
        self._batch_key = None         # tuple of resident DecodeSeq ids
        self._batch_cache = None       # persistent stacked cache pytree
        self._batch_pos = None
        self._batch_states: List[SeqState] = []

    def _flush_batch_cache(self):
        """Write the persistent stacked decode cache back into the
        per-sequence states (on residency change / eviction). Loop-thread
        only, like decode_iteration."""
        if self._batch_cache is not None:
            self._unstack(self._batch_cache, self._batch_states)
        self._reset_batch_cache()

    def decode_iteration(self, seqs: List[DecodeSeq]):
        """One decode step for every resident sequence (called by the
        loop each iteration). The stacked batch cache persists across
        iterations and is rebuilt only when RESIDENCY changes (admission
        or eviction) — steady-state iterations pay no per-token
        stack/unstack of the KV pytree. KV occupancy advances per
        iteration — one token per resident sequence — not per batch up
        front."""
        t0 = time.time()
        B = _bucket(len(seqs), BUCKETS_B)
        key = tuple(id(r) for r in seqs)
        if key != self._batch_key:
            self._flush_batch_cache()
            self._batch_states = [r.state for r in seqs] + \
                self._pad_states(B - len(seqs))
            self._batch_cache, self._batch_pos = \
                self._stack_states(self._batch_states)
            self._batch_key = key
        cur = jnp.array([[s.last_token] for s in self._batch_states],
                        jnp.int32)
        logits, self._batch_cache = self._step(
            self.params, cur, self._batch_cache, self._batch_pos)
        self._batch_pos = self._batch_pos + 1
        nxt = jnp.argmax(logits, axis=-1)
        for i, r in enumerate(seqs):
            tok = int(nxt[i])
            r.state.pos += 1
            r.state.last_token = tok
            r.tokens.append(tok)
            self.meter.advance(r.sid, 1)
        with self._stats_lock:
            self.stats["decode_tokens"] += len(seqs)
            self.stats["decode_iters"] += 1
            self.stats["busy_s"] += time.time() - t0

    # -- high-level ops used by the schedulers ------------------------------
    def op_prefill(self, task_batch):
        """task_batch: list of dicts with keys:
        sid, text, continue_partial(bool), prefix_instruction(str|None)."""
        items = []
        for t in task_batch:
            sid = t["sid"]
            with self._lock:
                st = self.states.get(sid)
                if st is None:
                    if t.get("prefix_state") is not None:
                        st = self.fork_state(t["prefix_state"])
                    else:
                        st = self.new_state()
                    self.states[sid] = st
            toks = self.tok.encode(t["text"])[: self.max_len - st.pos - 8]
            toks = toks or [HashTokenizer.SEP]
            self.meter.advance(sid, len(toks))
            items.append((st, toks))
        self.prefill_batch(items)
        return [None] * len(task_batch)

    def op_decode(self, task_batch, on_chunk=None):
        """task_batch: list of dicts: sid, max_new. Returns texts.
        on_chunk(i, text_so_far): incremental decode emission."""
        items = []
        for t in task_batch:
            st = self.states[t["sid"]]
            self.meter.advance(t["sid"], int(t["max_new"]))
            items.append((st, int(t["max_new"])))
        cb = None
        if on_chunk is not None:
            cb = lambda i, ids: on_chunk(i, self.tok.decode(ids))  # noqa: E731
        outs = self.decode_batch(items, on_chunk=cb)
        return [self.tok.decode(o) for o in outs]

    def get_prefix_state(self, instruction: str) -> SeqState:
        """Instruction-prefix KV cache (LlamaDistPC cache-reuse)."""
        with self._lock:
            st = self.prefix_cache.get(instruction)
        if st is None:
            st = self.new_state()
            toks = self.tok.encode(instruction)
            self.prefill_batch([(st, toks)])
            with self._lock:
                self.prefix_cache[instruction] = st
        return st

    def release(self, sid: str):
        with self._lock:
            self.states.pop(sid, None)
        self.meter.release(sid)
