"""LLM execution engine.

Runs a real (engine-scale) JAX decoder with:
  - per-sequence KV-cache state store (continuous batching across queries
    with per-sequence positions),
  - decomposed ops: prefill / partial_prefill / full_prefill (chunked
    prefill against the sequence's existing KV prefix — Teola Pass 3) and
    decode / partial_decode (n-token continuation — Teola Pass 4),
  - bucketed jit shapes (batch, chunk length) so engine calls reuse
    compiled programs,
  - an instruction prefix cache (LlamaDistPC baseline's cache-reuse).

``paged=True`` switches the KV store to the BLOCK-PAGED pool
(serving/kv_cache.py): one physical cache per replica carved into
fixed-size token blocks, per-sequence block tables instead of private
dense pytrees, O(1) copy-on-write prefix forks, and a decode loop that
indexes the shared pool through the tables — admission/eviction never
stacks or unstacks KV, and occupancy/backpressure are counted in
allocated blocks (true memory). ``paged=False`` (default) preserves the
dense per-sequence path.

On TPU the attention inside apply_model would route to the Pallas
flash_prefill / decode_attention kernels (paged_decode_attention for the
paged pool); on CPU the XLA take/scatter path is used.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.engines.decode_loop import (ContinuousDecodeLoop, DecodeLoopMixin,
                                       DecodeSeq, PrefillJob)
from repro.engines.tokenizer import HashTokenizer
from repro.models.transformer import apply_model, init_params
from repro.serving import kv_cache as kvc

BUCKETS_B = (1, 2, 4, 8, 16)
BUCKETS_S = (8, 16, 32, 64, 128, 256, 384, 512)


def _bucket(n, buckets):
    for b in buckets:
        if n <= b:
            return b
    return buckets[-1]


@dataclass
class SeqState:
    cache: object               # single-sequence cache pytree (B=1)
    pos: int = 0
    last_token: int = 1         # BOS


@dataclass
class PagedSeqState:
    """Paged-mode sequence handle: a block table (physical block id per
    logical block) into the replica's shared pool, instead of a private
    cache pytree. Forking copies the table and bumps refcounts — O(table),
    no tensor copies."""
    table: List[int] = field(default_factory=list)
    pos: int = 0
    last_token: int = 1         # BOS


class LLMEngine(DecodeLoopMixin):
    kind = "llm"

    ALLOC_TIMEOUT = 30.0        # prefill backpressure wait (s)

    def __init__(self, name: str, cfg: ModelConfig, *, max_len: int = 512,
                 seed: int = 0, max_batch: int = 8, max_tokens: int = 1024,
                 dtype=jnp.float32, stream_chunk: int = 4,
                 paged: bool = False, block_size: int = 16,
                 num_blocks: Optional[int] = None,
                 chunked_prefill: bool = False, prefill_chunk: int = 128,
                 token_budget: Optional[int] = None,
                 prefix_cache: str = "none"):
        self.name = name
        self.cfg = cfg
        self.max_len = max_len
        self.max_batch = max_batch
        self.max_tokens = max_tokens
        self.stream_chunk = stream_chunk   # decode tokens per emitted chunk
        # chunked prefill (Sarathi-style stall-free mixed batches): with
        # the flag on, prompts are admitted into the continuous loop as
        # resumable PrefillJobs and advance prefill_chunk tokens at a
        # time between decode iterations, under the loop's per-pass
        # token_budget (None = max_batch + prefill_chunk). Flag off
        # keeps every prefill the monolithic whole-prompt forward.
        if prefill_chunk < 1 or prefill_chunk > BUCKETS_S[-1]:
            raise ValueError(
                f"prefill_chunk must be in [1, {BUCKETS_S[-1]}], got "
                f"{prefill_chunk}")
        if token_budget is not None and token_budget < 1:
            raise ValueError(f"token_budget must be >= 1, got "
                             f"{token_budget}")
        self.chunked_prefill = chunked_prefill
        self.prefill_chunk = int(prefill_chunk)
        self.token_budget = token_budget
        # global radix-tree prefix cache ("radix"): ANY fresh prompt
        # sharing a cached block-aligned token prefix — across queries
        # and tenants, not just warmed instructions — forks those blocks
        # and prefills only the uncached tail. "none" keeps the pre-
        # existing paths byte-identical (the bespoke instruction-prefix
        # scan under use_prefix_cache included).
        if prefix_cache not in ("none", "radix"):
            raise ValueError(
                f"prefix_cache must be 'none' or 'radix', got "
                f"{prefix_cache!r}")
        if prefix_cache == "radix" and not paged:
            raise ValueError(
                "prefix_cache='radix' requires paged=True (cached "
                "prefixes live in the refcounted block pool)")
        self.prefix_cache_mode = prefix_cache
        self.tok = HashTokenizer(cfg.vocab_size)
        self.params = init_params(cfg, jax.random.key(seed), dtype)
        self.states: Dict[str, SeqState] = {}
        self.prefix_cache: Dict[str, SeqState] = {}
        self._prefix_toks: Dict[str, list] = {}   # instr -> token list
        self.use_prefix_cache = False      # enabled by orchestrator warmup
        self._lock = threading.Lock()
        self._step = self._build_step()
        self._pstep = self._build_prefill_step()
        self.paged = paged
        self.block_size = block_size
        if paged:
            # default pool: the dense worst case (max_batch full-length
            # sequences) plus the reserved pad block
            self.num_blocks = num_blocks if num_blocks is not None else \
                1 + max_batch * kvc.blocks_for(max_len, block_size)
            self.alloc = kvc.BlockAllocator(self.num_blocks)
            self.pool = kvc.init_paged_pool(cfg, self.num_blocks, block_size)
            self._paged_step = self._build_paged_step()
            self._paged_pstep = self._build_paged_prefill_step()
            # block-table width buckets (jit shape reuse), capped at the
            # engine's own maximum
            cap = kvc.blocks_for(max_len, block_size)
            self._blk_buckets = tuple(b for b in
                                      (1, 2, 4, 8, 16, 32, 64, 128, 256)
                                      if b < cap) + (cap,)
            # worst-case blocks still owed to admitted decode sequences
            # (admission reservations — guarantees resident decodes never
            # hit OutOfBlocks)
            self._decode_reserved: Dict[str, int] = {}
            # serializes ALL paged-pool mutation: block planning, COW
            # copies, and the jitted steps (prefill thread vs decode-loop
            # thread share one physical pool)
            self._paged_lock = threading.RLock()
            self.meter = kvc.OccupancyMeter(
                kvc.bytes_per_token(cfg), decode_slots=max_batch,
                allocator=self.alloc, block_size=block_size,
                block_bytes=kvc.paged_block_bytes(cfg, block_size))
            self.radix = kvc.RadixPrefixCache(self.alloc, block_size) \
                if prefix_cache == "radix" else None
        else:
            self.num_blocks = 0
            self.radix = None
            self.meter = kvc.OccupancyMeter(kvc.bytes_per_token(cfg),
                                            decode_slots=max_batch)
        self.stats = {"prefill_tokens": 0, "decode_tokens": 0, "calls": 0,
                      "decode_iters": 0, "busy_s": 0.0,
                      "migrations_in": 0, "migrated_blocks": 0,
                      "migrate_s": 0.0}
        # decode_iteration (loop thread) and prefill/decode batches
        # (scheduler thread) update stats concurrently
        self._stats_lock = threading.Lock()
        self._decode_loop: Optional[ContinuousDecodeLoop] = None
        self._pads: List[SeqState] = []   # reusable batch-padding states
        self.spec = None                  # SpeculativeDecoder (opt-in)
        # fault tolerance: an attached FaultInjector (None = hooks are a
        # single attribute read) and this replica's own health mark
        # (escalated by loop death / injected crash; the pool's health
        # view takes the worse of the two)
        self.faults = None
        self.health = "healthy"
        # SLO scheduling (serving/slo.py): policy attached by attach_slo
        # (None = every scheduling path byte-identical to pre-SLO code).
        # _slo_ptoks records each sid's prefilled token context so a
        # preempted sequence can rebuild its KV by replay; the block
        # charge mirrors try_admit reservations into the fair-share
        # ledger.
        self.slo = None
        self._slo_ptoks: Dict[str, list] = {}
        self._slo_block_charge: Dict[str, tuple] = {}
        self._reset_batch_cache()

    def clone(self, idx: int = 1) -> "LLMEngine":
        """Pool replica: SHARED weights, tokenizer and compiled steps;
        PER-REPLICA sequence/KV store, lock, occupancy meter and stats.
        The instruction-prefix cache is shared in dense mode (states are
        portable pytrees) but PER-REPLICA in paged mode (a prefix state's
        blocks live in one replica's physical pool)."""
        c = LLMEngine.__new__(LLMEngine)
        c.name = f"{self.name}.r{idx}"
        c.cfg = self.cfg
        c.max_len = self.max_len
        c.max_batch = self.max_batch
        c.max_tokens = self.max_tokens
        c.stream_chunk = self.stream_chunk
        c.chunked_prefill = self.chunked_prefill
        c.prefill_chunk = self.prefill_chunk
        c.token_budget = self.token_budget
        c.prefix_cache_mode = self.prefix_cache_mode
        c.tok = self.tok
        c.params = self.params
        c.states = {}
        c.use_prefix_cache = self.use_prefix_cache
        c._lock = threading.Lock()
        c._step = self._step
        c._pstep = self._pstep
        c.paged = self.paged
        c.block_size = self.block_size
        c.num_blocks = self.num_blocks
        if self.paged:
            c.prefix_cache = {}
            c._prefix_toks = {}
            c.alloc = kvc.BlockAllocator(self.num_blocks)
            c.pool = kvc.init_paged_pool(c.cfg, c.num_blocks, c.block_size)
            c._paged_step = self._paged_step
            c._paged_pstep = self._paged_pstep
            c._blk_buckets = self._blk_buckets
            c._decode_reserved = {}
            c._paged_lock = threading.RLock()
            c.meter = kvc.OccupancyMeter(
                self.meter.bytes_per_tok, decode_slots=c.max_batch,
                allocator=c.alloc, block_size=c.block_size,
                block_bytes=self.meter.block_bytes)
            # per-replica tree: cached blocks live in one replica's pool
            c.radix = kvc.RadixPrefixCache(c.alloc, c.block_size) \
                if self.prefix_cache_mode == "radix" else None
        else:
            c.prefix_cache = self.prefix_cache
            c._prefix_toks = self._prefix_toks
            c.radix = None
            c.meter = kvc.OccupancyMeter(self.meter.bytes_per_tok,
                                         decode_slots=c.max_batch)
        c.stats = {"prefill_tokens": 0, "decode_tokens": 0, "calls": 0,
                   "decode_iters": 0, "busy_s": 0.0,
                   "migrations_in": 0, "migrated_blocks": 0,
                   "migrate_s": 0.0}
        c._stats_lock = threading.Lock()
        c._decode_loop = None            # per-replica decode loop
        c._pads = []
        c.spec = None                    # re-attach per replica if wanted
        c.faults = None                  # armed per replica (FaultInjector)
        c.health = "healthy"
        c.slo = None                     # armed per replica (attach_slo)
        c._slo_ptoks = {}
        c._slo_block_charge = {}
        c._reset_batch_cache()
        return c

    def enable_speculative(self, draft: "LLMEngine" = None, k: int = 4,
                           max_ngram: int = 3):
        """Attach a SpeculativeDecoder to this replica: decode paths
        (run-to-completion batches AND the continuous decode loop) switch
        to draft-k/verify-once iterations. ``draft`` is a co-located
        draft engine (``engine_pool.pair_replicas`` picks it pool-wide);
        None drafts via model-free prompt lookup. Greedy outputs stay
        token-identical to the plain paths."""
        from repro.engines.spec_decode import (EngineDrafter,
                                               SpeculativeDecoder)
        if draft is not None and draft.cfg.vocab_size != self.cfg.vocab_size:
            raise ValueError(
                f"draft vocab {draft.cfg.vocab_size} != target vocab "
                f"{self.cfg.vocab_size}: draft token ids would not "
                f"transfer")
        self._vstep = self._build_verify_step()
        if self.paged:
            self._paged_vstep = self._build_paged_verify_step()
        drafter = EngineDrafter(draft) if draft is not None else None
        self.spec = SpeculativeDecoder(self, drafter=drafter, k=k,
                                       max_ngram=max_ngram)
        return self.spec

    def kv_occupancy(self) -> int:
        """Resident KV tokens on this replica (pool-router load input).
        Paged engines report allocated blocks * block_size — the true
        memory footprint, counting shared prefixes once."""
        return self.meter.tokens()

    def kv_free_blocks(self) -> Optional[int]:
        """Unreserved free pool blocks (None in dense mode) — the pool
        router's admission-backpressure input. Deliberately LOCK-FREE
        (allocator has its own lock, the reservation read is GIL-atomic):
        the router polls every replica, and _paged_lock is held across
        whole decode loops — taking it here would serialize routing
        behind a busy replica's decode."""
        if not self.paged:
            return None
        free = self.alloc.free_blocks() - self._reserved_snapshot()
        if self.radix is not None:
            # cached leaves are EVICTABLE capacity: a pool "full" of
            # sole-owner radix blocks is not exhausted — admission
            # evicts on demand — so it must not demote this replica
            free += self._evictable_snapshot()
        return max(0, free)

    def _evictable_snapshot(self) -> int:
        """Radix-cached blocks reclaimable on demand (tree is the sole
        owner). LOCK-FREE on the radix side — the mirror list is rebound,
        never mutated — so wait predicates and the router can call this
        without risking lock-order inversion against tree mutators."""
        if self.radix is None:
            return 0
        refs = self.alloc.refs_snapshot()
        return sum(1 for b in self.radix.block_snapshot() if refs[b] == 1)

    def _reserved_less_evictable(self) -> int:
        """wait_for_free predicate input: reservations minus the radix
        tree's reclaimable blocks — a prefill waiter whose need is
        covered by free + evictable wakes up, and the authoritative
        under-lock recheck in _acquire_with_blocks performs the actual
        eviction."""
        return self._reserved_snapshot() - self._evictable_snapshot()

    def prefix_match_len(self, text: str) -> int:
        """Longest radix-cached token prefix of ``text`` (0 without the
        radix cache) — the pool router's prefix-affinity probe.
        Read-only: no increfs, no LRU touches."""
        if self.radix is None:
            return 0
        toks = self.tok.encode(text)
        if len(toks) < 2:
            return 0
        return self.radix.match_len(toks[:len(toks) - 1])

    # -- jitted batched step: write chunk, return logits of last position
    def _build_step(self):
        cfg = self.cfg

        def step(params, tokens, cache, pos):
            logits, cache, _ = apply_model(cfg, params, tokens, cache, pos,
                                           q_block=256, remat=False,
                                           logits_slice=1)
            return logits[:, -1], cache

        return jax.jit(step)

    def _build_prefill_step(self):
        cfg = self.cfg

        def step(params, tokens, cache, pos, last_idx):
            # exact bucketed prefill: per-sequence logits at chunk index
            # len(t)-1 (not the padded tail)
            logits, cache, _ = apply_model(cfg, params, tokens, cache, pos,
                                           q_block=256, remat=False,
                                           logits_at=last_idx)
            return logits[:, 0], cache

        return jax.jit(step)

    # The pool argument is DONATED in both paged steps: the engine holds
    # the only reference (mutation is serialized by _paged_lock and
    # self.pool is reassigned from the return value), so the update is
    # in-place on backends with donation — no transient second pool.
    def _build_paged_step(self):
        cfg = self.cfg

        def step(params, tokens, pool, tables, pos):
            logits, pool, _ = apply_model(cfg, params, tokens, pool, pos,
                                          q_block=256, remat=False,
                                          logits_slice=1,
                                          block_tables=tables)
            return logits[:, -1], pool

        return jax.jit(step, donate_argnums=(2,))

    def _build_paged_prefill_step(self):
        cfg = self.cfg

        def step(params, tokens, pool, tables, pos, last_idx):
            logits, pool, _ = apply_model(cfg, params, tokens, pool, pos,
                                          q_block=256, remat=False,
                                          logits_at=last_idx,
                                          block_tables=tables)
            return logits[:, 0], pool

        return jax.jit(step, donate_argnums=(2,))

    # -- speculative verification steps: write a (k+1)-token chunk and
    # return logits at EVERY chunk position (the causal position mask
    # keeps draft token i blind to drafts > i, so one forward scores the
    # whole chunk — q_len generalizes the decode step's q_len=1).
    def _build_verify_step(self):
        cfg = self.cfg

        def step(params, tokens, cache, pos):
            logits, cache, _ = apply_model(cfg, params, tokens, cache, pos,
                                           q_block=256, remat=False)
            return logits, cache

        # the stacked cache is freshly concatenated per call — donate it
        # so verification never holds two copies of the batch KV
        return jax.jit(step, donate_argnums=(2,))

    def _build_paged_verify_step(self):
        cfg = self.cfg

        def step(params, tokens, pool, tables, pos):
            logits, pool, _ = apply_model(cfg, params, tokens, pool, pos,
                                          q_block=256, remat=False,
                                          block_tables=tables)
            return logits, pool

        return jax.jit(step, donate_argnums=(2,))

    def spec_verify(self, chunk_items, loop_sids=None):
        """ONE multi-position target forward over drafted chunks.

        chunk_items: list of (state, chunk) with chunk =
        [last_token, d1..dk] (uniform length k+1). Writes the chunk's KV
        at state positions [pos, pos+k] and returns the greedy
        next-token prediction at every chunk position as an int array of
        shape (len(items), k+1) — prediction j answers "what follows
        position pos+j". State positions are NOT advanced here; the
        caller commits the accepted prefix and rolls the rest back.

        ``loop_sids`` marks the continuous-decode-loop path: resident
        sequences hold admission reservations covering their full budget
        horizon, so the write draws down reservations directly instead
        of waiting for UNRESERVED free blocks (which would double-count
        their own reservation)."""
        S = len(chunk_items[0][1])
        B = _bucket(len(chunk_items), BUCKETS_B)
        states = [s for s, _ in chunk_items]
        toks = np.ones((B, S), np.int32)
        for i, (_, ch) in enumerate(chunk_items):
            toks[i] = ch
        if self.paged:
            if loop_sids is None:
                self._acquire_with_blocks([(s, S) for s in states])
            else:
                self._paged_lock.acquire()
            try:
                sids = loop_sids or [None] * len(states)
                for s, sid in zip(states, sids):
                    got = self._prepare_write(s, S)
                    if got and sid is not None:
                        resv = self._decode_reserved.get(sid)
                        if resv is not None:
                            self._decode_reserved[sid] = max(0, resv - got)
                tables, pos = self._table_batch(states, B, S)
                logits, self.pool = self._paged_vstep(
                    self.params, jnp.asarray(toks), self.pool, tables, pos)
            finally:
                self._paged_lock.release()
        else:
            # pad with the engine's reusable scratch states (their rows
            # are discarded), not fresh max_len caches per call
            pad_states = states + self._pad_states(B - len(states))
            cache, pos = self._stack_states(pad_states)
            logits, cache = self._vstep(self.params, jnp.asarray(toks),
                                        cache, pos)
            self._unstack(cache, pad_states)
        return np.asarray(jnp.argmax(logits, axis=-1))[:len(states)]

    def spec_rollback(self, st, sid=None):
        """Roll back rejected draft tokens: ``st.pos`` already stands at
        the accepted prefix (stale KV beyond it is masked by position and
        overwritten by the next chunk); on the paged path additionally
        trim overshoot table blocks back to the pool. For a loop-resident
        sequence (``sid``) the freed blocks are re-credited to its
        admission reservation, preserving the no-OOM guarantee."""
        if not self.paged:
            return
        with self._paged_lock:
            freed = kvc.trim_table(self.alloc, st.table, st.pos,
                                   self.block_size)
            if freed and sid is not None:
                resv = self._decode_reserved.get(sid)
                if resv is not None:
                    self._decode_reserved[sid] = resv + freed

    def new_state(self):
        if self.paged:
            return PagedSeqState()
        return SeqState(cache=kvc.init_cache(self.cfg, 1, self.max_len))

    def fork_state(self, st):
        """Copy-on-write fork: paged mode shares every block (refcount
        bump per table entry, no tensor copies); dense mode shares the
        immutable cache arrays until the next functional write."""
        if self.paged:
            with self._paged_lock:
                for b in st.table:
                    self.alloc.incref(b)
                return PagedSeqState(table=list(st.table), pos=st.pos,
                                     last_token=st.last_token)
        return SeqState(cache=jax.tree.map(lambda a: a, st.cache),
                        pos=st.pos, last_token=st.last_token)

    # -- paged block planning ----------------------------------------------
    # (all helpers below require self._paged_lock held)
    def _blocks_needed(self, st: PagedSeqState, n_new: int) -> int:
        """Worst-case NEW blocks a write of n_new tokens at st.pos needs:
        table growth plus copy-on-write of shared blocks in the write
        range."""
        bs = self.block_size
        first, last = st.pos // bs, (st.pos + n_new - 1) // bs
        grow = max(0, last + 1 - len(st.table))
        cow = sum(1 for bi in range(first, min(last + 1, len(st.table)))
                  if self.alloc.refcount(st.table[bi]) > 1)
        return grow + cow

    def _prepare_write(self, st: PagedSeqState, n_new: int) -> int:
        """Make st.table cover positions [0, pos+n_new) with exclusively
        owned blocks over the write range: grow the table from the free
        list and copy-on-write any shared block about to be written —
        all COW pairs in ONE batched (donated) copy, and decref only
        AFTER the copy, so concurrent owners keep seeing refcount>1 and
        COW their own writes. Returns blocks consumed (reservation
        drawdown)."""
        bs = self.block_size
        first, last = st.pos // bs, (st.pos + n_new - 1) // bs
        consumed = 0
        srcs, dsts = [], []
        for bi in range(first, last + 1):
            if bi < len(st.table):
                b = st.table[bi]
                if self.alloc.refcount(b) > 1:
                    dst = self.alloc.alloc()
                    consumed += 1
                    srcs.append(b)
                    dsts.append(dst)
                    st.table[bi] = dst
            else:
                st.table.append(self.alloc.alloc())
                consumed += 1
        if srcs:
            self.pool = kvc.copy_pool_blocks(self.pool, srcs, dsts)
            for b in srcs:
                self.alloc.decref(b)
        return consumed

    def _reserved_locked(self) -> int:
        return sum(self._decode_reserved.values())

    def _reserved_snapshot(self) -> int:
        """Lock-free reservation total for wait predicates: dict(d) is a
        C-level (GIL-atomic) copy, so concurrent try_admit/release
        mutations cannot raise mid-iteration. Must NOT take _paged_lock —
        the caller holds the allocator condition, and lock-holders call
        back into the allocator (lock-order inversion)."""
        return sum(dict(self._decode_reserved).values())

    def _table_batch(self, states: List[PagedSeqState], B: int, n_new,
                     pad_new: Optional[int] = None):
        """Block-table + position arrays for a padded batch: width is the
        bucketed max of ceil((pos+n_new)/bs) — n_new a scalar or a
        per-state list; padding rows cover pad_new (default n_new) write
        positions with the reserved pad block (their writes land on
        scratch)."""
        bs = self.block_size
        ns = n_new if isinstance(n_new, list) else [n_new] * len(states)
        need = [kvc.blocks_for(s.pos + n, bs) for s, n in zip(states, ns)]
        need.append(kvc.blocks_for(
            pad_new if pad_new is not None else max(ns, default=1), bs))
        if max(need) > self._blk_buckets[-1]:
            # loud failure instead of silent table truncation + clamped
            # scatter corrupting the last block
            raise ValueError(
                f"{self.name}: write needs {max(need)} blocks but tables "
                f"cap at {self._blk_buckets[-1]} (max_len {self.max_len})")
        maxblk = _bucket(max(need), self._blk_buckets)
        tables = np.full((B, maxblk), kvc.PAD_BLOCK, np.int32)
        for i, s in enumerate(states):
            n = min(len(s.table), maxblk)
            tables[i, :n] = s.table[:n]
        pos = np.zeros((B,), np.int32)
        pos[:len(states)] = [s.pos for s in states]
        return jnp.asarray(tables), jnp.asarray(pos)

    def _acquire_with_blocks(self, pairs):
        """Admission backpressure: acquire self._paged_lock WITH enough
        unreserved free blocks to cover the planned writes — `pairs` is
        [(state, n_new_tokens), ...] — (the check and the subsequent
        allocation happen under one lock hold, so admitted decodes'
        reservations cannot race in between). Waits unlocked so the
        decode loop keeps draining; caller must release the lock."""
        self._fault("alloc")
        deadline = time.time() + self.ALLOC_TIMEOUT
        timed_out = False
        while True:
            self._paged_lock.acquire()
            needed = sum(self._blocks_needed(s, n) for s, n in pairs)
            avail = self.alloc.free_blocks() - self._reserved_locked()
            if needed > avail and self.radix is not None:
                # cached leaves are evictable capacity: reclaim LRU
                # leaves before treating the pool as full
                avail += self.radix.evict(needed - avail)
            if needed <= avail:
                return
            self._paged_lock.release()
            # one authoritative under-lock recheck happens above even
            # after a wait timeout (a missed wakeup must not fail a
            # request the pool could serve)
            if timed_out:
                raise kvc.OutOfBlocks(
                    f"{self.name}: paged KV pool exhausted "
                    f"({self.alloc.capacity} blocks, "
                    f"{self.alloc.free_blocks()} free, need {needed}); "
                    f"{self._pool_diag()}")
            timed_out = not self.alloc.wait_for_free(
                needed, timeout=deadline - time.time(),
                reserved_fn=self._reserved_less_evictable)

    def _pool_diag(self) -> str:
        """Allocator diagnostics attached to exhaustion errors: what is
        holding the pool — outstanding decode reservations, evictable
        radix capacity, waiter count, resident sequences — so an
        OutOfBlocks/ALLOC_TIMEOUT failure is actionable, not bare."""
        with self._paged_lock:
            reserved = sum(self._decode_reserved.values())
        evictable = self.radix.evictable_blocks() \
            if self.radix is not None else 0
        return (f"diag: reserved={reserved} evictable_radix={evictable} "
                f"waiters={self.alloc.waiters()} "
                f"resident_seqs={len(self.states)}")

    def _fault(self, point: str):
        """Fault-injection hook: a single attribute read when unarmed."""
        inj = self.faults
        if inj is not None:
            inj.fire(self, point)

    # -- batched execution -------------------------------------------------
    def _stack_states(self, states: List[SeqState]):
        cache = jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=1),
                             *[s.cache for s in states])
        pos = jnp.array([s.pos for s in states], jnp.int32)
        return cache, pos

    def _unstack(self, cache, states: List[SeqState]):
        for i, s in enumerate(states):
            s.cache = jax.tree.map(lambda a, i=i: a[:, i:i + 1], cache)

    def _prefill_toks(self, items, B, S):
        """Padded (B,S) token grid + exact per-row last-chunk index."""
        toks = np.zeros((B, S), np.int32)
        last_idx = np.zeros((B,), np.int32)
        for i, (_, t) in enumerate(items):
            toks[i, :len(t)] = t[:S]
            last_idx[i] = min(len(t), S) - 1
        return toks, last_idx

    def prefill_batch(self, items):
        """items: list of (state, token_list). Pads to a (B,S) bucket and
        runs one chunked-prefill step per sequence position offset. The
        returned per-sequence logits are EXACT: gathered at chunk index
        len(t)-1, so bucketed (right-padded) prefill matches unpadded
        prefill token-for-token."""
        self._fault("prefill")
        t0 = time.time()
        B = _bucket(len(items), BUCKETS_B)
        S = _bucket(max(len(t) for _, t in items), BUCKETS_S)
        toks, last_idx = self._prefill_toks(items, B, S)
        if self.paged:
            logits = self._paged_prefill(items, B, S, toks, last_idx)
        else:
            logits = self._dense_prefill_exec([s for s, _ in items], B,
                                              toks, last_idx)
        for i, (s, t) in enumerate(items):
            s.pos += len(t)
            s.last_token = int(jnp.argmax(logits[i]))
        with self._stats_lock:
            self.stats["prefill_tokens"] += sum(len(t) for _, t in items)
            self.stats["calls"] += 1
            self.stats["busy_s"] += time.time() - t0

    def prefill_chunked(self, items, chunk: Optional[int] = None):
        """Resumable chunked prefill: advance every item's prompt by at
        most ``chunk`` tokens per step until all cursors reach the end.
        Token-identical to one monolithic ``prefill_batch`` by
        construction — each chunk is written at the state's cursor
        against the already-resident prefix (the position-mask attention
        path is the same), and chunk lengths land on the same bucketed
        jit shapes as any other prefill, so compile count stays bounded.
        This is the synchronous form; the continuous loop's PrefillJob
        path interleaves the same chunks with decode iterations."""
        chunk = int(chunk or self.prefill_chunk)
        if chunk < 1:
            raise ValueError(f"chunk must be >= 1, got {chunk}")
        cursors = [0] * len(items)
        while True:
            sub = []
            for i, (s, t) in enumerate(items):
                if cursors[i] < len(t):
                    sub.append((s, t[cursors[i]:cursors[i] + chunk]))
                    cursors[i] += len(sub[-1][1])
            if not sub:
                return
            self.prefill_batch(sub)

    def _dense_prefill_exec(self, states, B, toks, last_idx):
        pad_states = states + [self.new_state()
                               for _ in range(B - len(states))]
        cache, pos = self._stack_states(pad_states)
        logits, cache = self._pstep(self.params, jnp.asarray(toks),
                                    cache, pos, jnp.asarray(last_idx))
        self._unstack(cache, pad_states)
        return logits

    def _paged_prefill(self, items, B, S, toks, last_idx):
        """Paged prefill: allocate/COW only the blocks the REAL tokens
        write — padding-tail positions beyond each row's last block fall
        through to the reserved pad block (the batch table defaults to
        it), and the causal mask keeps every real query blind to keys
        past its own position, so bucket padding costs zero capacity.
        One step then indexes the shared pool through the batch table."""
        states = [s for s, _ in items]
        lens = [min(len(t), S) for _, t in items]
        self._acquire_with_blocks(list(zip(states, lens)))
        try:
            for s, n in zip(states, lens):
                self._prepare_write(s, n)
            logits = self._paged_prefill_exec(states, B, S, toks, last_idx)
        finally:
            self._paged_lock.release()
        return logits

    def _paged_prefill_exec(self, states, B, S, toks, last_idx):
        """Run the jitted paged prefill step (caller holds _paged_lock
        with the write range already prepared/COW-resolved)."""
        tables, pos = self._table_batch(states, B, S)
        logits, self.pool = self._paged_pstep(
            self.params, jnp.asarray(toks), self.pool, tables, pos,
            jnp.asarray(last_idx))
        return logits

    def decode_batch(self, items, on_chunk=None):
        """items: list of (state, n_tokens). Greedy continuous decode.
        With speculative decoding enabled the batch runs draft-k/verify
        iterations (token-identical outputs, fewer target forwards);
        otherwise all sequences step together for max(n) steps (finished
        ones keep writing into their own slots but results are
        truncated). on_chunk(i, token_ids_so_far): called every
        `stream_chunk` steps per live item — the streaming-decode
        emission point."""
        if self.spec is not None:
            return self.spec.decode_batch(items, on_chunk=on_chunk)
        return self._decode_batch_base(items, on_chunk)

    def _decode_batch_base(self, items, on_chunk=None):
        t0 = time.time()
        n_max = max(n for _, n in items)
        B = _bucket(len(items), BUCKETS_B)
        states = [s for s, _ in items]
        if self.paged:
            outs = self._paged_decode_batch(items, B, n_max, on_chunk)
        else:
            pad_states = states + [self.new_state()
                                   for _ in range(B - len(states))]
            cache, pos = self._stack_states(pad_states)
            cur = jnp.array([[s.last_token] for s in pad_states], jnp.int32)
            outs = [[] for _ in pad_states]
            emitted = [0] * len(items)
            for t in range(n_max):
                logits, cache = self._step(self.params, cur, cache, pos)
                nxt = jnp.argmax(logits, axis=-1)
                for i in range(len(pad_states)):
                    outs[i].append(int(nxt[i]))
                cur = nxt[:, None].astype(jnp.int32)
                pos = pos + 1
                if on_chunk and ((t + 1) % self.stream_chunk == 0
                                 or t + 1 == n_max):
                    for i, (_, n) in enumerate(items):
                        m = min(t + 1, n)
                        if m > emitted[i]:
                            emitted[i] = m
                            on_chunk(i, outs[i][:m])
            self._unstack(cache, pad_states)
        results = []
        for i, (s, n) in enumerate(items):
            s.pos += n          # overshoot steps (n_max - n) are discarded
            s.last_token = outs[i][n - 1]
            results.append(outs[i][:n])
        with self._stats_lock:
            self.stats["decode_tokens"] += sum(n for _, n in items)
            self.stats["calls"] += 1
            self.stats["busy_s"] += time.time() - t0
        return results

    def _paged_decode_batch(self, items, B, n_max, on_chunk):
        """Run-to-completion decode over the paged pool: pre-allocate
        each sequence's OWN n-step write range (COW resolved up front),
        then step with a FIXED batch block table. A shorter member's
        position FREEZES at its own horizon once it finishes — surplus
        steps rewrite its next-to-write slot, beyond its valid region —
        so no overshoot blocks are ever allocated."""
        states = [s for s, _ in items]
        self._acquire_with_blocks(list(items))
        try:
            for s, n in items:
                self._prepare_write(s, n)
            tables, pos = self._table_batch(
                states, B, [n for _, n in items], pad_new=1)
            limit = np.ones((B,), np.int32)
            limit[:len(states)] = [s.pos + n for s, n in items]
            limit = jnp.asarray(limit)
            cur = np.ones((B, 1), np.int32)
            cur[:len(states), 0] = [s.last_token for s in states]
            cur = jnp.asarray(cur)
            outs = [[] for _ in range(B)]
            emitted = [0] * len(items)
            for t in range(n_max):
                logits, self.pool = self._paged_step(
                    self.params, cur, self.pool, tables, pos)
                nxt = jnp.argmax(logits, axis=-1)
                for i in range(B):
                    outs[i].append(int(nxt[i]))
                cur = nxt[:, None].astype(jnp.int32)
                pos = jnp.minimum(pos + 1, limit)
                if on_chunk and ((t + 1) % self.stream_chunk == 0
                                 or t + 1 == n_max):
                    for i, (_, n) in enumerate(items):
                        m = min(t + 1, n)
                        if m > emitted[i]:
                            emitted[i] = m
                            on_chunk(i, outs[i][:m])
        finally:
            self._paged_lock.release()
        return outs

    # -- iteration-level continuous batching --------------------------------
    # (loop lifecycle — start/stop/slots — comes from DecodeLoopMixin)
    def submit_decode(self, sid: str, max_new: int, on_text=None,
                      on_done=None, slo=None) -> DecodeSeq:
        """Admit sequence `sid` into the continuous decode loop for
        `max_new` tokens. on_text(text_so_far) fires every iteration;
        on_done(seq) fires at eviction. ``slo`` is the request's SLO tag
        (ignored unless a policy is armed). Returns the DecodeSeq."""
        st = self.states[sid]
        max_new = self._clamp_new(st, max_new)
        if self.paged and \
                kvc.blocks_for(st.pos + max_new, self.block_size) > \
                self.alloc.capacity:
            raise ValueError(
                f"decode {sid}: pos {st.pos} + {max_new} new tokens can "
                f"never fit the {self.alloc.capacity}-block pool")
        seq = DecodeSeq(sid, st, max_new,
                        text_fn=lambda s: self.tok.decode(s.tokens),
                        on_text=on_text, on_done=on_done, slo=slo)
        return self.start_decode_loop().submit(seq)

    def recover_decode(self, sid: str, text: str, max_new: int,
                       failed=None, on_text=None, on_done=None,
                       slo=None) -> DecodeSeq:
        """Token-identical replay of a sequence lost on a DEAD replica
        (fault-tolerance path): re-prefill the prompt from the e-graph's
        payload, teacher-force the tokens the dead replica already
        emitted back into the KV cache, and resume greedy decode for the
        remainder. Greedy argmax is deterministic given identical weights
        and identical resident tokens, so the concatenation
        ``emitted + continued`` matches a no-fault run token for token.

        ``failed`` is the dead replica's DecodeSeq handle (its
        ``.tokens`` are the emitted prefix; host objects survive replica
        death) or None when nothing was emitted yet — e.g. the sequence's
        affinity pointed at a replica that died before its first decode."""
        emitted = [int(x) for x in getattr(failed, "tokens", [])] \
            if failed is not None else []
        self.release(sid)          # drop any stale local copy of the sid
        st, toks, ptoks = self._prepare_prefill_task(
            {"sid": sid, "text": text})
        if toks:
            self.meter.advance(sid, len(toks))
            self.prefill_batch([(st, toks)])
        if self.spec is not None:
            self.spec.note_prefill(sid, ptoks, toks)
        n = self._clamp_new(st, max_new)   # same clamp as a clean submit
        emitted = emitted[:n]
        if emitted:
            # teacher-force the emitted prefix: feeding
            # [p_prompt, e_1 .. e_{m-1}] recreates the exact pos /
            # last_token the dead replica held after emitting e_m
            feed = [st.last_token] + emitted[:-1]
            self.meter.advance(sid, len(feed))
            self.prefill_batch([(st, feed)])
        seq = DecodeSeq(sid, st, n,
                        text_fn=lambda s: self.tok.decode(s.tokens),
                        on_text=on_text, on_done=on_done, slo=slo)
        seq.tokens = list(emitted)
        seq.steps = len(emitted)
        if seq.steps >= seq.n:
            # the dead replica had already finished decoding — only the
            # completion callback was lost. Finish without the loop.
            seq.result = self.tok.decode(seq.tokens)
            seq.t_done = time.time()
            seq.done.set()
            if on_done is not None:
                on_done(seq)
            return seq
        if self.paged and \
                kvc.blocks_for(st.pos + (n - seq.steps), self.block_size) \
                > self.alloc.capacity:
            raise ValueError(
                f"decode {sid}: recovery at pos {st.pos} + "
                f"{n - seq.steps} new tokens can never fit the "
                f"{self.alloc.capacity}-block pool")
        return self.start_decode_loop().submit(seq)

    def submit_prefill(self, task: dict, on_done=None) -> PrefillJob:
        """Chunked-prefill admission into the continuous loop: the
        prompt is tokenized (and instruction-prefix forked) NOW on the
        caller's thread, then queued as a resumable PrefillJob whose
        chunks the loop packs into mixed prefill/decode passes under the
        token budget — co-resident decodes never wait behind the whole
        prompt. ``task`` uses the op_prefill dict shape (sid, text,
        optional prefix_state); on_done(job) fires on the loop thread
        once the full prompt is resident (job.error set on failure)."""
        if not self.chunked_prefill:
            raise RuntimeError(
                f"{self.name}: chunked_prefill is disabled")
        sid = task["sid"]
        st, toks, ptoks = self._prepare_prefill_task(task)

        def _done(job):
            if job.error is None and toks:
                self._radix_insert(st, ptoks, toks)
            if job.error is None and self.spec is not None:
                self.spec.note_prefill(sid, ptoks, toks)
            if on_done is not None:
                on_done(job)

        job = PrefillJob(sid, st, toks, on_done=_done, ptoks=ptoks,
                         slo=task.get("slo"))
        if not toks:
            # prompt fully covered by the forked instruction prefix —
            # nothing to write; complete without touching the loop
            job.t_done = time.time()
            job.done.set()
            _done(job)
            return job
        if self.paged and \
                kvc.blocks_for(st.pos + len(toks), self.block_size) > \
                self.alloc.capacity:
            raise ValueError(
                f"prefill {sid}: pos {st.pos} + {len(toks)} tokens can "
                f"never fit the {self.alloc.capacity}-block pool")
        return self.start_decode_loop().submit_prefill(job)

    def decode_token_cost(self, seqs) -> int:
        """Query tokens one decode pass over ``seqs`` carries (the
        loop's token-budget input): 1 per sequence, or k+1 for sequences
        the speculative decoder will verify as a chunk this pass."""
        if self.spec is None:
            return len(seqs)
        k = self.spec.k
        return sum(k + 1 if (r.n - len(r.tokens) >= k + 1 and
                             r.state.pos + k + 1 <= self.max_len) else 1
                   for r in seqs)

    def mixed_iteration(self, seqs: List[DecodeSeq], pitems):
        """One stall-free mixed pass (loop thread): the resident decode
        batch advances FIRST, then this pass's budget-bounded prefill
        chunks land back-to-back — a decode's time-between-tokens is
        bounded by one chunk's compute, never by a whole prompt's."""
        if seqs:
            self.decode_iteration(seqs)
        if pitems:
            self._prefill_chunk_step(pitems)

    def _prefill_chunk_step(self, pitems):
        """Land one bucketed prefill chunk per planned (job, n) pair and
        advance the jobs' cursors. Paged admission is NON-BLOCKING:
        chunks take only UNRESERVED free blocks (admitted decodes'
        reservations stay untouchable) and when the pool — or its lock,
        held by a scheduler-side batch — is busy, the chunk is DECLINED:
        the job stays queued and the loop retries next pass. The decode
        loop must never sleep on prefill backpressure."""
        self._fault("prefill")
        t0 = time.time()
        items = []                       # (job, chunk_token_list)
        if self.paged:
            if not self._paged_lock.acquire(blocking=False):
                return
            try:
                free = self.alloc.free_blocks() - self._reserved_locked()
                for job, n in pitems:
                    chunk = job.tokens[job.cursor:job.cursor + n]
                    need = self._blocks_needed(job.state, len(chunk))
                    if need > free and self.radix is not None:
                        # reclaim cached leaves (non-blocking, decrefs
                        # only) before declining the chunk
                        free += self.radix.evict(need - free)
                    if need <= free:
                        free -= need
                        items.append((job, chunk))
                if not items:
                    return
                for job, chunk in items:
                    self._prepare_write(job.state, len(chunk))
                B = _bucket(len(items), BUCKETS_B)
                S = _bucket(max(len(c) for _, c in items), BUCKETS_S)
                toks, last_idx = self._prefill_toks(
                    [(j.state, c) for j, c in items], B, S)
                logits = self._paged_prefill_exec(
                    [j.state for j, _ in items], B, S, toks, last_idx)
            finally:
                self._paged_lock.release()
        else:
            items = [(job, job.tokens[job.cursor:job.cursor + n])
                     for job, n in pitems]
            B = _bucket(len(items), BUCKETS_B)
            S = _bucket(max(len(c) for _, c in items), BUCKETS_S)
            toks, last_idx = self._prefill_toks(
                [(j.state, c) for j, c in items], B, S)
            logits = self._dense_prefill_exec(
                [j.state for j, _ in items], B, toks, last_idx)
        for i, (job, chunk) in enumerate(items):
            job.state.pos += len(chunk)
            job.state.last_token = int(jnp.argmax(logits[i]))
            job.cursor += len(chunk)
            self.meter.advance(job.sid, len(chunk))
        with self._stats_lock:
            self.stats["prefill_tokens"] += sum(len(c) for _, c in items)
            self.stats["calls"] += 1
            self.stats["busy_s"] += time.time() - t0

    def try_admit(self, seq: DecodeSeq) -> bool:
        """Block-level admission control (decode-loop hook): admit only
        when the pool's unreserved free blocks cover this sequence's
        worst-case growth, and RESERVE them — admitted sequences can then
        never hit OutOfBlocks mid-decode. Dense mode always admits.

        NON-BLOCKING on the pool lock: the loop calls this while holding
        its condition variable (which slots_free/submit and the pool
        router also take), so waiting here for a long-held _paged_lock
        (a prefill step, a run-to-completion decode) would stall routing
        for every replica. If the pool is busy, defer — the loop retries
        next iteration."""
        if not self.paged:
            return True
        if not self._paged_lock.acquire(blocking=False):
            return False
        try:
            if getattr(seq, "slo_preempted", False):
                # preempted sequence re-entering: its table is empty and
                # the whole replay horizon (recorded prompt context +
                # teacher-forced emitted tokens + remaining steps) must
                # be re-written — reserve for all of it
                horizon = len(self._slo_ptoks.get(seq.sid, ())) + seq.n
                needed = kvc.blocks_for(horizon, self.block_size)
            else:
                needed = self._blocks_needed(seq.state, seq.n)
            pol = self.slo
            if pol is not None and pol.blocks is not None:
                tenant = pol.tag_of(seq).tenant
                if not pol.may_take_blocks(tenant, needed):
                    return False    # over block fair share — defer
            avail = self.alloc.free_blocks() - self._reserved_locked()
            if needed > avail and self.radix is not None:
                # cached leaves never count AGAINST admission: they are
                # evictable capacity, reclaimed eagerly here so the
                # reservation is backed by actually-free blocks
                avail += self.radix.evict(needed - avail)
            if needed <= avail:
                self._decode_reserved[seq.sid] = needed
                if pol is not None and pol.blocks is not None:
                    pol.blocks.acquire(tenant, needed)
                    self._slo_block_charge[seq.sid] = (tenant, needed)
                return True
            return False
        finally:
            self._paged_lock.release()

    def note_slot_acquired(self, seq: DecodeSeq):
        self.meter.acquire_slot(seq.sid)

    def _slo_drop_block_charge(self, sid: str):
        """Return a sequence's KV-block charge to the fair-share ledger
        (eviction, preemption, or release — whichever comes first)."""
        charge = self._slo_block_charge.pop(sid, None)
        if charge is not None and self.slo is not None and \
                self.slo.blocks is not None:
            self.slo.blocks.release(*charge)

    def note_slot_released(self, seq: DecodeSeq):
        if self.paged:
            with self._paged_lock:
                dropped = self._decode_reserved.pop(seq.sid, None)
            self._slo_drop_block_charge(seq.sid)
            if dropped:
                # headroom improved without a decref — wake prefill waiters
                self.alloc.notify_waiters()
        else:
            # an evicted sequence's KV must be current in its own state
            # before the slot is reused (its sid may decode again later)
            self._flush_batch_cache()
        self.meter.release_slot(seq.sid)

    # -- SLO preemption (serving/slo.py): evict-to-recompute ---------------
    def can_preempt(self, seq: DecodeSeq) -> bool:
        """A sequence is preemptable only when its full KV context is
        reconstructible from the recorded prompt tokens plus its emitted
        tokens (single-decode lifecycles; a multi-turn state whose
        earlier partial-decode tokens were never recorded, or a
        migrated-in sequence with no record here, is excluded — losing
        KV we cannot rebuild would break token identity)."""
        rec = self._slo_ptoks.get(seq.sid)
        if rec is None:
            return False
        return seq.state.pos == len(rec) + len(seq.tokens)

    def preempt_decode(self, seq: DecodeSeq):
        """Evict-to-recompute (loop thread): free ALL of the sequence's
        KV — paged: trim its block table to position 0 (shared/radix
        blocks just decref); dense: drop the per-sequence cache — and
        release its decode slot, reservation and fair-share charge. The
        loop re-queues the same DecodeSeq (tokens/steps intact); on
        re-admission ``_slo_resume`` rebuilds the KV by replay."""
        sid, st = seq.sid, seq.state
        if self.paged:
            with self._paged_lock:
                kvc.trim_table(self.alloc, st.table, 0, self.block_size)
                dropped = self._decode_reserved.pop(sid, None)
            self._slo_drop_block_charge(sid)
            if dropped:
                self.alloc.notify_waiters()
        else:
            # write the shared batch cache back first (residency is
            # changing), then drop this sequence's KV arrays
            self._flush_batch_cache()
            st.cache = kvc.init_cache(self.cfg, 1, self.max_len)
        st.pos = 0
        st.last_token = 1                # replay re-derives it
        seq.slo_preempted = True
        self.meter.release(sid)          # tokens gone from memory
        self.meter.release_slot(sid)

    def _slo_resume(self, seq: DecodeSeq):
        """Rebuild a preempted sequence's KV before it rejoins a decode
        pass: re-prefill the recorded prompt context, then teacher-force
        the already-emitted tokens — the same construction as
        ``recover_decode``, so causal attention recreates the exact
        pre-preemption state and the continuation is token-identical.
        Paged writes draw down the sequence's re-admission reservation
        (sized for the whole replay horizon in try_admit)."""
        sid, st = seq.sid, seq.state
        seq.slo_preempted = False
        toks = list(self._slo_ptoks.get(sid, []))
        if toks:
            self._slo_replay_write(sid, st, toks)
        emitted = [int(x) for x in seq.tokens]
        if emitted:
            self._slo_replay_write(sid, st,
                                   [st.last_token] + emitted[:-1])

    def _slo_replay_write(self, sid: str, st, toks: list):
        """Prefill ``toks`` for a resuming sequence, bucketed-chunk by
        chunk. Paged mode bypasses free-block admission: the blocks come
        out of the sequence's own decode reservation."""
        t0 = time.time()
        i = 0
        while i < len(toks):
            chunk = toks[i:i + BUCKETS_S[-1]]
            i += len(chunk)
            B = _bucket(1, BUCKETS_B)
            S = _bucket(len(chunk), BUCKETS_S)
            grid, last_idx = self._prefill_toks([(st, chunk)], B, S)
            if self.paged:
                with self._paged_lock:
                    got = self._prepare_write(st, len(chunk))
                    if got:
                        resv = self._decode_reserved.get(sid)
                        if resv is not None:
                            self._decode_reserved[sid] = max(0,
                                                             resv - got)
                    logits = self._paged_prefill_exec(
                        [st], B, S, grid, last_idx)
            else:
                logits = self._dense_prefill_exec([st], B, grid, last_idx)
            st.pos += len(chunk)
            st.last_token = int(jnp.argmax(logits[0]))
            self.meter.advance(sid, len(chunk))
        with self._stats_lock:
            self.stats["prefill_tokens"] += len(toks)
            self.stats["calls"] += 1
            self.stats["busy_s"] += time.time() - t0

    def tenant_stats(self) -> dict:
        """Per-(tenant, class) scheduling stats (empty when SLO
        scheduling is not armed on this replica)."""
        return self.slo.tenant_stats() if self.slo is not None else {}

    def _pad_states(self, k: int) -> List[SeqState]:
        while len(self._pads) < k:
            self._pads.append(self.new_state())
        return self._pads[:k]

    def _reset_batch_cache(self):
        self._batch_key = None         # tuple of resident DecodeSeq ids
        self._batch_cache = None       # persistent stacked cache pytree
        self._batch_pos = None
        self._batch_states: List[SeqState] = []

    def _flush_batch_cache(self):
        """Write the persistent stacked decode cache back into the
        per-sequence states (on residency change / eviction). Loop-thread
        only, like decode_iteration."""
        if self._batch_cache is not None:
            self._unstack(self._batch_cache, self._batch_states)
        self._reset_batch_cache()

    def decode_iteration(self, seqs: List[DecodeSeq]):
        """One loop pass for every resident sequence. With speculative
        decoding enabled, sequences with enough remaining budget advance
        by a whole verified draft chunk per pass (the loop counts their
        emitted tokens); the rest — and everything, with it disabled —
        take the legacy single-token step."""
        self._fault("decode")
        if self.slo is not None:
            for r in seqs:
                if getattr(r, "slo_preempted", False):
                    self._slo_resume(r)
        if self.spec is not None:
            return self.spec.decode_iteration(seqs)
        return self._decode_iteration_base(seqs)

    def _decode_iteration_base(self, seqs: List[DecodeSeq]):
        """One decode step for every resident sequence (called by the
        loop each iteration). The stacked batch cache persists across
        iterations and is rebuilt only when RESIDENCY changes (admission
        or eviction) — steady-state iterations pay no per-token
        stack/unstack of the KV pytree. KV occupancy advances per
        iteration — one token per resident sequence — not per batch up
        front.

        In PAGED mode residency changes are free: there is no stacked
        batch cache at all — every iteration scatters one token per
        sequence into the shared pool through a block table rebuilt from
        host-side lists (B*maxblk int32s, trivial next to the KV pytree
        restack the dense path pays on every admission/eviction)."""
        t0 = time.time()
        B = _bucket(len(seqs), BUCKETS_B)
        if self.paged:
            with self._paged_lock:
                for r in seqs:
                    got = self._prepare_write(r.state, 1)
                    if got:
                        resv = self._decode_reserved.get(r.sid)
                        if resv is not None:
                            self._decode_reserved[r.sid] = max(0,
                                                               resv - got)
                states = [r.state for r in seqs]
                tables, pos = self._table_batch(states, B, 1)
                cur = np.ones((B, 1), np.int32)
                cur[:len(states), 0] = [s.last_token for s in states]
                logits, self.pool = self._paged_step(
                    self.params, jnp.asarray(cur), self.pool, tables, pos)
        else:
            key = tuple(id(r) for r in seqs)
            if key != self._batch_key:
                self._flush_batch_cache()
                self._batch_states = [r.state for r in seqs] + \
                    self._pad_states(B - len(seqs))
                self._batch_cache, self._batch_pos = \
                    self._stack_states(self._batch_states)
                self._batch_key = key
            cur = jnp.array([[s.last_token] for s in self._batch_states],
                            jnp.int32)
            logits, self._batch_cache = self._step(
                self.params, cur, self._batch_cache, self._batch_pos)
            self._batch_pos = self._batch_pos + 1
        nxt = jnp.argmax(logits, axis=-1)
        for i, r in enumerate(seqs):
            tok = int(nxt[i])
            r.state.pos += 1
            r.state.last_token = tok
            r.tokens.append(tok)
            self.meter.advance(r.sid, 1)
        with self._stats_lock:
            self.stats["decode_tokens"] += len(seqs)
            self.stats["decode_iters"] += 1
            self.stats["busy_s"] += time.time() - t0

    # -- high-level ops used by the schedulers ------------------------------
    def _match_prefix_locked(self, toks):
        """Longest cached instruction whose TOKEN sequence prefixes
        `toks` (self._lock held; token lists are cached at warmup, so
        matching is pure list comparison). Returns
        (prefix_state, prefix_tokens) or (None, None)."""
        best_st, best_ptoks = None, None
        for instr, st in self.prefix_cache.items():
            ptoks = self._prefix_toks.get(instr)
            if ptoks is None:
                ptoks = self._prefix_toks[instr] = self.tok.encode(instr)
            if len(ptoks) <= len(toks) and toks[:len(ptoks)] == ptoks \
                    and (best_ptoks is None or len(ptoks) > len(best_ptoks)):
                best_st, best_ptoks = st, ptoks
        return best_st, best_ptoks

    def _radix_fork_locked(self, toks):
        """Radix-cache front half of a fresh prefill: fork the longest
        cached block-aligned prefix. The match is capped at len-1 so at
        least one token always prefills — the forked sequence's
        next-token logits are then computed fresh, exactly as on the
        cold path (the tree never needs to store last-token logits).
        Returns (state, prefix_tokens, suffix_tokens)."""
        # cap at len-1 (>= 1 token must prefill) AND max_len-9 (the
        # suffix must survive _prepare_prefill_task's max_len clamp —
        # a radix fork's last_token is a placeholder until it does)
        cap = max(0, min(len(toks) - 1, self.max_len - 9))
        with self._paged_lock:
            blocks, mlen = self.radix.match_prefix(toks[:cap])
        if not mlen:
            return self.new_state(), [], toks
        st = PagedSeqState(table=blocks, pos=mlen)
        return st, toks[:mlen], toks[mlen:]

    def _radix_insert(self, st, ptoks, toks):
        """Publish a completed prefill's full-block prefix into the
        radix tree (incref'd by the tree; the sequence keeps its own
        refs, so release() never strips cached blocks). Skipped when the
        state's position doesn't equal the known token count — explicit
        prefix-state forks with unknown prefix tokens and partial-
        prefill continuations must not be cached under a wrong key."""
        if self.radix is None:
            return
        full = list(ptoks) + list(toks)
        full = full[: (len(full) // self.block_size) * self.block_size]
        if not full or st.pos != len(list(ptoks) + list(toks)):
            return
        with self._paged_lock:
            self.radix.insert(full, st.table)

    def _prepare_prefill_task(self, t: dict):
        """Per-task prefill front half (shared by op_prefill and
        submit_prefill): resolve/create the sequence state, fork a
        cached instruction prefix when one matches, and return
        (state, tokens_to_prefill, prefix_tokens). Empty tokens mean the
        forked prefix already covers the whole prompt."""
        sid = t["sid"]
        toks = self.tok.encode(t["text"])
        forked = False
        ptoks = []
        with self._lock:
            st = self.states.get(sid)
            if st is None:
                ps = t.get("prefix_state")
                if ps is None and self.radix is not None:
                    # the GENERAL mechanism: any cached block-aligned
                    # token prefix forks, warmed instruction or not —
                    # this replaces the bespoke instruction scan below
                    st, ptoks, toks = self._radix_fork_locked(toks)
                    forked = bool(ptoks)
                else:
                    if ps is not None:
                        ptoks = self._prefix_tokens_of_locked(ps)
                    elif self.use_prefix_cache:
                        ps, mtoks = self._match_prefix_locked(toks)
                        if ps is not None:
                            ptoks = mtoks
                            toks = toks[len(mtoks):]
                    st = self.fork_state(ps) if ps is not None \
                        else self.new_state()
                    forked = ps is not None
                self.states[sid] = st
        toks = toks[: self.max_len - st.pos - 8]
        if forked and not toks:
            # prompt == cached instruction: the forked state is already
            # complete (pos and last_token carried over) — prefilling a
            # spurious SEP would diverge from the cold path
            out = []
        else:
            out = toks or [HashTokenizer.SEP]
        if self.slo is not None:
            # preemption replay record: every token that becomes part of
            # this sid's KV context through a prefill path (cached
            # prefixes included — replay re-prefills them fresh, same
            # numerics)
            self._slo_ptoks.setdefault(sid, []).extend(
                list(ptoks) + list(out))
        return st, out, ptoks

    def op_prefill(self, task_batch):
        """task_batch: list of dicts with keys:
        sid, text, continue_partial(bool), prefix_state(optional).

        With ``use_prefix_cache`` on (set by the orchestrator's prefix
        warmup), a FRESH sequence whose prompt starts with a cached
        instruction forks that instruction's KV state instead of
        re-prefilling it — in paged mode an O(table) copy-on-write block
        share, in dense mode a functional pytree share. Only the
        remaining suffix tokens are prefilled (chunked prefill makes
        this exactly equivalent to prefilling the whole prompt).

        With ``chunked_prefill`` on, prompts STREAM through the
        continuous loop as budget-bounded PrefillJob chunks instead of
        one monolithic forward — this scheduler thread blocks until the
        prompt is resident, but the engine keeps interleaving decode
        iterations (and upstream primitives keep feeding other
        sequences), so co-resident decodes never stall."""
        if self.chunked_prefill:
            # submit_prefill owns the whole per-task path (prep, loud
            # capacity check, queueing, spec note on completion); this
            # scheduler thread just waits for the prompts to be resident
            jobs = [self.submit_prefill(t) for t in task_batch]
            for job in jobs:
                job.wait(300)     # raises the job's error on failure
            return [None] * len(task_batch)
        items = []
        notes = []            # (sid, prefix_tokens, suffix_tokens)
        for t in task_batch:
            st, toks, ptoks = self._prepare_prefill_task(t)
            notes.append((t["sid"], ptoks, toks))
            if not toks:
                continue
            self.meter.advance(t["sid"], len(toks))
            items.append((st, toks))
        if items:
            self.prefill_batch(items)
        if self.radix is not None:
            # publish AFTER the forward pass so cached blocks always
            # hold fully-written KV
            for sid, ptoks, toks in notes:
                if toks:
                    self._radix_insert(self.states[sid], ptoks, toks)
        if self.spec is not None:
            # record token contexts (prompt-lookup drafting) and mirror
            # the prefill onto the draft engine — AFTER the prefill so
            # each state's next-token prediction is final
            for sid, ptoks, toks in notes:
                self.spec.note_prefill(sid, ptoks, toks)
        return [None] * len(task_batch)

    def _prefix_tokens_of_locked(self, ps) -> list:
        """Token list of an explicitly passed prefix state (identity
        lookup against the instruction cache; self._lock held). Unknown
        states — e.g. hand-built in tests — map to [] (prompt-lookup
        context just starts at the suffix)."""
        for instr, st in self.prefix_cache.items():
            if st is ps:
                toks = self._prefix_toks.get(instr)
                if toks is None:
                    toks = self._prefix_toks[instr] = self.tok.encode(instr)
                return list(toks)
        return []

    def _clamp_new(self, st, n: int) -> int:
        """Cap a decode request to the sequence's remaining KV capacity —
        writes past max_len would silently clamp into the last cache
        slots (dense) or the last table block (paged) and corrupt it."""
        cap = self.max_len - st.pos
        if cap <= 0:
            raise ValueError(
                f"{self.name}: sequence at pos {st.pos} has no KV "
                f"capacity left (max_len {self.max_len})")
        return min(int(n), cap)

    def op_decode(self, task_batch, on_chunk=None):
        """task_batch: list of dicts: sid, max_new (capped to the
        sequence's remaining max_len capacity). Returns texts.
        on_chunk(i, text_so_far): incremental decode emission."""
        items = []
        for t in task_batch:
            st = self.states[t["sid"]]
            n = self._clamp_new(st, int(t["max_new"]))
            self.meter.advance(t["sid"], n)
            items.append((st, n))
        cb = None
        if on_chunk is not None:
            cb = lambda i, ids: on_chunk(i, self.tok.decode(ids))  # noqa: E731
        outs = self.decode_batch(items, on_chunk=cb)
        return [self.tok.decode(o) for o in outs]

    def get_prefix_state(self, instruction: str) -> SeqState:
        """Instruction-prefix KV cache (LlamaDistPC cache-reuse)."""
        with self._lock:
            st = self.prefix_cache.get(instruction)
        if st is None:
            st = self.new_state()
            toks = self.tok.encode(instruction)
            self.prefill_batch([(st, toks)])
            with self._lock:
                self.prefix_cache[instruction] = st
                self._prefix_toks[instruction] = toks
            # with the radix cache on, warmup seeds the GLOBAL tree too
            # — a cold replica and a warmed one then serve identical
            # forks whether or not the orchestrator warmed them
            self._radix_insert(st, [], toks)
        return st

    def release(self, sid: str):
        if self.spec is not None:
            self.spec.release(sid)     # drop ctx + draft-engine mirror
        with self._lock:
            st = self.states.pop(sid, None)
        if self.paged and st is not None:
            with self._paged_lock:
                for b in st.table:
                    self.alloc.decref(b)      # frees when refcount hits 0
                dropped = self._decode_reserved.pop(sid, None)
            if dropped:
                self.alloc.notify_waiters()
        self._slo_drop_block_charge(sid)
        self._slo_ptoks.pop(sid, None)
        self.meter.release(sid)

    # -- sequence migration (disaggregated prefill/decode handoff) ---------
    def export_seq(self, sid: str) -> dict:
        """Snapshot sequence ``sid`` for migration to another replica
        (``dst.import_seq(handle)``). The sequence stays fully resident
        HERE until the import lands — on import failure nothing was
        lost. A prompt still mid-flight in this engine's chunked-prefill
        queue is detached first (cursor frozen); its remaining tokens
        travel in the handle and resume on the destination. The caller
        must not export a sequence while it is actively decoding in the
        loop (serving migrates between prefill completion and decode
        submission)."""
        job = None
        loop = self._decode_loop
        if loop is not None and loop.is_alive():
            job = loop.detach_prefill(sid)
        with self._lock:
            st = self.states[sid]
        ctx = self.spec.export_ctx(sid) if self.spec is not None else None
        return {"sid": sid, "engine": self, "state": st,
                "paged": self.paged, "block_size": self.block_size,
                "spec_ctx": ctx, "job": job}

    def import_seq(self, handle) -> Optional[PrefillJob]:
        """Adopt a sequence exported from another replica so it resumes
        decoding here TOKEN-IDENTICALLY. This is the engine-level form
        of ``kv_cache.migrate_blocks``, phased so each pool's lock is
        held only for the phase touching it (the destination's decode
        loop keeps iterating while the source stages blocks — migration
        cost overlaps the loop's cadence):

          1. reserve len(table) destination blocks under THIS pool's
             lock, with the same backpressure/radix-eviction wait as
             prefill admission (all-or-nothing: on timeout the source
             is untouched);
          2. stage the source blocks out under the SOURCE pool's lock
             (gather only reads — the source keeps serving);
          3. scatter the staged blocks into the reserved slots under
             this pool's lock and register the sequence, then release
             the source atomically (``src.release`` drops exactly the
             sequence's own refs — blocks shared with the source's
             radix tree or COW forks survive there; every block here is
             freshly allocated, refcount 1: the migrated copy is
             sequence-private and is NOT inserted into this replica's
             prefix cache).

        Returns the continuation PrefillJob when the handle carried a
        mid-flight prompt (completing it also completes the original
        job so source-side waiters unblock), else None."""
        self._fault("migrate")
        src, sid, st = handle["engine"], handle["sid"], handle["state"]
        if src is self:
            # self-import: nothing moves; re-queue a detached job
            job = handle.get("job")
            if job is not None and job.remaining() and \
                    not job.done.is_set():
                return self.start_decode_loop().submit_prefill(job)
            return None
        if handle["paged"] != self.paged or \
                (self.paged and handle["block_size"] != self.block_size):
            raise ValueError(
                f"{self.name}: cannot import {sid} from "
                f"{getattr(src, 'name', '?')} (paged/block_size mismatch)")
        t0 = time.time()
        n_blocks = 0
        if self.paged:
            n_blocks = len(st.table)
            dst_table = self._acquire_import_blocks(n_blocks)
            if n_blocks:
                with src._paged_lock:
                    stage = kvc.gather_pool_blocks(src.pool, st.table)
                    stage = jax.block_until_ready(stage)
                with self._paged_lock:
                    self.pool = kvc.scatter_pool_blocks(
                        self.pool, stage, dst_table)
            new_st = PagedSeqState(table=dst_table, pos=st.pos,
                                   last_token=st.last_token)
        else:
            # dense states are portable pytrees — adopt the object
            new_st = st
        with self._lock:
            self.states[sid] = new_st
        self.meter.advance(sid, new_st.pos)
        if self.spec is not None and handle.get("spec_ctx"):
            self.spec.import_ctx(sid, handle["spec_ctx"], new_st)
        src.release(sid)                 # atomic source-side release
        with self._stats_lock:
            self.stats["migrations_in"] += 1
            self.stats["migrated_blocks"] += n_blocks
            self.stats["migrate_s"] += time.time() - t0
        job = handle.get("job")
        if job is not None and job.remaining() and not job.done.is_set():
            return self._resume_prefill(sid, new_st, job)
        return None

    def _acquire_import_blocks(self, n: int) -> List[int]:
        """Reserve ``n`` fresh pool blocks for an incoming migration
        with the same backpressure as ``_acquire_with_blocks``: wait
        unlocked (the decode loop keeps draining), evict radix leaves
        under pressure, honor admitted decodes' reservations, and time
        out loudly. Returns the reserved block list (each refcount 1);
        the paged lock is NOT held on return — allocated blocks cannot
        be taken by anyone else."""
        self._fault("alloc")
        deadline = time.time() + self.ALLOC_TIMEOUT
        timed_out = False
        while True:
            with self._paged_lock:
                avail = self.alloc.free_blocks() - self._reserved_locked()
                if n > avail and self.radix is not None:
                    avail += self.radix.evict(n - avail)
                if n <= avail:
                    return kvc.reserve_blocks(self.alloc, n)
            if timed_out:
                raise kvc.OutOfBlocks(
                    f"{self.name}: cannot reserve {n} blocks for an "
                    f"incoming migration ({self.alloc.capacity} blocks, "
                    f"{self.alloc.free_blocks()} free); "
                    f"{self._pool_diag()}")
            timed_out = not self.alloc.wait_for_free(
                n, timeout=deadline - time.time(),
                reserved_fn=self._reserved_less_evictable)

    def _resume_prefill(self, sid: str, st, old: PrefillJob) -> PrefillJob:
        """Continue a mid-flight chunked prefill after migration: the
        remaining prompt tokens stream through THIS engine's loop (or
        land synchronously when this engine is not chunked). The original
        job object is completed when the continuation lands so exporters'
        waiters unblock; its ``on_done`` chain is NOT re-fired — those
        hooks (source-engine radix insert, spec note) belong to the
        source, and the migrated copy is sequence-private here."""
        pending = list(old.tokens[old.cursor:])

        def _done(job):
            if job.error is None and self.spec is not None:
                # full-job context, exactly what the source would have
                # noted at completion (where the compute ran is
                # irrelevant to the token stream)
                self.spec.note_prefill(sid, list(old.ptoks),
                                       list(old.tokens))
            old.t_done = time.time()
            old.error = job.error
            old.done.set()

        job = PrefillJob(sid, st, pending, on_done=_done, ptoks=old.ptoks)
        if self.chunked_prefill:
            return self.start_decode_loop().submit_prefill(job)
        # monolithic destination: land the remainder now
        try:
            self.meter.advance(sid, len(pending))
            self.prefill_batch([(st, pending)])
            job.cursor = len(pending)
        except Exception as e:  # noqa: BLE001
            job.error = e
        job.t_done = time.time()
        if job.error is None and self.spec is not None:
            self.spec.note_prefill(sid, list(old.ptoks), list(old.tokens))
        old.t_done = job.t_done
        old.error = job.error
        job.done.set()
        old.done.set()
        return job
