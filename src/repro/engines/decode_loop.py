"""Iteration-level continuous batching: the persistent decode loop.

Run-to-completion decode batching (the legacy ``decode_batch`` path)
forms a batch once and steps it until the LONGEST member finishes: short
sequences idle in their slots and a sequence arriving one iteration
after batch formation waits an entire batch-time. Teola's timing-aware
batching (§5) — like Orca-style iteration-level scheduling — instead
re-forms the decode batch every iteration.

``ContinuousDecodeLoop`` is that loop, engine-agnostic. Per iteration it

  1. admits waiting sequences into free decode slots (``max_slots``),
  2. advances every resident sequence by ONE token via the engine's
     ``decode_iteration(seqs)``,
  3. emits a per-iteration chunk per sequence (``on_text`` receives the
     cumulative decoded text — the TokenStream emission point),
  4. evicts finished sequences IMMEDIATELY, freeing their slot for the
     next admission pass, and fires their ``on_done``.

The engine owns all model state and numerics; the loop owns residency,
slot accounting (mirrored into the engine via the optional
``note_slot_acquired`` / ``note_slot_released`` hooks, which the real
engine forwards to its ``OccupancyMeter``), and completion signaling.
Both the real ``LLMEngine`` and the latency-profile ``SimLLMEngine``
drive the same loop.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from typing import Callable, List, Optional


class DecodeSeq:
    """One sequence's residency in a continuous decode loop.

    ``state`` is an engine-specific handle (the real engine's per-sequence
    KV ``SeqState``, the sim engine's state dict); ``tokens`` is the
    engine-appended output payload (token ids / words); ``text_fn``
    renders it to text for chunk emission and the final ``result``.
    """

    def __init__(self, sid: str, state, n: int, *,
                 text_fn: Callable[["DecodeSeq"], str],
                 on_text: Optional[Callable[[str], None]] = None,
                 on_done: Optional[Callable[["DecodeSeq"], None]] = None):
        self.sid = sid
        self.state = state
        self.n = int(n)
        self.text_fn = text_fn
        self.on_text = on_text
        self.on_done = on_done
        self.tokens: list = []
        self.steps = 0
        self.result: Optional[str] = None
        self.error: Optional[Exception] = None
        self.done = threading.Event()
        self.t_submit = time.time()
        self.t_admit: Optional[float] = None
        self.t_done: Optional[float] = None

    def wait(self, timeout: float = 300) -> str:
        """Block until eviction; return the final decoded text."""
        if not self.done.wait(timeout):
            raise TimeoutError(
                f"decode {self.sid} not evicted after {timeout}s "
                f"({self.steps}/{self.n} steps)")
        if self.error is not None:
            raise self.error
        return self.result

    def __repr__(self):
        return (f"<DecodeSeq {self.sid} {self.steps}/{self.n} "
                f"done={self.done.is_set()}>")


class ContinuousDecodeLoop(threading.Thread):
    """Persistent decode loop over an engine's decode slots."""

    def __init__(self, engine, max_slots: int, idle_wait: float = 0.05,
                 admit_timeout: float = 60.0):
        super().__init__(
            daemon=True,
            name=f"decode-loop-{getattr(engine, 'name', '?')}")
        self.engine = engine
        self.max_slots = max(1, int(max_slots))
        self.idle_wait = idle_wait
        # how long a sequence may sit at the queue head with the engine
        # refusing admission (KV backpressure) before it is failed —
        # without this, one unsatisfiable waiter starves every decode
        # submitted after it
        self.admit_timeout = admit_timeout
        self.waiting: deque = deque()
        self.active: List[DecodeSeq] = []
        self.cv = threading.Condition()
        self.running = True
        # introspection (tests / benchmarks)
        self.iterations = 0
        self.max_resident = 0
        self.admissions: List[tuple] = []   # (sid, iteration_admitted)
        self.evictions: List[tuple] = []    # (sid, iteration_evicted, steps)
        self.callback_errors: List[tuple] = []   # (sid, exception)

    # -- producer side ------------------------------------------------------
    def submit(self, seq: DecodeSeq) -> DecodeSeq:
        with self.cv:
            self.waiting.append(seq)
            self.cv.notify()
        return seq

    def slots_free(self) -> int:
        """Slots not claimed by resident or already-queued sequences."""
        with self.cv:
            return max(0, self.max_slots - len(self.active)
                       - len(self.waiting))

    def occupancy(self) -> int:
        with self.cv:
            return len(self.active) + len(self.waiting)

    def stop(self):
        with self.cv:
            self.running = False
            self.cv.notify()
        if threading.current_thread() is not self:
            self.join(timeout=10)

    # -- loop internals -----------------------------------------------------
    def _admit_locked(self):
        """Admit waiters into free slots; returns sequences that timed
        out waiting for engine admission (evicted by the caller OUTSIDE
        the condition variable — eviction hooks may take engine locks)."""
        expired = []
        admit_hook = getattr(self.engine, "try_admit", None)
        while self.waiting and len(self.active) < self.max_slots:
            seq = self.waiting[0]
            # engine-level admission control (paged KV backpressure: the
            # engine reserves the sequence's worst-case blocks, or defers
            # it). Head-of-line FIFO: if the head cannot be admitted, stop
            # — the loop retries every iteration / idle tick — unless it
            # has been deferred past admit_timeout, in which case it is
            # failed so it cannot starve the queue behind it.
            if admit_hook is not None and not admit_hook(seq):
                if self.admit_timeout is not None and \
                        time.time() - seq.t_submit > self.admit_timeout:
                    self.waiting.popleft()
                    expired.append(seq)
                    continue
                break
            self.waiting.popleft()
            seq.t_admit = time.time()
            self.active.append(seq)
            self.admissions.append((seq.sid, self.iterations))
            hook = getattr(self.engine, "note_slot_acquired", None)
            if hook is not None:
                hook(seq)
        return expired

    def _evict(self, seq: DecodeSeq, error: Optional[Exception] = None):
        seq.t_done = time.time()
        if error is None:
            try:
                seq.result = seq.text_fn(seq)
            except Exception as e:  # noqa: BLE001
                error = e
        seq.error = error
        self.evictions.append((seq.sid, self.iterations, seq.steps))
        hook = getattr(self.engine, "note_slot_released", None)
        if hook is not None:
            hook(seq)
        seq.done.set()
        if seq.on_done is not None:
            # on_done runs runtime bookkeeping (store writes, graph
            # completion) on the loop thread; a failure there must not
            # kill the loop and strand the other resident sequences
            try:
                seq.on_done(seq)
            except Exception as e:  # noqa: BLE001
                self.callback_errors.append((seq.sid, e))

    def run(self):
        while True:
            with self.cv:
                if not self.running:
                    break
                expired = self._admit_locked()
                if not self.active and not expired:
                    self.cv.wait(timeout=self.idle_wait)
                    continue
                batch = list(self.active)
                self.max_resident = max(self.max_resident, len(batch))
            for seq in expired:
                self._evict(seq, error=TimeoutError(
                    f"decode {seq.sid} not admitted within "
                    f"{self.admit_timeout}s (KV pool backpressure)"))
            if not batch:
                continue
            # an engine may emit SEVERAL tokens per sequence per pass
            # (speculative decoding: a verified draft chunk); progress is
            # the number of tokens appended, floor 1 for engines that
            # track progress elsewhere — plain engines append exactly one
            # token, preserving the legacy step-per-iteration behavior
            before = [len(seq.tokens) for seq in batch]
            try:
                self.engine.decode_iteration(batch)
            except Exception as e:  # noqa: BLE001 — fail resident seqs
                with self.cv:
                    for seq in batch:
                        self.active.remove(seq)
                for seq in batch:
                    self._evict(seq, error=e)
                continue
            self.iterations += 1
            finished, errored = [], []
            for seq, n_before in zip(batch, before):
                seq.steps += max(1, len(seq.tokens) - n_before)
                # a failing per-sequence emission (on_text runs stream
                # plumbing and the first-chunk early-release hook) fails
                # THAT sequence, never the shared loop
                try:
                    if seq.on_text is not None:
                        seq.on_text(seq.text_fn(seq))
                except Exception as e:  # noqa: BLE001
                    errored.append((seq, e))
                    continue
                if seq.steps >= seq.n:
                    finished.append(seq)
            if finished or errored:
                with self.cv:
                    for seq in finished:
                        self.active.remove(seq)
                    for seq, _ in errored:
                        self.active.remove(seq)
                for seq, e in errored:
                    self._evict(seq, error=e)
                for seq in finished:        # slot freed before next admit
                    self._evict(seq)
        # stopped: unblock anything still resident or queued
        with self.cv:
            leftovers = list(self.active) + list(self.waiting)
            self.active.clear()
            self.waiting.clear()
        for seq in leftovers:
            self._evict(seq, error=RuntimeError("decode loop stopped"))


class DecodeLoopMixin:
    """Decode-loop lifecycle shared by the real and sim LLM engines.
    Host class must provide ``_lock``, ``max_batch`` and initialize
    ``_decode_loop = None``."""

    def start_decode_loop(self) -> ContinuousDecodeLoop:
        """Start (or return) this replica's persistent decode loop."""
        with self._lock:
            if self._decode_loop is None or \
                    not self._decode_loop.is_alive():
                self._decode_loop = ContinuousDecodeLoop(
                    self, max_slots=self.max_batch)
                self._decode_loop.start()
            return self._decode_loop

    def stop_decode_loop(self):
        with self._lock:
            loop = self._decode_loop
            self._decode_loop = None
        if loop is not None:
            loop.stop()

    def decode_slots_free(self) -> int:
        """Free decode slots (pool-router slot-aware routing input)."""
        loop = self._decode_loop
        if loop is None or not loop.is_alive():
            return self.max_batch
        return loop.slots_free()
