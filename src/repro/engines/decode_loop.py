"""Iteration-level continuous batching: the persistent decode loop.

Run-to-completion decode batching (the legacy ``decode_batch`` path)
forms a batch once and steps it until the LONGEST member finishes: short
sequences idle in their slots and a sequence arriving one iteration
after batch formation waits an entire batch-time. Teola's timing-aware
batching (§5) — like Orca-style iteration-level scheduling — instead
re-forms the decode batch every iteration.

``ContinuousDecodeLoop`` is that loop, engine-agnostic. Per iteration it

  1. admits waiting sequences into free decode slots (``max_slots``),
  2. advances every resident sequence by ONE token via the engine's
     ``decode_iteration(seqs)``,
  3. emits a per-iteration chunk per sequence (``on_text`` receives the
     cumulative decoded text — the TokenStream emission point),
  4. evicts finished sequences IMMEDIATELY, freeing their slot for the
     next admission pass, and fires their ``on_done``.

CHUNKED PREFILL (Sarathi-style stall-free mixed batches): with
``prefill_chunk > 0`` the loop also owns a PREFILL queue of
``PrefillJob``s — resumable per-sequence prompt cursors. Each pass packs
all resident decode tokens FIRST, then fills the remaining per-pass
``token_budget`` with prefill-chunk tokens, and hands both to the
engine's ``mixed_iteration(seqs, prefill_items)``. A long prompt
therefore advances in bounded chunks BETWEEN decode steps instead of
head-of-line-blocking every co-resident decode for a whole-prompt
forward: decode time-between-tokens is bounded by one chunk's compute,
never by prompt length. Decodes always advance (the budget caps prefill
admission, it never splits the resident decode batch); a pass with no
budget headroom simply carries no prefill tokens.

The engine owns all model state and numerics; the loop owns residency,
slot accounting (mirrored into the engine via the optional
``note_slot_acquired`` / ``note_slot_released`` hooks, which the real
engine forwards to its ``OccupancyMeter``), the prefill token-budget
admission, and completion signaling. Both the real ``LLMEngine`` and
the latency-profile ``SimLLMEngine`` drive the same loop.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from typing import Callable, List, Optional


class DecodeSeq:
    """One sequence's residency in a continuous decode loop.

    ``state`` is an engine-specific handle (the real engine's per-sequence
    KV ``SeqState``, the sim engine's state dict); ``tokens`` is the
    engine-appended output payload (token ids / words); ``text_fn``
    renders it to text for chunk emission and the final ``result``.
    """

    def __init__(self, sid: str, state, n: int, *,
                 text_fn: Callable[["DecodeSeq"], str],
                 on_text: Optional[Callable[[str], None]] = None,
                 on_done: Optional[Callable[["DecodeSeq"], None]] = None,
                 slo=None):
        self.sid = sid
        self.state = state
        self.n = int(n)
        self.text_fn = text_fn
        self.on_text = on_text
        self.on_done = on_done
        # SLO scheduling metadata (serving/slo.SLOTag) — None means
        # untagged; the loop only consults it when the engine has an
        # attached SLOPolicy
        self.slo = slo
        # set by the engine when this sequence was preempted
        # (evict-to-recompute) and must have its KV rebuilt on re-entry
        self.slo_preempted = False
        self.tokens: list = []
        self.steps = 0
        self.result: Optional[str] = None
        self.error: Optional[Exception] = None
        self.done = threading.Event()
        self.t_submit = time.time()
        self.t_admit: Optional[float] = None
        self.t_done: Optional[float] = None

    def wait(self, timeout: float = 300) -> str:
        """Block until eviction; return the final decoded text."""
        if not self.done.wait(timeout):
            raise TimeoutError(
                f"decode {self.sid} not evicted after {timeout}s "
                f"({self.steps}/{self.n} steps)")
        if self.error is not None:
            raise self.error
        return self.result

    def __repr__(self):
        return (f"<DecodeSeq {self.sid} {self.steps}/{self.n} "
                f"done={self.done.is_set()}>")


class PrefillJob:
    """One prompt's resumable residency in the loop's PREFILL queue.

    ``state`` is the engine's per-sequence handle (its ``pos`` is the
    authoritative write cursor); ``tokens`` is the full remaining token
    list to prefill; ``cursor`` counts tokens already consumed by landed
    chunks. The engine's ``mixed_iteration`` advances the cursor chunk
    by chunk; the loop evicts the job (firing ``on_done``) once the
    cursor reaches the end.
    """

    def __init__(self, sid: str, state, tokens: list, *,
                 on_done: Optional[Callable[["PrefillJob"], None]] = None,
                 ptoks: Optional[list] = None, slo=None):
        self.sid = sid
        self.slo = slo
        self.state = state
        self.tokens = list(tokens)
        # tokens already resident when the job was created (radix/COW
        # prefix + earlier turns) — carried so a mid-flight migration can
        # reconstruct the sequence's full token context on the new engine
        self.ptoks = list(ptoks) if ptoks else []
        self.cursor = 0
        self.chunks = 0                     # landed chunk count
        self.on_done = on_done
        self.error: Optional[Exception] = None
        self.done = threading.Event()
        self.t_submit = time.time()
        self.t_progress = time.time()       # last time a chunk landed
        self.t_done: Optional[float] = None

    def remaining(self) -> int:
        return len(self.tokens) - self.cursor

    def wait(self, timeout: float = 300):
        """Block until the whole prompt has been prefilled."""
        if not self.done.wait(timeout):
            raise TimeoutError(
                f"prefill {self.sid} not finished after {timeout}s "
                f"({self.cursor}/{len(self.tokens)} tokens)")
        if self.error is not None:
            raise self.error

    def __repr__(self):
        return (f"<PrefillJob {self.sid} {self.cursor}/{len(self.tokens)} "
                f"done={self.done.is_set()}>")


class ContinuousDecodeLoop(threading.Thread):
    """Persistent decode loop over an engine's decode slots, optionally
    mixing budget-bounded prefill chunks into each pass."""

    def __init__(self, engine, max_slots: int, idle_wait: float = 0.05,
                 admit_timeout: float = 60.0, prefill_chunk: int = 0,
                 token_budget: Optional[int] = None):
        super().__init__(
            daemon=True,
            name=f"decode-loop-{getattr(engine, 'name', '?')}")
        self.engine = engine
        self.max_slots = max(1, int(max_slots))
        self.idle_wait = idle_wait
        # how long a sequence may sit at the queue head with the engine
        # refusing admission (KV backpressure) before it is failed —
        # without this, one unsatisfiable waiter starves every decode
        # submitted after it
        self.admit_timeout = admit_timeout
        # chunked prefill: tokens per prefill chunk (0 disables the
        # prefill queue) and the per-pass token budget shared by decode
        # and prefill tokens. Default budget fits a full decode batch
        # plus one full chunk, so decodes never shrink a chunk and a
        # chunk never starves.
        self.prefill_chunk = max(0, int(prefill_chunk or 0))
        self.token_budget = int(token_budget) if token_budget else \
            (self.prefill_chunk + self.max_slots if self.prefill_chunk
             else 0)
        self.waiting: deque = deque()
        self.prefill_waiting: deque = deque()
        # ids of PrefillJobs whose chunk is inside the currently-executing
        # mixed pass (the engine call runs OUTSIDE the cv) — detach must
        # wait these out before handing the job's state to another engine
        self._inflight_prefill: frozenset = frozenset()
        self.active: List[DecodeSeq] = []
        self.cv = threading.Condition()
        self.running = True
        # fault tolerance: `last_pass` is the loop's heartbeat — updated
        # at the top of every pass, so a pass stuck inside an engine
        # call (hung replica) goes stale and the watchdog can tell a
        # hung loop from an idle one. `fatal_error` captures the first
        # exception that escapes the loop body (loop-thread death must
        # never be silent — the run() wrapper drains every queued
        # sequence with it and marks the engine suspect).
        self.last_pass = time.time()
        self.fatal_error: Optional[Exception] = None
        # introspection (tests / benchmarks)
        self.iterations = 0
        self.max_resident = 0
        self.admissions: List[tuple] = []   # (sid, iteration_admitted)
        self.evictions: List[tuple] = []    # (sid, iteration_evicted, steps)
        self.callback_errors: List[tuple] = []   # (sid, exception)
        self.prefill_chunks: List[tuple] = []    # (sid, iteration, ntokens)
        self.mixed_log: List[tuple] = []    # (decode_cost, prefill_tokens)
        self.preemptions: List[tuple] = []  # (sid, iteration, steps_kept)
        # SLO mode: set by _admit_locked when an urgent (interactive or
        # aged) waiter was deferred this pass — the preemption trigger
        self._slo_deferred_urgent = False

    # -- producer side ------------------------------------------------------
    def submit(self, seq: DecodeSeq) -> DecodeSeq:
        pol = getattr(self.engine, "slo", None)
        if pol is not None:
            pol.stats.bump(pol.tag_of(seq), "submitted")
        with self.cv:
            self.waiting.append(seq)
            self.cv.notify()
        return seq

    def submit_prefill(self, job: PrefillJob) -> PrefillJob:
        """Queue a prompt for chunked prefill inside the loop. Requires
        ``prefill_chunk > 0`` (the engine enables it)."""
        if not self.prefill_chunk:
            raise RuntimeError(
                f"decode loop of {getattr(self.engine, 'name', '?')} has "
                f"chunked prefill disabled (prefill_chunk=0)")
        with self.cv:
            self.prefill_waiting.append(job)
            self.cv.notify()
        return job

    def detach_prefill(self, sid: str) -> Optional[PrefillJob]:
        """Pull ``sid``'s mid-flight PrefillJob out of the loop so its
        sequence can migrate to another engine (disaggregated handoff of
        a partially-prefilled prompt). Removes the job from the queue,
        then waits out any pass currently landing one of its chunks —
        on return the job's cursor/state are final and no loop thread
        will touch them again. Returns None when ``sid`` has no queued
        job (already finished, or never chunk-prefilled). A job that
        FINISHES in the very pass being waited out completes normally on
        this engine (its ``on_done`` fires here); callers see
        ``remaining() == 0`` and skip the continuation."""
        with self.cv:
            job = next((j for j in self.prefill_waiting if j.sid == sid),
                       None)
            if job is None:
                return None
            self.prefill_waiting.remove(job)
            while id(job) in self._inflight_prefill:
                self.cv.wait(timeout=0.05)
        return job

    def slots_free(self) -> int:
        """Slots not claimed by resident or already-queued sequences."""
        with self.cv:
            return max(0, self.max_slots - len(self.active)
                       - len(self.waiting))

    def occupancy(self) -> int:
        with self.cv:
            return len(self.active) + len(self.waiting)

    def stop(self):
        with self.cv:
            self.running = False
            self.cv.notify()
        if threading.current_thread() is not self:
            self.join(timeout=10)

    # -- loop internals -----------------------------------------------------
    def _decode_cost(self, batch) -> int:
        """Query tokens the decode part of this pass will carry (plain
        engines: one per resident sequence; speculative engines report
        k+1 for chunk-eligible sequences via ``decode_token_cost``)."""
        fn = getattr(self.engine, "decode_token_cost", None)
        return int(fn(batch)) if fn is not None else len(batch)

    def _take_prefill_locked(self, decode_cost: int):
        """Token-budget admission: plan prefill chunks for this pass —
        FIFO over the prefill queue, each job contributing at most one
        chunk of min(prefill_chunk, remaining, budget room) tokens.
        Decode tokens are packed first; prefill only ever takes the
        leftover budget (decodes never wait behind a prompt)."""
        if not self.prefill_chunk or not self.prefill_waiting:
            return []
        room = self.token_budget - decode_cost
        items = []
        pol = getattr(self.engine, "slo", None)
        # SLO mode: interactive chunks pack first (per-class FIFO behind
        # that, aging promotes starved batch jobs). A batch PrefillJob
        # skipped while interactive jobs drain the budget is PAUSED at
        # its cursor — resuming is free, the cursor is the state. FIFO
        # (byte-identical) when no policy is armed.
        queue = self.prefill_waiting if pol is None else \
            pol.admission_order(list(self.prefill_waiting))
        for job in queue:
            if room <= 0:
                break
            limit = self.prefill_chunk
            cap = getattr(job, "chunk_cap", 0)
            if cap:
                # degraded mode (overload layer): this job's chunks are
                # capped below the engine-wide chunk size, trading its
                # own prefill latency for co-resident decode TBT
                limit = min(limit, int(cap))
            n = min(limit, job.remaining(), room)
            if n > 0:
                items.append((job, n))
                room -= n
        return items

    def _note_prefill_progress(self, pitems, cursors_before) -> int:
        """Account chunks the engine landed this pass (it may decline a
        planned chunk — e.g. paged pool momentarily out of unreserved
        blocks — in which case the job just stays queued); evict jobs
        whose prompt is fully resident. Returns tokens landed."""
        landed = 0
        finished = []
        for (job, _n), c0 in zip(pitems, cursors_before):
            got = job.cursor - c0
            if got:
                landed += got
                job.chunks += 1
                job.t_progress = time.time()
                self.prefill_chunks.append((job.sid, self.iterations, got))
                if job.remaining() == 0:
                    finished.append(job)
        with self.cv:
            for job in finished:
                if job in self.prefill_waiting:
                    self.prefill_waiting.remove(job)
            if landed:
                # the queue is moving: refresh every waiter's progress
                # stamp so the starvation guard only fires when prefill
                # as a whole is stuck, not on tail jobs behind a long
                # but advancing FIFO
                now = time.time()
                for job in self.prefill_waiting:
                    job.t_progress = now
        for job in finished:
            self._evict_prefill(job)
        return landed

    def _expire_prefill(self):
        """Fail prefill jobs that made no progress for admit_timeout
        (paged pool can never serve their next chunk) — same starvation
        guard as decode admission."""
        if self.admit_timeout is None:
            return
        now = time.time()
        stuck = []
        with self.cv:
            for job in list(self.prefill_waiting):
                if now - job.t_progress > self.admit_timeout:
                    self.prefill_waiting.remove(job)
                    stuck.append(job)
        for job in stuck:
            self._evict_prefill(job, error=TimeoutError(
                f"prefill {job.sid} made no progress within "
                f"{self.admit_timeout}s (KV pool backpressure) at "
                f"{job.cursor}/{len(job.tokens)} tokens"))

    def _evict_prefill(self, job: PrefillJob,
                       error: Optional[Exception] = None):
        job.t_done = time.time()
        job.error = error
        if job.on_done is not None:
            # on_done runs engine/runtime bookkeeping on the loop
            # thread; a failure there must not kill the loop. It fires
            # BEFORE done is set, so job.wait() returning implies the
            # completion hooks (e.g. the speculative-drafter prefill
            # note) have already run.
            try:
                job.on_done(job)
            except Exception as e:  # noqa: BLE001
                self.callback_errors.append((job.sid, e))
        job.done.set()

    def _admit_locked(self):
        """Admit waiters into free slots; returns sequences that timed
        out waiting for engine admission (evicted by the caller OUTSIDE
        the condition variable — eviction hooks may take engine locks)."""
        admit_hook = getattr(self.engine, "try_admit", None)
        pol = getattr(self.engine, "slo", None)
        if pol is not None:
            return self._admit_slo_locked(admit_hook, pol)
        expired = []
        while self.waiting and len(self.active) < self.max_slots:
            seq = self.waiting[0]
            # engine-level admission control (paged KV backpressure: the
            # engine reserves the sequence's worst-case blocks, or defers
            # it). Head-of-line FIFO: if the head cannot be admitted, stop
            # — the loop retries every iteration / idle tick — unless it
            # has been deferred past admit_timeout, in which case it is
            # failed so it cannot starve the queue behind it.
            if admit_hook is not None and not admit_hook(seq):
                if self.admit_timeout is not None and \
                        time.time() - seq.t_submit > self.admit_timeout:
                    self.waiting.popleft()
                    expired.append(seq)
                    continue
                break
            self.waiting.popleft()
            seq.t_admit = time.time()
            self.active.append(seq)
            self.admissions.append((seq.sid, self.iterations))
            hook = getattr(self.engine, "note_slot_acquired", None)
            if hook is not None:
                hook(seq)
        return expired

    def _admit_slo_locked(self, admit_hook, pol):
        """SLO-mode admission: rank waiters (class, priority, e-graph
        depth, arrival — aging promotes starved batch work), consult the
        per-tenant slot fair share, and record whether an urgent waiter
        was deferred (the preemption trigger). Unlike FIFO mode a
        non-admissible waiter is SKIPPED, not head-of-line blocking —
        admission order is the rank order, so there is no FIFO contract
        to preserve behind it."""
        expired = []
        now = time.time()
        self._slo_deferred_urgent = False
        pol.note_live(pol.tag_of(s).tenant for s in self.waiting)
        demand = pol.slot_demand(self.waiting, self.active)
        for seq in pol.admission_order(list(self.waiting), now):
            deferred = False
            if len(self.active) >= self.max_slots:
                deferred = True
            elif not pol.may_take_slot(pol.tag_of(seq), demand):
                # over slot fair share while another tenant has unmet
                # demand — hold this one back, keep scanning (a
                # different tenant further down may still fit)
                deferred = True
            elif admit_hook is not None and not admit_hook(seq):
                deferred = True          # engine (KV) backpressure
            if deferred:
                if self.admit_timeout is not None and \
                        now - seq.t_submit > self.admit_timeout:
                    self.waiting.remove(seq)
                    expired.append(seq)
                elif pol.is_urgent(seq, now):
                    self._slo_deferred_urgent = True
                continue
            self.waiting.remove(seq)
            seq.t_admit = time.time()
            self.active.append(seq)
            self.admissions.append((seq.sid, self.iterations))
            pol.note_admit(seq)
            hook = getattr(self.engine, "note_slot_acquired", None)
            if hook is not None:
                hook(seq)
        return expired

    def _plan_preempt_locked(self):
        """SLO mode: when this pass deferred an urgent waiter while
        non-urgent sequences are resident, ask the policy's governor for
        a victim (cooldown + per-seq cap = hysteresis). Victims are
        pulled out of ``active`` here; the caller frees their KV and
        re-queues them OUTSIDE the condition variable (engine locks)."""
        pol = getattr(self.engine, "slo", None)
        if pol is None or not self._slo_deferred_urgent:
            return []
        can = getattr(self.engine, "can_preempt", None)
        cands = self.active if can is None else \
            [s for s in self.active if can(s)]
        victims = pol.plan_preemption(cands)
        for v in victims:
            self.active.remove(v)
        return victims

    def _evict(self, seq: DecodeSeq, error: Optional[Exception] = None):
        seq.t_done = time.time()
        if error is None:
            try:
                seq.result = seq.text_fn(seq)
            except Exception as e:  # noqa: BLE001
                error = e
        seq.error = error
        self.evictions.append((seq.sid, self.iterations, seq.steps))
        pol = getattr(self.engine, "slo", None)
        if pol is not None:
            pol.note_evict(seq, failed=error is not None)
        hook = getattr(self.engine, "note_slot_released", None)
        if hook is not None:
            hook(seq)
        seq.done.set()
        if seq.on_done is not None:
            # on_done runs runtime bookkeeping (store writes, graph
            # completion) on the loop thread; a failure there must not
            # kill the loop and strand the other resident sequences
            try:
                seq.on_done(seq)
            except Exception as e:  # noqa: BLE001
                self.callback_errors.append((seq.sid, e))

    def run(self):
        try:
            self._run_loop()
        except Exception as e:  # noqa: BLE001 — loop-thread death is fatal
            # satellite bugfix: a background decode-loop thread must not
            # swallow its own death — capture the first exception, mark
            # the owning engine suspect, and fail everything queued so
            # every submitting caller sees the error.
            self.fatal_error = e
            try:
                if getattr(self.engine, "health", "healthy") == "healthy":
                    self.engine.health = "suspect"
            except Exception:  # noqa: BLE001
                pass
        if self.fatal_error is not None:
            err: Exception = RuntimeError(
                f"decode loop died: {self.fatal_error!r}")
            err.__cause__ = self.fatal_error
        else:
            err = RuntimeError("decode loop stopped")
        # stopped or died: unblock anything still resident or queued
        with self.cv:
            self.running = False
            leftovers = list(self.active) + list(self.waiting)
            pleft = list(self.prefill_waiting)
            self.active.clear()
            self.waiting.clear()
            self.prefill_waiting.clear()
        for seq in leftovers:
            self._evict(seq, error=err)
        for job in pleft:
            self._evict_prefill(job, error=err)

    def _run_loop(self):
        while True:
            self.last_pass = time.time()
            with self.cv:
                if not self.running:
                    break
                expired = self._admit_locked()
                victims = self._plan_preempt_locked()
                if not self.active and not expired and not victims and \
                        not self.prefill_waiting:
                    self.cv.wait(timeout=self.idle_wait)
                    continue
                batch = list(self.active)
                self.max_resident = max(self.max_resident, len(batch))
                dcost = self._decode_cost(batch)
                pitems = self._take_prefill_locked(dcost)
                self._inflight_prefill = frozenset(
                    id(j) for j, _ in pitems)
                pwaiting = bool(self.prefill_waiting)
            for seq in expired:
                self._evict(seq, error=TimeoutError(
                    f"decode {seq.sid} not admitted within "
                    f"{self.admit_timeout}s (KV pool backpressure)"))
            if victims:
                # evict-to-recompute: free each victim's KV (engine call
                # — outside the cv), then re-queue it with its emitted
                # tokens intact; on re-admission the engine rebuilds KV
                # by re-prefilling prompt+emitted, so the continuation
                # is token-identical. The pass restarts so the freed
                # slots/blocks go to the urgent waiter immediately.
                pol = getattr(self.engine, "slo", None)
                with self.cv:
                    self._inflight_prefill = frozenset()
                    self.cv.notify_all()
                for v in victims:
                    try:
                        self.engine.preempt_decode(v)
                    except Exception as e:  # noqa: BLE001
                        self._evict(v, error=e)
                        continue
                    self.preemptions.append((v.sid, self.iterations,
                                             v.steps))
                    if pol is not None:
                        pol.note_preempted(v)
                    v.t_submit = time.time()   # fresh admission clock
                    with self.cv:
                        self.waiting.append(v)
                        self.cv.notify()
                continue
            if pwaiting and not pitems:
                # prefill queued but no chunk planned — either resident
                # decodes consume the whole budget every pass (room
                # permanently <= 0, e.g. speculative cost with a small
                # budget) or the queue drained between checks. The
                # starvation guard must fire HERE too, not only on idle
                # passes, so a budget-starved job fails loudly after
                # admit_timeout instead of hanging its query forever.
                self._expire_prefill()
            if not batch and not pitems:
                time.sleep(self.idle_wait)
                continue
            # an engine may emit SEVERAL tokens per sequence per pass
            # (speculative decoding: a verified draft chunk); progress is
            # the number of tokens appended, floor 1 for engines that
            # track progress elsewhere — plain engines append exactly one
            # token, preserving the legacy step-per-iteration behavior
            before = [len(seq.tokens) for seq in batch]
            pbefore = [job.cursor for job, _ in pitems]
            try:
                if pitems:
                    # mixed pass: all resident decode tokens first, then
                    # the budget's leftover as prefill chunks
                    self.engine.mixed_iteration(batch, pitems)
                else:
                    self.engine.decode_iteration(batch)
            except Exception as e:  # noqa: BLE001 — fail resident seqs
                with self.cv:
                    for seq in batch:
                        self.active.remove(seq)
                    for job, _ in pitems:
                        if job in self.prefill_waiting:
                            self.prefill_waiting.remove(job)
                    self._inflight_prefill = frozenset()
                    self.cv.notify_all()
                for seq in batch:
                    self._evict(seq, error=e)
                for job, _ in pitems:
                    self._evict_prefill(job, error=e)
                continue
            self.iterations += 1
            landed = self._note_prefill_progress(pitems, pbefore)
            if pitems:
                with self.cv:
                    self._inflight_prefill = frozenset()
                    self.cv.notify_all()
            if pitems:
                self.mixed_log.append(
                    (dcost, sum(n for _, n in pitems), landed))
                if not landed:
                    self._expire_prefill()
                    if not batch:     # nothing advanced at all this pass
                        time.sleep(self.idle_wait)
            if landed:
                # a prefill chunk landing changes pool block state
                # mid-pass: re-check engine admission for deferred
                # waiters NOW (try_admit is re-evaluated fresh — a
                # pre-chunk admission decision must never be reused)
                with self.cv:
                    late = self._admit_locked()
                for seq in late:
                    self._evict(seq, error=TimeoutError(
                        f"decode {seq.sid} not admitted within "
                        f"{self.admit_timeout}s (KV pool backpressure)"))
            finished, errored = [], []
            pol = getattr(self.engine, "slo", None)
            for seq, n_before in zip(batch, before):
                seq.steps += max(1, len(seq.tokens) - n_before)
                if pol is not None:
                    # TTFT on the first pass, TBT per pass after that
                    pol.note_tokens(seq)
                # a failing per-sequence emission (on_text runs stream
                # plumbing and the first-chunk early-release hook) fails
                # THAT sequence, never the shared loop
                try:
                    if seq.on_text is not None:
                        seq.on_text(seq.text_fn(seq))
                except Exception as e:  # noqa: BLE001
                    errored.append((seq, e))
                    continue
                if seq.steps >= seq.n:
                    finished.append(seq)
            if finished or errored:
                with self.cv:
                    for seq in finished:
                        self.active.remove(seq)
                    for seq, _ in errored:
                        self.active.remove(seq)
                for seq, e in errored:
                    self._evict(seq, error=e)
                for seq in finished:        # slot freed before next admit
                    self._evict(seq)


class DecodeLoopMixin:
    """Decode-loop lifecycle shared by the real and sim LLM engines.
    Host class must provide ``_lock``, ``max_batch`` and initialize
    ``_decode_loop = None``."""

    def start_decode_loop(self) -> ContinuousDecodeLoop:
        """Start (or return) this replica's persistent decode loop. An
        engine with ``chunked_prefill`` enabled hands the loop its
        prefill-chunk size and per-pass token budget, arming the loop's
        prefill queue (``submit_prefill``)."""
        with self._lock:
            if self._decode_loop is None or \
                    not self._decode_loop.is_alive():
                chunk = getattr(self, "prefill_chunk", 0) \
                    if getattr(self, "chunked_prefill", False) else 0
                self._decode_loop = ContinuousDecodeLoop(
                    self, max_slots=self.max_batch, prefill_chunk=chunk,
                    token_budget=getattr(self, "token_budget", None))
                self._decode_loop.start()
            return self._decode_loop

    def stop_decode_loop(self):
        with self._lock:
            loop = self._decode_loop
            self._decode_loop = None
        if loop is not None:
            loop.stop()

    def decode_slots_free(self) -> int:
        """Free decode slots (pool-router slot-aware routing input)."""
        loop = self._decode_loop
        if loop is None or not loop.is_alive():
            return self.max_batch
        return loop.slots_free()
