"""Simulation-profile engines: same op_ interface as the real engines, but
execution time comes from latency models CALIBRATED TO THE PAPER'S OWN
MEASUREMENTS (NVIDIA 3090-class GPUs), served by sleeping threads.

Why this exists: this container is a 2-core CPU — real tiny-model engines
are *compute-bound at batch size 1*, so GPU-style batching/parallelization
gains (the paper's entire premise: Fig. 4's 1.3x from batch 4->16, true
inter-engine concurrency) cannot manifest in wall-clock there. The
orchestration layer under test is identical — schedulers cannot tell a
profiled engine from a real one. This is the standard methodology for
evaluating schedulers without the paper's testbed; DESIGN.md §2 records
it, and tests validate the real-compute path for correctness separately.

Calibration anchors (paper):
  Fig 4a: embedding 48 reqs: batch 4 -> 1.8 s, batch 16 -> 1.35 s
          => t_embed(b) ~= 50 + 25*b ms per call
  Table 3: single prefill 1000/1700/3000 tok = 260/414/720 ms
          => t_prefill ~= 20 + 0.235 ms/token (per seq, + batch discount)
  Fig 7:  512-tok prefill 0.5 s; batch of two 0.8 s  (0.78 batch factor)
  decode: ~25 ms/step (13B, 2x3090), +2 ms/step per extra seq in batch
"""
from __future__ import annotations

import hashlib
import threading
import time
from typing import Dict

import numpy as np

from repro.engines.decode_loop import DecodeLoopMixin, DecodeSeq, PrefillJob
from repro.engines.model_free import ChunkerEngine, SearchAPIEngine, \
    VectorDBEngine

SPEED = float(__import__("os").environ.get("REPRO_SIM_SPEED", "8.0"))
# SPEED scales all modeled latencies down so benchmark sweeps finish in
# container time; it divides every scheme equally (ratios are preserved).


def _sleep(ms: float):
    time.sleep(ms / 1000.0 / SPEED)


def _hvec(text: str, dim: int = 64) -> np.ndarray:
    """Deterministic bag-of-words hash embedding (retrieval-meaningful)."""
    v = np.zeros(dim, np.float32)
    for w in text.split():
        h = int.from_bytes(hashlib.md5(w.encode()).digest()[:8], "little")
        v[h % dim] += 1.0 + (h >> 32) % 7 / 7.0
    n = np.linalg.norm(v)
    return v / (n + 1e-9)


def _ptext(seed: str, n: int) -> str:
    h = hashlib.md5(seed.encode()).hexdigest()
    return " ".join(f"w{h[i % 28]}{i}" for i in range(n))


class SimLLMEngine(DecodeLoopMixin):
    kind = "llm"

    def __init__(self, name: str, *, max_batch: int = 8,
                 prefill_ms_per_tok: float = 0.235, prefill_setup: float = 20,
                 decode_ms_per_step: float = 25.0,
                 decode_ms_per_extra_seq: float = 2.0,
                 batch_factor: float = 0.78, stream_chunk: int = 4,
                 paged: bool = False, block_size: int = 16,
                 num_blocks: int = 0, speculative: bool = False,
                 draft_k: int = 4, spec_accept: float = 0.7,
                 spec_draft_cost: float = 0.25,
                 chunked_prefill: bool = False, prefill_chunk: int = 128,
                 token_budget=None, prefix_cache: str = "none",
                 migrate_ms_per_block: float = 0.02):
        self.name = name
        self.max_batch = max_batch
        # disaggregated-handoff ACCOUNTING: import_seq charges
        # migrate_ms_per_block per block-quantized resident block — the
        # PCIe/NVLink-class staging copy the real engine pays in
        # migrate_blocks — so scheduler studies see the handoff on the
        # dispatch critical path exactly where the real runtime puts it.
        self.migrate_ms_per_block = migrate_ms_per_block
        # radix prefix-cache ACCOUNTING: with prefix_cache="radix" a
        # fresh prompt's longest block-aligned word prefix already seen
        # by this replica is "cached" — its tokens are skipped from the
        # modeled prefill cost (capped at len-1: one token always
        # prefills, like the real engine) and every block-aligned prefix
        # of the prompt is remembered. The chunk set is prefix-closed,
        # so its size equals the real tree's node-block count; kv_blocks
        # counts it once (shared prefixes are deduplicated capacity).
        if prefix_cache not in ("none", "radix"):
            raise ValueError(
                f"prefix_cache must be 'none' or 'radix', got "
                f"{prefix_cache!r}")
        if prefix_cache == "radix" and not paged:
            raise ValueError(
                "prefix_cache='radix' requires paged=True")
        self.prefix_cache_mode = prefix_cache
        self._radix_chunks: set = set()
        # chunked-prefill ACCOUNTING: prompts queued via submit_prefill
        # advance prefill_chunk tokens per mixed loop pass, each pass
        # paying the per-call setup plus per-token cost the monolithic
        # prefill formula charges — scheduler simulations see both the
        # bounded decode time-between-tokens AND the decomposition
        # overhead (Table 3) the real engine pays. Decoded text is
        # unchanged (pos advances to the same place before any decode).
        self.chunked_prefill = chunked_prefill
        self.prefill_chunk = int(prefill_chunk)
        self.token_budget = token_budget
        # speculative step ACCOUNTING: with `speculative` on, each target
        # step carries draft_k draft-model steps (each spec_draft_cost of
        # a target step — the lite/core latency ratio) and emits
        # mean_accept_len tokens (expected accepted prefix + bonus under
        # per-token acceptance rate spec_accept), so scheduler
        # simulations see the same target-steps-per-token reduction the
        # real SpeculativeDecoder delivers. Decoded TEXT is unchanged.
        self.speculative = speculative
        self.draft_k = draft_k
        self.spec_accept = spec_accept
        self.spec_draft_cost = spec_draft_cost
        # paged-KV ACCOUNTING (the sim models latency, not tensors): load
        # is reported in allocated blocks — block-quantized resident
        # tokens with shared instruction prefixes counted once — matching
        # the real engine's block-based occupancy. num_blocks>0 also
        # enables kv_free_blocks() for router backpressure.
        self.paged = paged
        self.block_size = block_size
        self.num_blocks = num_blocks
        self.pf_tok = prefill_ms_per_tok
        self.pf_setup = prefill_setup
        self.dec_step = decode_ms_per_step
        self.dec_extra = decode_ms_per_extra_seq
        self.bf = batch_factor
        self.stream_chunk = stream_chunk
        self.states: Dict[str, dict] = {}
        self.prefix_cache: Dict[str, dict] = {}
        self.use_prefix_cache = False      # enabled by LlamaDistPC
        self._lock = threading.Lock()
        self.stats = {"prefill_tokens": 0, "decode_tokens": 0, "calls": 0,
                      "decode_iters": 0, "busy_ms": 0.0,
                      "radix_hit_tokens": 0,
                      "migrations_in": 0, "migrated_blocks": 0}
        self._stats_lock = threading.Lock()
        self._decode_loop = None
        # fault tolerance: injector hook + replica health (see LLMEngine)
        self.faults = None
        self.health = "healthy"
        # SLO scheduling policy (attached per replica by slo.attach_slo;
        # None keeps every scheduling path byte-identical)
        self.slo = None

    def _fault(self, point: str):
        inj = self.faults
        if inj is not None:
            inj.fire(self, point)

    def clone(self, idx: int = 1) -> "SimLLMEngine":
        """Pool replica: same latency profile and SHARED instruction-prefix
        cache (weights-equivalent), PER-REPLICA sequence store and stats."""
        c = SimLLMEngine(
            f"{self.name}.r{idx}", max_batch=self.max_batch,
            prefill_ms_per_tok=self.pf_tok, prefill_setup=self.pf_setup,
            decode_ms_per_step=self.dec_step,
            decode_ms_per_extra_seq=self.dec_extra, batch_factor=self.bf,
            stream_chunk=self.stream_chunk, paged=self.paged,
            block_size=self.block_size, num_blocks=self.num_blocks,
            speculative=self.speculative, draft_k=self.draft_k,
            spec_accept=self.spec_accept,
            spec_draft_cost=self.spec_draft_cost,
            chunked_prefill=self.chunked_prefill,
            prefill_chunk=self.prefill_chunk,
            token_budget=self.token_budget,
            prefix_cache=self.prefix_cache_mode,
            migrate_ms_per_block=self.migrate_ms_per_block)
        c.prefix_cache = self.prefix_cache
        c.use_prefix_cache = self.use_prefix_cache
        return c

    # -- sequence migration (disaggregated prefill/decode handoff) ----------
    def export_seq(self, sid: str) -> dict:
        """Sim form of ``LLMEngine.export_seq``: snapshot the sequence
        for adoption by another replica. The state stays resident here
        until the import lands."""
        job = None
        loop = self._decode_loop
        if loop is not None and loop.is_alive():
            job = loop.detach_prefill(sid)
        with self._lock:
            st = self.states[sid]
        return {"sid": sid, "engine": self, "state": st,
                "paged": self.paged, "block_size": self.block_size,
                "job": job}

    def import_seq(self, handle):
        """Sim form of ``LLMEngine.import_seq``: adopt the sequence and
        charge the modeled block-transfer cost (the staging copy of
        ``migrate_blocks``) on the CALLER's thread — the scheduler pays
        it, the destination decode loop keeps iterating. Returns the
        continuation PrefillJob for a mid-flight prompt, else None."""
        self._fault("migrate")
        src, sid = handle["engine"], handle["sid"]
        st = handle["state"]
        job = handle.get("job")
        if src is self:
            if job is not None and job.remaining() \
                    and not job.done.is_set():
                return self.start_decode_loop().submit_prefill(job)
            return None
        blocks = -(-st.get("pos", 0) // self.block_size)
        _sleep(self.migrate_ms_per_block * blocks)
        with self._lock:
            new_st = dict(st)
            self.states[sid] = new_st
        src.release(sid)
        with self._stats_lock:
            self.stats["migrations_in"] += 1
            self.stats["migrated_blocks"] += blocks
        if job is not None and job.remaining() and not job.done.is_set():
            pending = job.tokens[job.cursor:]

            def _done(cont):
                job.t_done = time.time()
                job.error = cont.error
                job.done.set()

            cont = PrefillJob(sid, new_st, pending, on_done=_done,
                              ptoks=job.ptoks)
            return self.start_decode_loop().submit_prefill(cont)
        return None

    def mean_accept_len(self) -> float:
        """Expected tokens emitted per target verification step: the
        accepted draft prefix (geometric under per-token rate p) plus
        the bonus token — 1 + p + p^2 + ... + p^k."""
        return 1.0 + sum(self.spec_accept ** i
                         for i in range(1, self.draft_k + 1))

    def _spec_step_ms(self, b: int) -> float:
        """Modeled cost of ONE speculative iteration at batch size b:
        the target verify forward plus draft_k draft-model steps."""
        return (self.dec_step * (1.0 + self.draft_k * self.spec_draft_cost)
                + self.dec_extra * (b - 1))

    def kv_blocks(self) -> int:
        """Allocated-block count: per-sequence positions block-quantized,
        plus the shared instruction prefixes ONCE (their tokens are
        excluded from forked sequences' pos by op_prefill). In radix
        mode the tree's chunk set IS the shared capacity (each member is
        one cached block); sequences count only their uncached tails."""
        bs = self.block_size
        with self._lock:
            if self.prefix_cache_mode == "radix":
                # pos already excludes skipped (cached) prefix tokens —
                # each sequence contributes only its uncached tail
                blocks = sum(-(-st.get("pos", 0) // bs)
                             for st in self.states.values())
                return blocks + len(self._radix_chunks)
            blocks = sum(-(-st.get("pos", 0) // bs)
                         for st in self.states.values())
            blocks += sum(-(-st.get("pos", 0) // bs)
                          for st in self.prefix_cache.values())
        return blocks

    def kv_free_blocks(self):
        """Free pool blocks (None when no pool bound — dense accounting
        or unbounded sim)."""
        if not self.paged or not self.num_blocks:
            return None
        return max(0, self.num_blocks - self.kv_blocks())

    def kv_occupancy(self) -> int:
        """Resident KV tokens on this replica (pool-router load input).
        Paged accounting reports block-quantized true memory."""
        if self.paged:
            return self.kv_blocks() * self.block_size
        with self._lock:
            return sum(st.get("pos", 0) for st in self.states.values())

    def _ntok(self, text: str) -> int:
        return max(1, len(text.split()))

    def _radix_match_locked(self, words) -> int:
        """Longest cached block-aligned word prefix, capped at len-1
        (self._lock held). Returns matched word count."""
        bs = self.block_size
        kmax = max(0, (len(words) - 1)) // bs
        m = 0
        for k in range(1, kmax + 1):
            if tuple(words[:k * bs]) in self._radix_chunks:
                m = k * bs
            else:
                break
        return m

    def _radix_insert_locked(self, words):
        """Remember every block-aligned prefix of ``words`` (the modeled
        insert: one set member per cached tree block)."""
        bs = self.block_size
        for k in range(1, len(words) // bs + 1):
            self._radix_chunks.add(tuple(words[:k * bs]))

    def _prefill_task_len(self, t) -> tuple:
        """(state, effective prompt tokens) for one prefill task —
        instruction-prefix reuse skips cached prefix tokens exactly like
        the batch path; radix mode generalizes the skip to ANY cached
        block-aligned prompt prefix and remembers this prompt's."""
        text = t["text"]
        n = self._ntok(text)
        m = 0
        with self._lock:
            fresh = t["sid"] not in self.states
            st = self.states.setdefault(t["sid"], {"pos": 0})
            if fresh and self.prefix_cache_mode == "radix":
                words = text.split() or [text]
                m = self._radix_match_locked(words)
                self._radix_insert_locked(words)
                if m:
                    n = max(1, n - m)
            elif fresh and self.use_prefix_cache:
                # instruction-prefix KV reuse: skip cached prefix tokens
                for instr in self.prefix_cache:
                    if text.startswith(instr):
                        n = max(1, n - self._ntok(instr))
                        break
        if m:
            with self._stats_lock:
                self.stats["radix_hit_tokens"] += m
        return st, n

    def op_prefill(self, tasks):
        self._fault("prefill")
        if self.chunked_prefill:
            # stream every prompt through the loop's prefill queue (the
            # scheduler thread blocks; co-resident decodes keep ticking)
            jobs = [self.submit_prefill(t) for t in tasks]
            for job in jobs:
                job.wait(300)
            return [None] * len(tasks)
        toks = []
        for t in tasks:
            st, n = self._prefill_task_len(t)
            st["pos"] = st.get("pos", 0) + n
            toks.append(n)
        b = len(tasks)
        dur = self.pf_setup + self.pf_tok * sum(toks) * \
            (self.bf if b > 1 else 1.0)
        _sleep(dur)
        with self._stats_lock:
            self.stats["prefill_tokens"] += sum(toks)
            self.stats["calls"] += 1
            self.stats["busy_ms"] += dur
        return [None] * b

    def submit_prefill(self, task, on_done=None) -> PrefillJob:
        """Chunked-prefill admission into the continuous loop (sim form
        of ``LLMEngine.submit_prefill``): the job's cursor advances
        prefill_chunk tokens per mixed pass with modeled chunk cost."""
        if not self.chunked_prefill:
            raise RuntimeError(f"{self.name}: chunked_prefill is disabled")
        st, n = self._prefill_task_len(task)
        job = PrefillJob(task["sid"], st, list(range(n)), on_done=on_done,
                         slo=task.get("slo"))
        return self.start_decode_loop().submit_prefill(job)

    def decode_token_cost(self, seqs) -> int:
        """Loop token-budget input: speculative passes carry k+1 query
        tokens per sequence, plain passes one."""
        return len(seqs) * (self.draft_k + 1 if self.speculative else 1)

    def mixed_iteration(self, seqs, pitems):
        """One mixed pass: the resident decode batch advances first,
        then the pass's prefill chunks land with the monolithic-prefill
        cost formula applied per pass (per-call setup + per-token cost —
        the decomposition overhead Table 3 measures)."""
        if seqs:
            self.decode_iteration(seqs)
        if not pitems:
            return
        self._fault("prefill")
        ntok = sum(n for _, n in pitems)
        dur = self.pf_setup + self.pf_tok * ntok * \
            (self.bf if len(pitems) > 1 else 1.0)
        _sleep(dur)
        for job, n in pitems:
            job.state["pos"] = job.state.get("pos", 0) + n
            job.cursor += n
        with self._stats_lock:
            self.stats["prefill_tokens"] += ntok
            self.stats["calls"] += 1
            self.stats["busy_ms"] += dur

    def op_decode(self, tasks, on_chunk=None):
        self._fault("decode")
        n_max = max(int(t["max_new"]) for t in tasks)
        b = len(tasks)
        if self.speculative:
            # ceil(n / mean_accept_len) target steps, each carrying the
            # draft cost — the run-to-completion speculative latency
            steps = int(np.ceil(n_max / self.mean_accept_len()))
            dur = steps * self._spec_step_ms(b)
        else:
            dur = n_max * (self.dec_step + self.dec_extra * (b - 1))
        if on_chunk is None:
            _sleep(dur)
            out = []
            for t in tasks:
                st = self.states.setdefault(t["sid"], {"pos": 0})
                st["pos"] += int(t["max_new"])
                out.append(_ptext(t["sid"] + str(st["pos"]),
                                  int(t["max_new"])))
        else:
            # streaming: the final text is determined up front (the sim has
            # no real sampling); the modeled decode time is spent in
            # per-chunk slices, each emitting the words "decoded" so far
            out, words = [], []
            for t in tasks:
                st = self.states.setdefault(t["sid"], {"pos": 0})
                st["pos"] += int(t["max_new"])
                text = _ptext(t["sid"] + str(st["pos"]), int(t["max_new"]))
                out.append(text)
                words.append(text.split())
            step = 0
            while step < n_max:
                nsteps = min(self.stream_chunk, n_max - step)
                _sleep(dur * nsteps / n_max)
                step += nsteps
                for i, t in enumerate(tasks):
                    m = min(step, int(t["max_new"]))
                    if m > 0:
                        on_chunk(i, " ".join(words[i][:m]))
        with self._stats_lock:
            self.stats["decode_tokens"] += sum(int(t["max_new"])
                                               for t in tasks)
            self.stats["calls"] += 1
            self.stats["busy_ms"] += dur
        return out

    # -- iteration-level continuous batching --------------------------------
    # (loop lifecycle — start/stop/slots — comes from DecodeLoopMixin)
    def submit_decode(self, sid: str, max_new: int, on_text=None,
                      on_done=None, slo=None) -> DecodeSeq:
        """Admit `sid` into the continuous decode loop. The sim has no
        real sampling, so the final text is fixed at submit time exactly
        as the legacy path fixes it (same state/pos advance — continuous
        and run-to-completion decode produce identical text); the modeled
        decode TIME is spent iteration by iteration with per-iteration
        word release."""
        max_new = int(max_new)
        with self._lock:
            st = self.states.setdefault(sid, {"pos": 0})
            st["pos"] += max_new
            text = _ptext(sid + str(st["pos"]), max_new)
        seq = DecodeSeq(sid, st, max_new,
                        text_fn=lambda s: " ".join(s.tokens),
                        on_text=on_text, on_done=on_done, slo=slo)
        seq.words = text.split()
        return self.start_decode_loop().submit(seq)

    # -- SLO preemption (sim form): the output words are fixed at submit
    # time, so evict-to-recompute only has to model the MEMORY release
    # and the replay cost — token identity is free by construction.
    def can_preempt(self, seq) -> bool:
        return True

    def preempt_decode(self, seq):
        """Free the sequence's modeled KV (pos → 0 releases its blocks
        from kv_blocks/kv_free_blocks accounting); the loop re-queues
        the DecodeSeq with its emitted words intact."""
        with self._lock:
            seq._slo_saved_pos = seq.state.get("pos", 0)
            seq.state["pos"] = 0
        seq.slo_preempted = True

    def _slo_resume(self, seq):
        """Charge the replay prefill (recorded prompt + emitted tokens —
        what the real engine re-prefills) and restore the position."""
        seq.slo_preempted = False
        with self._lock:
            seq.state["pos"] = getattr(seq, "_slo_saved_pos", 0)
        # saved pos pre-charged the whole decode; resident at preemption
        # was prompt + steps
        replay = max(1, getattr(seq, "_slo_saved_pos", seq.n)
                     - seq.n + seq.steps)
        dur = self.pf_setup + self.pf_tok * replay
        _sleep(dur)
        with self._stats_lock:
            self.stats["prefill_tokens"] += replay
            self.stats["calls"] += 1
            self.stats["busy_ms"] += dur

    def tenant_stats(self) -> dict:
        """Per-(tenant, class) scheduling stats (empty unless armed)."""
        return self.slo.tenant_stats() if self.slo is not None else {}

    def recover_decode(self, sid: str, text: str, max_new: int,
                       failed=None, on_text=None, on_done=None,
                       slo=None) -> DecodeSeq:
        """Sim form of ``LLMEngine.recover_decode``: replay a sequence
        lost on a dead replica. The replay prefill's modeled cost is
        charged on the caller's thread (recovery latency is visible to
        scheduler studies); the dead replica's fixed output words are
        REUSED when its DecodeSeq handle survives — the sim's text
        depends on submit-time state, so regenerating it here would not
        be output-identical — and only the remaining words' decode time
        is spent."""
        max_new = int(max_new)
        self.release(sid)
        st, n = self._prefill_task_len({"sid": sid, "text": text})
        dur = self.pf_setup + self.pf_tok * n
        _sleep(dur)
        with self._lock:
            st["pos"] = st.get("pos", 0) + n + max_new
            if failed is not None and getattr(failed, "words", None):
                words = list(failed.words)
            else:
                words = _ptext(sid + str(st["pos"]), max_new).split()
        with self._stats_lock:
            self.stats["prefill_tokens"] += n
            self.stats["calls"] += 1
            self.stats["busy_ms"] += dur
        seq = DecodeSeq(sid, st, max_new,
                        text_fn=lambda s: " ".join(s.tokens),
                        on_text=on_text, on_done=on_done, slo=slo)
        seq.words = words
        emitted = list(getattr(failed, "tokens", [])) if failed is not None \
            else []
        seq.tokens = emitted[:max_new]
        seq.steps = len(seq.tokens)
        if seq.steps >= seq.n:
            seq.result = " ".join(seq.tokens)
            seq.t_done = time.time()
            seq.done.set()
            if on_done is not None:
                on_done(seq)
            return seq
        return self.start_decode_loop().submit(seq)

    def decode_iteration(self, seqs):
        """One modeled decode step for the resident batch: per-iteration
        latency depends on the CURRENT batch size (the iteration-level
        analogue of the legacy per-batch formula). In speculative mode
        the step carries the draft cost and releases mean_accept_len
        tokens per sequence (error-diffused to integers so long runs hit
        the mean exactly) — the loop advances each sequence by the
        emitted count, exactly like the real SpeculativeDecoder."""
        self._fault("decode")
        if self.slo is not None:
            for r in seqs:
                if getattr(r, "slo_preempted", False):
                    self._slo_resume(r)
        b = len(seqs)
        emitted = 0
        if self.speculative:
            dur = self._spec_step_ms(b)
            _sleep(dur)
            for r in seqs:
                carry = getattr(r, "spec_carry", 0.0) + self.mean_accept_len()
                emit = max(1, int(carry))
                r.spec_carry = carry - emit
                for _ in range(emit):
                    if len(r.tokens) < len(r.words):
                        r.tokens.append(r.words[len(r.tokens)])
                        emitted += 1
        else:
            dur = self.dec_step + self.dec_extra * (b - 1)
            _sleep(dur)
            for r in seqs:
                if len(r.tokens) < len(r.words):
                    r.tokens.append(r.words[len(r.tokens)])
            emitted = b
        with self._stats_lock:
            self.stats["decode_tokens"] += emitted
            self.stats["decode_iters"] += 1
            self.stats["busy_ms"] += dur

    def prefix_match_len(self, text: str) -> int:
        """Longest radix-cached word prefix of ``text`` (0 without the
        radix cache) — the pool router's prefix-affinity probe."""
        if self.prefix_cache_mode != "radix":
            return 0
        words = text.split() or [text]
        with self._lock:
            return self._radix_match_locked(words)

    def get_prefix_state(self, instruction: str):
        with self._lock:
            st = self.prefix_cache.get(instruction)
            if st is None:
                st = {"pos": self._ntok(instruction)}
                self.prefix_cache[instruction] = st
            if self.prefix_cache_mode == "radix":
                # warmup seeds the modeled tree too (cold/warm replica
                # symmetry, like the real engine)
                self._radix_insert_locked(instruction.split()
                                          or [instruction])
        return st

    def release(self, sid: str):
        with self._lock:
            self.states.pop(sid, None)


class SimEmbeddingEngine:
    kind = "embedding"

    def __init__(self, name="embedding", max_batch: int = 16,
                 setup_ms: float = 50.0, per_req_ms: float = 25.0):
        self.name = name
        self.max_batch = max_batch
        self.setup = setup_ms
        self.per_req = per_req_ms
        self.stats = {"requests": 0, "calls": 0, "busy_ms": 0.0}
        # fault tolerance / overload: injector hook + replica health so
        # pooled encoders participate in burst studies and hedging
        self.faults = None
        self.health = "healthy"

    def _fault(self, point: str):
        inj = self.faults
        if inj is not None:
            inj.fire(self, point)

    def clone(self, idx: int = 1) -> "SimEmbeddingEngine":
        return SimEmbeddingEngine(f"{self.name}.r{idx}", self.max_batch,
                                  self.setup, self.per_req)

    def op_embed(self, tasks):
        self._fault("encode")
        n = sum(len(t["texts"]) for t in tasks)
        # setup cost per underlying model call (ceil(n/max_batch) calls)
        dur = self.setup * max(1, -(-n // self.max_batch)) + self.per_req * n
        _sleep(dur)
        out = []
        for t in tasks:
            out.append(np.stack([_hvec(x) for x in t["texts"]])
                       if t["texts"] else np.zeros((0, 64), np.float32))
        self.stats["requests"] += n
        self.stats["calls"] += 1
        self.stats["busy_ms"] += dur
        return out


class SimRerankEngine:
    kind = "rerank"

    def __init__(self, name="rerank", max_batch: int = 16,
                 setup_ms: float = 40.0, per_pair_ms: float = 18.0):
        self.name = name
        self.max_batch = max_batch
        self.setup = setup_ms
        self.per_pair = per_pair_ms
        self.stats = {"requests": 0, "calls": 0, "busy_ms": 0.0}
        self.faults = None
        self.health = "healthy"

    def _fault(self, point: str):
        inj = self.faults
        if inj is not None:
            inj.fire(self, point)

    def clone(self, idx: int = 1) -> "SimRerankEngine":
        return SimRerankEngine(f"{self.name}.r{idx}", self.max_batch,
                               self.setup, self.per_pair)

    def op_rerank(self, tasks):
        self._fault("encode")
        n = sum(len(t["candidates"]) for t in tasks)
        dur = self.setup * max(1, -(-n // self.max_batch)) + self.per_pair * n
        _sleep(dur)
        out = []
        for t in tasks:
            cands = t["candidates"]
            if not cands:
                out.append([])
                continue
            qv = _hvec(t["question"])
            scores = [float(qv @ _hvec(c["text"])) for c in cands]
            order = np.argsort(scores)[::-1][: t.get("top_k", 3)]
            out.append([{**cands[i], "rerank_score": scores[i]}
                        for i in order])
        self.stats["requests"] += n
        self.stats["calls"] += 1
        self.stats["busy_ms"] += dur
        return out


class SimVectorDB(VectorDBEngine):
    def __init__(self, name="vectordb"):
        super().__init__(name, max_batch=64,
                         ingest_latency_per_vec=0.004 / SPEED,
                         search_latency=0.010 / SPEED)


class SimSearchAPI(SearchAPIEngine):
    def __init__(self, name="search_api"):
        super().__init__(name, max_batch=4, latency=0.18 / SPEED)


def build_sim_engines(*, llm_max_batch: int = 8, core_decode_ms: float = 25.0,
                      lite_scale: float = 0.25,
                      llm_instances: int = 1,
                      paged_kv: bool = False,
                      kv_block_size: int = 16,
                      speculative: bool = False,
                      draft_k: int = 4,
                      chunked_prefill: bool = False,
                      prefill_chunk: int = 128,
                      token_budget=None,
                      prefix_cache: str = "none",
                      disaggregate: bool = False,
                      prefill_replicas: int = 1,
                      decode_replicas: int = 1,
                      encoder_instances: int = 1) -> dict:
    """Engine set with paper-calibrated profiles. lite_llm (gemma-2-2B
    contextualizer / llama-7B judge) is ~4x faster than the core LLM.
    llm_instances>1 puts the LLM engines behind EnginePools (the paper's
    testbed provisions two instances per LLM); the pooled lower-tier
    scheduler routes fused batches to the least-loaded replica with
    sequence affinity. ``speculative`` switches the CORE LLM to
    draft-verify step accounting (drafted on the co-located lite profile:
    spec_draft_cost = lite_scale). ``disaggregate`` puts each LLM behind
    a DisaggregatedEnginePool of prefill_replicas prefill specialists +
    decode_replicas decode specialists with modeled KV-handoff cost
    (mutually exclusive with llm_instances > 1). ``encoder_instances>1``
    pools the embedding/rerank encoders too — the substrate hedged
    dispatch needs for backup requests."""
    from repro.core.engine_pool import DisaggregatedEnginePool, EnginePool

    core = SimLLMEngine("core_llm", max_batch=llm_max_batch,
                        decode_ms_per_step=core_decode_ms,
                        paged=paged_kv, block_size=kv_block_size,
                        speculative=speculative, draft_k=draft_k,
                        spec_draft_cost=lite_scale,
                        chunked_prefill=chunked_prefill,
                        prefill_chunk=prefill_chunk,
                        token_budget=token_budget,
                        prefix_cache=prefix_cache)
    lite = SimLLMEngine(
        "lite_llm", max_batch=llm_max_batch * 2,
        prefill_ms_per_tok=0.235 * lite_scale,
        prefill_setup=8,
        decode_ms_per_step=core_decode_ms * lite_scale,
        decode_ms_per_extra_seq=0.5,
        paged=paged_kv, block_size=kv_block_size,
        chunked_prefill=chunked_prefill,
        prefill_chunk=prefill_chunk,
        token_budget=token_budget,
        prefix_cache=prefix_cache)

    if disaggregate:
        if llm_instances > 1:
            raise ValueError(
                "disaggregate and llm_instances > 1 are mutually "
                "exclusive (replica counts come from prefill_replicas/"
                "decode_replicas)")
        core = DisaggregatedEnginePool.disaggregate(
            core, prefill_replicas, decode_replicas, name="core_llm")
        lite = DisaggregatedEnginePool.disaggregate(
            lite, prefill_replicas, decode_replicas, name="lite_llm")
    n = llm_instances
    if n > 1:
        core = EnginePool.replicate(core, n, name="core_llm")
        lite = EnginePool.replicate(lite, n, name="lite_llm")
    embedding = SimEmbeddingEngine()
    rerank = SimRerankEngine()
    if encoder_instances > 1:
        # pooled encoders: the hedged-dispatch substrate (a backup embed/
        # rerank needs a second healthy replica to land on)
        embedding = EnginePool.replicate(embedding, encoder_instances,
                                         name="embedding")
        rerank = EnginePool.replicate(rerank, encoder_instances,
                                      name="rerank")
    return {
        "core_llm": core,
        "lite_llm": lite,
        "embedding": embedding,
        "rerank": rerank,
        "vectordb": SimVectorDB(),
        "chunker": ChunkerEngine(),
        "search_api": SimSearchAPI(),
    }
