"""Deterministic word-level tokenizer (hash-bucketed, reversible for any
word seen during encoding)."""
from __future__ import annotations

import hashlib
import threading


class HashTokenizer:
    def __init__(self, vocab_size: int = 4096, reserved: int = 8):
        self.vocab = vocab_size
        self.reserved = reserved       # 0=pad 1=bos 2=eos 3=sep ...
        self._inv = {}
        self._lock = threading.Lock()

    def _wid(self, w: str) -> int:
        h = int.from_bytes(hashlib.md5(w.encode()).digest()[:4], "little")
        tid = self.reserved + h % (self.vocab - self.reserved)
        with self._lock:
            self._inv.setdefault(tid, w)
        return tid

    def encode(self, text: str):
        return [self._wid(w) for w in text.split()]

    def decode(self, ids):
        return " ".join(self._inv.get(int(i), f"<{int(i)}>") for i in ids
                        if int(i) >= self.reserved)

    SEP = 3
