"""Model-free engines (CPU): vector DB, chunker, search-API stub.

All three support ``clone()`` so they can sit behind an EnginePool: the
vector DB's replicas SHARE the collection store (an ingest on one replica
is visible to a search on another — the pool models extra query
parallelism over one index, not a sharded index); chunker and search-API
replicas are stateless."""
from __future__ import annotations

import copy
import threading
import time
from typing import Dict

import numpy as np


class VectorDBEngine:
    """Exact cosine top-k vector store (pgvector stand-in). Collections are
    per-query (the RAG workflows ingest the user's docs per request)."""
    kind = "vectordb"

    def __init__(self, name: str = "vectordb", max_batch: int = 64,
                 ingest_latency_per_vec: float = 0.0002,
                 search_latency: float = 0.002):
        self.name = name
        self.max_batch = max_batch
        self._store: Dict[str, list] = {}
        self._lock = threading.Lock()
        self.ingest_lat = ingest_latency_per_vec
        self.search_lat = search_latency

    def op_ingest(self, tasks):
        for t in tasks:
            vecs, meta = t["vectors"], t["meta"]
            with self._lock:
                col = self._store.setdefault(t["collection"], [])
                for v, m in zip(vecs, meta):
                    col.append((np.asarray(v, np.float32), m))
            time.sleep(self.ingest_lat * len(vecs))
        return [True] * len(tasks)

    def op_search(self, tasks):
        out = []
        for t in tasks:
            with self._lock:
                col = list(self._store.get(t["collection"], []))
            time.sleep(self.search_lat)
            if not col:
                out.append([])
                continue
            mat = np.stack([v for v, _ in col])
            q = np.asarray(t["query_vec"], np.float32)
            sims = mat @ q / (np.linalg.norm(mat, axis=1)
                              * np.linalg.norm(q) + 1e-9)
            top = np.argsort(-sims)[: t.get("top_k", 3)]
            out.append([{**col[i][1], "score": float(sims[i])}
                        for i in top])
        return out

    def drop(self, collection: str):
        with self._lock:
            self._store.pop(collection, None)

    def clone(self, idx: int = 1):
        c = copy.copy(self)             # shares _store and _lock
        c.name = f"{self.name}.r{idx}"
        return c


class ChunkerEngine:
    """Word-window chunker (LlamaIndex text-splitter stand-in)."""
    kind = "chunker"

    def __init__(self, name: str = "chunker", max_batch: int = 8):
        self.name = name
        self.max_batch = max_batch

    def clone(self, idx: int = 1):
        c = copy.copy(self)
        c.name = f"{self.name}.r{idx}"
        return c

    @staticmethod
    def count_chunks(docs, chunk_size=48, overlap=8) -> int:
        n = 0
        step = max(1, chunk_size - overlap)
        for doc in docs:
            w = len(doc["text"].split())
            n += len(range(0, max(1, w - overlap), step))
        return n

    def op_chunk(self, tasks):
        out = []
        for t in tasks:
            words_per = t.get("chunk_size", 48)
            overlap = t.get("overlap", 8)
            chunks = []
            for doc in t["docs"]:
                w = doc["text"].split()
                step = max(1, words_per - overlap)
                for i in range(0, max(1, len(w) - overlap), step):
                    piece = " ".join(w[i:i + words_per])
                    if piece:
                        chunks.append({"doc_id": doc["id"], "text": piece})
            out.append(chunks)
        return out


class SearchAPIEngine:
    """Web-search stub (offline container): deterministic results with a
    network-latency model. The one permitted non-modality stub, DESIGN.md."""
    kind = "search_api"

    def __init__(self, name: str = "search_api", max_batch: int = 4,
                 latency: float = 0.05):
        self.name = name
        self.max_batch = max_batch
        self.latency = latency

    def clone(self, idx: int = 1):
        c = copy.copy(self)
        c.name = f"{self.name}.r{idx}"
        return c

    def op_search(self, tasks):
        time.sleep(self.latency)   # one batched API round-trip
        out = []
        for t in tasks:
            q = t["question"]
            out.append([{"doc_id": f"web{i}",
                         "text": f"web result {i} for {q}"}
                        for i in range(t.get("top_k", 4))])
        return out
