"""Speculative decoding: draft–verify engine pairing.

Teola's end-to-end breakdown shows the core-LLM generation primitive
dominating application latency even after graph-level parallelization,
and the app pool already co-locates a cheap ``lite_llm`` next to
``core_llm``. Speculative decoding turns that co-location into raw
decode speed: a DRAFTER proposes k tokens per iteration and the target
verifies all of them in ONE multi-position forward pass
(``LLMEngine.spec_verify`` — q_len = k+1 with a causal intra-chunk mask;
Pallas kernel ``kernels/decode_attention.py::verify_attention`` on the
paged path), accepting the longest prefix that matches the target's own
greedy choices plus one bonus token from the first disagreeing position.

Correctness contract: greedy speculative output is TOKEN-IDENTICAL to
baseline greedy decode — every emitted token is an argmax of the target
model's logits given exactly the baseline prefix, so acceptance only
changes how many target forwards are spent, never what is generated.
Rejected draft tokens are rolled back by NOT advancing ``pos`` past the
accepted prefix (stale KV beyond ``pos`` is masked and overwritten by
the next chunk); on the paged path overshoot blocks are additionally
trimmed back to the pool (``kv_cache.trim_table``) so rejections never
hold memory.

Two drafters:

  ``PromptLookupDrafter`` — model-free n-gram prompt lookup: match the
      tail of the token context against earlier context and propose the
      continuation (free to run, wins on repetitive/extractive text).
      Always available; also the automatic fallback when an engine
      drafter cannot serve a sequence.
  ``EngineDrafter``      — a real draft ``LLMEngine`` (e.g. the pooled
      ``lite_llm`` replica co-located with the target replica — see
      ``engine_pool.pair_replicas``). Mirrors each target sequence on
      the draft engine (same tokenizer family + vocab => identical token
      ids), proposes k greedy draft steps per iteration, and is re-synced
      to the accepted prefix after every verification.

``attach_speculative`` wires a built engine set: every target replica is
paired with its index-aligned draft replica (co-location) or the
model-free drafter, surfaced as ``serve.py --speculative --draft-k``.
"""
from __future__ import annotations

import threading
import time
from typing import Dict, List, Optional


class PromptLookupDrafter:
    """Model-free prompt-lookup (n-gram) drafter.

    Finds the most recent earlier occurrence of the context's trailing
    m-gram (longest m first) and proposes the k tokens that followed it;
    with no match it repeats the last token (a guess is free — wrong
    drafts cost nothing but the already-paid verify slot)."""

    kind = "ngram"

    def __init__(self, max_ngram: int = 3):
        self.max_ngram = int(max_ngram)

    def propose(self, ctx: List[int], k: int) -> List[int]:
        n = len(ctx)
        if n == 0:
            return [1] * k
        for m in range(min(self.max_ngram, n - 1), 0, -1):
            pat = ctx[n - m:]
            for start in range(n - m - 1, -1, -1):
                if ctx[start:start + m] == pat:
                    cont = ctx[start + m:start + m + k]
                    if cont:
                        return (cont + [cont[-1]] * k)[:k]
        return [ctx[-1]] * k


class EngineDrafter:
    """Draft-model proposals from a real ``LLMEngine``.

    Maintains a MIRROR sequence per target sid on the draft engine
    (created/extended from the target's prefilled tokens — same hash
    tokenizer + vocab, so token ids transfer verbatim). ``propose`` runs
    k greedy draft steps; ``sync`` rolls the mirror back to the accepted
    prefix (filling any position the draft never wrote when the whole
    chunk was accepted). Draft-side failures (pool exhaustion, capacity)
    drop the mirror and return None — the decoder falls back to prompt
    lookup, never failing the target decode."""

    kind = "engine"

    def __init__(self, engine):
        self.engine = engine
        self._lock = threading.Lock()

    def _drop(self, sid: str):
        try:
            self.engine.release(sid)
        except Exception:  # noqa: BLE001 — cleanup must never propagate
            pass

    def extend(self, sid: str, tokens: List[int], last_token: int):
        """Mirror a target prefill: write `tokens` onto the draft
        sequence and adopt the target's next-token prediction."""
        eng = self.engine
        with self._lock:
            with eng._lock:
                st = eng.states.get(sid)
                if st is None:
                    st = eng.new_state()
                    eng.states[sid] = st
            try:
                toks = list(tokens)[: eng.max_len - st.pos - 8]
                if toks:
                    eng.prefill_batch([(st, toks)])
            except Exception:  # noqa: BLE001 — degrade to prompt lookup
                self._drop(sid)
                return
            st.last_token = int(last_token)

    def propose(self, sid: str, k: int) -> Optional[List[int]]:
        eng = self.engine
        with self._lock:
            st = eng.states.get(sid)
            if st is None:
                return None
            kd = min(k, eng.max_len - st.pos)
            if kd < 1:
                return None
            try:
                out = eng._decode_batch_base([(st, kd)])[0]
            except Exception:  # noqa: BLE001 — degrade to prompt lookup
                self._drop(sid)
                return None
            return (out + [out[-1]] * k)[:k]

    def sync(self, sid: str, base_pos: int, chunk: List[int], pos_t: int,
             last_token: int):
        """Re-align the mirror with the target after verification:
        ``chunk[j]`` is the token at absolute position ``base_pos + j``;
        the target now stands at ``pos_t`` with ``last_token`` pending.
        Rolls the draft back past rejected positions, or fills positions
        it never wrote (full acceptance ran past the draft's own k)."""
        eng = self.engine
        with self._lock:
            st = eng.states.get(sid)
            if st is None:
                return
            if st.pos < base_pos or pos_t < base_pos or \
                    pos_t > base_pos + len(chunk):
                # mirror drifted out of the chunk's coverage — rebuild
                # lazily from scratch rather than guessing
                self._drop(sid)
                return
            if st.pos < pos_t:
                fill = chunk[st.pos - base_pos: pos_t - base_pos]
                try:
                    eng.prefill_batch([(st, list(fill))])
                except Exception:  # noqa: BLE001
                    self._drop(sid)
                    return
            st.pos = pos_t
            st.last_token = int(last_token)
            eng.spec_rollback(st)

    def release(self, sid: str):
        with self._lock:
            self._drop(sid)


class SpeculativeDecoder:
    """Pairs a target ``LLMEngine`` with a drafter; owns the
    draft → verify → accept → rollback iteration for both decode paths
    (run-to-completion ``decode_batch`` and the continuous decode loop's
    per-iteration ``decode_iteration``).

    Per iteration and per sequence: draft k tokens, run ONE target
    forward over the (k+1)-token chunk ``[last_token, d1..dk]``
    (``spec_verify``), accept the longest prefix with
    ``d_i == argmax(logits[i-1])``, emit those plus the bonus token
    ``argmax(logits[a])``, advance ``pos`` by the emission only, and trim
    paged overshoot blocks. Stats track target steps vs tokens emitted —
    the acceptance length and steps-per-token the benchmark reports."""

    def __init__(self, target, drafter: Optional[EngineDrafter] = None,
                 k: int = 4, max_ngram: int = 3):
        if k < 1:
            raise ValueError(f"speculative draft_k must be >= 1, got {k}")
        self.target = target
        self.k = int(k)
        self.engine_drafter = drafter
        self.lookup = PromptLookupDrafter(max_ngram)
        self._ctx: Dict[str, List[int]] = {}
        self._sid_by_state: Dict[int, str] = {}
        self._ctx_lock = threading.Lock()
        # target_steps/fallback_steps count target-model FORWARDS (one
        # batched verify/decode call each); seq_steps counts per-SEQUENCE
        # step participations, so tokens_emitted / seq_steps is the mean
        # acceptance length per sequence (batch-size independent)
        self.stats = {"target_steps": 0, "fallback_steps": 0,
                      "seq_steps": 0, "tokens_emitted": 0, "drafted": 0,
                      "accepted": 0}
        self._slock = threading.Lock()

    # -- bookkeeping hooks (called by the target engine) --------------------
    # _ctx invariant: the sid's INPUT-token stream including the pending
    # next input (st.last_token — emitted by the head but not yet fed
    # back). _commit keeps it: the last accepted token IS the new
    # pending input, so extending with `emit` preserves the invariant
    # without ever duplicating the tail token in the lookup corpus.
    def note_prefill(self, sid: str, prefix_tokens: List[int],
                     tokens: List[int]):
        """Record a target prefill: extend the sid's token context (used
        by prompt lookup) and mirror it on the draft engine."""
        st = self.target.states.get(sid)
        with self._ctx_lock:
            ctx = self._ctx.setdefault(sid, [])
            fresh = not ctx
            if ctx:
                # a continuation prefill overwrites the position the old
                # pending prediction would have occupied — drop it, as
                # the engine does
                ctx.pop()
            new = (list(prefix_tokens) if fresh else []) + list(tokens)
            ctx.extend(new)
            if st is not None:
                ctx.append(int(st.last_token))
                self._sid_by_state[id(st)] = sid
        if self.engine_drafter is not None and st is not None:
            self.engine_drafter.extend(sid, new, st.last_token)

    def release(self, sid: str):
        with self._ctx_lock:
            self._ctx.pop(sid, None)
            self._sid_by_state = {i: s for i, s in
                                  self._sid_by_state.items() if s != sid}
        if self.engine_drafter is not None:
            self.engine_drafter.release(sid)

    # -- migration (disaggregated prefill/decode handoff) -------------------
    def export_ctx(self, sid: str) -> Optional[List[int]]:
        """Snapshot the sid's prompt-lookup context for migration to
        another replica's SpeculativeDecoder. None when untracked —
        drafting from an empty context is token-identical-safe (greedy
        acceptance never depends on draft quality), just less effective."""
        with self._ctx_lock:
            ctx = self._ctx.get(sid)
            return list(ctx) if ctx else None

    def import_ctx(self, sid: str, ctx: List[int], state):
        """Adopt a migrated sid's context (the _ctx invariant travels
        intact: the source exported its input stream INCLUDING the
        pending next input) and bind it to the sequence's state object
        on THIS engine. The draft-engine mirror is NOT transferred —
        ``EngineDrafter.propose`` falls back to prompt lookup for
        unmirrored sids."""
        with self._ctx_lock:
            self._ctx[sid] = list(ctx)
            self._sid_by_state[id(state)] = sid

    # -- draft/accept core --------------------------------------------------
    def _propose(self, sid: Optional[str], last_token: int) -> List[int]:
        drafts = None
        if self.engine_drafter is not None and sid is not None:
            drafts = self.engine_drafter.propose(sid, self.k)
        if drafts is None:
            with self._ctx_lock:
                ctx = list(self._ctx.get(sid, ()))
            if not ctx:          # unknown sid: only the pending token
                ctx = [int(last_token)]
            drafts = self.lookup.propose(ctx, self.k)
        with self._slock:
            self.stats["drafted"] += self.k
        return drafts

    @staticmethod
    def _accept(drafts: List[int], preds) -> List[int]:
        """Longest greedy-matching prefix + the bonus token: exactly the
        tokens baseline greedy decode would emit."""
        a = 0
        while a < len(drafts) and int(preds[a]) == drafts[a]:
            a += 1
        return drafts[:a] + [int(preds[a])]

    def _commit(self, st, sid: Optional[str], chunk: List[int],
                emit: List[int], loop_sid: Optional[str] = None):
        base_pos = st.pos
        st.pos += len(emit)
        st.last_token = emit[-1]
        self.target.spec_rollback(st, sid=loop_sid)
        with self._ctx_lock:
            if sid in self._ctx:
                self._ctx[sid].extend(emit)
        if self.engine_drafter is not None and sid is not None:
            self.engine_drafter.sync(sid, base_pos, chunk, st.pos,
                                     st.last_token)
        with self._slock:
            self.stats["tokens_emitted"] += len(emit)
            self.stats["accepted"] += len(emit) - 1

    def _sid_of(self, st) -> Optional[str]:
        with self._ctx_lock:
            return self._sid_by_state.get(id(st))

    # -- run-to-completion path (decode_batch / op_decode) ------------------
    def decode_batch(self, items, on_chunk=None):
        """Speculative replacement for ``LLMEngine.decode_batch``: same
        contract (items = [(state, n)], greedy, returns n tokens per item,
        state advanced by n), fewer target forwards. ``on_chunk`` fires
        with cumulative token ids whenever a sequence grows."""
        eng = self.target
        t0 = time.time()
        outs: List[List[int]] = [[] for _ in items]
        spec_tokens = 0              # fallback rounds count their own
        while True:
            live = [i for i, (st, n) in enumerate(items)
                    if len(outs[i]) < n]
            if not live:
                break
            spec = [i for i in live
                    if items[i][0].pos + self.k + 1 <= eng.max_len]
            rest = [i for i in live if i not in spec]
            if spec:
                chunks = []
                for i in spec:
                    st = items[i][0]
                    drafts = self._propose(self._sid_of(st), st.last_token)
                    chunks.append((st, [int(st.last_token)] + drafts))
                preds = eng.spec_verify(chunks)
                with self._slock:
                    self.stats["target_steps"] += 1
                    self.stats["seq_steps"] += len(spec)
                for i, (st, chunk), pr in zip(spec, chunks, preds):
                    emit = self._accept(chunk[1:], pr)
                    emit = emit[: items[i][1] - len(outs[i])]
                    self._commit(st, self._sid_of(st), chunk, emit)
                    outs[i].extend(emit)
                    spec_tokens += len(emit)
            if rest:
                # no room for a k+1 chunk before max_len: plain one-token
                # steps through the legacy batch path
                prev_last = [int(items[i][0].last_token) for i in rest]
                res = eng._decode_batch_base([(items[i][0], 1)
                                              for i in rest])
                with self._slock:
                    self.stats["fallback_steps"] += 1
                    self.stats["seq_steps"] += len(rest)
                    self.stats["tokens_emitted"] += len(rest)
                for i, lt, r in zip(rest, prev_last, res):
                    st = items[i][0]
                    sid = self._sid_of(st)
                    with self._ctx_lock:
                        if sid in self._ctx:
                            self._ctx[sid].extend(r)
                    if self.engine_drafter is not None and sid is not None:
                        self.engine_drafter.sync(sid, st.pos - 1, [lt],
                                                 st.pos, st.last_token)
                    outs[i].extend(r)
            if on_chunk is not None:
                for i in live:
                    on_chunk(i, outs[i][: items[i][1]])
        with eng._stats_lock:
            # fallback rounds went through _decode_batch_base, which
            # already counted their tokens/busy time
            eng.stats["decode_tokens"] += spec_tokens
            eng.stats["calls"] += 1
            eng.stats["busy_s"] += time.time() - t0
        return outs

    # -- continuous decode loop path ----------------------------------------
    def decode_iteration(self, seqs):
        """One loop pass: verify a drafted chunk for every sequence that
        can take one (>= k+1 tokens of remaining budget — paged admission
        reservations cover exactly the sequence's budget horizon — and
        k+1 slots of physical max_len room); everything else advances one
        token through the legacy iteration. A sequence only ever moves
        spec -> fallback (remaining budget shrinks monotonically), so the
        dense path's persistent batch cache stays coherent."""
        eng = self.target
        k = self.k
        spec, rest = [], []
        for r in seqs:
            remaining = r.n - len(r.tokens)
            if remaining >= k + 1 and r.state.pos + k + 1 <= eng.max_len:
                spec.append(r)
            else:
                rest.append(r)
        if rest:
            eng._decode_iteration_base(rest)
            with self._slock:
                self.stats["fallback_steps"] += 1
                self.stats["seq_steps"] += len(rest)
                self.stats["tokens_emitted"] += len(rest)
        if not spec:
            return
        t0 = time.time()
        chunks = []
        for r in spec:
            drafts = self._propose(r.sid, r.state.last_token)
            chunks.append((r.state, [int(r.state.last_token)] + drafts))
        preds = eng.spec_verify(chunks, loop_sids=[r.sid for r in spec])
        with self._slock:
            self.stats["target_steps"] += 1
            self.stats["seq_steps"] += len(spec)
        emitted = 0
        for r, (st, chunk), pr in zip(spec, chunks, preds):
            emit = self._accept(chunk[1:], pr)
            emit = emit[: r.n - len(r.tokens)]
            self._commit(st, r.sid, chunk, emit, loop_sid=r.sid)
            r.tokens.extend(emit)
            eng.meter.advance(r.sid, len(emit))
            emitted += len(emit)
        with eng._stats_lock:
            eng.stats["decode_tokens"] += emitted
            eng.stats["decode_iters"] += 1
            eng.stats["busy_s"] += time.time() - t0


def attach_speculative(engines: Dict, *, target: str = "core_llm",
                       draft: Optional[str] = "lite_llm", k: int = 4):
    """Enable draft–verify speculative decoding on every replica of the
    target engine/pool. ``draft=None`` uses the model-free prompt-lookup
    drafter; otherwise draft replicas are paired index-aligned with
    target replicas (``engine_pool.pair_replicas``) so each target
    replica drafts on its co-located draft replica."""
    from repro.core.engine_pool import pair_replicas, replicas_of
    tgt = engines[target]
    if draft is None:
        for rep in replicas_of(tgt):
            rep.enable_speculative(draft=None, k=k)
    else:
        for t_rep, d_rep in pair_replicas(tgt, engines[draft]):
            t_rep.enable_speculative(draft=d_rep, k=k)
    return [rep.spec for rep in replicas_of(tgt)]
