"""Global radix-tree prefix cache: tree unit behavior (block-aligned
insert/match/split, LRU leaf eviction, refcount ownership), engine-level
token identity with the cache on vs off (monolithic + chunked prefill),
eviction composing with admission reservations, cold-vs-warm replica
symmetry, prefix-aware pool routing, the sim engine's modeled hit rate,
hypothesis property tests against a dict-of-prefixes oracle, and a
concurrency stress run under eviction pressure."""
import threading
import time

import pytest

from repro.configs.base import get_config
from repro.core.engine_pool import EnginePool
from repro.engines.llm_engine import LLMEngine
from repro.engines.sim_engines import SimLLMEngine
from repro.serving import kv_cache as kvc

CFG = get_config("tiny-lite-llm")
BS = 8                                  # block size used across the file
SHARED = " ".join(f"ctx{i}" for i in range(40))     # 40-token shared prefix


def _tree(num_blocks=64, bs=4):
    alloc = kvc.BlockAllocator(num_blocks)
    return kvc.RadixPrefixCache(alloc, bs), alloc


def _seq_blocks(alloc, n):
    """Allocate n blocks as a live 'sequence table'."""
    return [alloc.alloc() for _ in range(n)]


def _engine(*, radix=True, **kw):
    kw.setdefault("max_len", 256)
    kw.setdefault("max_batch", 4)
    kw.setdefault("block_size", BS)
    return LLMEngine("t", CFG, paged=True,
                     prefix_cache="radix" if radix else "none", **kw)


def _wait(pred, timeout=30.0):
    t0 = time.time()
    while time.time() - t0 < timeout:
        if pred():
            return True
        time.sleep(0.002)
    return False


# ---------------------------------------------------------------------------
# Tree unit behavior (allocator only — no model)

def test_insert_match_roundtrip_and_block_alignment():
    tree, alloc = _tree(bs=4)
    tbl = _seq_blocks(alloc, 3)          # covers 10 tokens at bs=4
    toks = list(range(100, 110))
    added = tree.insert(toks, tbl)
    assert added == 2                    # only the 2 FULL blocks cached
    assert tree.num_blocks() == 2
    # partial tail block stays sequence-owned
    assert alloc.refcount(tbl[2]) == 1
    blocks, m = tree.match_prefix(toks)
    assert m == 8 and blocks == tbl[:2]
    # match increfs on the caller's behalf: seq ref + tree ref + ours
    assert all(alloc.refcount(b) == 3 for b in blocks)
    # matches never cover a partial block
    _, m2 = tree.match_prefix(toks[:7])
    assert m2 == 4


def test_shared_prefix_deduplicated_and_split():
    tree, alloc = _tree(bs=4)
    ta = _seq_blocks(alloc, 3)
    a = [1, 2, 3, 4, 5, 6, 7, 8, 9, 10, 11, 12]
    tree.insert(a, ta)
    assert tree.num_blocks() == 3 and tree.num_nodes() == 1
    # b shares a's first 2 blocks then diverges mid-edge -> split
    tb = list(ta[:2]) + [alloc.alloc()]
    b = a[:8] + [99, 98, 97, 96]
    added = tree.insert(b, tb)
    assert added == 1                    # only b's divergent block adopted
    assert tree.num_blocks() == 4
    assert tree.num_nodes() == 3         # split node + two leaves
    # both full paths still match
    assert tree.match_prefix(a)[1] == 12
    assert tree.match_prefix(b)[1] == 12
    # the shared run is cached once: ONE tree ref per block
    tree2, m = tree.match_prefix(a[:8])
    assert m == 8 and tree2 == ta[:2]


def test_duplicate_insert_adopts_nothing():
    tree, alloc = _tree(bs=4)
    tbl = _seq_blocks(alloc, 2)
    toks = list(range(8))
    assert tree.insert(toks, tbl) == 2
    refs = [alloc.refcount(b) for b in tbl]
    assert tree.insert(toks, tbl) == 0   # idempotent
    assert [alloc.refcount(b) for b in tbl] == refs


def test_evict_frees_sole_owner_and_skips_live():
    tree, alloc = _tree(bs=4)
    ta = _seq_blocks(alloc, 2)
    tb = _seq_blocks(alloc, 2)
    tree.insert([1, 2, 3, 4, 5, 6, 7, 8], ta)
    tree.insert([9, 10, 11, 12, 13, 14, 15, 16], tb)
    # drop sequence A's refs: its cached blocks become tree-sole-owned
    for b in ta:
        alloc.decref(b)
    assert tree.evictable_blocks() == 2
    free0 = alloc.free_blocks()
    freed = tree.evict(2)
    assert freed == 2
    assert alloc.free_blocks() == free0 + 2
    # B's leaf survives (all its blocks are live-referenced: freeing it
    # would reclaim nothing)
    assert tree.match_prefix([9, 10, 11, 12, 13, 14, 15, 16])[1] == 8
    assert all(alloc.refcount(b) >= 1 for b in tb)
    # A's path is gone
    assert tree.match_prefix([1, 2, 3, 4])[1] == 0


def test_evict_cascades_through_exposed_parents():
    tree, alloc = _tree(bs=4)
    tbl = _seq_blocks(alloc, 3)
    tree.insert(list(range(12)), tbl)
    tb2 = list(tbl[:1]) + [alloc.alloc()]
    tree.insert(list(range(4)) + [50, 51, 52, 53], tb2)  # splits at 4
    for b in set(tbl + tb2):
        alloc.decref(b)                  # all sequences released
    assert tree.evictable_blocks() == tree.num_blocks() == 4
    freed = tree.evict(100)              # ask for more than exists
    assert freed == 4
    assert tree.num_blocks() == 0 and tree.num_nodes() == 0
    assert alloc.free_blocks() == alloc.capacity


def test_lru_order_follows_matches():
    tree, alloc = _tree(bs=4)
    ta, tb = _seq_blocks(alloc, 1), _seq_blocks(alloc, 1)
    tree.insert([1, 2, 3, 4], ta)
    tree.insert([5, 6, 7, 8], tb)
    for b in ta + tb:
        alloc.decref(b)
    tree.match_prefix([1, 2, 3, 4])      # touch A: B becomes LRU
    assert tree.evict(1) == 1
    assert tree.match_prefix([1, 2, 3, 4])[1] == 4   # A survived
    assert tree.match_len([5, 6, 7, 8]) == 0         # B evicted


def test_match_len_is_read_only():
    tree, alloc = _tree(bs=4)
    tbl = _seq_blocks(alloc, 2)
    tree.insert(list(range(8)), tbl)
    refs = [alloc.refcount(b) for b in tbl]
    assert tree.match_len(list(range(8))) == 8
    assert [alloc.refcount(b) for b in tbl] == refs


# ---------------------------------------------------------------------------
# Engine-level: token identity, prefill savings, chunked skip, eviction

def _run_prompts(eng, prompts, max_new=6):
    outs = []
    for i, p in enumerate(prompts):
        sid = f"s{i}"
        eng.op_prefill([{"sid": sid, "text": p}])
        outs.append(eng.op_decode([{"sid": sid, "max_new": max_new}])[0])
    return outs


def test_radix_token_identity_and_prefill_savings():
    """Cache on == cache off token-for-token, while prefilling strictly
    fewer tokens on shared-prefix traffic."""
    prompts = [SHARED + " query one", SHARED + " query two about more",
               "unrelated cold prompt", SHARED + " query one"]
    on = _engine(radix=True)
    off = _engine(radix=False)
    assert _run_prompts(on, prompts) == _run_prompts(off, prompts)
    assert on.radix.stats["hit_tokens"] > 0
    assert on.stats["prefill_tokens"] < off.stats["prefill_tokens"]


def test_radix_release_keeps_cache_and_reuses_blocks():
    """Released sequences leave their prefix cached; a repeat prompt
    forks the SAME physical blocks instead of re-prefilling."""
    eng = _engine()
    eng.op_prefill([{"sid": "a", "text": SHARED + " tail"}])
    ta = set(eng.states["a"].table)
    eng.release("a")
    assert eng.radix.num_blocks() > 0
    used0 = eng.alloc.used_blocks()
    eng.op_prefill([{"sid": "b", "text": SHARED + " tail"}])
    assert set(eng.states["b"].table) & ta          # physical reuse
    # only the uncached tail allocated fresh blocks
    assert eng.alloc.used_blocks() - used0 <= 2
    eng.release("b")


def test_chunked_prefill_skips_cached_chunks():
    """With chunked prefill on, a cached prefix is skipped BEFORE
    chunking: the second prompt streams only its uncached tail through
    the loop, and tokens stay identical to the cache-off path."""
    def run(radix):
        eng = _engine(radix=radix, chunked_prefill=True, prefill_chunk=16)
        outs = _run_prompts(eng, [SHARED + " alpha", SHARED + " beta"])
        pf = eng.stats["prefill_tokens"]
        eng.stop_decode_loop()
        return outs, pf

    (on, pf_on), (off, pf_off) = run(True), run(False)
    assert on == off
    assert pf_on < pf_off               # whole cached chunks skipped


def test_eviction_under_pressure_stays_token_identical():
    """A pool too small to hold every query's cache forces LRU eviction
    mid-workload; outputs still match the cache-off engine and no block
    leaks (everything frees after release + full evict)."""
    prompts = [" ".join(f"p{k}w{i}" for i in range(30)) + " q"
               for k in range(6)]
    on = _engine(radix=True, num_blocks=16)
    off = _engine(radix=False, num_blocks=16)
    for i, p in enumerate(prompts):
        sid = f"s{i}"
        on.op_prefill([{"sid": sid, "text": p}])
        off.op_prefill([{"sid": sid, "text": p}])
        assert on.op_decode([{"sid": sid, "max_new": 4}]) == \
            off.op_decode([{"sid": sid, "max_new": 4}])
        on.release(sid)
        off.release(sid)
    assert on.radix.stats["evictions"] > 0          # pressure was real
    on.radix.evict(10**6)
    assert on.alloc.free_blocks() == on.alloc.capacity   # no leaks


def test_admission_counts_cached_blocks_as_evictable():
    """try_admit must treat tree-sole-owned blocks as reclaimable: a
    decode whose worst case exceeds raw free blocks — but not free +
    evictable — is admitted (evicting on demand), not deferred."""
    eng = _engine(num_blocks=12, max_len=64)        # 11 usable blocks
    eng.op_prefill([{"sid": "warm", "text": " ".join(
        f"w{i}" for i in range(50))}])
    eng.release("warm")                  # 6 full blocks, tree-sole-owned
    assert eng.kv_free_blocks() == 11    # evictable counts as free
    eng.op_prefill([{"sid": "d", "text": "short seed prompt"}])
    seq = eng.submit_decode("d", 48)     # worst case exceeds raw free
    assert seq.wait(60)
    eng.stop_decode_loop()
    assert eng.radix.stats["freed_blocks"] > 0


def test_cold_vs_warm_replica_symmetry():
    """op_prefill instruction-cache asymmetry fix: a replica warmed via
    get_prefix_state and a cold replica produce identical tokens AND
    identical cross-query block sharing, because warmup seeds the same
    radix tree the first query would."""
    instr = " ".join(f"inst{i}" for i in range(16))  # 2 full blocks
    queries = [instr + " ask one", instr + " ask two"]

    def sharing(eng):
        tables = [eng.states[f"s{i}"].table for i in range(len(queries))]
        return sorted(len(set(a) & set(b))
                      for i, a in enumerate(tables)
                      for b in tables[i + 1:])

    warm = _engine()
    warm.get_prefix_state(instr)         # warmup path
    warm_out = _run_prompts(warm, queries)
    cold = _engine()
    cold_out = _run_prompts(cold, queries)
    assert warm_out == cold_out
    assert sharing(warm) == sharing(cold)
    assert sharing(cold)[0] >= 2         # the instruction blocks ARE shared


def test_flag_off_paths_untouched():
    """prefix_cache='none' engines carry no tree and never consult one."""
    eng = _engine(radix=False)
    assert eng.radix is None
    assert eng.prefix_match_len("anything at all") == 0
    assert eng.kv_free_blocks() == eng.alloc.free_blocks()


def test_radix_requires_paged():
    with pytest.raises(ValueError, match="requires paged"):
        LLMEngine("t", CFG, paged=False, prefix_cache="radix")
    with pytest.raises(ValueError, match="prefix_cache"):
        LLMEngine("t", CFG, paged=True, prefix_cache="bogus")
    with pytest.raises(ValueError):
        SimLLMEngine("s", prefix_cache="radix")     # sim mirrors the rule


def test_serve_flag_validation():
    from repro.launch.serve import build_parser, validate_args
    ap = build_parser()
    args = ap.parse_args(["--prefix-cache", "radix", "--paged-kv"])
    validate_args(ap, args)              # valid combination
    args = ap.parse_args(["--prefix-cache", "radix"])
    with pytest.raises(SystemExit):
        validate_args(ap, args)          # radix without --paged-kv


# ---------------------------------------------------------------------------
# Prefix-aware pool routing

def test_pool_best_prefix_replica():
    proto = SimLLMEngine("llm", paged=True, block_size=4,
                         prefix_cache="radix")
    pool = EnginePool.replicate(proto, 2, name="llm")
    text = " ".join(f"w{i}" for i in range(12))
    assert pool.best_prefix_replica(text) is None   # nothing cached yet
    pool[1].op_prefill([{"sid": "seed", "text": text}])
    assert pool.best_prefix_replica(text + " more") == 1
    assert pool.prefix_match_len(1, text) >= 8
    assert pool.prefix_match_len(0, text) == 0


def test_scheduler_routes_prefill_to_prefix_replica():
    """An unpinned prefill whose prompt has a cached prefix on a BUSY
    replica still routes there — prefix affinity beats least-load."""
    from repro.core import primitives as P
    from repro.core.primitives import Graph, Primitive
    from repro.core.runtime import (NodeTask, PooledEngineScheduler,
                                    QueryContext)
    proto = SimLLMEngine("llm", paged=True, block_size=4,
                         prefix_cache="radix")
    pool = EnginePool.replicate(proto, 2, name="llm")
    text = " ".join(f"w{i}" for i in range(12))
    pool[1].op_prefill([{"sid": "seed", "text": text}])
    routed = []
    s = PooledEngineScheduler(pool, lambda eng, b: routed.append(eng.name),
                              policy="to")
    assert s.prefix_aware
    s.on_complete = lambda t: None
    s.start()
    pool.note_queued(1, 10_000)          # replica 1 looks heavily loaded
    prim = Primitive(op=P.PREFILL, engine="llm", component="c",
                     config={"sid": "q", "instruction": text + " more",
                             "parts": [("i", None)]},
                     produces={"out"})
    s.submit(NodeTask(prim, QueryContext(Graph(), {})))
    assert _wait(lambda: routed, timeout=5)
    assert routed[0].endswith(".r1")     # followed the cached prefix
    s.stop()


def test_scheduler_prefix_routing_off_without_radix():
    from repro.core.runtime import PooledEngineScheduler
    pool = EnginePool.replicate(SimLLMEngine("llm"), 2, name="llm")
    s = PooledEngineScheduler(pool, lambda eng, b: None, policy="to")
    assert not s.prefix_aware            # flag off: routing untouched


# ---------------------------------------------------------------------------
# Sim engine modeled hit rate

def test_sim_radix_models_prefill_savings():
    cold = SimLLMEngine("c", paged=True, block_size=4)
    warm = SimLLMEngine("w", paged=True, block_size=4,
                        prefix_cache="radix")
    text = " ".join(f"w{i}" for i in range(20))
    for eng in (cold, warm):
        eng.op_prefill([{"sid": "a", "text": text}])
        eng.op_prefill([{"sid": "b", "text": text + " tail"}])
        eng.op_prefill([{"sid": "c", "text": text + " other end"}])
    assert warm.stats["radix_hit_tokens"] == 40     # 20 tokens x 2 hits
    assert warm.stats["prefill_tokens"] < cold.stats["prefill_tokens"]
    # chunk set is prefix-closed: shared blocks counted ONCE pool-wide
    assert warm.kv_blocks() < cold.kv_blocks()
    assert warm.prefix_match_len(text) == 16        # capped at len-1


def test_sim_warmup_seeds_tree():
    eng = SimLLMEngine("s", paged=True, block_size=4,
                       prefix_cache="radix")
    instr = " ".join(f"i{k}" for k in range(8))
    eng.get_prefix_state(instr)
    assert eng.prefix_match_len(instr + " q") == 8


# ---------------------------------------------------------------------------
# Concurrency stress: shared-prefix prefills under eviction pressure

def test_concurrent_prefill_with_eviction_pressure():
    """Two threads prefill shared-prefix prompts while a third forces
    eviction; afterwards: no pad-block references anywhere, no negative
    or dangling refcounts, free-list conservation, and every decode
    matches the single-threaded cache-off engine token for token."""
    prompts = [SHARED + f" worker query {i}" for i in range(8)]
    ref = _engine(radix=False)
    expected = {p: None for p in prompts}
    for i, p in enumerate(prompts):
        ref.op_prefill([{"sid": f"r{i}", "text": p}])
        expected[p] = ref.op_decode([{"sid": f"r{i}", "max_new": 4}])[0]

    eng = _engine(num_blocks=48)
    results, errors = {}, []

    def worker(lo):
        try:
            for i in range(lo, len(prompts), 2):
                sid = f"w{i}"
                eng.op_prefill([{"sid": sid, "text": prompts[i]}])
                results[prompts[i]] = eng.op_decode(
                    [{"sid": sid, "max_new": 4}])[0]
                eng.release(sid)
        except Exception as e:  # noqa: BLE001
            errors.append(e)

    stop = threading.Event()

    def evictor():
        while not stop.is_set():
            eng.radix.evict(2)
            time.sleep(0.001)

    threads = [threading.Thread(target=worker, args=(k,)) for k in (0, 1)]
    ev = threading.Thread(target=evictor, daemon=True)
    ev.start()
    for t in threads:
        t.start()
    for t in threads:
        t.join(120)
    stop.set()
    ev.join(10)

    assert not errors, errors
    for p, out in results.items():
        assert out == expected[p], f"diverged on {p!r}"
    refs = eng.alloc.refs_snapshot()
    assert refs[0] == 0                  # pad block never touched
    assert all(r >= 0 for r in refs)
    # conservation: every non-free block is owned by the tree alone now
    # (all sequences released); a full evict returns the pool to empty
    eng.radix.evict(10**6)
    assert eng.alloc.free_blocks() == eng.alloc.capacity


# ---------------------------------------------------------------------------
# Property tests vs a brute-force dict-of-prefixes oracle. Run with
# hypothesis when the optional dep is installed; ALWAYS run with a
# seeded stdlib-random program generator (same executor, same
# invariants), so the oracle gates CI regardless of the environment.

import random  # noqa: E402

_OBS = 4                                 # oracle block size

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings
    HAVE_HYPOTHESIS = True
except ImportError:                      # pragma: no cover - env dependent
    HAVE_HYPOTHESIS = False


def _random_program(rng, n_ops=24):
    """Random interleaving of insert / match / release / evict over a
    tiny token alphabet (high collision rate -> deep prefix sharing)."""
    ops = []
    for _ in range(rng.randint(1, n_ops)):
        kind = rng.choice(["insert", "insert", "match", "release",
                           "evict"])
        if kind in ("insert", "match"):
            toks = [rng.randint(0, 2)
                    for _ in range(rng.randint(0, 14))]
            ops.append((kind, toks))
        elif kind == "release":
            ops.append((kind, rng.randint(0, 30)))
        else:
            ops.append((kind, rng.randint(1, 8)))
    return ops


def _oracle_match(cached, toks):
    """Longest block-aligned prefix present in the dict-of-prefixes."""
    best = 0
    for k in range(_OBS, len(toks) + 1, _OBS):
        if tuple(toks[:k]) in cached:
            best = k
    return best


def _run_oracle_program(ops):
    """Execute a program against tree + oracle, asserting after every
    op: match_prefix returns the longest cached block-aligned prefix;
    every refcount equals (live tables holding b) + (1 if cached) — so
    eviction can never have freed a live-referenced block; the free
    list conserves blocks exactly."""
    alloc = kvc.BlockAllocator(256)
    tree = kvc.RadixPrefixCache(alloc, _OBS)
    cached = {}                          # tuple(prefix) -> True (oracle)
    live = []                            # live sequence tables

    def check_invariants():
        owners = {}
        for tbl in live:
            for b in tbl:
                owners[b] = owners.get(b, 0) + 1
        for b in tree.block_snapshot():
            owners[b] = owners.get(b, 0) + 1
        refs = alloc.refs_snapshot()
        for b in range(1, alloc.num_blocks):
            assert refs[b] == owners.get(b, 0), f"block {b}"
        assert alloc.free_blocks() == alloc.capacity - len(
            [b for b in range(1, alloc.num_blocks) if owners.get(b)])

    for kind, arg in ops:
        if kind == "insert":
            toks = arg
            nfull = len(toks) // _OBS
            # build a live sequence the way the engine does: fork the
            # cached prefix, allocate fresh blocks for the tail
            blocks, m = tree.match_prefix(toks[:max(0, len(toks) - 1)])
            tail = [alloc.alloc()
                    for _ in range(-(-(len(toks) - m) // _OBS))]
            tbl = blocks + tail
            live.append(tbl)
            tree.insert(toks, tbl)
            for k in range(_OBS, nfull * _OBS + 1, _OBS):
                cached[tuple(toks[:k])] = True
        elif kind == "match":
            toks = arg
            blocks, m = tree.match_prefix(toks)
            assert m == _oracle_match(cached, toks)
            assert len(blocks) == m // _OBS
            for b in blocks:             # give the refs straight back
                alloc.decref(b)
        elif kind == "release" and live:
            tbl = live.pop(arg % len(live))
            for b in tbl:
                alloc.decref(b)
        elif kind == "evict":
            freed = tree.evict(arg)
            assert 0 <= freed <= alloc.capacity
            # sync the oracle: drop entries no longer matchable
            dead = [k for k in cached
                    if tree.match_len(list(k)) < len(k)]
            for k in dead:
                del cached[k]
        check_invariants()

    # teardown: every block must come back to the free list
    for tbl in live:
        for b in tbl:
            alloc.decref(b)
    live.clear()
    tree.evict(10**6)
    assert tree.num_blocks() == 0
    assert alloc.free_blocks() == alloc.capacity


def _run_longest_prefix_case(seqs):
    alloc = kvc.BlockAllocator(128)
    tree = kvc.RadixPrefixCache(alloc, _OBS)
    cached = {}
    for toks in seqs:
        nfull = len(toks) // _OBS
        tbl = [alloc.alloc() for _ in range(-(-len(toks) // _OBS))]
        tree.insert(toks, tbl)
        for k in range(_OBS, nfull * _OBS + 1, _OBS):
            cached[tuple(toks[:k])] = True
        for b in tbl:
            alloc.decref(b)
    for toks in seqs:
        for probe in (toks, toks + [0], toks[:5]):
            blocks, m = tree.match_prefix(probe)
            assert m == _oracle_match(cached, probe)
            for b in blocks:
                alloc.decref(b)


@pytest.mark.parametrize("seed", range(60))
def test_radix_matches_prefix_dict_oracle(seed):
    _run_oracle_program(_random_program(random.Random(seed)))


@pytest.mark.parametrize("seed", range(40))
def test_match_is_longest_cached_prefix(seed):
    rng = random.Random(1000 + seed)
    seqs = [[rng.randint(0, 1) for _ in range(rng.randint(4, 12))]
            for _ in range(rng.randint(1, 6))]
    _run_longest_prefix_case(seqs)


if HAVE_HYPOTHESIS:
    @st.composite
    def _hyp_programs(draw):
        n = draw(st.integers(1, 24))
        ops = []
        for _ in range(n):
            kind = draw(st.sampled_from(
                ["insert", "match", "release", "evict"]))
            if kind in ("insert", "match"):
                ops.append((kind, draw(st.lists(st.integers(0, 2),
                                                max_size=14))))
            elif kind == "release":
                ops.append((kind, draw(st.integers(0, 30))))
            else:
                ops.append((kind, draw(st.integers(1, 8))))
        return ops

    @given(_hyp_programs())
    @settings(max_examples=80, deadline=None)
    def test_radix_oracle_hypothesis(ops):
        _run_oracle_program(ops)

    @given(st.lists(st.lists(st.integers(0, 1), min_size=4, max_size=12),
                    min_size=1, max_size=6))
    @settings(max_examples=60, deadline=None)
    def test_longest_prefix_hypothesis(seqs):
        _run_longest_prefix_case(seqs)
