"""SLO-aware multi-tenant scheduling (serving/slo.py): priority
admission ranking, fair-share ledger properties vs a brute-force
weighted max-min oracle, paged/dense preempt->resume token identity,
the aging starvation bound, the QueryContext.priority continuous-path
regression, and flag-off byte-identity."""
import itertools
import time

from repro.configs.base import get_config
from repro.core.engine_pool import EnginePool
from repro.engines.decode_loop import ContinuousDecodeLoop, DecodeSeq
from repro.engines.llm_engine import LLMEngine
from repro.engines.sim_engines import SimLLMEngine
from repro.serving.slo import (BATCH, INTERACTIVE, FairShareLedger,
                               SLOPolicy, SLOTag, attach_slo, derive_tag,
                               pool_tenant_stats)


def _wait(pred, timeout=30.0):
    t0 = time.time()
    while time.time() - t0 < timeout:
        if pred():
            return True
        time.sleep(0.002)
    return False


# ---------------------------------------------------------------------------
# FairShareLedger vs a brute-force weighted max-min oracle

def _oracle_unweighted_maxmin(capacity, demand):
    """Exact integer max-min optimum for EQUAL weights: the ascending-
    sorted share vector that is lexicographically maximal (leximin) over
    all feasible allocations.  With equal weights progressive filling is
    plain round-robin and realizes exactly this optimum."""
    tenants = sorted(t for t, d in demand.items() if d > 0)
    if not tenants or capacity <= 0:
        return None
    total = min(capacity, sum(demand[t] for t in tenants))
    best = None
    for alloc in itertools.product(
            *(range(demand[t] + 1) for t in tenants)):
        if sum(alloc) != total:
            continue
        vec = sorted(alloc)
        if best is None or vec > best:
            best = vec
    return best


def test_shares_match_unweighted_maxmin_oracle():
    """Exhaustive small grid + assorted larger cells: the ledger's
    shares equal the brute-force integer leximin optimum whenever
    weights are equal."""
    cases = [(4, {"a": 3, "b": 3}), (5, {"a": 1, "b": 9}),
             (8, {"a": 4, "b": 4, "c": 4}), (7, {"a": 5, "b": 2, "c": 6}),
             (3, {"a": 2, "b": 2, "c": 2}), (8, {"a": 2, "b": 0, "c": 7})]
    for cap in (1, 2, 3, 5):
        for da in range(0, 4):
            for db in range(0, 4):
                cases.append((cap, {"a": da, "b": db}))
    for cap, demand in cases:
        led = FairShareLedger(cap)
        share = led.shares(demand)
        # feasibility invariants
        assert sum(share.values()) == min(
            cap, sum(d for d in demand.values() if d > 0))
        for t, s in share.items():
            assert 0 <= s <= demand[t]
        want = _oracle_unweighted_maxmin(cap, demand)
        if want is None:
            assert sum(share.values()) == 0
            continue
        assert sorted(share.values()) == want, (cap, demand, share)


def test_weighted_shares_proportional_and_monotone():
    """Weighted filling is weighted round-robin: under saturated demand
    shares track ``capacity * w / sum(w)`` within one unit, and raising
    a tenant's weight never lowers its share (all else equal)."""
    for cap in (4, 6, 9, 12):
        for wa, wb in ((1.0, 1.0), (1.0, 2.0), (1.0, 3.0), (2.0, 3.0)):
            led = FairShareLedger(cap, {"a": wa, "b": wb})
            share = led.shares({"a": cap, "b": cap})   # both saturated
            assert sum(share.values()) == cap
            tot = wa + wb
            assert abs(share["a"] - cap * wa / tot) <= 1.0, (cap, wa, wb)
            assert abs(share["b"] - cap * wb / tot) <= 1.0, (cap, wa, wb)
    # monotonicity in weight
    prev = -1
    for w in (0.5, 1.0, 2.0, 4.0):
        led = FairShareLedger(6, {"a": w, "b": 1.0})
        s = led.shares({"a": 6, "b": 6})["a"]
        assert s >= prev
        prev = s


def test_may_take_work_conserving_and_bounded():
    led = FairShareLedger(4)
    # alone: no other tenant has unmet demand -> unlimited (work
    # conservation never idles capacity)
    for _ in range(4):
        assert led.may_take("a", 1, {"a": 4})
        led.acquire("a")
    assert led.usage_of("a") == 4
    # contender appears with unmet demand: a is over its 2-slot share
    assert not led.may_take("a", 1, {"a": 5, "b": 4})
    # b is within its share
    assert led.may_take("b", 1, {"a": 5, "b": 4})
    led.release("a", 3)
    assert led.may_take("a", 1, {"a": 2, "b": 4})


def test_ledger_release_floors_at_zero():
    led = FairShareLedger(4)
    led.acquire("a", 2)
    led.release("a", 5)
    assert led.usage_of("a") == 0
    assert led.snapshot() == {}


# ---------------------------------------------------------------------------
# ranking: priority admission, aging bound, depth tie-break

def _seq(cls=BATCH, prio=0, depth=0, age=0.0, sid="s"):
    s = DecodeSeq(sid, None, 4, text_fn=lambda q: "")
    s.slo = SLOTag(cls=cls, priority=prio, depth=depth,
                   t_submit=time.time() - age)
    return s


def test_admission_order_class_priority_depth_fifo():
    pol = SLOPolicy(slots=4, aging_s=1e9)
    it = _seq(INTERACTIVE, sid="i")
    hi = _seq(BATCH, prio=7, sid="hp")
    deep = _seq(BATCH, depth=5, sid="deep")
    old = _seq(BATCH, age=0.5, sid="old")
    new = _seq(BATCH, sid="new")
    order = [s.sid for s in
             pol.admission_order([new, old, deep, hi, it])]
    # interactive first; then batch by priority desc, depth desc, FIFO
    assert order == ["i", "hp", "deep", "old", "new"]


def test_aging_bound_promotes_starved_batch():
    pol = SLOPolicy(slots=4, aging_s=0.05)
    aged = _seq(BATCH, age=0.2, sid="aged")
    fresh_i = _seq(INTERACTIVE, sid="i")
    assert pol.is_urgent(aged)
    # both urgent -> FIFO within the urgent band: the aged batch item
    # (earlier submit) goes FIRST — batch can never starve
    assert [s.sid for s in pol.admission_order([fresh_i, aged])] == \
        ["aged", "i"]


def test_derive_tag_folds_legacy_priority():
    """Satellite regression: the QueryContext.priority knob (previously
    only honored by legacy form_batch) maps into the SLO class that
    orders the continuous path."""
    assert derive_tag(priority=3).cls == INTERACTIVE
    assert derive_tag(priority=0).cls == BATCH
    assert derive_tag(slo="batch", priority=3).cls == BATCH  # explicit wins
    assert derive_tag(slo="interactive").cls == INTERACTIVE


class _FakeEngine:
    """Minimal engine for driving loop admission without threads."""

    def __init__(self, pol=None):
        self.name = "fake"
        self.slo = pol


def test_loop_priority_admission_orders_continuous_path():
    """The continuous loop's admission pass honors the rank: a
    higher-priority later arrival is admitted before an earlier batch
    waiter (the satellite-1 gap, closed)."""
    pol = SLOPolicy(slots=1, aging_s=1e9)
    loop = ContinuousDecodeLoop(_FakeEngine(pol), max_slots=1)
    lo = _seq(BATCH, sid="lo")
    hi = _seq(BATCH, prio=5, sid="hi")   # derive: prio>0 -> interactive
    hi.slo = derive_tag(priority=5)
    loop.waiting.extend([lo, hi])
    with loop.cv:
        expired = loop._admit_locked()
    assert expired == []
    assert [s.sid for s in loop.active] == ["hi"]
    assert [s.sid for s in loop.waiting] == ["lo"]
    # urgent waiter got the slot -> no preemption pressure recorded
    assert not loop._slo_deferred_urgent


def test_loop_fifo_admission_when_unarmed():
    """Flag off (engine.slo is None): admission is the legacy FIFO
    head-of-line pass, regardless of tags on the sequences."""
    loop = ContinuousDecodeLoop(_FakeEngine(None), max_slots=1)
    lo = _seq(BATCH, sid="lo")
    hi = _seq(INTERACTIVE, prio=5, sid="hi")
    loop.waiting.extend([lo, hi])
    with loop.cv:
        loop._admit_locked()
    assert [s.sid for s in loop.active] == ["lo"]


def test_loop_slot_fair_share_across_tenants():
    """With both tenants demanding slots, neither may exceed its
    max-min share: tenant a's third sequence defers while b is owed."""
    pol = SLOPolicy(slots=4, aging_s=1e9)
    loop = ContinuousDecodeLoop(_FakeEngine(pol), max_slots=4)
    seqs = []
    for i in range(4):
        s = _seq(BATCH, sid=f"a{i}")
        s.slo = SLOTag(cls=BATCH, tenant="ta",
                       t_submit=time.time() - 1 + i * 1e-4)
        seqs.append(s)
    b0 = _seq(BATCH, sid="b0")
    b0.slo = SLOTag(cls=BATCH, tenant="tb", t_submit=time.time())
    loop.waiting.extend(seqs + [b0])
    with loop.cv:
        loop._admit_locked()
    admitted = sorted(s.sid for s in loop.active)
    # a gets its 2-share + work-conserving extras only AFTER b's demand
    # is met: b0 must be among the 4 admitted
    assert "b0" in admitted
    assert len(loop.active) == 4
    assert pol.slots.usage_of("tb") == 1


# ---------------------------------------------------------------------------
# preempt -> resume token identity (real engine, dense and paged)

def _drive(eng, seq, iters):
    for _ in range(iters):
        before = len(seq.tokens)
        eng.decode_iteration([seq])
        seq.steps += max(1, len(seq.tokens) - before)


def _preempt_resume_run(paged):
    cfg = get_config("tiny-lite-llm")
    kw = dict(max_len=128, seed=0, max_batch=4)
    if paged:
        kw.update(paged=True, block_size=8, num_blocks=64)

    def fresh():
        eng = LLMEngine("t", cfg, **kw)
        attach_slo({"llm": eng}, preempt_cooldown_s=0.0)
        eng.op_prefill([{"sid": "s", "text":
                         "some moderately long prompt words here"}])
        st = eng.states["s"]
        seq = DecodeSeq("s", st, 10,
                        text_fn=lambda q: eng.tok.decode(q.tokens))
        assert eng.try_admit(seq)
        eng.note_slot_acquired(seq)
        return eng, seq

    # baseline: 10 uninterrupted iterations
    eng0, base = fresh()
    _drive(eng0, base, 10)
    baseline = list(base.tokens)

    # preempted run: 4 iterations, evict-to-recompute, then finish
    eng, seq = fresh()
    _drive(eng, seq, 4)
    assert eng.can_preempt(seq)
    if paged:
        used_before = eng.num_blocks - eng.alloc.free_blocks()
    eng.preempt_decode(seq)
    if paged:
        # ALL of the sequence's blocks were freed (prompt + 4 steps)
        assert eng.num_blocks - eng.alloc.free_blocks() < used_before
        assert seq.state.pos == 0 and len(seq.state.table) == 0
    # re-admission re-reserves for the whole replay horizon
    assert eng.try_admit(seq)
    eng.note_slot_acquired(seq)
    _drive(eng, seq, 6)     # resume happens inside the first iteration
    assert not seq.slo_preempted
    assert seq.tokens == baseline, (seq.tokens, baseline)
    # teardown parity: release and audit for leaks
    eng.note_slot_released(seq)
    eng.release("s")
    eng0.note_slot_released(base)
    eng0.release("s")
    if paged:
        rep = eng.alloc.audit()
        assert rep["bad_free"] == 0 and rep["leaked"] == 0
        assert eng.alloc.free_blocks() == eng.alloc.capacity
    return eng.slo


def test_preempt_resume_token_identical_dense():
    _preempt_resume_run(paged=False)


def test_preempt_resume_token_identical_paged():
    _preempt_resume_run(paged=True)


def test_can_preempt_excludes_unrecorded_sequences():
    """A sequence whose prompt context was never recorded (prefilled
    before the policy was armed / migrated in) must not be preempted —
    its KV could not be rebuilt."""
    cfg = get_config("tiny-lite-llm")
    eng = LLMEngine("t", cfg, max_len=128, seed=0)
    eng.op_prefill([{"sid": "s", "text": "prompt words"}])   # unarmed
    attach_slo({"llm": eng})
    seq = DecodeSeq("s", eng.states["s"], 4,
                    text_fn=lambda q: eng.tok.decode(q.tokens))
    assert not eng.can_preempt(seq)


# ---------------------------------------------------------------------------
# loop-driven preemption under pressure (sim engine)

def test_pressure_preempts_batch_for_interactive():
    """One decode slot, a long batch resident, an interactive arrival:
    the loop preempts the batch sequence (evict-to-recompute), serves
    the interactive one, then resumes the batch sequence — both outputs
    exactly what an uncontended run would produce."""
    eng = SimLLMEngine("llm", max_batch=1, decode_ms_per_step=20.0)
    attach_slo({"llm": eng}, preempt_cooldown_s=0.0)
    btag = derive_tag(slo="batch", tenant="tb")
    itag = derive_tag(slo="interactive", tenant="ti")
    batch = eng.submit_decode("long", 40, slo=btag)
    expect_batch = " ".join(batch.words)
    assert _wait(lambda: batch.t_admit is not None and batch.steps > 2)
    inter = eng.submit_decode("quick", 4, slo=itag)
    out_i = inter.wait(60)
    out_b = batch.wait(60)
    loop = eng._decode_loop
    assert [p[0] for p in loop.preemptions] == ["long"]
    assert out_b == expect_batch      # token-identical despite preemption
    assert out_i == " ".join(inter.words)
    # interactive finished while the batch sequence was still out
    assert inter.t_done <= batch.t_done
    stats = eng.tenant_stats()
    assert stats["tb/batch"]["preempted"] == 1
    assert stats["ti/interactive"]["admitted"] == 1
    assert stats["ti/interactive"]["ttft_p99_ms"] > 0
    eng.stop_decode_loop()


def test_preemption_hysteresis_cap():
    """A sequence preempted max_preempts_per_seq times runs to
    completion — the governor refuses to nominate it again."""
    pol = SLOPolicy(slots=1, aging_s=1e9, preempt_cooldown_s=0.0,
                    max_preempts_per_seq=1)
    v = _seq(BATCH, sid="v")
    v.t_admit = time.time()
    assert pol.plan_preemption([v]) == [v]
    assert pol.plan_preemption([v]) == []      # cap reached


def test_preemption_cooldown():
    pol = SLOPolicy(slots=1, aging_s=1e9, preempt_cooldown_s=30.0,
                    max_preempts_per_seq=10)
    a, b = _seq(BATCH, sid="a"), _seq(BATCH, sid="b")
    a.t_admit = b.t_admit = time.time()
    assert pol.plan_preemption([a, b]) != []
    assert pol.plan_preemption([a, b]) == []   # inside the cooldown


def test_urgent_sequences_never_preempted():
    pol = SLOPolicy(slots=1, aging_s=1e9, preempt_cooldown_s=0.0)
    i = _seq(INTERACTIVE, sid="i")
    i.t_admit = time.time()
    assert pol.plan_preemption([i]) == []


# ---------------------------------------------------------------------------
# per-tenant stats + pool surfaces

def test_tenant_stats_rollup_across_pool():
    pool = EnginePool.replicate(SimLLMEngine("llm", max_batch=4), 2,
                                name="llm")
    attach_slo({"llm": pool})
    t0 = derive_tag(slo="interactive", tenant="t0")
    t1 = derive_tag(slo="batch", tenant="t1")
    pool[0].submit_decode("a", 3, slo=t0).wait(60)
    pool[1].submit_decode("b", 3, slo=t1).wait(60)
    merged = pool.tenant_stats()
    assert merged["t0/interactive"]["done"] == 1
    assert merged["t1/batch"]["done"] == 1
    # name->engine mapping rollup (serve.py exit surface)
    top = pool_tenant_stats({"llm": pool})
    assert top == merged
    for r in pool:
        r.stop_decode_loop()


def test_tenant_aware_pool_routing():
    """Among equally-free replicas the router prefers the one where the
    tenant holds fewer decode slots; tenant=None is byte-identical to
    the legacy key."""
    pool = EnginePool.replicate(SimLLMEngine("llm", max_batch=4), 2,
                                name="llm")
    attach_slo({"llm": pool})
    assert pool.least_loaded_decode() == 0            # legacy tie -> min
    pool[0].slo.slots.acquire("t0", 2)
    assert pool.least_loaded_decode(tenant="t0") == 1
    assert pool.least_loaded_decode(tenant="t1") == 0
    assert pool.least_loaded_decode() == 0            # unchanged unarmed


# ---------------------------------------------------------------------------
# flag-off byte-identity

def test_flag_off_paths_untouched():
    """Without attach_slo every surface reports the pre-SLO shape:
    admission is FIFO, no stats, no preemptions, routing identical."""
    eng = SimLLMEngine("llm", max_batch=2, decode_ms_per_step=5.0)
    assert eng.slo is None
    tag = derive_tag(slo="interactive", tenant="t0")
    a = eng.submit_decode("a", 4, slo=tag)     # tags carried, ignored
    b = eng.submit_decode("b", 4)
    a.wait(60)
    b.wait(60)
    assert eng.tenant_stats() == {}
    assert eng._decode_loop.preemptions == []
    admitted = [s for s, _ in eng._decode_loop.admissions]
    assert admitted == ["a", "b"]              # FIFO
    eng.stop_decode_loop()


def test_clone_does_not_inherit_policy():
    eng = SimLLMEngine("llm", max_batch=2)
    attach_slo({"llm": eng})
    assert eng.slo is not None
    assert eng.clone(1).slo is None            # armed per replica


# ---------------------------------------------------------------------------
# end-to-end: runtime threads tags into the loop

def test_runtime_threads_slo_metadata_to_engine():
    from repro.core import primitives as P
    from repro.core.primitives import Graph, Primitive
    from repro.core.runtime import Runtime

    def gen_graph():
        g = Graph(query_id="q")
        pre = Primitive(op=P.PREFILL, engine="llm", component="gen",
                        consumes={"question"}, produces={"state:s"},
                        config={"sid": "s", "instruction": "hello",
                                "parts": [("instr", None),
                                          ("q", "question")]})
        dec = Primitive(op=P.DECODE, engine="llm", component="gen",
                        consumes={"state:s"}, produces={"draft"},
                        config={"sid": "s", "max_new": 4})
        g.add(pre)
        g.add(dec)
        g.edge(pre, dec)
        g.assign_depths()
        return g

    eng = SimLLMEngine("llm", decode_ms_per_step=5.0)
    attach_slo({"llm": eng})
    rt = Runtime({"llm": eng}, policy="to", continuous_batching=True)
    ctx = rt.submit(gen_graph(), {"question": "x"}, output_key="draft",
                    slo="interactive", tenant="acme")
    assert ctx.done.wait(60)
    assert ctx.error is None
    stats = eng.tenant_stats()
    assert stats["acme/interactive"]["done"] == 1
    rt.shutdown()
