"""Graph optimizer pass tests: structure of the e-graph per pass on the
paper's workflows."""
import pytest

from repro.core import primitives as P
from repro.core.apps import advanced_rag, naive_rag, search_gen, \
    contextual_retrieval
from repro.core.passes import (graph_opt, pass1_prune_dependencies,
                               pass2_stage_decompose, pass3_prefill_split,
                               pass4_decode_pipeline)
from repro.core.pgraph import graph_transform
from repro.engines.sim_engines import build_sim_engines
from repro.training.data import doc_corpus

Q = {"question": "what is fact 3 about optics", "docs": doc_corpus(4)}


def _app(mk):
    engines = build_sim_engines()
    return mk(engines)


def _ops(g):
    return sorted(n.op for n in g.nodes.values())


def test_pgraph_decomposition_advanced_rag():
    app = _app(advanced_rag)
    g = graph_transform(app, Q)
    ops = _ops(g)
    assert ops.count(P.PREFILL) == 1 + 3       # expansion + 3 refine steps
    assert ops.count(P.DECODE) == 1 + 3
    assert ops.count(P.EMBEDDING) == 2         # indexing + query embed
    assert ops.count(P.INGESTION) == 1
    assert ops.count(P.SEARCHING) == 1
    assert ops.count(P.RERANKING) == 1
    g.validate()


def test_pass1_detaches_independent_branches():
    app = _app(advanced_rag)
    g = graph_transform(app, Q)
    roots_before = len(g.roots())
    pass1_prune_dependencies(g)
    g.validate()
    roots_after = len(g.roots())
    # chunking AND query-expansion prefill become independent roots
    assert roots_after > roots_before
    comps = {g.nodes[r.pid].component for r in g.roots()}
    assert "query_expansion" in comps
    # every consumed key is produced by some node or is a query input
    produced = {k for n in g.nodes.values() for k in n.produces}
    inputs = {"docs", "question"}
    for n in g.nodes.values():
        for k in n.consumes:
            assert k in produced or k in inputs, (n.pid, k)


def test_pass2_stage_decomposition_counts():
    app = _app(naive_rag)
    g = graph_transform(app, Q)
    pass1_prune_dependencies(g)
    n_chunks = next(n for n in g.nodes.values()
                    if n.op == P.EMBEDDING and n.component == "indexing"
                    ).num_requests
    pass2_stage_decompose(g, app.engines)
    g.validate()
    maxb = app.engines["embedding"].max_batch
    stages = [n for n in g.nodes.values() if n.op == P.EMBEDDING
              and n.component == "indexing"]
    import math
    assert len(stages) == math.ceil(n_chunks / maxb)
    assert sum(s.num_requests for s in stages) == n_chunks
    # pipelined pairwise with ingestion stages + final Aggregate
    ings = [n for n in g.nodes.values() if n.op == P.INGESTION]
    assert len(ings) == len(stages)
    aggs = [n for n in g.nodes.values() if n.op == P.AGGREGATE
            and n.component == "indexing"]
    assert len(aggs) == 1


def test_pass3_prefill_split_structure():
    app = _app(advanced_rag)
    g = graph_transform(app, Q)
    pass1_prune_dependencies(g)
    pass3_prefill_split(g)
    g.validate()
    pps = [n for n in g.nodes.values() if n.op == P.PARTIAL_PREFILL]
    fps = [n for n in g.nodes.values() if n.op == P.FULL_PREFILL]
    # the 3 refine-mode synthesize prefills split (instruction+question
    # early, context late); expansion prefill does NOT (all parts early)
    assert len(pps) == 3 and len(fps) == 3
    for pp in pps:
        assert not any(g.nodes[p].op not in () for p in pp.parents
                       if g.nodes[p].produces & pp.consumes
                       and g.nodes[p].op == P.RERANKING)
    for fp in fps:
        # full prefill waits for its partial + the context producer
        par_ops = {g.nodes[p].op for p in fp.parents}
        assert P.PARTIAL_PREFILL in par_ops


def test_pass4_decode_pipelining_structure():
    app = _app(advanced_rag)
    g = graph_transform(app, Q)
    pass1_prune_dependencies(g)
    pass4_decode_pipeline(g)
    g.validate()
    pds = [n for n in g.nodes.values() if n.op == P.PARTIAL_DECODE]
    assert len(pds) == 3
    # each PD feeds its own per-item embedding -> searching chain
    embs = [n for n in g.nodes.values() if n.op == P.EMBEDDING
            and n.component == "query_embedding"]
    assert len(embs) == 3
    searches = [n for n in g.nodes.values() if n.op == P.SEARCHING]
    assert len(searches) == 3
    # rerank consumes all per-item retrieved keys
    rr = next(n for n in g.nodes.values() if n.op == P.RERANKING)
    assert {f"retrieved#{i}" for i in range(3)} <= rr.consumes


@pytest.mark.parametrize("mk", [naive_rag, advanced_rag, search_gen,
                                contextual_retrieval])
def test_full_graph_opt_invariants(mk):
    app = _app(mk)
    g = graph_transform(app, Q)
    g = graph_opt(g, app.engines)
    g.validate()
    # final answer still produced
    produced = {k for n in g.nodes.values() for k in n.produces}
    assert "answer" in produced
    # all consumed keys resolvable
    inputs = {"docs", "question"}
    for n in g.nodes.values():
        for k in n.consumes:
            assert k in produced or k in inputs, (n.pid, k)
    # depths valid: every parent strictly deeper than child
    for n in g.nodes.values():
        for c in n.children:
            assert n.depth > g.nodes[c].depth


def test_egraph_caching_different_queries():
    app = _app(advanced_rag)
    g1 = graph_opt(graph_transform(app, Q), app.engines)
    q2 = dict(Q, docs=doc_corpus(1))
    g2 = graph_opt(graph_transform(app, q2), app.engines)
    # fewer docs -> fewer chunks -> fewer embedding stages
    e1 = sum(1 for n in g1.nodes.values() if n.op == P.EMBEDDING)
    e2 = sum(1 for n in g2.nodes.values() if n.op == P.EMBEDDING)
    assert e2 <= e1
