"""Training substrate: loss descent, microbatch equivalence, data
pipeline determinism, checkpoint round-trip."""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.models.transformer import init_params
from repro.training.checkpoint import load_checkpoint, save_checkpoint
from repro.training.data import SyntheticLM, doc_corpus
from repro.training.optimizer import AdamWConfig, init_opt_state, lr_at
from repro.training.train_step import make_train_step


def test_loss_decreases_dense():
    cfg = get_config("tiny-core-llm")
    params = init_params(cfg, jax.random.key(0))
    oc = AdamWConfig(lr=3e-3, warmup_steps=2, total_steps=30)
    opt = init_opt_state(oc, params)
    step = jax.jit(make_train_step(cfg, oc, compute_dtype=jnp.float32,
                                   q_block=64))
    toks = jax.random.randint(jax.random.key(1), (8, 33), 0, cfg.vocab_size)
    ces = []
    for _ in range(8):
        params, opt, m = step(params, opt, {"tokens": toks})
        ces.append(float(m["ce"]))
    assert ces[-1] < ces[0] * 0.8


def test_microbatch_accumulation_matches_full_batch():
    cfg = get_config("tinyllama-1.1b").reduced()
    params = init_params(cfg, jax.random.key(0))
    oc = AdamWConfig(lr=1e-3, warmup_steps=0, total_steps=10)
    toks = jax.random.randint(jax.random.key(1), (4, 17), 0, cfg.vocab_size)
    outs = {}
    for nmb in (1, 2, 4):
        opt = init_opt_state(oc, params)
        step = jax.jit(make_train_step(cfg, oc, num_microbatches=nmb,
                                       compute_dtype=jnp.float32,
                                       q_block=64))
        p2, _, m = step(params, opt, {"tokens": toks})
        outs[nmb] = (np.asarray(jax.tree.leaves(p2)[0]), float(m["loss"]))
    np.testing.assert_allclose(outs[1][1], outs[2][1], rtol=1e-4)
    np.testing.assert_allclose(outs[1][0], outs[2][0], rtol=1e-3,
                               atol=1e-5)
    np.testing.assert_allclose(outs[1][0], outs[4][0], rtol=1e-3,
                               atol=1e-5)


def test_lr_schedule_shape():
    oc = AdamWConfig(lr=1e-3, warmup_steps=10, total_steps=100,
                     min_lr_ratio=0.1)
    lrs = [float(lr_at(oc, jnp.asarray(s))) for s in
           [0, 5, 10, 50, 100]]
    assert lrs[0] == 0.0
    assert lrs[1] == pytest.approx(5e-4)
    assert lrs[2] == pytest.approx(1e-3, rel=0.1)
    assert lrs[3] < lrs[2]
    assert lrs[4] == pytest.approx(1e-4, rel=0.05)


def test_synthetic_data_deterministic_and_learnable():
    d1 = SyntheticLM(256, batch=2, seq_len=16, seed=3)
    d2 = SyntheticLM(256, batch=2, seq_len=16, seed=3)
    b1, b2 = next(iter(d1)), next(iter(d2))
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    d1.close()
    d2.close()
    assert b1["tokens"].shape == (2, 17)


def test_doc_corpus_stable():
    a, b = doc_corpus(3), doc_corpus(3)
    assert a == b
    assert all("text" in d and "id" in d for d in a)


def test_checkpoint_roundtrip_mixed_dtypes():
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.bfloat16),
                  "d": jnp.array(3, jnp.int32)}}
    with tempfile.TemporaryDirectory() as td:
        save_checkpoint(td, tree, step=7)
        back = load_checkpoint(td, tree)
    assert jax.tree.all(jax.tree.map(
        lambda x, y: bool(jnp.allclose(x.astype(jnp.float32),
                                       y.astype(jnp.float32))), tree, back))


def test_grad_clipping_bounds_update():
    cfg = get_config("tiny-lite-llm")
    params = init_params(cfg, jax.random.key(0))
    oc = AdamWConfig(lr=1e-3, warmup_steps=0, total_steps=10)
    opt = init_opt_state(oc, params)
    step = jax.jit(make_train_step(cfg, oc, compute_dtype=jnp.float32,
                                   q_block=64))
    toks = jax.random.randint(jax.random.key(1), (2, 17), 0,
                              cfg.vocab_size)
    _, _, m = step(params, opt, {"tokens": toks})
    assert np.isfinite(float(m["gnorm"]))
