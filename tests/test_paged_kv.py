"""Paged KV cache: allocator semantics, paged==dense token-stream
equivalence (prefill->decode, chunked prefill, continuous batching),
copy-on-write prefix sharing, block-based occupancy, and pool-exhaustion
backpressure."""
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.core.engine_pool import EnginePool
from repro.engines.llm_engine import LLMEngine
from repro.engines.sim_engines import SimLLMEngine
from repro.models.transformer import apply_model
from repro.serving import kv_cache as kvc


def _engines(arch, **paged_kw):
    dense = LLMEngine("d", get_config(arch), max_len=128, seed=0)
    paged = LLMEngine("p", get_config(arch), max_len=128, seed=0,
                      paged=True, block_size=8, **paged_kw)
    return dense, paged


# ---------------------------------------------------------------------------
# allocator

def test_block_allocator_refcount_and_free_list():
    a = kvc.BlockAllocator(6)
    assert a.capacity == 5 and a.free_blocks() == 5
    b1, b2 = a.alloc(), a.alloc()
    assert kvc.PAD_BLOCK not in (b1, b2)       # pad block never handed out
    assert a.used_blocks() == 2
    a.incref(b1)
    a.decref(b1)
    assert a.used_blocks() == 2                # still held once
    a.decref(b1)
    assert a.used_blocks() == 1 and a.free_blocks() == 4
    for _ in range(4):
        a.alloc()
    with pytest.raises(kvc.OutOfBlocks):
        a.alloc()
    a.decref(b2)
    assert a.alloc() is not None               # freed block is reusable


def test_block_allocator_wait_for_free_unblocks_on_decref():
    a = kvc.BlockAllocator(4)
    held = [a.alloc() for _ in range(3)]
    done = []

    def waiter():
        done.append(a.wait_for_free(2, timeout=10))

    th = threading.Thread(target=waiter)
    th.start()
    time.sleep(0.05)
    assert not done                            # still blocked
    a.decref(held[0])
    a.decref(held[1])
    th.join(timeout=10)
    assert done == [True]
    assert not a.wait_for_free(4, timeout=0.05)   # can never reach 4


# ---------------------------------------------------------------------------
# paged == dense equivalence

@pytest.mark.parametrize("arch", ["tiny-core-llm", "tiny-lite-llm"])
def test_paged_matches_dense_prefill_decode(arch):
    """Same prompts, same greedy decode: the paged pool (block-table
    scatter/gather, windowed layers paged linearly) must produce the
    dense path's token streams exactly — including a chunked (partial)
    prefill extension mid-conversation."""
    dense, paged = _engines(arch)
    for eng in (dense, paged):
        eng.op_prefill([{"sid": "x", "text": "alpha beta gamma"},
                        {"sid": "y", "text": "delta epsilon zeta eta"}])
    assert dense.op_decode([{"sid": "x", "max_new": 6},
                            {"sid": "y", "max_new": 3}]) == \
        paged.op_decode([{"sid": "x", "max_new": 6},
                         {"sid": "y", "max_new": 3}])
    for eng in (dense, paged):                 # partial prefill continuity
        eng.op_prefill([{"sid": "x", "text": "more words appended now"}])
    assert dense.op_decode([{"sid": "x", "max_new": 5}]) == \
        paged.op_decode([{"sid": "x", "max_new": 5}])


def test_paged_matches_dense_continuous_batching():
    """Iteration-level decode loop over the paged pool: staggered
    admissions/evictions (different max_new) must not disturb token
    streams vs the dense loop."""
    dense, paged = _engines("tiny-lite-llm")
    outs = {}
    for name, eng in (("d", dense), ("p", paged)):
        eng.op_prefill([{"sid": "a", "text": "one two three"},
                        {"sid": "b", "text": "four five six seven eight"},
                        {"sid": "c", "text": "nine ten"}])
        seqs = [eng.submit_decode("a", 5), eng.submit_decode("b", 9),
                eng.submit_decode("c", 3)]
        outs[name] = tuple(s.wait(120) for s in seqs)
        eng.stop_decode_loop()
    assert outs["d"] == outs["p"]


def test_bucketed_prefill_last_token_exact():
    """Satellite: right-padded bucketed prefill must yield the SAME next
    token as an unpadded forward pass — per-sequence logits are gathered
    at position len(t)-1, not argmaxed over the padded tail."""
    for paged in (False, True):
        eng = LLMEngine("e", get_config("tiny-core-llm"), max_len=128,
                        seed=0, paged=paged, block_size=8)
        text = "alpha beta gamma"              # 3 tokens, S bucket = 8
        eng.op_prefill([{"sid": "s", "text": text}])
        toks = eng.tok.encode(text)
        full, _, _ = apply_model(eng.cfg, eng.params,
                                 jnp.asarray([toks], jnp.int32))
        expect = int(jnp.argmax(full[0, len(toks) - 1]))
        assert eng.states["s"].last_token == expect, f"paged={paged}"


def test_bucketed_prefill_batch_matches_solo():
    """Mixed-length batched prefill equals per-sequence unpadded prefill
    for EVERY member (not just the bucket-filling longest one)."""
    a = LLMEngine("a", get_config("tiny-lite-llm"), max_len=128, seed=0)
    b = LLMEngine("b", get_config("tiny-lite-llm"), max_len=128, seed=0)
    a.op_prefill([{"sid": "x", "text": "alpha beta gamma"},
                  {"sid": "y", "text": "delta epsilon zeta eta theta"}])
    b.op_prefill([{"sid": "x", "text": "alpha beta gamma"}])
    assert a.op_decode([{"sid": "x", "max_new": 3}])[0] == \
        b.op_decode([{"sid": "x", "max_new": 3}])[0]


# ---------------------------------------------------------------------------
# copy-on-write prefix sharing

def test_prefix_fork_shares_blocks_and_matches_dense():
    """Fork an instruction-prefix state into two branches: full prefix
    blocks must be SHARED (refcounted, not duplicated), the partially
    filled tail block copy-on-written per branch, and both branches'
    outputs must equal the unshared dense path."""
    cfg = get_config("tiny-core-llm")
    instr = " ".join(f"w{i}" for i in range(30))     # 30 tokens, bs=8
    paged = LLMEngine("p", cfg, max_len=128, seed=0, paged=True,
                      block_size=8)
    pre = paged.get_prefix_state(instr)
    prefix_blocks = paged.alloc.used_blocks()
    assert prefix_blocks == len(pre.table) == 4      # ceil(30/8)

    paged.op_prefill([{"sid": "q1", "text": "question one here",
                       "prefix_state": pre}])
    paged.op_prefill([{"sid": "q2", "text": "question two other words",
                       "prefix_state": pre}])
    # 3 full prefix blocks (24 tokens) shared three ways: prefix + forks
    assert [paged.alloc.refcount(b) for b in pre.table[:3]] == [3, 3, 3]
    # each fork added 2 blocks (1 COW tail + 1 growth), NOT 4 duplicates
    assert paged.alloc.used_blocks() == prefix_blocks + 4

    dense = LLMEngine("d", cfg, max_len=128, seed=0)
    pd = dense.get_prefix_state(instr)
    dense.op_prefill([{"sid": "q1", "text": "question one here",
                       "prefix_state": pd}])
    dense.op_prefill([{"sid": "q2", "text": "question two other words",
                       "prefix_state": pd}])
    for sid in ("q1", "q2"):
        assert paged.op_decode([{"sid": sid, "max_new": 4}]) == \
            dense.op_decode([{"sid": sid, "max_new": 4}])
    # the shared prefix itself must be untouched by either branch
    assert [paged.alloc.refcount(b) for b in pre.table[:3]] == [3, 3, 3]


def test_bucket_padding_costs_no_blocks():
    """A prompt shorter than its S bucket must only allocate blocks for
    its REAL tokens — padding-tail writes fall through to the reserved
    pad block, so bucket padding never erodes pool capacity."""
    paged = LLMEngine("p", get_config("tiny-lite-llm"), max_len=128,
                      seed=0, paged=True, block_size=4)
    paged.op_prefill([{"sid": "s", "text": "alpha beta gamma"}])
    # 3 tokens pad to the S=8 bucket: 1 block (ceil(3/4)), not 2
    assert paged.alloc.used_blocks() == 1
    dense = LLMEngine("d", get_config("tiny-lite-llm"), max_len=128, seed=0)
    dense.op_prefill([{"sid": "s", "text": "alpha beta gamma"}])
    assert paged.op_decode([{"sid": "s", "max_new": 6}]) == \
        dense.op_decode([{"sid": "s", "max_new": 6}])


def test_op_prefill_forks_cached_instruction_prefix():
    """End-to-end prefix reuse (the path the orchestrator's warmup
    enables): op_prefill on a prompt starting with a cached instruction
    must fork the cached KV (sharing blocks in paged mode) and prefill
    only the suffix — with token streams identical to the cold path."""
    cfg = get_config("tiny-core-llm")
    instr = " ".join(f"w{i}" for i in range(24))
    for paged in (False, True):
        warm = LLMEngine("w", cfg, max_len=128, seed=0, paged=paged,
                         block_size=8)
        warm.use_prefix_cache = True
        warm.get_prefix_state(instr)
        before = warm.stats["prefill_tokens"]
        warm.op_prefill([{"sid": "q", "text": instr + " tail question"}])
        assert warm.stats["prefill_tokens"] - before == 2   # suffix only
        assert warm.states["q"].pos == 26
        if paged:
            # the fork shares the instruction's full blocks
            pre = warm.prefix_cache[instr]
            assert [warm.alloc.refcount(b) for b in pre.table[:3]] == \
                [2, 2, 2]
        cold = LLMEngine("c", cfg, max_len=128, seed=0, paged=paged,
                         block_size=8)
        cold.op_prefill([{"sid": "q", "text": instr + " tail question"}])
        assert warm.op_decode([{"sid": "q", "max_new": 5}]) == \
            cold.op_decode([{"sid": "q", "max_new": 5}]), f"paged={paged}"


def test_decode_batch_overshoot_blocks_trimmed():
    """Run-to-completion decode with mixed lengths: a short member must
    not retain blocks allocated for the batch-wide n_max horizon."""
    paged = LLMEngine("p", get_config("tiny-lite-llm"), max_len=128,
                      seed=0, paged=True, block_size=8)
    paged.op_prefill([{"sid": "a", "text": "one two three"},
                      {"sid": "b", "text": "four five six"}])
    paged.op_decode([{"sid": "a", "max_new": 24}, {"sid": "b", "max_new": 2}])
    b = paged.states["b"]
    assert b.pos == 5
    assert len(b.table) == kvc.blocks_for(5, 8) == 1


def test_release_frees_blocks():
    paged = LLMEngine("p", get_config("tiny-lite-llm"), max_len=128,
                      seed=0, paged=True, block_size=8)
    assert paged.alloc.used_blocks() == 0
    paged.op_prefill([{"sid": "s", "text": "some words to prefill"}])
    paged.op_decode([{"sid": "s", "max_new": 8}])
    assert paged.alloc.used_blocks() > 0
    paged.release("s")
    assert paged.alloc.used_blocks() == 0
    assert "s" not in paged.states


# ---------------------------------------------------------------------------
# occupancy + backpressure

def test_block_occupancy_counts_true_memory():
    """kv_occupancy reports allocated blocks * block_size (shared prefix
    counted once), and the meter's bytes() uses per-block bytes."""
    paged = LLMEngine("p", get_config("tiny-lite-llm"), max_len=128,
                      seed=0, paged=True, block_size=8)
    paged.op_prefill([{"sid": "s", "text": "six words of prompt text here"}])
    used = paged.alloc.used_blocks()
    assert paged.kv_occupancy() == used * 8
    assert paged.meter.blocks() == used
    assert paged.meter.bytes() == \
        used * kvc.paged_block_bytes(paged.cfg, 8)


def test_decode_admission_backpressure_on_pool_exhaustion():
    """With a pool sized for ~one sequence, the second decode must WAIT
    (deferred admission, no OutOfBlocks crash) until the first sequence
    is released, then complete correctly."""
    cfg = get_config("tiny-lite-llm")
    paged = LLMEngine("p", cfg, max_len=128, seed=0, paged=True,
                      block_size=8, num_blocks=8)      # 7 usable blocks
    paged.op_prefill([{"sid": "a", "text": "one two three"}])
    sa = paged.submit_decode("a", 24)                  # a: needs 4 blocks
    assert sa.wait(120)
    # pool now holds a's 4 blocks; b needs 4 (prefill 1 + decode growth 3)
    # -> prefill fits (3 free), but decode admission must defer
    paged.op_prefill([{"sid": "b", "text": "four five six"}])
    sb = paged.submit_decode("b", 24)
    time.sleep(0.3)
    assert not sb.done.is_set()                        # backpressured
    loop = paged._decode_loop
    assert loop.occupancy() == 1                       # waiting, unadmitted
    paged.release("a")                                 # frees 4 blocks
    out = sb.wait(120)
    assert isinstance(out, str) and out
    paged.stop_decode_loop()


def test_decode_admission_timeout_fails_unsatisfiable_waiter():
    """A waiter whose block need can never be met (blocks held by an
    abandoned sequence) must be failed after admit_timeout instead of
    starving the queue behind it."""
    paged = LLMEngine("p", get_config("tiny-lite-llm"), max_len=128,
                      seed=0, paged=True, block_size=8, num_blocks=6)
    paged.op_prefill([{"sid": "a", "text": " ".join(["w"] * 24)}])  # 3 blk
    loop = paged.start_decode_loop()
    loop.admit_timeout = 0.3
    paged.op_prefill([{"sid": "b", "text": "hi"}])                  # 1 blk
    sb = paged.submit_decode("b", 32)        # needs 4 more blocks; 1 free
    with pytest.raises(TimeoutError, match="not admitted"):
        sb.wait(30)
    # the queue behind the failed waiter keeps flowing
    paged.op_prefill([{"sid": "c", "text": "ok"}])
    sc = paged.submit_decode("c", 2)
    assert sc.wait(60)
    paged.stop_decode_loop()


def test_decode_clamped_to_max_len():
    """Decode requests past max_len are capped (not silently written
    into clamped cache slots / block tables)."""
    for paged in (False, True):
        eng = LLMEngine("e", get_config("tiny-lite-llm"), max_len=32,
                        seed=0, paged=paged, block_size=8)
        eng.op_prefill([{"sid": "s", "text": "one two three four"}])
        out = eng.op_decode([{"sid": "s", "max_new": 100}])[0]
        assert eng.states["s"].pos == 32                 # capped exactly
        assert len(out.split()) == 32 - 4
        with pytest.raises(ValueError, match="no KV capacity"):
            eng.op_decode([{"sid": "s", "max_new": 1}])


def test_op_prefill_prompt_equal_to_instruction_matches_cold():
    """Warm-path edge: a prompt EXACTLY equal to a cached instruction
    must fork the finished prefix state as-is (no spurious SEP prefill)
    and decode identically to the cold path."""
    cfg = get_config("tiny-core-llm")
    instr = " ".join(f"w{i}" for i in range(12))
    for paged in (False, True):
        warm = LLMEngine("w", cfg, max_len=128, seed=0, paged=paged,
                         block_size=8)
        warm.use_prefix_cache = True
        warm.get_prefix_state(instr)
        warm.op_prefill([{"sid": "q", "text": instr}])
        assert warm.states["q"].pos == 12
        cold = LLMEngine("c", cfg, max_len=128, seed=0, paged=paged,
                         block_size=8)
        cold.op_prefill([{"sid": "q", "text": instr}])
        assert warm.op_decode([{"sid": "q", "max_new": 5}]) == \
            cold.op_decode([{"sid": "q", "max_new": 5}]), f"paged={paged}"


def test_submit_decode_rejects_impossible_request():
    paged = LLMEngine("p", get_config("tiny-lite-llm"), max_len=128,
                      seed=0, paged=True, block_size=8, num_blocks=4)
    paged.op_prefill([{"sid": "a", "text": "hi"}])
    with pytest.raises(ValueError, match="never fit"):
        paged.submit_decode("a", 100)


def test_prefill_backpressure_raises_after_timeout():
    paged = LLMEngine("p", get_config("tiny-lite-llm"), max_len=128,
                      seed=0, paged=True, block_size=8, num_blocks=6)
    paged.ALLOC_TIMEOUT = 0.2
    paged.op_prefill([{"sid": "a", "text": " ".join(["w"] * 30)}])
    with pytest.raises(kvc.OutOfBlocks):
        paged.op_prefill([{"sid": "b", "text": " ".join(["v"] * 30)}])
    paged.release("a")                       # frees the pool -> b fits now
    paged.op_prefill([{"sid": "b", "text": " ".join(["v"] * 30)}])


def test_pool_routing_avoids_block_exhausted_replica():
    """EnginePool routing: a replica whose block pool is exhausted loses
    both batch and decode routing to a replica with free blocks, even at
    higher token load."""
    full = SimLLMEngine("r0", paged=True, block_size=8, num_blocks=4)
    free = SimLLMEngine("r1", paged=True, block_size=8, num_blocks=4)
    pool = EnginePool([full, free], name="llm")
    full.states["s"] = {"pos": 32}                   # 4/4 blocks used
    assert full.kv_free_blocks() == 0
    pool.note_queued(1, 500)                         # r1 busier by tokens
    assert pool.least_loaded() == 1
    assert pool.least_loaded_decode() == 1
    full.states.clear()                              # blocks freed
    assert pool.least_loaded() == 0


def test_sim_engine_block_accounting_counts_prefix_once():
    sim = SimLLMEngine("s", paged=True, block_size=8)
    sim.use_prefix_cache = True
    instr = " ".join(f"i{k}" for k in range(16))     # 16 tok = 2 blocks
    sim.get_prefix_state(instr)
    assert sim.kv_blocks() == 2
    # two queries sharing the instruction: its tokens are excluded from
    # their pos, so the prefix's 2 blocks appear exactly once
    sim.op_prefill([{"sid": "q1", "text": instr + " one two three"}])
    sim.op_prefill([{"sid": "q2", "text": instr + " four five six"}])
    assert sim.kv_blocks() == 2 + 1 + 1
    assert sim.kv_occupancy() == 4 * 8


# ---------------------------------------------------------------------------
# model-level paged equivalence (MLA archs have no engine-scale config)

def test_apply_model_paged_matches_dense_mla():
    cfg = get_config("deepseek-v3-671b").reduced()
    assert cfg.attention_kind == "mla"
    from repro.models.transformer import init_params
    params = init_params(cfg, jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (2, 8), 0, cfg.vocab_size)
    cache = kvc.init_cache(cfg, 2, 16)
    ld, _, _ = apply_model(cfg, params, toks, cache, 0)
    pool = kvc.init_paged_pool(cfg, 8, 4)
    bt = jnp.array([[1, 2], [3, 4]], jnp.int32)
    lp, _, _ = apply_model(cfg, params, toks, pool, 0, block_tables=bt)
    np.testing.assert_allclose(np.asarray(ld[:, -1]), np.asarray(lp[:, -1]),
                               rtol=1e-5, atol=1e-5)


def test_init_paged_pool_rejects_recurrent_state():
    with pytest.raises(ValueError, match="rwkv|hybrid"):
        kvc.init_paged_pool(get_config("rwkv6-3b"), 8, 16)
