"""Chunked prefill with stall-free mixed prefill/decode iterations:
chunked == monolithic token identity (dense + paged, legacy + continuous
loop), mid-prefill decode admission/eviction interleaving, token-budget
admission (never exceeded), flag-off identity, prefix-fork and
speculative-decode composition, and the post-chunk admission re-check."""
import itertools
import threading
import time

import pytest

import repro.core.passes as passes_mod
import repro.core.pgraph as pgraph_mod
import repro.core.primitives as prims_mod
import repro.core.runtime as runtime_mod
from repro.configs.base import get_config
from repro.engines.decode_loop import ContinuousDecodeLoop, PrefillJob
from repro.engines.llm_engine import LLMEngine
from repro.engines.sim_engines import SimLLMEngine, build_sim_engines

CFG = get_config("tiny-lite-llm")
LONG = " ".join(f"tok{i}" for i in range(90))


def _engine(*, paged=False, chunked=False, **kw):
    kw.setdefault("max_len", 256)
    kw.setdefault("max_batch", 4)
    return LLMEngine("t", CFG, paged=paged, chunked_prefill=chunked,
                     **kw)


def _wait(pred, timeout=30.0):
    t0 = time.time()
    while time.time() - t0 < timeout:
        if pred():
            return True
        time.sleep(0.002)
    return False


# ---------------------------------------------------------------------------
# Token identity: chunked == monolithic by construction

@pytest.mark.parametrize("paged", [False, True])
def test_prefill_chunked_matches_monolithic(paged):
    """The resumable-cursor path must land the sequence in exactly the
    monolithic prefill state: same pos, same next-token prediction, and
    an identical greedy continuation."""
    a = _engine(paged=paged)
    sa, toks, _ = a._prepare_prefill_task({"sid": "x", "text": LONG})
    a.prefill_batch([(sa, toks)])

    b = _engine(paged=paged)
    sb, toks_b, _ = b._prepare_prefill_task({"sid": "x", "text": LONG})
    assert toks_b == toks
    b.prefill_chunked([(sb, toks_b)], chunk=32)

    assert (sa.pos, sa.last_token) == (sb.pos, sb.last_token)
    assert a.op_decode([{"sid": "x", "max_new": 8}]) == \
        b.op_decode([{"sid": "x", "max_new": 8}])


@pytest.mark.parametrize("paged", [False, True])
def test_loop_chunked_prefill_token_identity(paged):
    """op_prefill with chunked_prefill on streams the prompt through the
    continuous loop's mixed passes; the decoded continuation must equal
    the flag-off monolithic path token for token."""
    def run(chunked):
        eng = _engine(paged=paged, chunked=chunked, prefill_chunk=32)
        eng.op_prefill([{"sid": "a", "text": LONG}])
        out = eng.op_decode([{"sid": "a", "max_new": 8}])[0]
        eng.stop_decode_loop()
        return out

    assert run(True) == run(False)


@pytest.mark.parametrize("paged", [False, True])
def test_mixed_iterations_token_identity_with_resident_decodes(paged):
    """A long prompt arriving while decodes are resident advances in
    chunks BETWEEN their iterations; every sequence — the co-resident
    decodes and the chunked prompt's own continuation — must match the
    sequential monolithic run exactly."""
    def run(chunked):
        eng = _engine(paged=paged, chunked=chunked, prefill_chunk=16,
                      token_budget=24)
        # warm the hash tokenizer's id->word table up front: decoded
        # TEXT renders an id as a word only once that word has been
        # encoded, and the two runs encode LONG at different times
        # (token ids are what identity is asserted over)
        eng.tok.encode(LONG)
        eng.op_prefill([{"sid": "d1", "text": "short prompt one"},
                        {"sid": "d2", "text": "another short prompt"}])
        # same co-resident decode batch in both runs — only the LONG
        # prompt's prefill mode differs (loop chunks vs one monolithic
        # forward once the decodes are done)
        s1 = eng.submit_decode("d1", 20)
        s2 = eng.submit_decode("d2", 20)
        assert _wait(lambda: s1.steps >= 2)
        if chunked:
            job = eng.submit_prefill({"sid": "long", "text": LONG})
            job.wait(120)
            assert job.chunks > 1        # genuinely chunked
            outs = [s1.wait(120), s2.wait(120)]
        else:
            outs = [s1.wait(120), s2.wait(120)]
            eng.op_prefill([{"sid": "long", "text": LONG}])
        outs.append(eng.op_decode([{"sid": "long", "max_new": 8}])[0])
        eng.stop_decode_loop()
        return outs

    assert run(True) == run(False)


def test_mid_prefill_decode_admission_and_eviction():
    """Decode admissions and evictions must interleave with a long
    prompt's chunks: a decode submitted mid-prefill is admitted before
    the prefill finishes, and a short decode finishes (is evicted) while
    the prompt is still chunking."""
    eng = _engine(paged=True, chunked=True, prefill_chunk=8,
                  token_budget=12, max_len=384)
    eng.op_prefill([{"sid": "d1", "text": "short prompt one"}])
    s1 = eng.submit_decode("d1", 6)          # evicted mid-prefill
    job = eng.submit_prefill({"sid": "long", "text": LONG})
    assert _wait(lambda: job.chunks >= 1)
    eng.op_prefill([{"sid": "d2", "text": "another short prompt"}])
    s2 = eng.submit_decode("d2", 6)          # admitted mid-prefill
    job.wait(120)
    s1.wait(120)
    s2.wait(120)
    loop = eng._decode_loop
    first_chunk = min(i for _, i, _ in loop.prefill_chunks)
    last_chunk = max(i for _, i, _ in loop.prefill_chunks)
    evict_d1 = next(i for sid, i, _ in loop.evictions if sid == "d1")
    admit_d2 = next(i for sid, i in loop.admissions if sid == "d2")
    assert first_chunk < evict_d1 <= last_chunk + 1
    assert first_chunk < admit_d2 <= last_chunk
    eng.stop_decode_loop()


# ---------------------------------------------------------------------------
# Token-budget admission

def _budget_holds(loop: ContinuousDecodeLoop):
    for dcost, planned, landed in loop.mixed_log:
        assert landed <= planned
        assert planned <= max(0, loop.token_budget - dcost), \
            (dcost, planned, loop.token_budget)


def test_token_budget_never_exceeded_sim():
    """Every mixed pass: decode query tokens are packed first and
    prefill chunks only ever take the leftover budget."""
    eng = SimLLMEngine("s", max_batch=4, decode_ms_per_step=2.0,
                       prefill_ms_per_tok=0.05, prefill_setup=1.0,
                       chunked_prefill=True, prefill_chunk=16,
                       token_budget=20)
    seqs = [eng.submit_decode(f"d{i}", 30) for i in range(3)]
    jobs = [eng.submit_prefill({"sid": f"p{i}", "text": _words(64)})
            for i in range(3)]
    for j in jobs:
        j.wait(60)
    for s in seqs:
        s.wait(60)
    loop = eng._decode_loop
    assert loop.mixed_log, "no mixed passes ran"
    _budget_holds(loop)
    eng.stop_decode_loop()


def _words(n):
    return " ".join(f"w{i}" for i in range(n))


def test_token_budget_property():
    """Property sweep over budget/chunk/decode-load combinations: the
    per-pass budget is never exceeded by planned prefill tokens, and
    decodes always advance even when the budget is below the resident
    decode cost (prefill is simply starved, never the decodes)."""
    hypothesis = pytest.importorskip(
        "hypothesis", reason="property tests need hypothesis")
    st = pytest.importorskip("hypothesis.strategies")
    given, settings = hypothesis.given, hypothesis.settings

    @settings(max_examples=10, deadline=None)
    @given(budget=st.integers(1, 40), chunk=st.integers(1, 32),
           ndec=st.integers(0, 4), nprompts=st.integers(1, 3))
    def check(budget, chunk, ndec, nprompts):
        eng = SimLLMEngine("s", max_batch=4, decode_ms_per_step=1.0,
                           prefill_ms_per_tok=0.02, prefill_setup=0.5,
                           chunked_prefill=True, prefill_chunk=chunk,
                           token_budget=budget)
        seqs = [eng.submit_decode(f"d{i}", 8) for i in range(ndec)]
        jobs = [eng.submit_prefill({"sid": f"p{i}", "text": _words(40)})
                for i in range(nprompts)]
        for s in seqs:
            s.wait(60)
        for j in jobs:
            j.wait(60)
        _budget_holds(eng._decode_loop)
        eng.stop_decode_loop()

    check()


# ---------------------------------------------------------------------------
# Flag-off identity

def test_flag_off_monolithic_path_untouched():
    """chunked_prefill=False must keep op_prefill the monolithic
    whole-prompt forward: no decode loop is started, exactly one engine
    call per op_prefill, and the loop built later has no prefill queue
    armed (submit_prefill refuses)."""
    eng = _engine(paged=False, chunked=False)
    eng.op_prefill([{"sid": "a", "text": LONG}])
    assert eng._decode_loop is None          # never touched the loop
    assert eng.stats["calls"] == 1           # one monolithic forward
    with pytest.raises(RuntimeError, match="chunked_prefill is disabled"):
        eng.submit_prefill({"sid": "b", "text": "x"})
    loop = eng.start_decode_loop()
    assert loop.prefill_chunk == 0 and loop.token_budget == 0
    with pytest.raises(RuntimeError, match="chunked prefill disabled"):
        loop.submit_prefill(PrefillJob("b", None, [1]))
    eng.stop_decode_loop()


def test_runtime_flag_off_scheduler_keeps_batch_path():
    """With chunked prefill off, the continuous scheduler must NOT pull
    prefill primitives out of batch formation."""
    engines = build_sim_engines()
    rt = runtime_mod.Runtime(engines, continuous_batching=True)
    try:
        for s in rt.scheds.values():
            assert not s.chunked
    finally:
        rt.shutdown()


# ---------------------------------------------------------------------------
# Composition: COW prefix forks and speculative decode

def test_chunked_prefill_with_prefix_fork_identity():
    """Chunked prefill over a copy-on-write forked instruction prefix
    (paged pool) must match the monolithic cold path token for token —
    only the suffix is chunked, against the shared prefix blocks."""
    instr = "system instruction used for every query"
    suffix = " ".join(f"q{i}" for i in range(70))

    def run(chunked):
        eng = _engine(paged=True, chunked=chunked, prefill_chunk=16)
        eng.get_prefix_state(instr)
        eng.use_prefix_cache = True
        eng.op_prefill([{"sid": "a", "text": f"{instr} {suffix}"}])
        out = eng.op_decode([{"sid": "a", "max_new": 8}])[0]
        eng.stop_decode_loop()
        return out, eng.stats["prefill_tokens"]

    (out_c, ntok_c), (out_m, ntok_m) = run(True), run(False)
    assert out_c == out_m
    assert ntok_c == ntok_m            # both prefilled only the suffix


def test_chunked_prefill_with_speculative_decode():
    """Mixed passes compose with speculative decoding: spec verify
    chunks advance resident decodes while a prompt chunks through, and
    outputs stay token-identical to the plain engine."""
    def run(spec):
        eng = _engine(paged=True, chunked=True, prefill_chunk=16,
                      token_budget=48, max_len=384)
        if spec:
            eng.enable_speculative(draft=None, k=3)
        eng.op_prefill([{"sid": "d", "text": "repeat repeat repeat"}])
        s = eng.submit_decode("d", 24)
        assert _wait(lambda: s.steps >= 1)
        job = eng.submit_prefill({"sid": "long", "text": LONG})
        job.wait(120)
        out = [s.wait(120), eng.op_decode([{"sid": "long",
                                            "max_new": 8}])[0]]
        eng.stop_decode_loop()
        return out

    assert run(True) == run(False)


# ---------------------------------------------------------------------------
# Paged backpressure and capacity

def test_submit_prefill_impossible_capacity_fails_loudly():
    eng = LLMEngine("t", CFG, max_len=256, max_batch=2, paged=True,
                    block_size=16, num_blocks=4, chunked_prefill=True,
                    prefill_chunk=16)
    with pytest.raises(ValueError, match="never fit"):
        eng.submit_prefill({"sid": "big", "text": LONG})


def test_chunk_declined_under_reservation_then_retried():
    """A planned chunk that cannot take unreserved free blocks is
    DECLINED (the loop never sleeps on prefill backpressure) and lands
    later once decodes finish and release their reservations."""
    eng = LLMEngine("t", CFG, max_len=256, max_batch=2, paged=True,
                    block_size=16, num_blocks=12, chunked_prefill=True,
                    prefill_chunk=32)
    eng.op_prefill([{"sid": "d", "text": "short prompt"}])
    s = eng.submit_decode("d", 40)           # reserves most of the pool
    assert _wait(lambda: s.steps >= 1)
    job = eng.submit_prefill({"sid": "p", "text": _words(60)})
    job.wait(120)
    s.wait(120)
    assert job.cursor == len(job.tokens)
    eng.stop_decode_loop()


# ---------------------------------------------------------------------------
# Bugfix: admission re-check after a prefill chunk lands

class _RecheckEngine(SimLLMEngine):
    """try_admit defers every decode until the first prefill chunk has
    landed — models a paged pool whose free blocks only materialize
    mid-pass. The loop must re-run try_admit in the SAME pass the chunk
    lands instead of reusing its pre-chunk admission decision."""

    def __init__(self):
        super().__init__("recheck", max_batch=2, decode_ms_per_step=1.0,
                         prefill_ms_per_tok=0.02, prefill_setup=0.5,
                         chunked_prefill=True, prefill_chunk=8,
                         token_budget=16)
        self.chunk_landed = False

    def try_admit(self, seq):
        return self.chunk_landed

    def mixed_iteration(self, seqs, pitems):
        super().mixed_iteration(seqs, pitems)
        if pitems:
            self.chunk_landed = True


def test_admit_rechecked_after_prefill_chunk_lands():
    eng = _RecheckEngine()
    seq = eng.submit_decode("d", 4)
    time.sleep(0.05)
    assert seq.t_admit is None               # deferred: no chunk yet
    job = eng.submit_prefill({"sid": "p", "text": _words(24)})
    job.wait(60)
    seq.wait(60)
    loop = eng._decode_loop
    first_chunk = min(i for _, i, _ in loop.prefill_chunks)
    admit_iter = next(i for sid, i in loop.admissions if sid == "d")
    # admitted by the post-chunk re-check of the SAME pass the chunk
    # landed in (both log the same post-increment iteration number) —
    # without the re-check the admission would land a pass later
    assert admit_iter == first_chunk
    eng.stop_decode_loop()


# ---------------------------------------------------------------------------
# Runtime end-to-end (sim): chunked == monolithic answers

def test_runtime_sim_chunked_identity():
    from repro.core.apps import ALL_APPS
    from repro.core.teola import Teola
    from repro.training.data import doc_corpus

    def run(chunked):
        runtime_mod._qid = itertools.count()
        prims_mod._counter = itertools.count()
        pgraph_mod._sid = itertools.count()
        passes_mod._uid = itertools.count()
        engines = build_sim_engines(chunked_prefill=chunked,
                                    prefill_chunk=32, token_budget=48)
        app = ALL_APPS["advanced_rag"](engines)
        orch = Teola(app, engines, policy="topo",
                     continuous_batching=True)
        docs = doc_corpus(2)
        outs = [orch.query({"question": f"what is fact {i} about optics",
                            "docs": docs}, timeout=300)[0]
                for i in range(2)]
        loop = engines["core_llm"]._decode_loop
        chunks = len(loop.prefill_chunks) if loop else 0
        for name in ("core_llm", "lite_llm"):
            assert orch.runtime.scheds[name].chunked == chunked
        orch.shutdown()
        return outs, chunks

    base, _ = run(False)
    got, nchunks = run(True)
    assert got == base
    assert nchunks > 0                 # prompts really went through the loop


def test_concurrent_submitters_fifo_progress():
    """Several scheduler threads queueing prompts concurrently while
    decodes run: all jobs and decodes complete, budget holds."""
    eng = SimLLMEngine("s", max_batch=4, decode_ms_per_step=1.0,
                       prefill_ms_per_tok=0.02, prefill_setup=0.5,
                       chunked_prefill=True, prefill_chunk=8,
                       token_budget=16)
    seqs = [eng.submit_decode(f"d{i}", 12) for i in range(2)]
    jobs, threads = [], []

    def submit(i):
        jobs.append(eng.submit_prefill({"sid": f"p{i}",
                                        "text": _words(30)}))

    for i in range(4):
        t = threading.Thread(target=submit, args=(i,))
        t.start()
        threads.append(t)
    for t in threads:
        t.join()
    for j in jobs:
        j.wait(60)
    for s in seqs:
        s.wait(60)
    _budget_holds(eng._decode_loop)
    eng.stop_decode_loop()
