"""Engine-pool subsystem tests: load-aware routing, sequence affinity,
pool-of-1 equivalence with the single-instance path, and streaming
decode chunks reaching a downstream primitive before sequence
completion."""
import itertools
import time

import pytest

import repro.core.passes as passes_mod
import repro.core.pgraph as pgraph_mod
import repro.core.primitives as prims_mod
import repro.core.runtime as runtime_mod
from repro.core import primitives as P
from repro.core.engine_pool import (EnginePool, RESIDENT_WEIGHT,
                                    estimate_tokens, pool_size, replicas_of)
from repro.core.primitives import Graph, Primitive
from repro.core.runtime import (NodeTask, PooledEngineScheduler,
                                QueryContext, Runtime)
from repro.core.streams import TokenStream
from repro.engines.sim_engines import SimLLMEngine, build_sim_engines


class FakeLLM:
    """Minimal stateful LLM engine: decode asserts the sequence's KV state
    is resident on THIS replica (the affinity invariant)."""
    kind = "llm"
    max_batch = 4

    def __init__(self, name="fake_llm"):
        self.name = name
        self.states = {}

    def clone(self, idx: int = 1):
        return FakeLLM(f"{self.name}.r{idx}")

    def kv_occupancy(self):
        return sum(self.states.values())

    def op_prefill(self, tasks):
        for t in tasks:
            self.states[t["sid"]] = self.states.get(t["sid"], 0) + 10
        return [None] * len(tasks)

    def op_decode(self, tasks):
        for t in tasks:
            assert t["sid"] in self.states, \
                f"{self.name}: decode for {t['sid']} but KV state absent"
        return ["out"] * len(tasks)


def _prim(op, sid=None, **cfg):
    config = dict(cfg)
    if sid is not None:
        config["sid"] = sid
    return Primitive(op=op, engine="llm", component="c", config=config,
                     produces={"out"})


def _wait(pred, timeout=5.0):
    t0 = time.time()
    while time.time() - t0 < timeout:
        if pred():
            return True
        time.sleep(0.002)
    return False


# ---------------------------------------------------------------------------
# EnginePool unit behavior

def test_replicate_shares_profile_not_state():
    pool = EnginePool.replicate(SimLLMEngine("llm"), 3, name="llm")
    assert len(pool) == 3 and pool_size(pool) == 3
    assert len(replicas_of(pool)) == 3
    a, b = pool[0], pool[1]
    assert a.prefix_cache is b.prefix_cache      # shared "weights"
    assert a.states is not b.states              # per-replica KV store
    assert a.dec_step == b.dec_step


def test_least_loaded_uses_tokens_and_kv_occupancy():
    pool = EnginePool.replicate(FakeLLM(), 2)
    assert pool.least_loaded() == 0              # tie -> first
    pool.note_queued(0, 100)
    assert pool.least_loaded() == 1
    pool.note_started(0, 100)                    # still outstanding
    assert pool.least_loaded() == 1
    pool.note_finished(0, 100)
    # now only KV occupancy distinguishes: park a sequence on replica 1
    pool[1].states["s"] = 1000
    assert pool.load(1) == pytest.approx(RESIDENT_WEIGHT * 1000)
    assert pool.least_loaded() == 0


def test_estimate_tokens_scales_with_op():
    dec = _prim(P.DECODE, max_new=32)
    pre = _prim(P.PREFILL)
    emb = Primitive(op=P.EMBEDDING, engine="e", component="c")
    assert estimate_tokens(dec) == 32
    assert estimate_tokens(pre) > estimate_tokens(emb)


# ---------------------------------------------------------------------------
# PooledEngineScheduler routing

def _sched(pool, executor):
    s = PooledEngineScheduler(pool, executor, policy="to")
    s.on_complete = lambda t: None
    s.start()
    return s


def test_router_prefers_least_loaded_replica():
    routed = []
    pool = EnginePool.replicate(FakeLLM(), 2)
    s = _sched(pool, lambda eng, batch: routed.append(eng.name))
    pool.note_queued(0, 10_000)                  # replica 0 is swamped
    ctx = QueryContext(Graph(), {})
    s.submit(NodeTask(_prim(P.PREFILL, sid="a"), ctx))
    assert _wait(lambda: routed)
    assert routed[0].endswith(".r1")
    s.stop()


def test_sequence_affinity_overrides_load():
    routed = []
    pool = EnginePool.replicate(FakeLLM(), 2)
    s = _sched(pool, lambda eng, batch: routed.append(eng.name))
    ctx = QueryContext(Graph(), {})
    s.submit(NodeTask(_prim(P.PREFILL, sid="a"), ctx))
    assert _wait(lambda: len(routed) == 1)
    home = routed[0]
    # make the home replica look terrible; the decode must still follow
    # its KV state
    idx = 0 if home == pool[0].name else 1
    pool.note_queued(idx, 100_000)
    s.submit(NodeTask(_prim(P.DECODE, sid="a"), ctx))
    assert _wait(lambda: len(routed) == 2)
    assert routed[1] == home
    s.stop()


def test_mixed_affinity_batch_is_partitioned():
    seen = []                                    # (engine, [sids])
    pool = EnginePool.replicate(FakeLLM(), 2)

    def executor(eng, batch):
        seen.append((eng.name, [t.prim.config["sid"] for t in batch]))

    s = PooledEngineScheduler(pool, executor, policy="to")
    s.on_complete = lambda t: None
    # pin sid a -> replica 0, sid b -> replica 1 (scheduler not started yet)
    ctx = QueryContext(Graph(), {})
    s.affinity[(ctx.qid, "a")] = 0
    s.affinity[(ctx.qid, "b")] = 1
    s.submit(NodeTask(_prim(P.DECODE, sid="a"), ctx))
    s.submit(NodeTask(_prim(P.DECODE, sid="b"), ctx))
    pool[0].states["a"] = 10
    pool[1].states["b"] = 10
    s.start()
    assert _wait(lambda: sum(len(x[1]) for x in seen) == 2)
    by_engine = {name: sids for name, sids in seen}
    for name, sids in by_engine.items():
        if "a" in sids:
            assert name == pool[0].name
        if "b" in sids:
            assert name == pool[1].name
    s.stop()


def test_end_to_end_on_pool_releases_and_completes():
    engines = build_sim_engines(llm_instances=2)
    from repro.core.apps import advanced_rag
    from repro.core.teola import Teola
    orch = Teola(advanced_rag(engines), engines)
    from repro.training.data import doc_corpus
    out, ctx = orch.query({"question": "what is fact 3 about optics",
                           "docs": doc_corpus(2)}, timeout=300)
    assert ctx.error is None and out
    sched = orch.runtime.scheds["core_llm"]
    assert isinstance(sched, PooledEngineScheduler)
    assert sched.routes                          # router actually ran
    for rep in engines["core_llm"]:
        assert len(rep.states) == 0              # released on finish
    assert not sched.affinity                    # forgotten on finish
    orch.shutdown()


# ---------------------------------------------------------------------------
# Pool-of-1 equivalence with the single-instance path

def _reset_counters():
    runtime_mod._qid = itertools.count()
    prims_mod._counter = itertools.count()
    pgraph_mod._sid = itertools.count()
    passes_mod._uid = itertools.count()


def _answer(pooled: bool, streaming: bool = False):
    from repro.core.apps import advanced_rag
    from repro.core.teola import Teola
    from repro.training.data import doc_corpus
    _reset_counters()
    engines = build_sim_engines()
    if pooled:
        engines = {k: (EnginePool.replicate(v, 1, name=k)
                       if hasattr(v, "clone") else v)
                   for k, v in engines.items()}
    orch = Teola(advanced_rag(engines), engines, streaming=streaming)
    out, ctx = orch.query({"question": "what is fact 3 about optics",
                           "docs": doc_corpus(2)}, timeout=300)
    orch.shutdown()
    assert ctx.error is None
    return out


def test_pool_of_one_byte_identical_to_single_instance():
    single = _answer(pooled=False)
    pooled = _answer(pooled=True)       # same ops through the pool router
    assert pooled == single


def test_streaming_byte_identical_final_output():
    assert _answer(pooled=False, streaming=True) == _answer(pooled=False)


# ---------------------------------------------------------------------------
# Streaming decode -> downstream pipelining

def test_stream_chunks_reach_downstream_before_completion():
    llm = SimLLMEngine("llm", decode_ms_per_step=60.0)
    rt = Runtime({"llm": llm}, policy="to", streaming=True)

    g = Graph(query_id="q")
    pre = Primitive(op=P.PREFILL, engine="llm", component="gen",
                    consumes={"question"}, produces={"state:s"},
                    config={"sid": "s", "instruction": "hello world",
                            "parts": [("instr", None),
                                      ("q", "question")]})
    dec = Primitive(op=P.DECODE, engine="llm", component="gen",
                    consumes={"state:s"}, produces={"draft"},
                    config={"sid": "s", "max_new": 24})
    agg = Primitive(op=P.AGGREGATE, engine="control", component="agg",
                    consumes={"draft"}, produces={"final"})
    for p in (pre, dec, agg):
        g.add(p)
    g.edge(pre, dec)
    g.edge(dec, agg)
    g.assign_depths()

    ctx = rt.submit(g, {"question": "what is up"}, output_key="final")
    # sniff the TokenStream out of the store while the decode is running
    stream = None

    def saw_stream():
        nonlocal stream
        v = ctx.store.get("draft")
        if isinstance(v, TokenStream):
            stream = v
            return True
        return False

    assert _wait(saw_stream, timeout=10), "stream never appeared in store"
    assert ctx.done.wait(60)
    assert ctx.error is None

    dec_t1 = ctx.node_spans[dec.pid][1]
    agg_t0 = ctx.node_spans[agg.pid][0]
    # the downstream primitive was dispatched BEFORE the decode finished
    assert agg_t0 < dec_t1
    # and chunks arrived progressively, starting before completion
    assert len(stream.chunk_times) >= 2
    assert stream.chunk_times[0] < dec_t1
    # final store layout is the plain text, byte-equal to the stream text
    assert isinstance(ctx.store["draft"], str)
    assert ctx.store["draft"] == stream.wait_text()
    assert ctx.store["final"] == [ctx.store["draft"]]
    rt.shutdown()


def test_streaming_disabled_keeps_plain_path():
    llm = SimLLMEngine("llm")
    rt = Runtime({"llm": llm}, policy="to", streaming=False)
    g = Graph(query_id="q")
    pre = Primitive(op=P.PREFILL, engine="llm", component="gen",
                    consumes={"question"}, produces={"state:s"},
                    config={"sid": "s", "instruction": "hi",
                            "parts": [("instr", None)]})
    dec = Primitive(op=P.DECODE, engine="llm", component="gen",
                    consumes={"state:s"}, produces={"draft"},
                    config={"sid": "s", "max_new": 8})
    for p in (pre, dec):
        g.add(p)
    g.edge(pre, dec)
    g.assign_depths()
    ctx = rt.submit(g, {"question": "x"}, output_key="draft")
    assert ctx.done.wait(60)
    assert ctx.error is None
    assert isinstance(ctx.store["draft"], str)
    assert not ctx.early_edges
    rt.shutdown()
