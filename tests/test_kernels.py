"""Pallas kernel validation: interpret-mode execution vs pure-jnp oracles,
swept over shapes and dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

TOL = {jnp.float32: dict(rtol=2e-5, atol=2e-5),
       jnp.bfloat16: dict(rtol=2e-2, atol=2e-2)}


@pytest.mark.parametrize("B,Sq,H,K,hd,T,prefix", [
    (1, 128, 4, 4, 64, 128, 0),        # plain causal (MHA)
    (2, 128, 4, 2, 64, 256, 64),       # GQA + prefix (partial prefill)
    (1, 256, 8, 1, 128, 512, 128),     # MQA, bigger head dim
    (2, 64, 4, 2, 64, 256, 192),       # chunk smaller than block
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_prefill_sweep(B, Sq, H, K, hd, T, prefix, dtype):
    ks = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(ks[0], (B, Sq, H, hd), dtype)
    k = jax.random.normal(ks[1], (B, T, K, hd), dtype)
    v = jax.random.normal(ks[2], (B, T, K, hd), dtype)
    o = ops.flash_prefill(q, k, v, prefix_len=prefix, bq=64, bk=64)
    o_ref = ref.flash_prefill_ref(q, k, v, prefix_len=prefix)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(o_ref, np.float32), **TOL[dtype])


@pytest.mark.parametrize("window,cap", [(64, None), (None, 30.0),
                                        (100, 50.0)])
def test_flash_prefill_window_softcap(window, cap):
    ks = jax.random.split(jax.random.key(1), 3)
    B, Sq, H, K, hd, T, prefix = 2, 128, 4, 2, 64, 256, 96
    q = jax.random.normal(ks[0], (B, Sq, H, hd))
    k = jax.random.normal(ks[1], (B, T, K, hd))
    v = jax.random.normal(ks[2], (B, T, K, hd))
    o = ops.flash_prefill(q, k, v, prefix_len=prefix, window=window,
                          cap=cap, bq=64, bk=64)
    o_ref = ref.flash_prefill_ref(q, k, v, prefix_len=prefix, window=window,
                                  cap=cap)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("B,H,K,hd,T", [
    (2, 4, 2, 64, 256), (1, 8, 8, 128, 128), (3, 4, 1, 64, 512),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_sweep(B, H, K, hd, T, dtype):
    ks = jax.random.split(jax.random.key(2), 3)
    q = jax.random.normal(ks[0], (B, H, hd), dtype)
    k = jax.random.normal(ks[1], (B, T, K, hd), dtype)
    v = jax.random.normal(ks[2], (B, T, K, hd), dtype)
    length = jnp.arange(1, B + 1) * (T // (B + 1)) + 1
    o = ops.decode_attention(q, k, v, length, bk=64)
    o_ref = ref.decode_attention_ref(q, k, v, length)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(o_ref, np.float32), **TOL[dtype])


def test_decode_attention_window():
    ks = jax.random.split(jax.random.key(3), 3)
    B, H, K, hd, T = 2, 4, 2, 64, 256
    q = jax.random.normal(ks[0], (B, H, hd))
    k = jax.random.normal(ks[1], (B, T, K, hd))
    v = jax.random.normal(ks[2], (B, T, K, hd))
    length = jnp.array([200, 256])
    o = ops.decode_attention(q, k, v, length, window=64, bk=64)
    o_ref = ref.decode_attention_ref(q, k, v, length, window=64)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("B,H,K,hd,nb,bs,maxblk", [
    (2, 4, 2, 64, 16, 16, 8), (1, 8, 8, 128, 8, 32, 4),
    (3, 4, 1, 64, 40, 8, 12),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_decode_attention_sweep(B, H, K, hd, nb, bs, maxblk, dtype):
    """Pallas paged kernel (block-table index maps) vs the XLA take-based
    reference vs DENSE decode attention on the gathered view — all three
    must agree on randomly permuted physical block assignments."""
    ks = jax.random.split(jax.random.key(8), 3)
    q = jax.random.normal(ks[0], (B, H, hd), dtype)
    k_pool = jax.random.normal(ks[1], (nb, bs, K, hd), dtype)
    v_pool = jax.random.normal(ks[2], (nb, bs, K, hd), dtype)
    # distinct random physical blocks per sequence (vLLM-style scatter)
    perm = jax.random.permutation(jax.random.key(9), nb)
    tables = perm[: B * maxblk].reshape(B, maxblk).astype(jnp.int32)
    length = jnp.arange(1, B + 1) * (maxblk * bs // (B + 1)) + 1
    o = ops.paged_decode_attention(q, k_pool, v_pool, tables, length)
    o_ref = ref.paged_decode_attention_ref(q, k_pool, v_pool, tables, length)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(o_ref, np.float32), **TOL[dtype])
    gathered_k = jnp.take(k_pool, tables, axis=0).reshape(B, -1, K, hd)
    gathered_v = jnp.take(v_pool, tables, axis=0).reshape(B, -1, K, hd)
    o_dense = ref.decode_attention_ref(q, gathered_k, gathered_v, length)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(o_dense, np.float32), **TOL[dtype])


def test_paged_decode_attention_window_softcap():
    ks = jax.random.split(jax.random.key(10), 3)
    B, H, K, hd, nb, bs, maxblk = 2, 4, 2, 64, 16, 16, 8
    q = jax.random.normal(ks[0], (B, H, hd))
    k_pool = jax.random.normal(ks[1], (nb, bs, K, hd))
    v_pool = jax.random.normal(ks[2], (nb, bs, K, hd))
    tables = jnp.arange(B * maxblk, dtype=jnp.int32).reshape(B, maxblk) % nb
    length = jnp.array([100, 128])
    o = ops.paged_decode_attention(q, k_pool, v_pool, tables, length,
                                   window=64, cap=30.0)
    o_ref = ref.paged_decode_attention_ref(q, k_pool, v_pool, tables,
                                           length, window=64, cap=30.0)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("B,Sq,H,K,hd,nb,bs,maxblk", [
    (2, 5, 4, 2, 64, 16, 16, 8),       # GQA, draft_k=4 chunk
    (1, 3, 8, 8, 128, 8, 32, 4),       # MHA, bigger head dim
    (3, 8, 4, 1, 64, 40, 8, 12),       # MQA, chunk spans blocks
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_verify_attention_sweep(B, Sq, H, K, hd, nb, bs, maxblk, dtype):
    """Pallas multi-token verification kernel (q_len=Sq, causal
    intra-chunk mask, block-table index maps) vs the XLA take-based
    reference on randomly permuted physical blocks."""
    ks = jax.random.split(jax.random.key(11), 3)
    q = jax.random.normal(ks[0], (B, Sq, H, hd), dtype)
    k_pool = jax.random.normal(ks[1], (nb, bs, K, hd), dtype)
    v_pool = jax.random.normal(ks[2], (nb, bs, K, hd), dtype)
    perm = jax.random.permutation(jax.random.key(12), nb)
    tables = perm[: B * maxblk].reshape(B, maxblk).astype(jnp.int32) % nb
    length = jnp.arange(1, B + 1) * (maxblk * bs // (B + 1)) + Sq
    o = ops.verify_attention(q, k_pool, v_pool, tables, length)
    o_ref = ref.verify_attention_ref(q, k_pool, v_pool, tables, length)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(o_ref, np.float32), **TOL[dtype])


def test_verify_attention_window_softcap():
    ks = jax.random.split(jax.random.key(13), 3)
    B, Sq, H, K, hd, nb, bs, maxblk = 2, 4, 4, 2, 64, 16, 16, 8
    q = jax.random.normal(ks[0], (B, Sq, H, hd))
    k_pool = jax.random.normal(ks[1], (nb, bs, K, hd))
    v_pool = jax.random.normal(ks[2], (nb, bs, K, hd))
    tables = jnp.arange(B * maxblk, dtype=jnp.int32).reshape(B, maxblk) % nb
    length = jnp.array([90, 128])
    o = ops.verify_attention(q, k_pool, v_pool, tables, length,
                             window=48, cap=30.0)
    o_ref = ref.verify_attention_ref(q, k_pool, v_pool, tables, length,
                                     window=48, cap=30.0)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref),
                               rtol=2e-5, atol=2e-5)


def test_verify_attention_qlen1_equals_paged_decode():
    """Sq == 1 must reduce exactly to the paged decode kernel (the
    speculative verify path generalizes it, never forks from it)."""
    ks = jax.random.split(jax.random.key(14), 3)
    B, H, K, hd, nb, bs, maxblk = 2, 4, 2, 64, 16, 16, 8
    q = jax.random.normal(ks[0], (B, 1, H, hd))
    k_pool = jax.random.normal(ks[1], (nb, bs, K, hd))
    v_pool = jax.random.normal(ks[2], (nb, bs, K, hd))
    tables = jnp.arange(B * maxblk, dtype=jnp.int32).reshape(B, maxblk) % nb
    length = jnp.array([70, 113])
    o = ops.verify_attention(q, k_pool, v_pool, tables, length)
    od = ops.paged_decode_attention(q[:, 0], k_pool, v_pool, tables, length)
    np.testing.assert_allclose(np.asarray(o[:, 0]), np.asarray(od),
                               rtol=2e-5, atol=2e-5)


def test_verify_attention_causal_intra_chunk():
    """Draft position i must be blind to drafts > i: extending the chunk
    with different future tokens cannot change earlier positions'
    outputs (the property greedy-prefix acceptance relies on)."""
    ks = jax.random.split(jax.random.key(15), 4)
    B, Sq, H, K, hd, nb, bs, maxblk = 1, 4, 4, 2, 64, 8, 16, 4
    q = jax.random.normal(ks[0], (B, Sq, H, hd))
    k_pool = jax.random.normal(ks[1], (nb, bs, K, hd))
    v_pool = jax.random.normal(ks[2], (nb, bs, K, hd))
    tables = jnp.arange(B * maxblk, dtype=jnp.int32).reshape(B, maxblk)
    length = jnp.array([40])
    o = ops.verify_attention(q, k_pool, v_pool, tables, length)
    # perturb the KV at the LAST chunk position (absolute pos 39)
    k2 = k_pool.at[39 // bs, 39 % bs].add(3.0)
    v2 = v_pool.at[39 // bs, 39 % bs].add(3.0)
    o2 = ops.verify_attention(q, k2, v2, tables, length)
    np.testing.assert_allclose(np.asarray(o[:, :-1]),
                               np.asarray(o2[:, :-1]), rtol=2e-5,
                               atol=2e-5)
    assert not np.allclose(np.asarray(o[:, -1]), np.asarray(o2[:, -1]))


def test_pallas_paged_attn_optflag_matches_gather_path():
    """Model-level integration: with the 'pallas_paged_attn' optflag the
    paged GQA layers route through the Pallas verify kernel; logits must
    match the XLA gather path for prefill-shaped AND verify-shaped
    chunks."""
    from repro.configs.base import get_config
    from repro.launch import optflags
    from repro.models.transformer import apply_model, init_params
    from repro.serving import kv_cache as kvc

    cfg = get_config("tiny-lite-llm")     # includes a sliding-window layer
    params = init_params(cfg, jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (2, 5), 0, cfg.vocab_size)
    tables = jnp.array([[1, 2, 3], [4, 5, 6]], jnp.int32)
    pos = jnp.array([7, 3], jnp.int32)

    def run_once():
        pool = kvc.init_paged_pool(cfg, 8, 8)
        # context before the chunk, then the 5-token verify chunk
        ctx = jax.random.randint(jax.random.key(2), (2, 3), 0,
                                 cfg.vocab_size)
        _, pool, _ = apply_model(cfg, params, ctx, pool, pos - 3,
                                 block_tables=tables)
        logits, _, _ = apply_model(cfg, params, toks, pool, pos,
                                   block_tables=tables)
        return np.asarray(logits)

    base = run_once()
    optflags.set_flags(["pallas_paged_attn"])
    try:
        got = run_once()
    finally:
        optflags.set_flags([])
    np.testing.assert_allclose(got, base, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("B,S,H,hd,chunk", [
    (1, 64, 2, 32, 16), (2, 128, 4, 64, 64), (1, 96, 3, 64, 32),
])
def test_rwkv6_scan_sweep(B, S, H, hd, chunk):
    ks = jax.random.split(jax.random.key(4), 5)
    r, k, v = [jax.random.normal(kk, (B, S, H, hd)) * 0.5 for kk in ks[:3]]
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (B, S, H, hd))) * 0.5 + 0.4
    u = jax.random.normal(ks[4], (H, hd)) * 0.1
    s0 = jax.random.normal(jax.random.key(5), (B, H, hd, hd)) * 0.1
    y, sf = ops.rwkv6_scan(r, k, v, w, u, s0, chunk=chunk)
    y_ref, sf_ref = ref.rwkv6_scan_ref(r, k, v, w, u, s0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(sf), np.asarray(sf_ref),
                               rtol=2e-4, atol=2e-4)


def test_rwkv6_scan_state_carry():
    """Scanning two halves with carried state == one scan (the property the
    engine's chunked prefill relies on)."""
    ks = jax.random.split(jax.random.key(6), 5)
    B, S, H, hd = 1, 128, 2, 32
    r, k, v = [jax.random.normal(kk, (B, S, H, hd)) * 0.5 for kk in ks[:3]]
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (B, S, H, hd))) * 0.5 + 0.4
    u = jnp.zeros((H, hd))
    s0 = jnp.zeros((B, H, hd, hd))
    y_full, sf_full = ops.rwkv6_scan(r, k, v, w, u, s0, chunk=32)
    y1, s1 = ops.rwkv6_scan(r[:, :64], k[:, :64], v[:, :64], w[:, :64], u,
                            s0, chunk=32)
    y2, s2 = ops.rwkv6_scan(r[:, 64:], k[:, 64:], v[:, 64:], w[:, 64:], u,
                            s1, chunk=32)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_full), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(s2), np.asarray(sf_full),
                               rtol=1e-5, atol=1e-5)


def test_flash_prefill_chunked_equals_one_shot():
    """Teola Table-3 property: prefilling in two chunks (partial+full)
    returns the same attention output for the second chunk as a single
    full prefill computes for those positions."""
    ks = jax.random.split(jax.random.key(7), 3)
    B, S, H, K, hd = 1, 256, 4, 2, 64
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, K, hd))
    v = jax.random.normal(ks[2], (B, S, K, hd))
    one = ops.flash_prefill(q, k, v, prefix_len=0, bq=64, bk=64)
    part2 = ops.flash_prefill(q[:, 128:], k, v, prefix_len=128, bq=64,
                              bk=64)
    np.testing.assert_allclose(np.asarray(one[:, 128:]), np.asarray(part2),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# chunk_prefill_attention: chunked prefill over the paged block pool

@pytest.mark.parametrize("B,Sq,H,K,hd,nb,bs,maxblk,starts", [
    (2, 64, 4, 2, 64, 24, 16, 8, (37, 0)),     # GQA, mid-block + zero start
    (1, 32, 8, 1, 128, 12, 32, 4, (64,)),      # MQA, start at block boundary
    (3, 16, 4, 4, 64, 40, 8, 12, (5, 48, 79)),  # MHA, tiny blocks
    (2, 128, 4, 2, 64, 24, 16, 12, (16, 33)),  # chunk > block, q tiled
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_chunk_prefill_attention_sweep(B, Sq, H, K, hd, nb, bs, maxblk,
                                       starts, dtype):
    ks = jax.random.split(jax.random.key(21), 3)
    q = jax.random.normal(ks[0], (B, Sq, H, hd), dtype)
    k_pool = jax.random.normal(ks[1], (nb, bs, K, hd), dtype)
    v_pool = jax.random.normal(ks[2], (nb, bs, K, hd), dtype)
    tables = (jnp.arange(B * maxblk, dtype=jnp.int32).reshape(B, maxblk)
              % (nb - 1)) + 1
    start = jnp.array(starts, jnp.int32)
    o = ops.chunk_prefill_attention(q, k_pool, v_pool, tables, start, bq=32)
    o_ref = ref.chunk_prefill_attention_ref(q, k_pool, v_pool, tables,
                                            start)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(o_ref, np.float32), **TOL[dtype])


@pytest.mark.parametrize("window,cap", [(24, None), (None, 30.0),
                                        (40, 50.0)])
def test_chunk_prefill_attention_window_softcap(window, cap):
    ks = jax.random.split(jax.random.key(22), 3)
    B, Sq, H, K, hd, nb, bs, maxblk = 2, 64, 4, 2, 64, 24, 16, 8
    q = jax.random.normal(ks[0], (B, Sq, H, hd))
    k_pool = jax.random.normal(ks[1], (nb, bs, K, hd))
    v_pool = jax.random.normal(ks[2], (nb, bs, K, hd))
    tables = (jnp.arange(B * maxblk, dtype=jnp.int32).reshape(B, maxblk)
              % (nb - 1)) + 1
    start = jnp.array([41, 8], jnp.int32)
    o = ops.chunk_prefill_attention(q, k_pool, v_pool, tables, start,
                                    window=window, cap=cap, bq=32)
    o_ref = ref.chunk_prefill_attention_ref(q, k_pool, v_pool, tables,
                                            start, window=window, cap=cap)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref),
                               rtol=2e-5, atol=2e-5)


def test_chunk_prefill_qlen1_equals_paged_decode():
    """Sq == 1 at start = length - 1 must reduce exactly to the paged
    decode kernel (the chunk kernel generalizes it, never forks)."""
    ks = jax.random.split(jax.random.key(23), 3)
    B, H, K, hd, nb, bs, maxblk = 2, 4, 2, 64, 16, 16, 8
    q = jax.random.normal(ks[0], (B, 1, H, hd))
    k_pool = jax.random.normal(ks[1], (nb, bs, K, hd))
    v_pool = jax.random.normal(ks[2], (nb, bs, K, hd))
    tables = jnp.arange(B * maxblk, dtype=jnp.int32).reshape(B, maxblk) % nb
    length = jnp.array([70, 113])
    o = ops.chunk_prefill_attention(q, k_pool, v_pool, tables, length - 1)
    od = ops.paged_decode_attention(q[:, 0], k_pool, v_pool, tables, length)
    np.testing.assert_allclose(np.asarray(o[:, 0]), np.asarray(od),
                               rtol=2e-5, atol=2e-5)


def test_chunk_prefill_two_chunks_equal_one_shot():
    """Chunked == monolithic at the kernel level, across a prefix-block
    boundary: prefilling [0,64) then [64,128) over the paged pool must
    reproduce a single [0,128) call's outputs for the second chunk."""
    ks = jax.random.split(jax.random.key(24), 3)
    B, S, H, K, hd, nb, bs = 1, 128, 4, 2, 64, 10, 16
    maxblk = S // bs
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k_pool = jax.random.normal(ks[1], (nb, bs, K, hd))
    v_pool = jax.random.normal(ks[2], (nb, bs, K, hd))
    tables = jnp.arange(1, maxblk + 1, dtype=jnp.int32)[None, :]
    one = ops.chunk_prefill_attention(q, k_pool, v_pool, tables,
                                      jnp.array([0], jnp.int32), bq=32)
    part2 = ops.chunk_prefill_attention(q[:, 64:], k_pool, v_pool, tables,
                                        jnp.array([64], jnp.int32), bq=32)
    np.testing.assert_allclose(np.asarray(one[:, 64:]), np.asarray(part2),
                               rtol=2e-5, atol=2e-5)


def test_chunk_prefill_matches_dense_flash_prefill():
    """Cross-kernel: the paged chunk kernel over a block pool must match
    the DENSE flash_prefill kernel given the same logical KV, with the
    pool laid out through an identity-ish block table."""
    ks = jax.random.split(jax.random.key(25), 3)
    B, Sq, H, K, hd, bs = 1, 64, 4, 2, 64, 16
    prefix = 64
    T = prefix + Sq
    maxblk = T // bs
    q = jax.random.normal(ks[0], (B, Sq, H, hd))
    k = jax.random.normal(ks[1], (B, T, K, hd))
    v = jax.random.normal(ks[2], (B, T, K, hd))
    # pool: block 0 reserved pad, blocks 1..maxblk hold the sequence
    k_pool = jnp.concatenate(
        [jnp.zeros((1, bs, K, hd)), k.reshape(maxblk, bs, K, hd)])
    v_pool = jnp.concatenate(
        [jnp.zeros((1, bs, K, hd)), v.reshape(maxblk, bs, K, hd)])
    tables = jnp.arange(1, maxblk + 1, dtype=jnp.int32)[None, :]
    o = ops.chunk_prefill_attention(q, k_pool, v_pool, tables,
                                    jnp.array([prefix], jnp.int32), bq=32)
    o_dense = ops.flash_prefill(q, k, v, prefix_len=prefix, bq=32, bk=32)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_dense),
                               rtol=2e-5, atol=2e-5)


def test_pallas_chunk_prefill_optflag_matches_gather_path():
    """Model-level integration: with the 'pallas_chunk_prefill' optflag
    paged GQA layers route prefill chunks (S > 1) through the Pallas
    chunk kernel while decode steps (S == 1) keep their own path; logits
    must match the XLA gather path for both."""
    from repro.configs.base import get_config
    from repro.launch import optflags
    from repro.models.transformer import apply_model, init_params
    from repro.serving import kv_cache as kvc

    cfg = get_config("tiny-lite-llm")     # includes a sliding-window layer
    params = init_params(cfg, jax.random.key(0))
    chunk1 = jax.random.randint(jax.random.key(1), (2, 6), 0,
                                cfg.vocab_size)
    chunk2 = jax.random.randint(jax.random.key(2), (2, 4), 0,
                                cfg.vocab_size)
    dec = jax.random.randint(jax.random.key(3), (2, 1), 0, cfg.vocab_size)
    tables = jnp.array([[1, 2, 3], [4, 5, 6]], jnp.int32)
    pos = jnp.array([5, 2], jnp.int32)

    def run_once():
        pool = kvc.init_paged_pool(cfg, 8, 8)
        out = []
        p = pos
        for toks in (chunk1, chunk2, dec):
            logits, pool, _ = apply_model(cfg, params, toks, pool, p,
                                          block_tables=tables)
            out.append(np.asarray(logits))
            p = p + toks.shape[1]
        return out

    base = run_once()
    optflags.set_flags(["pallas_chunk_prefill"])
    try:
        got = run_once()
    finally:
        optflags.set_flags([])
    for g, b in zip(got, base):
        np.testing.assert_allclose(g, b, rtol=2e-4, atol=2e-4)
