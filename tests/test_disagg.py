"""Disaggregated prefill/decode serving: the ``migrate_blocks`` paged-KV
handoff primitive (free-list conservation, refcount ground truth,
atomicity, pad-block exclusion, radix/COW co-ownership survival),
engine-level ``export_seq``/``import_seq`` token identity (monolithic and
mid-flight chunked prefill), role-specialized pool routing, and serve.py
flag validation. Property tests run seeded-random always and add a
hypothesis pass when the library is installed."""
import random

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.core.engine_pool import (DisaggregatedEnginePool, EnginePool,
                                    disaggregate_pools)
from repro.engines.decode_loop import PrefillJob
from repro.engines.llm_engine import LLMEngine
from repro.engines.sim_engines import SimLLMEngine, build_sim_engines
from repro.serving import kv_cache as kvc

try:
    from hypothesis import given, settings
    from hypothesis import strategies as hst
    HAVE_HYPOTHESIS = True
except ImportError:                      # seeded-random tests still run
    HAVE_HYPOTHESIS = False

_CFG = get_config("tiny-lite-llm")


def _stamped_pool(num_blocks, block_size=4):
    """Paged pool whose every cell holds its own BLOCK ID — migrated data
    is then recognizable at the destination (block axis is axis 1)."""
    pool = kvc.init_paged_pool(_CFG, num_blocks, block_size)

    def stamp(a):
        ids = jnp.arange(a.shape[1], dtype=jnp.float32)
        ids = ids.reshape((1, -1) + (1,) * (a.ndim - 2))
        return jnp.broadcast_to(ids, a.shape).astype(a.dtype)

    return jax.tree.map(stamp, pool)


def _assert_dst_holds_src_ids(dst_pool, table, dst_table):
    """Every destination slot dst_table[i] must now hold the stamped id
    of source block table[i], in every pool leaf."""
    for leaf in jax.tree.leaves(dst_pool):
        arr = np.asarray(leaf, dtype=np.float32)
        for s, d in zip(table, dst_table):
            np.testing.assert_array_equal(
                arr[:, d], np.full_like(arr[:, d], float(s)))


# ---------------------------------------------------------------------------
# migrate_blocks: the raw primitive

def test_migrate_blocks_moves_data_and_refcounts():
    sa, da = kvc.BlockAllocator(8), kvc.BlockAllocator(8)
    src_pool, dst_pool = _stamped_pool(8), kvc.init_paged_pool(_CFG, 8, 4)
    table = kvc.reserve_blocks(sa, 3)
    dst_table, dst_pool = kvc.migrate_blocks(sa, src_pool, da, dst_pool,
                                             table)
    assert len(dst_table) == 3 and kvc.PAD_BLOCK not in dst_table
    assert sa.free_blocks() == sa.capacity      # src refs all dropped
    assert da.used_blocks() == 3
    assert all(da.refcount(b) == 1 for b in dst_table)
    _assert_dst_holds_src_ids(dst_pool, table, dst_table)


def test_migrate_blocks_empty_table_is_a_noop():
    sa, da = kvc.BlockAllocator(4), kvc.BlockAllocator(4)
    src_pool, dst_pool = _stamped_pool(4), kvc.init_paged_pool(_CFG, 4, 4)
    dst_table, out_pool = kvc.migrate_blocks(sa, src_pool, da, dst_pool, [])
    assert dst_table == [] and out_pool is dst_pool
    assert sa.free_blocks() == sa.capacity
    assert da.free_blocks() == da.capacity


def test_migrate_blocks_rejects_pad_block():
    sa, da = kvc.BlockAllocator(4), kvc.BlockAllocator(4)
    src_pool, dst_pool = _stamped_pool(4), kvc.init_paged_pool(_CFG, 4, 4)
    with pytest.raises(AssertionError, match="pad block"):
        kvc.migrate_blocks(sa, src_pool, da, dst_pool, [kvc.PAD_BLOCK])


def test_migrate_blocks_atomic_when_destination_exhausted():
    """Reservation failure must leave BOTH allocators exactly as found:
    the source keeps every reference (nothing was staged or decref'd)
    and reserve_blocks rolls back any partial destination grab."""
    sa, da = kvc.BlockAllocator(8), kvc.BlockAllocator(4)
    src_pool, dst_pool = _stamped_pool(8), kvc.init_paged_pool(_CFG, 4, 4)
    held = kvc.reserve_blocks(da, 2)             # 1 of 3 dst blocks free
    table = kvc.reserve_blocks(sa, 3)
    src_refs = sa.refs_snapshot()
    dst_refs = da.refs_snapshot()
    with pytest.raises(kvc.OutOfBlocks):
        kvc.migrate_blocks(sa, src_pool, da, dst_pool, table)
    assert sa.refs_snapshot() == src_refs
    assert da.refs_snapshot() == dst_refs
    assert da.free_blocks() == da.capacity - len(held)


def _migrate_invariants(sa, src_pool, da, dst_pool, n, share_mask):
    """One migration trial against ground-truth bookkeeping: blocks
    flagged by ``share_mask`` get an extra reference first (a radix tree
    or COW fork co-owns them) and must SURVIVE on the source."""
    sf, df = sa.free_blocks(), da.free_blocks()
    table = kvc.reserve_blocks(sa, n)
    shared = [b for b, s in zip(table, share_mask) if s]
    for b in shared:
        sa.incref(b)
    dst_table, dst_pool = kvc.migrate_blocks(sa, src_pool, da, dst_pool,
                                             table)
    assert kvc.PAD_BLOCK not in dst_table
    assert len(set(dst_table)) == n              # fresh, distinct slots
    assert da.free_blocks() == df - n            # exactly n consumed
    assert all(da.refcount(b) == 1 for b in dst_table)
    for b in table:                              # src ground truth
        assert sa.refcount(b) == (1 if b in shared else 0)
    # free list regained every exclusively-owned block, nothing more
    assert sa.free_blocks() == sf - len(shared)
    _assert_dst_holds_src_ids(dst_pool, table, dst_table)
    return shared, dst_table, dst_pool


def test_migrate_blocks_randomized_conservation():
    rng = random.Random(1234)
    sa, da = kvc.BlockAllocator(20), kvc.BlockAllocator(20)
    src_pool = _stamped_pool(20)
    dst_pool = kvc.init_paged_pool(_CFG, 20, 4)
    shared_held, dst_held = [], []
    for _ in range(5):
        n = rng.randrange(1, 4)
        mask = [rng.random() < 0.5 for _ in range(n)]
        shared, dst_table, dst_pool = _migrate_invariants(
            sa, src_pool, da, dst_pool, n, mask)
        shared_held += shared
        dst_held += dst_table
    # dropping the surviving co-owner refs and the migrated tables must
    # return BOTH pools to full capacity — nothing leaked either side
    for b in shared_held:
        sa.decref(b)
    for b in dst_held:
        da.decref(b)
    assert sa.free_blocks() == sa.capacity
    assert da.free_blocks() == da.capacity


if HAVE_HYPOTHESIS:
    @settings(max_examples=15, deadline=None)
    @given(n=hst.integers(1, 3),
           mask=hst.lists(hst.booleans(), min_size=3, max_size=3))
    def test_migrate_blocks_hypothesis_conservation(n, mask):
        sa, da = kvc.BlockAllocator(8), kvc.BlockAllocator(8)
        src_pool = _stamped_pool(8)
        dst_pool = kvc.init_paged_pool(_CFG, 8, 4)
        shared, dst_table, _ = _migrate_invariants(
            sa, src_pool, da, dst_pool, n, mask[:n])
        for b in shared:
            sa.decref(b)
        for b in dst_table:
            da.decref(b)
        assert sa.free_blocks() == sa.capacity
        assert da.free_blocks() == da.capacity


# ---------------------------------------------------------------------------
# engine-level migration: export_seq / import_seq

def test_engine_migration_token_identical_monolithic():
    """Prefill on a prefill specialist, migrate, decode on a decode
    specialist: the token stream must equal the dense single-engine
    run, the source pool must drain to empty, and the destination must
    account the migration."""
    dense = LLMEngine("dn", _CFG, max_len=128, seed=0)
    pe = LLMEngine("pe", _CFG, max_len=128, seed=0, paged=True,
                   block_size=8)
    de = pe.clone(1)
    prompts = [("s0", "alpha beta gamma delta epsilon"),
               ("s1", " ".join(f"word{i}" for i in range(18)))]
    for sid, text in prompts:
        dense.op_prefill([{"sid": sid, "text": text}])
        pe.op_prefill([{"sid": sid, "text": text}])
    expect = {sid: dense.op_decode([{"sid": sid, "max_new": 8}])[0]
              for sid, _ in prompts}
    total_blocks = sum(len(pe.states[sid].table) for sid, _ in prompts)
    for sid, _ in prompts:
        cont = de.import_seq(pe.export_seq(sid))
        assert cont is None                      # nothing was mid-flight
        assert sid not in pe.states
    assert pe.alloc.free_blocks() == pe.alloc.capacity   # src drained
    assert de.alloc.used_blocks() == total_blocks
    assert de.stats["migrations_in"] == 2
    assert de.stats["migrated_blocks"] == total_blocks
    outs = {sid: de.op_decode([{"sid": sid, "max_new": 8}])[0]
            for sid, _ in prompts}
    assert outs == expect


def test_engine_migration_mid_flight_chunked_prefill():
    """A prompt frozen mid-chunked-prefill (cursor between chunks)
    migrates with its remaining tokens, resumes on the destination's
    loop, completes the ORIGINAL job for source-side waiters, and
    decodes token-identically to the dense baseline."""
    text = " ".join(f"w{i}" for i in range(20))
    dense = LLMEngine("dn", _CFG, max_len=128, seed=0)
    dense.op_prefill([{"sid": "s", "text": text}])
    expect = dense.op_decode([{"sid": "s", "max_new": 8}])[0]

    pe = LLMEngine("pe", _CFG, max_len=128, seed=0, paged=True,
                   block_size=8, chunked_prefill=True, prefill_chunk=8)
    de = pe.clone(1)
    st, toks, ptoks = pe._prepare_prefill_task({"sid": "s", "text": text})
    job = PrefillJob("s", st, toks, ptoks=ptoks)
    pe._prefill_chunk_step([(job, 8)])           # land the first chunk only
    assert 0 < job.cursor < len(toks)            # genuinely mid-flight

    handle = pe.export_seq("s")
    handle["job"] = job          # loop isn't running: attach the frozen job
    cont = de.import_seq(handle)
    assert cont is not None and cont.remaining() == len(toks) - job.cursor
    cont.wait(120)
    job.wait(10)                 # original job completion chained through
    assert "s" not in pe.states
    assert pe.alloc.free_blocks() == pe.alloc.capacity

    sq = de.submit_decode("s", 8)
    assert sq.wait(120), "post-migration decode timed out"
    assert sq.result == expect
    de.stop_decode_loop()
    pe.stop_decode_loop()


def test_engine_migration_preserves_radix_cached_source_blocks():
    """Cached prefix blocks are co-owned by the source's radix tree and
    the migrating sequence. Migration drops only the SEQUENCE's refs:
    the tree keeps serving the prefix afterwards, and the migrated copy
    stays sequence-private on the destination (never inserted there)."""
    shared = " ".join(f"c{i}" for i in range(16))
    pe = LLMEngine("pe", _CFG, max_len=256, seed=0, paged=True,
                   block_size=8, prefix_cache="radix")
    de = pe.clone(1)
    pe.op_prefill([{"sid": "s0", "text": shared + " alpha beta"}])
    cached = list(pe.radix.block_snapshot())
    assert cached                                # full prefix blocks cached
    assert all(pe.alloc.refcount(b) == 2 for b in cached)   # tree + seq

    de.import_seq(pe.export_seq("s0"))
    assert pe.radix.block_snapshot() == cached   # tree untouched
    assert all(pe.alloc.refcount(b) == 1 for b in cached)   # tree only
    assert pe.alloc.used_blocks() == len(cached)

    hits0 = pe.radix.stats["hits"]
    pe.op_prefill([{"sid": "s1", "text": shared + " gamma delta"}])
    assert pe.radix.stats["hits"] > hits0        # cache still serves
    assert de.radix.num_blocks() == 0            # migrated copy is private


def test_engine_import_backpressure_is_atomic():
    """When the destination pool cannot fit the incoming table, the
    import times out with OutOfBlocks and the SOURCE sequence is fully
    intact; freeing destination capacity lets the same handle land."""
    text = " ".join(f"y{i}" for i in range(20))
    pe = LLMEngine("pe", _CFG, max_len=128, seed=0, paged=True,
                   block_size=8)
    pe.op_prefill([{"sid": "s", "text": text}])
    nb = len(pe.states["s"].table)

    de = LLMEngine("de", _CFG, max_len=128, seed=0, paged=True,
                   block_size=8, num_blocks=nb + 1)   # capacity == nb
    de.ALLOC_TIMEOUT = 0.2
    de.op_prefill([{"sid": "bg", "text": text}])      # occupies all blocks
    assert de.alloc.free_blocks() == 0

    handle = pe.export_seq("s")
    dst_refs = de.alloc.refs_snapshot()
    with pytest.raises(kvc.OutOfBlocks):
        de.import_seq(handle)
    assert "s" in pe.states                      # source untouched
    assert pe.alloc.used_blocks() == nb
    assert de.alloc.refs_snapshot() == dst_refs  # destination untouched

    de.release("bg")                             # free capacity
    assert de.import_seq(handle) is None
    assert "s" not in pe.states
    assert de.alloc.used_blocks() == nb


# ---------------------------------------------------------------------------
# role-specialized pools

class _Replica:
    """Minimal pool citizen (no KV pool, no radix, no clone)."""

    def __init__(self, tag):
        self.name = tag


def test_engine_pool_roles_validate_and_stamp():
    reps = [_Replica("a"), _Replica("b")]
    pool = EnginePool(reps, name="p")
    assert pool.role == "unified"
    assert all(r.pool_role == "unified" for r in reps)
    with pytest.raises(ValueError, match="unknown pool role"):
        EnginePool(reps, role="draft")
    EnginePool(reps, role="prefill")
    assert all(r.pool_role == "prefill" for r in reps)


def test_disaggregated_pool_partitions_and_routes():
    reps = [_Replica(f"r{i}") for i in range(3)]
    pool = DisaggregatedEnginePool(reps, n_prefill=2, name="core")
    assert pool.prefill_indices == (0, 1) and pool.decode_indices == (2,)
    assert [pool.role_of(i) for i in range(3)] == \
        ["prefill", "prefill", "decode"]
    assert [r.pool_role for r in reps] == ["prefill", "prefill", "decode"]
    assert "2p+1d" in repr(pool)
    # restricted routing honors the candidate set; None stays pool-wide
    pool.note_queued(0, 100)
    assert pool.least_loaded(pool.prefill_indices) == 1
    assert pool.least_loaded(pool.decode_indices) == 2
    pool.note_queued(1, 200)
    pool.note_queued(2, 300)
    assert pool.least_loaded() == 0              # unrestricted: replica 0
    pool.note_migration("s0", 0, 2)
    assert pool.migrations == [("s0", 0, 2)]
    with pytest.raises(ValueError, match="disaggregated pool needs"):
        DisaggregatedEnginePool(reps, n_prefill=3)
    with pytest.raises(ValueError, match="disaggregated pool needs"):
        DisaggregatedEnginePool(reps, n_prefill=0)


def test_disaggregate_classmethod_and_registry_helper():
    proto = SimLLMEngine("core_llm")
    pool = DisaggregatedEnginePool.disaggregate(proto, 1, 2,
                                                name="core_llm")
    assert len(pool) == 3 and pool.n_prefill == 1
    assert pool[0] is proto and proto.pool_role == "prefill"
    assert pool[1].pool_role == "decode" and pool[2].pool_role == "decode"
    engines = {"core_llm": SimLLMEngine("core_llm"),
               "rerank": _Replica("rerank")}
    out = disaggregate_pools(engines, ("core_llm", "lite_llm"), 1, 1)
    assert isinstance(out["core_llm"], DisaggregatedEnginePool)
    assert out["rerank"] is engines["rerank"]    # untouched passthrough
    with pytest.raises(ValueError, match=">=1 prefill"):
        DisaggregatedEnginePool.disaggregate(proto, 0, 1)


def test_build_sim_engines_disaggregate_wiring():
    engines = build_sim_engines(paged_kv=True, chunked_prefill=True,
                                prefill_chunk=64, disaggregate=True,
                                prefill_replicas=1, decode_replicas=1)
    for name in ("core_llm", "lite_llm"):
        assert isinstance(engines[name], DisaggregatedEnginePool)
        assert len(engines[name]) == 2
    with pytest.raises(ValueError):
        build_sim_engines(paged_kv=True, disaggregate=True,
                          llm_instances=2)


# ---------------------------------------------------------------------------
# serve.py flag validation (satellite) — table-driven, alongside the
# speculative-flag suite in test_spec_decode.py

def _validate(argv):
    from repro.launch.serve import build_parser, validate_args
    ap = build_parser()
    args = ap.parse_args(argv)
    validate_args(ap, args)
    return args


_DISAGG_OK = ["--disaggregate", "--paged-kv", "--continuous-batching"]


@pytest.mark.parametrize("argv,msg", [
    (["--prefill-replicas", "2"], "--prefill-replicas requires"),
    (["--decode-replicas", "2"], "--decode-replicas requires"),
    (["--disaggregate", "--continuous-batching"], "--paged-kv"),
    (["--disaggregate", "--paged-kv"], "--continuous-batching"),
    (_DISAGG_OK + ["--scheme", "LlamaDist-TO"], "--scheme Teola"),
    (_DISAGG_OK + ["--llm-instances", "2"], "--llm-instances"),
    (_DISAGG_OK + ["--prefill-replicas", "0"],
     "--prefill-replicas must be >= 1"),
    (_DISAGG_OK + ["--decode-replicas", "0"],
     "--decode-replicas must be >= 1"),
])
def test_serve_rejects_incompatible_disagg_flags(argv, msg, capsys):
    with pytest.raises(SystemExit) as e:
        _validate(argv)
    assert e.value.code == 2                 # argparse error, not traceback
    assert msg in capsys.readouterr().err


def test_serve_accepts_valid_disagg_flags():
    args = _validate(_DISAGG_OK)
    assert args.disaggregate
    assert args.prefill_replicas == 1 and args.decode_replicas == 1
    args = _validate(_DISAGG_OK + ["--prefill-replicas", "2",
                                   "--decode-replicas", "3"])
    assert args.prefill_replicas == 2 and args.decode_replicas == 3
    args = _validate([])                     # plain serve untouched
    assert not args.disaggregate
    assert args.prefill_replicas == 1 and args.decode_replicas == 1
