"""Serving-path correctness: chunked (partial) prefill + decode against the
KV/state cache must match the full forward pass — this is the property
Teola's Pass 3/4 depend on."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import ASSIGNED
from repro.configs.base import get_config
from repro.models.transformer import apply_model, init_params
from repro.serving.kv_cache import init_cache, cache_bytes


@pytest.mark.parametrize("arch", ASSIGNED)
def test_partial_prefill_decode_matches_full(arch):
    cfg = get_config(arch).reduced()
    params = init_params(cfg, jax.random.key(0))
    B, S = 2, 16
    if cfg.embed_stub:
        inputs = jax.random.normal(jax.random.key(1), (B, S, cfg.d_model),
                                   jnp.float32)
    else:
        inputs = jax.random.randint(jax.random.key(1), (B, S), 0,
                                    cfg.vocab_size)
    cache = init_cache(cfg, B, 32)
    _, cache, _ = apply_model(cfg, params, inputs[:, :6], cache, 0)
    _, cache, _ = apply_model(cfg, params, inputs[:, 6:11], cache, 6)
    last, cache, _ = apply_model(cfg, params, inputs[:, 11:12], cache, 11)
    full, _, _ = apply_model(cfg, params, inputs[:, :12])
    np.testing.assert_allclose(np.asarray(last[:, -1]),
                               np.asarray(full[:, -1]), rtol=3e-2, atol=3e-2)


@pytest.mark.parametrize("arch", ["gemma2-9b", "hymba-1.5b"])
def test_ring_buffer_matches_full_within_window(arch):
    """Sliding-window layers with a ring buffer smaller than the sequence:
    decode logits must match a full forward (the window masks identically)."""
    cfg = get_config(arch).reduced()
    params = init_params(cfg, jax.random.key(0))
    B = 2
    window = None
    for st in cfg.stages:
        for sp in st.pattern:
            if sp.window:
                window = sp.window
    assert window is not None
    S = window + 8                      # sequence longer than the window
    toks = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab_size)
    cache = init_cache(cfg, B, S)       # windowed layers get ring buffers
    pos = 0
    out = None
    for chunk in range(0, S, 8):
        out, cache, _ = apply_model(cfg, params, toks[:, chunk:chunk + 8],
                                    cache, pos)
        pos += 8
    full, _, _ = apply_model(cfg, params, toks)
    np.testing.assert_allclose(np.asarray(out[:, -1]),
                               np.asarray(full[:, -1]), rtol=4e-2, atol=4e-2)


def test_windowed_cache_is_smaller():
    cfg = get_config("gemma2-9b")
    full = cache_bytes(cfg, 1, 524288)
    # a hypothetical all-global variant: replace windows with None
    import dataclasses
    from repro.configs.base import Stage
    stages = tuple(
        Stage(pattern=tuple(dataclasses.replace(sp, window=None)
                            for sp in st.pattern), repeat=st.repeat)
        for st in cfg.stages)
    allglobal = dataclasses.replace(cfg, stages=stages)
    assert full < 0.55 * cache_bytes(allglobal, 1, 524288)


def test_per_sequence_positions():
    """Continuous batching: sequences at different positions in one batch."""
    cfg = get_config("tinyllama-1.1b").reduced()
    params = init_params(cfg, jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (2, 12), 0, cfg.vocab_size)
    # seq0 has 4 tokens prefilled, seq1 has 7
    cache = init_cache(cfg, 2, 32)
    _, cache, _ = apply_model(cfg, params, toks[:, :4], cache, 0)
    c1 = jax.tree.map(lambda a: a[:, 1:2], cache["stages"][0][0])
    cache1 = {"stages": [[c1]]}
    _, cache1, _ = apply_model(cfg, params, toks[1:2, 4:7], cache1, 4)
    # merge back: batch with per-seq pos [4, 7], decode one token each
    merged = {"stages": [[jax.tree.map(
        lambda a, b: jnp.concatenate([a[:, :1], b], axis=1),
        cache["stages"][0][0], cache1["stages"][0][0])]]}
    nxt = jnp.stack([toks[0, 4], toks[1, 7]])[:, None]
    out, _, _ = apply_model(cfg, params, nxt, merged, jnp.array([4, 7]))
    # references: independent full forwards
    f0, _, _ = apply_model(cfg, params, toks[:1, :5])
    f1, _, _ = apply_model(cfg, params, toks[1:2, :8])
    np.testing.assert_allclose(np.asarray(out[0, -1]), np.asarray(f0[0, -1]),
                               rtol=3e-2, atol=3e-2)
    np.testing.assert_allclose(np.asarray(out[1, -1]), np.asarray(f1[0, -1]),
                               rtol=3e-2, atol=3e-2)
