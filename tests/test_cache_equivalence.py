"""Serving-path correctness: chunked (partial) prefill + decode against the
KV/state cache must match the full forward pass — this is the property
Teola's Pass 3/4 depend on. The engine-level matrix at the bottom extends
the same contract across every serving-feature combination: {radix prefix
cache on/off} x {dense/paged} x {legacy/continuous decode} x {chunked
prefill on/off} x {speculative on/off} must all emit the exact tokens of
the canonical all-off engine. The disaggregated matrix re-runs the paged
cells split across TWO replicas — prefill on one, ``export_seq`` /
``import_seq`` migration, decode on the other — under the same exact
token-identity contract."""
import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import ASSIGNED
from repro.configs.base import get_config
from repro.engines.llm_engine import LLMEngine
from repro.models.transformer import apply_model, init_params
from repro.serving.kv_cache import init_cache, cache_bytes


@pytest.mark.parametrize("arch", ASSIGNED)
def test_partial_prefill_decode_matches_full(arch):
    cfg = get_config(arch).reduced()
    params = init_params(cfg, jax.random.key(0))
    B, S = 2, 16
    if cfg.embed_stub:
        inputs = jax.random.normal(jax.random.key(1), (B, S, cfg.d_model),
                                   jnp.float32)
    else:
        inputs = jax.random.randint(jax.random.key(1), (B, S), 0,
                                    cfg.vocab_size)
    cache = init_cache(cfg, B, 32)
    _, cache, _ = apply_model(cfg, params, inputs[:, :6], cache, 0)
    _, cache, _ = apply_model(cfg, params, inputs[:, 6:11], cache, 6)
    last, cache, _ = apply_model(cfg, params, inputs[:, 11:12], cache, 11)
    full, _, _ = apply_model(cfg, params, inputs[:, :12])
    np.testing.assert_allclose(np.asarray(last[:, -1]),
                               np.asarray(full[:, -1]), rtol=3e-2, atol=3e-2)


@pytest.mark.parametrize("arch", ["gemma2-9b", "hymba-1.5b"])
def test_ring_buffer_matches_full_within_window(arch):
    """Sliding-window layers with a ring buffer smaller than the sequence:
    decode logits must match a full forward (the window masks identically)."""
    cfg = get_config(arch).reduced()
    params = init_params(cfg, jax.random.key(0))
    B = 2
    window = None
    for st in cfg.stages:
        for sp in st.pattern:
            if sp.window:
                window = sp.window
    assert window is not None
    S = window + 8                      # sequence longer than the window
    toks = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab_size)
    cache = init_cache(cfg, B, S)       # windowed layers get ring buffers
    pos = 0
    out = None
    for chunk in range(0, S, 8):
        out, cache, _ = apply_model(cfg, params, toks[:, chunk:chunk + 8],
                                    cache, pos)
        pos += 8
    full, _, _ = apply_model(cfg, params, toks)
    np.testing.assert_allclose(np.asarray(out[:, -1]),
                               np.asarray(full[:, -1]), rtol=4e-2, atol=4e-2)


def test_windowed_cache_is_smaller():
    cfg = get_config("gemma2-9b")
    full = cache_bytes(cfg, 1, 524288)
    # a hypothetical all-global variant: replace windows with None
    import dataclasses
    from repro.configs.base import Stage
    stages = tuple(
        Stage(pattern=tuple(dataclasses.replace(sp, window=None)
                            for sp in st.pattern), repeat=st.repeat)
        for st in cfg.stages)
    allglobal = dataclasses.replace(cfg, stages=stages)
    assert full < 0.55 * cache_bytes(allglobal, 1, 524288)


def test_per_sequence_positions():
    """Continuous batching: sequences at different positions in one batch."""
    cfg = get_config("tinyllama-1.1b").reduced()
    params = init_params(cfg, jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (2, 12), 0, cfg.vocab_size)
    # seq0 has 4 tokens prefilled, seq1 has 7
    cache = init_cache(cfg, 2, 32)
    _, cache, _ = apply_model(cfg, params, toks[:, :4], cache, 0)
    c1 = jax.tree.map(lambda a: a[:, 1:2], cache["stages"][0][0])
    cache1 = {"stages": [[c1]]}
    _, cache1, _ = apply_model(cfg, params, toks[1:2, 4:7], cache1, 4)
    # merge back: batch with per-seq pos [4, 7], decode one token each
    merged = {"stages": [[jax.tree.map(
        lambda a, b: jnp.concatenate([a[:, :1], b], axis=1),
        cache["stages"][0][0], cache1["stages"][0][0])]]}
    nxt = jnp.stack([toks[0, 4], toks[1, 7]])[:, None]
    out, _, _ = apply_model(cfg, params, nxt, merged, jnp.array([4, 7]))
    # references: independent full forwards
    f0, _, _ = apply_model(cfg, params, toks[:1, :5])
    f1, _, _ = apply_model(cfg, params, toks[1:2, :8])
    np.testing.assert_allclose(np.asarray(out[0, -1]), np.asarray(f0[0, -1]),
                               rtol=3e-2, atol=3e-2)
    np.testing.assert_allclose(np.asarray(out[1, -1]), np.asarray(f1[0, -1]),
                               rtol=3e-2, atol=3e-2)


# ---------------------------------------------------------------------------
# Cross-feature equivalence matrix: every serving-feature combination must
# be TOKEN-IDENTICAL to the canonical all-off engine. Greedy decode makes
# the contract exact — no tolerance, string equality.

_MCFG = get_config("tiny-lite-llm")
_MSHARED = " ".join(f"ctx{i}" for i in range(24))
_MPROMPTS = [
    ("q0", _MSHARED + " alpha beta"),
    ("q1", _MSHARED + " gamma delta"),      # shared 24-word prefix
    ("q2", _MSHARED + " epsilon zeta"),
    ("q3", "a totally different prompt about optics"),
]

# (radix, paged, continuous, chunked, spec); radix requires the paged
# block pool -> those 8 cells are structurally invalid, leaving 24.
_MATRIX = [c for c in itertools.product([False, True], repeat=5)
           if not (c[0] and not c[1])]


def _run_cell(*, radix, paged, continuous, chunked, spec,
              num_blocks=None):
    eng = LLMEngine("m", _MCFG, max_len=256, seed=0, max_batch=4,
                    paged=paged, block_size=8, num_blocks=num_blocks,
                    chunked_prefill=chunked, prefill_chunk=24,
                    prefix_cache="radix" if radix else "none")
    if spec:
        eng.enable_speculative(draft=None, k=3)
    # prefill sequentially so later prompts can hit prefixes cached by
    # earlier ones (same-batch tasks insert only after the batch)
    for sid, text in _MPROMPTS:
        eng.op_prefill([{"sid": sid, "text": text}])
    if continuous:
        seqs = [(sid, eng.submit_decode(sid, 10)) for sid, _ in _MPROMPTS]
        outs = {}
        for sid, sq in seqs:
            assert sq.wait(120), f"decode {sid} timed out"
            outs[sid] = sq.result
    else:
        res = eng.op_decode([{"sid": sid, "max_new": 10}
                             for sid, _ in _MPROMPTS])
        outs = {sid: r for (sid, _), r in zip(_MPROMPTS, res)}
    stats = dict(eng.radix.stats) if eng.radix is not None else None
    eng.stop_decode_loop()
    return outs, stats


_BASELINE = {}


def _baseline():
    """Canonical all-off run, computed once per module."""
    if not _BASELINE:
        outs, _ = _run_cell(radix=False, paged=False, continuous=False,
                            chunked=False, spec=False)
        _BASELINE.update(outs)
    return dict(_BASELINE)


@pytest.mark.parametrize("radix,paged,continuous,chunked,spec", _MATRIX)
def test_feature_matrix_token_identity(radix, paged, continuous, chunked,
                                       spec):
    outs, stats = _run_cell(radix=radix, paged=paged, continuous=continuous,
                            chunked=chunked, spec=spec)
    assert outs == _baseline()
    if radix:
        # the shared 24-word prefix (3 full blocks) must actually hit
        assert stats["hits"] >= 2 and stats["hit_tokens"] >= 2 * 24


def test_matrix_mid_stream_admission_and_eviction():
    """The hardest cell exercised mid-stream: radix + paged + continuous
    + chunked with a pool small enough that later admissions must evict
    cached leaves while a long decode stays resident. Outputs remain
    token-identical to the all-off engine run sequentially."""
    # 16 shared words (2 full blocks) + 8 distinct words (1 full block):
    # each prompt caches one NEW block, so the tree grows under a fixed
    # pool until admission must evict LRU leaves
    shared16 = " ".join(_MSHARED.split()[:16])
    prompts = [("p%d" % i, shared16 + " " +
                " ".join(f"t{i}w{j}" for j in range(8)))
               for i in range(8)]

    base = LLMEngine("b", _MCFG, max_len=256, seed=0, max_batch=8,
                     paged=False)
    expect = {}
    for sid, text in prompts + [("bg", "background long decode prompt")]:
        base.op_prefill([{"sid": sid, "text": text}])
    for sid, _ in prompts:
        expect[sid] = base.op_decode([{"sid": sid, "max_new": 8}])[0]
    expect["bg"] = base.op_decode([{"sid": "bg", "max_new": 40}])[0]

    eng = LLMEngine("m", _MCFG, max_len=256, seed=0, max_batch=8,
                    paged=True, block_size=8, num_blocks=14,
                    chunked_prefill=True, prefill_chunk=16,
                    prefix_cache="radix")
    eng.op_prefill([{"sid": "bg", "text": "background long decode prompt"}])
    bg = eng.submit_decode("bg", 40)    # stays resident throughout
    outs = {}
    for sid, text in prompts:           # admitted mid-decode, one by one
        eng.op_prefill([{"sid": sid, "text": text}])
        sq = eng.submit_decode(sid, 8)
        assert sq.wait(120), f"decode {sid} timed out"
        outs[sid] = sq.result
        eng.release(sid)                # only the radix refs survive
    assert bg.wait(120), "background decode timed out"
    outs["bg"] = bg.result
    stats = dict(eng.radix.stats)
    eng.stop_decode_loop()

    assert outs == expect
    assert stats["hits"] >= 4           # shared prefix reused across seqs
    assert stats["evictions"] > 0       # pool pressure forced LRU eviction
    # nothing leaked: dropping every ref returns the pool to capacity
    for sid in list(eng.states):
        eng.release(sid)
    eng.radix.evict(10 ** 6)
    assert eng.alloc.free_blocks() == eng.alloc.capacity


# ---------------------------------------------------------------------------
# SLO-armed cells: the same token-identity contract must survive SLO-aware
# scheduling (serving/slo.py) — admission reordering, per-tenant fair share,
# and evict-to-recompute preemption change WHEN sequences decode, never WHAT
# they decode.

def _run_slo_cell(*, paged, chunked, spec, max_batch):
    from repro.serving.slo import attach_slo, derive_tag
    eng = LLMEngine("m", _MCFG, max_len=256, seed=0, max_batch=max_batch,
                    paged=paged, block_size=8,
                    chunked_prefill=chunked, prefill_chunk=24)
    if spec:
        eng.enable_speculative(draft=None, k=3)
    attach_slo({"m": eng}, preempt_cooldown_s=0.0)   # armed BEFORE prefill
    for sid, text in _MPROMPTS:
        eng.op_prefill([{"sid": sid, "text": text}])
    seqs = []
    for i, (sid, _) in enumerate(_MPROMPTS):
        tag = derive_tag(slo="interactive" if i % 2 == 0 else "batch",
                         tenant=f"t{i % 2}")
        seqs.append((sid, eng.submit_decode(sid, 10, slo=tag)))
    outs = {}
    for sid, sq in seqs:
        assert sq.wait(120), f"decode {sid} timed out"
        outs[sid] = sq.result
    stats = eng.tenant_stats()
    eng.stop_decode_loop()
    if paged:
        for sid in list(eng.states):
            eng.release(sid)
        rep = eng.alloc.audit()
        assert rep["leaked"] == 0 and rep["bad_free"] == 0
    return outs, stats


@pytest.mark.parametrize("paged,chunked,spec,max_batch", [
    (False, False, False, 4),
    (True, True, False, 4),
    (True, False, True, 4),
    # max_batch=2 < 4 sequences: admission is genuinely SLO-ordered and
    # slot pressure exercises the fair-share / preemption paths
    (True, False, False, 2),
    (False, False, False, 2),
])
def test_matrix_mixed_slo_token_identity(paged, chunked, spec, max_batch):
    outs, stats = _run_slo_cell(paged=paged, chunked=chunked, spec=spec,
                                max_batch=max_batch)
    assert outs == _baseline()
    # both tenants' work was admitted and finished under the policy
    assert stats["t0/interactive"]["done"] == 2
    assert stats["t1/batch"]["done"] == 2


# ---------------------------------------------------------------------------
# Disaggregated prefill/decode: the paged cells re-run split across two
# replicas — prefill lands on a prefill specialist, the sequence migrates
# (paged KV block handoff), decode runs on a decode specialist. Token
# identity to the all-off engine must survive the migration in every
# feature combination.

def _run_disagg_cell(*, radix, chunked, spec):
    pe = LLMEngine("mp", _MCFG, max_len=256, seed=0, max_batch=4,
                   paged=True, block_size=8,
                   chunked_prefill=chunked, prefill_chunk=24,
                   prefix_cache="radix" if radix else "none")
    de = pe.clone(1)
    if spec:
        pe.enable_speculative(draft=None, k=3)
        de.enable_speculative(draft=None, k=3)
    for sid, text in _MPROMPTS:
        pe.op_prefill([{"sid": sid, "text": text}])
    for sid, _ in _MPROMPTS:
        de.import_seq(pe.export_seq(sid))
    assert not pe.states                     # source fully drained
    assert pe.alloc.free_blocks() == pe.alloc.capacity - (
        pe.radix.num_blocks() if pe.radix is not None else 0)
    seqs = [(sid, de.submit_decode(sid, 10)) for sid, _ in _MPROMPTS]
    outs = {}
    for sid, sq in seqs:
        assert sq.wait(120), f"decode {sid} timed out"
        outs[sid] = sq.result
    stats = dict(de.stats)
    pe.stop_decode_loop()
    de.stop_decode_loop()
    return outs, stats


@pytest.mark.parametrize("radix,chunked,spec",
                         list(itertools.product([False, True], repeat=3)))
def test_disagg_matrix_token_identity(radix, chunked, spec):
    outs, stats = _run_disagg_cell(radix=radix, chunked=chunked, spec=spec)
    assert outs == _baseline()
    assert stats["migrations_in"] == len(_MPROMPTS)


def test_disagg_mid_migration_eviction_and_admission():
    """The hardest disaggregated cell: the SOURCE's radix tree keeps
    filling its small pool as prompts stream through (migration drops
    only sequence refs, so cached blocks pile up until prefill admission
    must evict LRU leaves), while the DESTINATION admits each import
    under pressure from a long resident background decode (the import
    reservation waits on the decode's block frees). Every stream stays
    token-identical to the all-off engine run sequentially."""
    shared16 = " ".join(_MSHARED.split()[:16])
    prompts = [("p%d" % i, shared16 + " " +
                " ".join(f"t{i}w{j}" for j in range(8)))
               for i in range(8)]

    base = LLMEngine("b", _MCFG, max_len=256, seed=0, max_batch=8,
                     paged=False)
    expect = {}
    for sid, text in prompts + [("bg", "background long decode prompt")]:
        base.op_prefill([{"sid": sid, "text": text}])
    for sid, _ in prompts:
        expect[sid] = base.op_decode([{"sid": sid, "max_new": 8}])[0]
    expect["bg"] = base.op_decode([{"sid": "bg", "max_new": 40}])[0]

    pe = LLMEngine("mp", _MCFG, max_len=256, seed=0, max_batch=8,
                   paged=True, block_size=8, num_blocks=10,
                   chunked_prefill=True, prefill_chunk=16,
                   prefix_cache="radix")
    # destination sized so the resident background decode (6 blocks
    # worst-case) + one imported sequence (3) + its decode reservation
    # (1) just fit — every import lands against that standing pressure
    de = LLMEngine("md", _MCFG, max_len=256, seed=0, max_batch=8,
                   paged=True, block_size=8, num_blocks=12,
                   chunked_prefill=True, prefill_chunk=16,
                   prefix_cache="radix")
    pe.op_prefill([{"sid": "bg", "text": "background long decode prompt"}])
    de.import_seq(pe.export_seq("bg"))
    bg = de.submit_decode("bg", 40)          # stays resident throughout
    outs = {}
    for sid, text in prompts:                # migrated mid-decode, 1 by 1
        pe.op_prefill([{"sid": sid, "text": text}])
        de.import_seq(pe.export_seq(sid))
        sq = de.submit_decode(sid, 8)
        assert sq.wait(120), f"decode {sid} timed out"
        outs[sid] = sq.result
        de.release(sid)                      # frees dst capacity
    assert bg.wait(120), "background decode timed out"
    outs["bg"] = bg.result
    src_stats = dict(pe.radix.stats)
    de.stop_decode_loop()
    pe.stop_decode_loop()

    assert outs == expect
    assert de.stats["migrations_in"] == 9
    assert src_stats["hits"] >= 4            # prefix reused across seqs
    assert src_stats["evictions"] > 0        # src pool pressure evicted LRU
    assert de.radix.num_blocks() == 0        # migrated copies stay private
    # nothing leaked on either side
    for sid in list(pe.states):
        pe.release(sid)
    for sid in list(de.states):
        de.release(sid)
    pe.radix.evict(10 ** 6)
    de.radix.evict(10 ** 6)
    assert pe.alloc.free_blocks() == pe.alloc.capacity
    assert de.alloc.free_blocks() == de.alloc.capacity


# ---------------------------------------------------------------------------
# Overload layer armed-but-idle: the runtime with every overload
# mechanism switched on but under zero pressure (huge thresholds, no
# deadline stress) must produce the exact tokens of the unarmed runtime
# — the flag-off contract of serving/overload.py, end to end.

_OV_BASELINE = {}


def _run_overload_cell(overload):
    from repro.core.apps import build_engines, search_gen
    from repro.core.teola import Teola
    engines = build_engines(paged_kv=True)
    orch = Teola(search_gen(engines), engines, continuous_batching=True,
                 overload=overload)
    try:
        out, ctx = orch.query({"question": "what is fact 1 about optics"},
                              timeout=600)
        assert ctx.error is None
        return out
    finally:
        orch.shutdown()


def _overload_baseline():
    if "out" not in _OV_BASELINE:
        _OV_BASELINE["out"] = _run_overload_cell(None)
    return _OV_BASELINE["out"]


def _armed_no_deadline():
    """Shed + hedge + degrade armed; no deadline -> no slack pressure."""
    from repro.serving.overload import OverloadConfig, OverloadManager
    return OverloadManager(OverloadConfig(
        shed=True, max_queue_tokens=1e12, hedge=True, hedge_after_s=1e6,
        degrade=True))


def _armed_with_deadline():
    """Deadline stamped and decomposed into per-primitive budgets, but
    so loose that slack never goes negative (ladder stays at level 0)."""
    from repro.serving.overload import OverloadConfig, OverloadManager
    return OverloadManager(OverloadConfig(
        deadline_s=1e6, shed=True, max_queue_tokens=1e12, degrade=True))


@pytest.mark.parametrize("mk", [_armed_no_deadline, _armed_with_deadline])
def test_overload_armed_idle_is_token_identical(mk):
    ov = mk()
    out = _run_overload_cell(ov)
    assert out == _overload_baseline()
    snap = ov.snapshot()
    assert snap["admission"]["interactive"]["shed"] == 0
    assert snap["admission"]["batch"]["shed"] == 0
    assert snap["hedge"]["issued"] == 0
    assert snap["degrade"]["level"] == 0 and not snap["degrade"]["steps"]
