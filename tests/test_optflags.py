"""Beyond-paper optimization flags: numerical equivalence + spec sanity."""
import jax
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.launch import optflags
from repro.models.transformer import apply_model, init_params


@pytest.fixture(autouse=True)
def _clean_flags():
    optflags.set_flags([])
    yield
    optflags.set_flags([])


def test_causal_skip_exact():
    cfg = get_config("tinyllama-1.1b").reduced()
    params = init_params(cfg, jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (2, 256), 0,
                              cfg.vocab_size)
    base, _, _ = apply_model(cfg, params, toks, q_block=64)
    optflags.set_flags(["causal_skip"])
    skip, _, _ = apply_model(cfg, params, toks, q_block=64)
    np.testing.assert_allclose(np.asarray(base), np.asarray(skip),
                               rtol=2e-5, atol=2e-5)


def test_causal_skip_windowed_exact():
    cfg = get_config("gemma2-9b").reduced()
    params = init_params(cfg, jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (1, 256), 0,
                              cfg.vocab_size)
    base, _, _ = apply_model(cfg, params, toks, q_block=64)
    optflags.set_flags(["causal_skip"])
    skip, _, _ = apply_model(cfg, params, toks, q_block=64)
    np.testing.assert_allclose(np.asarray(base, np.float32),
                               np.asarray(skip, np.float32),
                               rtol=1e-4, atol=1e-4)


def test_flag_parsing():
    optflags.set_flags(["resident_weights", "microbatches=4"])
    assert optflags.has("resident_weights")
    assert not optflags.has("flat_dp")
    assert optflags.get_int("microbatches", 16) == 4
    assert optflags.get_int("missing", 7) == 7


def test_flat_dp_specs_have_no_duplicates():
    from repro.launch import shard_rules as sr
    optflags.set_flags(["flat_dp"])
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    from repro.models.transformer import param_shapes
    cfg = get_config("tinyllama-1.1b")
    tree = param_shapes(cfg)
    # must not raise DuplicateSpecError
    sr.tree_shardings(tree, mesh)
