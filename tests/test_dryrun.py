"""Dry-run machinery smoke test (subprocess — it forces 512 devices)."""
import json
import os
import subprocess
import sys
import tempfile

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def test_dryrun_one_case_single_and_multipod():
    with tempfile.TemporaryDirectory() as td:
        for extra in ([], ["--multi-pod"]):
            r = subprocess.run(
                [sys.executable, "-m", "repro.launch.dryrun",
                 "--arch", "tinyllama-1.1b", "--shape", "decode_32k",
                 "--out", td] + extra,
                env={**os.environ, "PYTHONPATH": SRC},
                capture_output=True, text=True, timeout=900)
            assert r.returncode == 0, r.stdout + r.stderr
        files = os.listdir(td)
        assert len(files) == 2
        for f in files:
            rec = json.load(open(os.path.join(td, f)))
            assert rec["status"] == "ok"
            assert rec["devices"] in (256, 512)
            t = rec["roofline_terms_s"]
            assert all(v >= 0 for v in t.values())
            assert rec["dominant_term"] in t
            assert rec["memory_analysis"]["argument_size_in_bytes"] > 0
            # roofline inputs present
            assert rec["per_device"]["analytic_flops"] > 0
            assert rec["per_device"]["collective_bytes"] > 0
            # a 1.1B model's bf16 weights fit 256+ chips easily
            assert rec["memory_analysis"]["argument_size_in_bytes"] < 2**32


def test_skip_note_for_full_attention_long_context():
    with tempfile.TemporaryDirectory() as td:
        r = subprocess.run(
            [sys.executable, "-m", "repro.launch.dryrun",
             "--arch", "deepseek-67b", "--shape", "long_500k", "--out", td],
            env={**os.environ, "PYTHONPATH": SRC},
            capture_output=True, text=True, timeout=300)
        assert r.returncode == 0
        assert "SKIP" in r.stdout
