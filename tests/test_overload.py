"""Overload control & graceful degradation (serving/overload.py):
e-graph deadline decomposition, unified SLO/FT deadlines, front-door
admission control with structured shedding, deterministic seeded burst
faults, hedged dispatch with first-result-wins, and the brown-out
degradation ladder (hysteresis, per-query attribution, chunk caps) —
plus end-to-end runs proving shed queries fail loudly, hedged queries
stay token-identical, and degraded paged prefill leaks no blocks."""
import itertools
import threading
import time
import types

import pytest

from repro.configs.base import get_config
from repro.core.engine_pool import replicas_of
from repro.core.primitives import Graph, Primitive
from repro.core import primitives as P
from repro.core.teola import Teola
from repro.engines.decode_loop import ContinuousDecodeLoop, PrefillJob
from repro.engines.llm_engine import LLMEngine
from repro.engines.sim_engines import build_sim_engines
from repro.serving.faults import FaultInjector, FaultSpec, FTConfig, \
    TaskRecovery
from repro.serving.overload import (AdmissionController, DegradationPolicy,
                                    HedgePolicy, Overloaded, OverloadConfig,
                                    OverloadManager, decompose_deadline,
                                    query_class, query_token_estimate)
from repro.serving.slo import BATCH, INTERACTIVE, SLOPolicy, derive_tag
from repro.training.data import doc_corpus

Q = {"question": "what is fact 3 about optics", "docs": doc_corpus(2)}


def _ctx(qid="q0"):
    return types.SimpleNamespace(qid=qid, done=threading.Event())


# ---------------------------------------------------------------------------
# Deadline decomposition along the e-graph

def _chain_graph():
    """embed(8 tok) -> prefill(64) -> decode(24); critical path 96."""
    g = Graph(query_id="q")
    a = g.add(Primitive(op=P.EMBEDDING, engine="emb", component="qe"))
    b = g.add(Primitive(op=P.PREFILL, engine="llm", component="gen"))
    c = g.add(Primitive(op=P.DECODE, engine="llm", component="gen",
                        config={"max_new": 24}))
    g.edge(a, b)
    g.edge(b, c)
    return g, a, b, c


def test_decompose_deadline_chain_fractions():
    g, a, b, c = _chain_graph()
    frac = decompose_deadline(g)
    assert frac[c.pid] == pytest.approx(1.0)          # sink gets full budget
    assert frac[b.pid] == pytest.approx(72 / 96)      # 24 downstream tokens
    assert frac[a.pid] == pytest.approx(8 / 96)       # 88 downstream tokens


def test_decompose_deadline_diamond_takes_heaviest_branch():
    g = Graph(query_id="q")
    a = g.add(Primitive(op=P.EMBEDDING, engine="e", component="a"))
    b = g.add(Primitive(op=P.PREFILL, engine="l", component="b"))    # 64
    c = g.add(Primitive(op=P.EMBEDDING, engine="e", component="c"))  # 8
    d = g.add(Primitive(op=P.DECODE, engine="l", component="d",
                        config={"max_new": 24}))
    for x in (b, c):
        g.edge(a, x)
        g.edge(x, d)
    frac = decompose_deadline(g)
    # a's downstream critical cost goes through b (64+24), not c (8+24)
    assert frac[a.pid] == pytest.approx(8 / 96)
    assert frac[b.pid] == frac[c.pid] == pytest.approx(72 / 96)
    assert frac[d.pid] == pytest.approx(1.0)
    # budgets are monotone along every edge
    for n in g.nodes.values():
        for cpid in n.children:
            assert frac[n.pid] <= frac[cpid]
    assert frac[a.pid] < frac[b.pid] < frac[d.pid]


def test_decompose_deadline_empty_graph():
    assert decompose_deadline(Graph(query_id="q")) == {}


def test_query_token_estimate_skips_control_ops():
    g = Graph(query_id="q")
    g.add(Primitive(op=P.EMBEDDING, engine="e", component="a"))      # 8
    g.add(Primitive(op=P.DECODE, engine="l", component="b",
                    config={"max_new": 24}))                         # 24
    g.add(Primitive(op=P.CONDITION, engine="control", component="c"))
    assert query_token_estimate(g) == pytest.approx(32.0)


def test_query_class_matches_slo_derivation():
    assert query_class(None, 0) == BATCH
    assert query_class(None, 3) == INTERACTIVE
    assert query_class("interactive", 0) == INTERACTIVE
    assert query_class("batch", 9) == BATCH


# ---------------------------------------------------------------------------
# Satellite: unified SLO-urgency / FT-watchdog deadline

def test_unified_deadline_urgent_by_slo_before_ft_deadline():
    """Regression: a query whose deadline is INSIDE the SLO slack window
    must rank urgent for scheduling while the FT watchdog (whose own
    request_deadline is far looser) has NOT expired it."""
    now = time.time()
    ctx = types.SimpleNamespace(deadline=now + 0.5, qid="q")
    task = types.SimpleNamespace(ctx=ctx)
    mgr = types.SimpleNamespace(cfg=FTConfig(request_deadline=10.0))
    tr = TaskRecovery(mgr, task, {"idx": 0, "tokens": 1}, "decode")
    # the watchdog enforces the TIGHTER query deadline, not the FT budget
    assert abs(tr.deadline - ctx.deadline) < 0.05
    assert tr.deadline > time.time()          # ... but it has not fired yet
    # the SLO layer already treats the same clock as urgent
    pol = SLOPolicy(deadline_slack_s=1.0)
    tagged = types.SimpleNamespace(
        slo=derive_tag(slo="batch", deadline=ctx.deadline))
    assert pol.is_urgent(tagged, now=now)
    far = types.SimpleNamespace(
        slo=derive_tag(slo="batch", deadline=now + 100.0))
    assert not pol.is_urgent(far, now=now)


def test_unified_deadline_fallbacks():
    task = types.SimpleNamespace(
        ctx=types.SimpleNamespace(deadline=None, qid="q"))
    mgr = types.SimpleNamespace(cfg=FTConfig(request_deadline=2.0))
    tr = TaskRecovery(mgr, task, {"idx": 0, "tokens": 1}, "decode")
    assert abs(tr.deadline - (time.time() + 2.0)) < 0.1   # FT budget only
    mgr = types.SimpleNamespace(cfg=FTConfig(request_deadline=None))
    tr = TaskRecovery(mgr, task, {"idx": 0, "tokens": 1}, "decode")
    assert tr.deadline is None                            # neither armed


# ---------------------------------------------------------------------------
# Admission control / load shedding

def test_admission_off_never_sheds():
    ac = AdmissionController(OverloadConfig(shed=False,
                                            max_queue_tokens=0.0))
    for i in range(4):
        assert ac.admit(_ctx(f"q{i}"), BATCH, 1000.0) is None
    assert ac.counts[BATCH]["admitted"] == 4
    assert ac.counts[BATCH]["shed"] == 0


def test_admission_sheds_batch_beyond_threshold_with_structured_error():
    ac = AdmissionController(OverloadConfig(shed=True,
                                            max_queue_tokens=50.0))
    assert ac.admit(_ctx("q0"), BATCH, 100.0) is None  # empty queue admits
    err = ac.admit(_ctx("q1"), BATCH, 10.0)
    assert isinstance(err, Overloaded)
    assert err.reason == "overloaded"
    assert err.qid == "q1" and err.cls == BATCH
    assert err.outstanding == pytest.approx(100.0)
    assert ac.snapshot()[BATCH] == {"admitted": 1, "shed": 1}


def test_admission_interactive_headroom_factor():
    ac = AdmissionController(OverloadConfig(
        shed=True, max_queue_tokens=50.0, interactive_factor=3.0))
    assert ac.admit(_ctx("q0"), BATCH, 100.0) is None
    assert isinstance(ac.admit(_ctx("q1"), BATCH, 1.0), Overloaded)
    # interactive keeps 3x the allowance: 100 <= 150
    assert ac.admit(_ctx("q2"), INTERACTIVE, 1.0) is None


def test_admission_unmeetable_deadline_sheds_any_class():
    ac = AdmissionController(OverloadConfig(shed=True,
                                            max_queue_tokens=1e9))
    err = ac.admit(_ctx(), INTERACTIVE, 1.0, slack_s=-0.1)
    assert isinstance(err, Overloaded)


def test_admission_ledger_prunes_completed_queries():
    ac = AdmissionController(OverloadConfig(shed=True))
    c = _ctx()
    ac.admit(c, BATCH, 100.0)
    assert ac.outstanding_tokens() == pytest.approx(100.0)
    c.done.set()
    assert ac.outstanding_tokens() == pytest.approx(0.0)


def test_admission_pool_signal_rate_and_deadline_tightening():
    ac = AdmissionController(OverloadConfig(shed=True,
                                            max_queue_tokens=100.0))
    ac.register_pool(types.SimpleNamespace(
        outstanding_tokens=lambda: 75.0))
    assert ac.outstanding_tokens() == pytest.approx(75.0)
    assert ac.queue_delay_s() is None          # no rate observed yet
    ac.note_done(100.0, 2.0)
    assert ac.service_rate == pytest.approx(50.0)
    assert ac.queue_delay_s() == pytest.approx(1.5)
    # static threshold admits (75 <= 100) ...
    ok, out, delay = ac.decide(BATCH, slack_s=None)
    assert ok and out == pytest.approx(75.0)
    # ... but a 1s deadline tightens the allowance to rate*slack = 50
    ok, out, delay = ac.decide(BATCH, slack_s=1.0)
    assert not ok and delay == pytest.approx(1.5)
    # a dying pool never blocks admission
    ac.register_pool(types.SimpleNamespace(
        outstanding_tokens=lambda: (_ for _ in ()).throw(RuntimeError())))
    assert ac.outstanding_tokens() == pytest.approx(75.0)


# ---------------------------------------------------------------------------
# Hedge trigger policy

def test_hedge_trigger_fixed_then_quantile():
    assert HedgePolicy(OverloadConfig(hedge=False)) \
        .trigger_delay("Embedding") is None
    hp = HedgePolicy(OverloadConfig(hedge=True, hedge_after_s=0.02))
    assert hp.trigger_delay("Embedding") == pytest.approx(0.02)
    hp = HedgePolicy(OverloadConfig(hedge=True, hedge_min_samples=4,
                                    hedge_quantile=0.5))
    assert hp.trigger_delay("Embedding") is None   # not enough samples
    for dt in (0.04, 0.01, 0.03, 0.02):
        hp.note_latency("Embedding", dt)
    assert hp.trigger_delay("Embedding") == pytest.approx(0.03)
    assert hp.trigger_delay("Reranking") is None   # per-op history


# ---------------------------------------------------------------------------
# Satellite: seeded burst faults

def test_burst_spec_parse_roundtrip_and_validation():
    inj = FaultInjector.parse("burst:embedding:encode:2:0.05:3")
    (s,) = inj.specs
    assert (s.kind, s.engine, s.point, s.at, s.duration, s.width) == \
        ("burst", "embedding", "encode", 2, 0.05, 3)
    with pytest.raises(ValueError):
        FaultSpec("burst", "e", "encode", at=1, width=0)


def test_burst_fires_on_consecutive_call_window_deterministically():
    def trial():
        eng = types.SimpleNamespace(name="e0", health="healthy")
        inj = FaultInjector([FaultSpec("burst", "e0", "encode", at=2,
                                       duration=0.001, width=3)])
        for _ in range(6):
            inj.fire(eng, "encode")
        assert eng.health == "healthy"     # a burst slows, never kills
        return inj.log
    log1, log2 = trial(), trial()
    assert log1 == log2                    # same spec -> same schedule
    assert [k for (_kind, _e, _p, k) in log1] == [2, 3, 4]


def test_arm_encoders_flag_reaches_pooled_encoder_replicas():
    engines = build_sim_engines(encoder_instances=2)
    inj = FaultInjector()
    armed = inj.arm(engines, encoders=True)
    assert {"embedding", "embedding.r1"} <= set(armed)
    assert any(n.startswith("rerank") for n in armed)
    assert all(r.faults is inj for r in replicas_of(engines["embedding"]))
    # default arm stays LLM-only (pre-existing behavior preserved)
    armed2 = FaultInjector().arm(build_sim_engines(encoder_instances=2))
    assert not any(n.startswith(("embedding", "rerank")) for n in armed2)


# ---------------------------------------------------------------------------
# Degradation ladder: hysteresis, cooldown, plans, attribution

def test_ladder_hysteresis_and_cooldown():
    cfg = OverloadConfig(degrade=True, degrade_after=2, recover_after=2,
                         cooldown_s=1.0, max_level=3)
    dp = DegradationPolicy(cfg)
    t = 1000.0
    assert dp.note_slack(-1.0, now=t) == 0           # one sample: no move
    assert dp.note_slack(-1.0, now=t + 0.1) == 1     # streak of 2: step up
    assert dp.note_slack(-1.0, now=t + 0.2) == 1     # cooldown holds it
    assert dp.note_slack(-1.0, now=t + 0.3) == 1
    assert dp.note_slack(-1.0, now=t + 1.2) == 2     # cooldown expired
    # positive samples recover, same hysteresis
    assert dp.note_slack(1.0, now=t + 1.3) == 2
    assert dp.note_slack(1.0, now=t + 1.4) == 2      # cooldown holds
    assert dp.note_slack(1.0, now=t + 2.3) == 1
    assert dp.note_slack(1.0, now=t + 2.4) == 1
    assert dp.note_slack(1.0, now=t + 3.5) == 0
    assert dp.note_slack(1.0, now=t + 9.0) == 0      # floor at 0


def test_ladder_streak_resets_on_sign_flip_and_caps_at_max_level():
    dp = DegradationPolicy(OverloadConfig(
        degrade=True, degrade_after=2, recover_after=99, cooldown_s=0.0,
        max_level=1))
    t = 0.0
    assert dp.note_slack(-1.0, now=t) == 0
    assert dp.note_slack(1.0, now=t + 0.1) == 0      # flip resets streak
    assert dp.note_slack(-1.0, now=t + 0.2) == 0
    assert dp.note_slack(-1.0, now=t + 0.3) == 1
    for i in range(4):                               # capped at max_level
        assert dp.note_slack(-1.0, now=t + 1.0 + i) == 1


def test_plan_levels_and_floors():
    dp = DegradationPolicy(OverloadConfig(degrade=True))
    ann = {"min_top_k": 2, "skippable": True, "min_new": 8,
           "chunk_cap": 64}
    cfg = {"top_k": 8, "max_new": 32}
    assert dp.plan(ann, cfg, level=0) is None
    assert dp.plan(None, cfg, level=3) is None
    assert dp.plan(ann, cfg, level=1) == {"top_k": 4}
    assert dp.plan(ann, cfg, level=2) == {"top_k": 4, "skip": True}
    assert dp.plan(ann, cfg, level=3) == {"top_k": 4, "skip": True,
                                          "max_new": 16, "chunk_cap": 64}
    # floors: already at (or below) the minimum -> nothing fires
    assert dp.plan({"min_top_k": 2}, {"top_k": 2}, level=1) is None
    assert dp.plan({"min_new": 8}, {"max_new": 8}, level=3) is None
    # min_new floor binds the halving
    assert dp.plan({"min_new": 8}, {"max_new": 12}, level=3) == \
        {"max_new": 8}


def test_attribution_is_idempotent_per_query():
    dp = DegradationPolicy(OverloadConfig(degrade=True))
    dp.attribute("q0", ["skip", "top_k"])
    dp.attribute("q0", ["skip"])                     # no double count
    dp.attribute("q1", ["skip"])
    assert dp.step_counts == {"skip": 2, "top_k": 1}
    assert dp.snapshot()["queries_degraded"] == 2
    assert dp.degraded_queries()["q0"] == {"skip", "top_k"}


# ---------------------------------------------------------------------------
# OverloadManager: stamping, per-task slack, degrade hook

def test_stamp_and_task_slack_follow_decomposed_budgets():
    ov = OverloadManager(OverloadConfig(deadline_s=10.0,
                                        interactive_deadline_s=2.0))
    assert ov.deadline_for(INTERACTIVE) == pytest.approx(2.0)
    assert ov.deadline_for(BATCH) == pytest.approx(10.0)
    g, a, b, c = _chain_graph()
    ctx = types.SimpleNamespace(qid="q", t_submit=1000.0,
                                done=threading.Event())
    ov.stamp(ctx, g, BATCH)
    assert ctx.deadline == pytest.approx(1010.0)
    assert ctx.ov_tokens == pytest.approx(96.0)
    # the sink's budget expires exactly at the query deadline
    assert ov.task_slack(c, ctx, now=1010.0) == pytest.approx(0.0)
    # the first hop must finish within its critical-path share
    assert ov.task_slack(a, ctx, now=1000.0) == pytest.approx(10 * 8 / 96)
    assert ov.task_slack(b, ctx, now=1010.0) < 0.0   # behind schedule
    # no deadline configured -> no slack accounting at all
    ov2 = OverloadManager(OverloadConfig())
    ctx2 = types.SimpleNamespace(qid="q", t_submit=1000.0,
                                 done=threading.Event())
    ov2.stamp(ctx2, g, BATCH)
    assert getattr(ctx2, "deadline", None) is None
    assert ov2.task_slack(c, ctx2) is None


def test_degrade_plan_hook_steps_ladder_and_attributes():
    now = time.time()
    ov = OverloadManager(OverloadConfig(
        deadline_s=1.0, degrade=True, degrade_after=1, cooldown_s=0.0))
    prim = Primitive(op=P.RERANKING, engine="rerank", component="rr",
                     config={"top_k": 8, "degrade": {"min_top_k": 2}})
    ctx = types.SimpleNamespace(qid="qx", t_submit=now - 10.0,
                                deadline=now - 5.0,
                                budget_frac={prim.pid: 1.0})
    assert ov.degrade_plan(prim, ctx) == {"top_k": 4}
    assert ov.degrade.snapshot()["queries_degraded"] == 1
    assert ctx.degraded_steps == {"top_k"}
    # gate: cfg.degrade off -> hook is inert even behind schedule
    ov_off = OverloadManager(OverloadConfig(deadline_s=1.0, degrade=False))
    assert ov_off.degrade_plan(prim, ctx) is None


# ---------------------------------------------------------------------------
# Chunk-cap: degraded prefill chunk planning + paged block hygiene

def test_chunk_cap_bounds_prefill_take_per_job():
    loop = ContinuousDecodeLoop(types.SimpleNamespace(name="e"),
                                max_slots=4, prefill_chunk=32,
                                token_budget=128)
    j1 = PrefillJob("a", None, list(range(100)))
    j2 = PrefillJob("b", None, list(range(100)))
    j2.chunk_cap = 8                        # degraded job
    j3 = PrefillJob("c", None, list(range(100)))
    j3.chunk_cap = 512                      # cap above chunk: no-op
    loop.prefill_waiting.extend([j1, j2, j3])
    took = {j.sid: n for j, n in loop._take_prefill_locked(0)}
    assert took == {"a": 32, "b": 8, "c": 32}


def test_degraded_chunk_cap_token_identical_and_zero_leaked_blocks():
    cfg = get_config("tiny-lite-llm")
    text = " ".join(f"w{i}" for i in range(40))

    def run(cap):
        eng = LLMEngine("d", cfg, max_len=256, seed=0, max_batch=4,
                        paged=True, block_size=8, chunked_prefill=True,
                        prefill_chunk=32)
        job = eng.submit_prefill({"sid": "s", "text": text})
        if cap:
            job.chunk_cap = cap
        job.wait(120)
        sq = eng.submit_decode("s", 8)
        assert sq.wait(120)
        toks = list(sq.tokens)
        eng.stop_decode_loop()
        eng.release("s")
        rep = eng.alloc.audit()
        assert rep["leaked"] == 0 and rep["bad_free"] == 0, rep
        assert eng.alloc.free_blocks() == eng.alloc.capacity
        return toks

    assert run(8) == run(0)                 # degraded prefill: same tokens


# ---------------------------------------------------------------------------
# End-to-end: shed, hedge, degrade through Teola on sim engines

def _fresh_sids():
    """Sim decode text depends on the engine-side sequence ids, which
    embed the global qid and sid streams; resetting both makes runs
    within one process comparable."""
    import repro.core.pgraph as pg
    import repro.core.runtime as rt
    pg._sid = itertools.count()
    rt._qid = itertools.count()


def test_e2e_shed_fails_loudly_with_structured_error():
    from repro.core.apps import search_gen
    engines = build_sim_engines()
    ov = OverloadManager(OverloadConfig(shed=True, max_queue_tokens=-1.0))
    orch = Teola(search_gen(engines), engines, continuous_batching=True,
                 overload=ov)
    try:
        ctx = orch.submit({"question": "hello"})
        assert ctx.done.is_set()             # rejected synchronously
        assert isinstance(ctx.error, Overloaded)
        assert ctx.error.reason == "overloaded"
        assert not ctx.node_spans            # nothing was dispatched
        with pytest.raises(Overloaded):
            ctx.result(1)
        assert ov.admission.counts[BATCH]["shed"] == 1
    finally:
        orch.shutdown()


def test_e2e_hedge_first_result_wins_token_identical_ledger_drained():
    from repro.core.apps import naive_rag

    def run(inj, ov):
        _fresh_sids()
        engines = build_sim_engines(encoder_instances=2)
        if inj is not None:
            inj.arm(engines, encoders=True)
        orch = Teola(naive_rag(engines), engines,
                     continuous_batching=True, overload=ov)
        try:
            out, ctx = orch.query(dict(Q), timeout=120)
            assert ctx.error is None and out
            # loser hygiene: the straggling primary still drains the
            # pool ledger (queued/started/finished net to zero)
            pool = engines["embedding"]
            deadline = time.time() + 5.0
            while any(pool.loads()) and time.time() < deadline:
                time.sleep(0.02)
            assert not any(pool.loads()), pool.loads()
            return out
        finally:
            orch.shutdown()

    base = run(None, None)
    inj = FaultInjector([FaultSpec("slow", "embedding", "encode", at=1,
                                   duration=0.8)])
    ov = OverloadManager(OverloadConfig(hedge=True, hedge_after_s=0.05))
    out = run(inj, ov)
    assert inj.log, "fault never fired (routing changed?)"
    assert out == base                       # first-result-wins, same text
    snap = ov.hedge.snapshot()
    assert snap["issued"] >= 1
    assert snap["wins"] >= 1                 # the backup beat the slow primary
    assert snap["backup_failures"] == 0


def test_e2e_degraded_mode_fires_and_query_still_completes():
    from repro.core.apps import advanced_rag
    engines = build_sim_engines()
    ov = OverloadManager(OverloadConfig(
        deadline_s=0.01, degrade=True, degrade_after=1, cooldown_s=0.0))
    orch = Teola(advanced_rag(engines), engines, continuous_batching=True,
                 overload=ov)
    try:
        out, ctx = orch.query(dict(Q), timeout=120)
        assert ctx.error is None and out     # degraded, never dropped
        snap = ov.degrade.snapshot()
        assert snap["level"] >= 1
        assert snap["queries_degraded"] == 1
        assert getattr(ctx, "degraded_steps", set())
    finally:
        orch.shutdown()


# ---------------------------------------------------------------------------
# serve.py flag validation

def _validate(argv):
    from repro.launch.serve import build_parser, validate_args
    ap = build_parser()
    args = ap.parse_args(argv)
    validate_args(ap, args)
    return args


@pytest.mark.parametrize("argv,msg", [
    (["--continuous-batching", "--query-deadline", "5"],
     "--overload-control"),
    (["--continuous-batching", "--shed-queue-tokens", "64"],
     "--overload-control"),
    (["--continuous-batching", "--hedge-after", "0.1"],
     "--overload-control"),
    (["--continuous-batching", "--degrade"], "--overload-control"),
    (["--overload-control"], "--continuous-batching"),
    (["--continuous-batching", "--overload-control",
      "--query-deadline", "0"], "--query-deadline must be > 0"),
    (["--continuous-batching", "--overload-control", "--degrade"],
     "--degrade requires --query-deadline"),
    (["--encoder-instances", "2"], "--sim"),
])
def test_serve_rejects_bad_overload_flags(argv, msg, capsys):
    with pytest.raises(SystemExit) as e:
        _validate(argv)
    assert e.value.code == 2
    assert msg in capsys.readouterr().err


def test_serve_accepts_overload_flags():
    args = _validate(["--sim", "--continuous-batching",
                      "--overload-control", "--query-deadline", "5",
                      "--shed-queue-tokens", "256", "--hedge-after",
                      "0.05", "--degrade", "--encoder-instances", "2"])
    assert args.overload_control and args.degrade
    args = _validate([])
    assert not args.overload_control         # plain serve untouched
