import os
import sys

# smoke tests and benches must see exactly ONE device (the dry-run forces
# 512 in its own process only)
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import pytest  # noqa: E402

from repro.configs.base import list_configs  # noqa: E402

ASSIGNED = [a for a in list_configs() if not a.startswith("tiny-")]


@pytest.fixture(scope="session")
def rng():
    return jax.random.key(0)
