"""Multi-device correctness via subprocess (forces 8 host devices —
cannot run in-process because smoke tests must see 1 device)."""
import os
import subprocess
import sys


SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(code: str, devices: int = 8, timeout: int = 600):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC
    r = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True, timeout=timeout)
    assert r.returncode == 0, r.stdout + r.stderr
    return r.stdout


def test_sharded_train_step_matches_single_device():
    out = _run("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs.base import get_config
from repro.models.transformer import init_params
from repro.models.sharding import mesh_context
from repro.training.train_step import next_token_loss

cfg = get_config('tinyllama-1.1b').reduced()
params = init_params(cfg, jax.random.key(0))
toks = jax.random.randint(jax.random.key(1), (4, 33), 0, cfg.vocab_size)

l_single, _ = jax.jit(lambda p, t: next_token_loss(
    cfg, p, t, compute_dtype=jnp.float32, q_block=64))(params, toks)

mesh = jax.make_mesh((4, 2), ('data', 'model'))
from repro.launch.shard_rules import tree_shardings
params_sh = jax.device_put(params, tree_shardings(params, mesh))
toks_sh = jax.device_put(toks, NamedSharding(mesh, P('data', None)))
with mesh_context(mesh):
    fn = jax.jit(lambda p, t: next_token_loss(
        cfg, p, t, compute_dtype=jnp.float32, q_block=64))
    l_shard, _ = fn(params_sh, toks_sh)
np.testing.assert_allclose(float(l_single), float(l_shard), rtol=2e-4)
print('OK', float(l_single), float(l_shard))
""")
    assert "OK" in out


def test_moe_ep_multi_device_matches_dense():
    out = _run("""
import dataclasses, jax, jax.numpy as jnp, numpy as np
from repro.configs.base import get_config
from repro.models import moe as moe_mod

base = get_config('qwen2-moe-a2.7b').reduced()
cfg = dataclasses.replace(base, moe=dataclasses.replace(
    base.moe, num_experts=4, top_k=2, capacity_factor=16.0))
p = moe_mod.init_moe_params(jax.random.key(0), cfg, jnp.float32)
x = jax.random.normal(jax.random.key(1), (32, cfg.d_model))
dense, _ = moe_mod.routed_dense(cfg, p, x)
mesh = jax.make_mesh((4, 2), ('data', 'model'))   # EP over model=2
ep, _ = jax.jit(lambda xx: moe_mod.routed_ep(cfg, p, xx, mesh))(x)
np.testing.assert_allclose(np.asarray(ep), np.asarray(dense),
                           rtol=3e-4, atol=3e-4)
print('OK')
""")
    assert "OK" in out


def test_moe_ep_uneven_experts_multi_device():
    """60-expert Qwen config over model=8: expert padding path."""
    out = _run("""
import dataclasses, jax, jax.numpy as jnp, numpy as np
from repro.configs.base import get_config
from repro.models import moe as moe_mod

base = get_config('qwen2-moe-a2.7b').reduced()
cfg = dataclasses.replace(base, moe=dataclasses.replace(
    base.moe, num_experts=6, top_k=2, capacity_factor=16.0))
p = moe_mod.init_moe_params(jax.random.key(0), cfg, jnp.float32)
x = jax.random.normal(jax.random.key(1), (32, cfg.d_model))
dense, _ = moe_mod.routed_dense(cfg, p, x)
mesh = jax.make_mesh((2, 4), ('data', 'model'))   # 6 experts over tp=4
ep, _ = jax.jit(lambda xx: moe_mod.routed_ep(cfg, p, xx, mesh))(x)
np.testing.assert_allclose(np.asarray(ep), np.asarray(dense),
                           rtol=3e-4, atol=3e-4)
print('OK')
""")
    assert "OK" in out


def test_moe_ep_all_axes_matches_dense():
    """Wide EP (experts over model AND data, resident weights) — the
    ep_all_axes beyond-paper optimization must stay numerically exact."""
    out = _run("""
import dataclasses, jax, jax.numpy as jnp, numpy as np
from repro.configs.base import get_config
from repro.launch import optflags
from repro.models import moe as moe_mod

optflags.set_flags(['ep_all_axes', 'resident_weights'])
base = get_config('qwen2-moe-a2.7b').reduced()
cfg = dataclasses.replace(base, moe=dataclasses.replace(
    base.moe, num_experts=8, top_k=2, capacity_factor=16.0))
p = moe_mod.init_moe_params(jax.random.key(0), cfg, jnp.float32)
x = jax.random.normal(jax.random.key(1), (32, cfg.d_model))
dense, _ = moe_mod.routed_dense(cfg, p, x)
mesh = jax.make_mesh((2, 4), ('data', 'model'))   # EP over 8 devices
ep, _ = jax.jit(lambda xx: moe_mod.routed_ep(cfg, p, xx, mesh))(x)
optflags.set_flags([])
np.testing.assert_allclose(np.asarray(ep), np.asarray(dense),
                           rtol=3e-4, atol=3e-4)
print('OK')
""")
    assert "OK" in out


def test_sharded_decode_matches_single_device():
    out = _run("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs.base import get_config, INPUT_SHAPES
from repro.models.transformer import init_params, apply_model
from repro.models.sharding import mesh_context
from repro.serving.kv_cache import init_cache
from repro.launch.shard_rules import tree_shardings, cache_spec

cfg = get_config('gemma2-9b').reduced()
params = init_params(cfg, jax.random.key(0))
toks = jax.random.randint(jax.random.key(1), (8, 16), 0, cfg.vocab_size)
cache = init_cache(cfg, 8, 32)
l1, cache1, _ = apply_model(cfg, params, toks[:, :15], cache, 0)
l1d, _, _ = apply_model(cfg, params, toks[:, 15:16], cache1, 15)

mesh = jax.make_mesh((4, 2), ('data', 'model'))
params_sh = jax.device_put(params, tree_shardings(params, mesh))
def csh(path, leaf):
    import jax.tree_util as jtu
    name = None
    for k in reversed(path):
        if isinstance(getattr(k, 'key', None), str):
            name = k.key; break
    return jax.device_put(leaf, NamedSharding(
        mesh, cache_spec(name, leaf.shape, mesh, batch=8)))
import jax.tree_util as jtu
cache_sh = jtu.tree_map_with_path(csh, init_cache(cfg, 8, 32))
with mesh_context(mesh):
    fn = jax.jit(lambda p, t, c, pos: apply_model(cfg, p, t, c, pos))
    _, cache_sh, _ = fn(params_sh, toks[:, :15], cache_sh, 0)
    l2d, _, _ = fn(params_sh, toks[:, 15:16], cache_sh, 15)
np.testing.assert_allclose(np.asarray(l1d), np.asarray(l2d),
                           rtol=3e-3, atol=3e-3)
print('OK')
""")
    assert "OK" in out
