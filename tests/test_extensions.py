"""Paper-adjacent extensions: e-graph caching (§4.2), multi-instance
engines with sequence affinity (§6/§7.1), priority scheduling (§7.2)."""
import numpy as np

from repro.core.apps import advanced_rag, naive_rag
from repro.core.teola import Teola
from repro.engines.sim_engines import build_sim_engines
from repro.training.data import doc_corpus

Q = {"question": "what is fact 3 about optics", "docs": doc_corpus(2)}


def test_egraph_cache_hit_and_correct_execution():
    engines = build_sim_engines()
    app = advanced_rag(engines)
    orch = Teola(app, engines)
    g1 = orch.build_egraph(dict(Q))
    g2 = orch.build_egraph(dict(Q))
    assert g1 is g2                               # structural cache hit
    # different doc size -> different structure -> different graph
    g3 = orch.build_egraph({"question": "x", "docs": doc_corpus(1)})
    assert g3 is not g1
    # two queries sharing the cached graph both complete correctly
    c1 = orch.submit(dict(Q))
    c2 = orch.submit(dict(Q))
    assert c1.result(120) and c2.result(120)
    assert c1.error is None and c2.error is None
    orch.shutdown()


def test_multi_instance_llm_affinity_and_completion():
    engines = build_sim_engines(llm_instances=2)
    app = naive_rag(engines)
    orch = Teola(app, engines)
    ctxs = [orch.submit(dict(Q)) for _ in range(4)]
    for c in ctxs:
        assert c.done.wait(180) and c.error is None
    # both instances did work
    insts = engines["core_llm"]
    calls = [i.stats["calls"] for i in insts]
    assert sum(calls) > 0
    # all sequence states released everywhere
    assert all(len(i.states) == 0 for i in insts)
    orch.shutdown()


def test_priority_scheduling_orders_buckets():
    from repro.core.runtime import EngineScheduler, NodeTask, QueryContext
    from repro.core import primitives as P
    from repro.core.primitives import Graph, Primitive

    class Fake:
        kind = "fake"
        max_batch = 1

    s = EngineScheduler(Fake(), lambda e, b: None, "topo")
    lo = QueryContext(Graph(), {}, priority=0)
    hi = QueryContext(Graph(), {}, priority=9)
    t_lo = NodeTask(Primitive(op=P.PREFILL, engine="fake", component="c"),
                    lo, t_arrival=1.0)
    t_hi = NodeTask(Primitive(op=P.PREFILL, engine="fake", component="c"),
                    hi, t_arrival=2.0)
    s.pending = [t_lo, t_hi]
    batch = s._form_batch()
    assert batch == [t_hi]            # priority beats arrival order


def test_high_priority_query_finishes_faster_under_load():
    engines = build_sim_engines()
    app = naive_rag(engines)
    orch = Teola(app, engines)
    ctxs = [orch.submit(dict(Q), priority=0) for _ in range(3)]
    hi = orch.submit(dict(Q), priority=10)
    for c in ctxs + [hi]:
        assert c.done.wait(180)
    avg_lo = np.mean([c.latency for c in ctxs])
    assert hi.latency < avg_lo * 1.1
    orch.shutdown()
