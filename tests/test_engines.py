"""Engine-level tests: encoders, vector DB, LLM engine state handling,
prefix cache, sim-engine calibration."""
import numpy as np

from repro.configs.base import get_config
from repro.engines.encoder_engines import EmbeddingEngine, RerankEngine
from repro.engines.llm_engine import LLMEngine
from repro.engines.model_free import ChunkerEngine, VectorDBEngine
from repro.engines.sim_engines import SimEmbeddingEngine, SimLLMEngine


def test_embedding_engine_normalized_and_deterministic():
    eng = EmbeddingEngine()
    v1 = eng.op_embed([{"texts": ["hello world", "optics fact"]}])[0]
    v2 = eng.op_embed([{"texts": ["hello world", "optics fact"]}])[0]
    np.testing.assert_allclose(v1, v2, rtol=1e-5)
    np.testing.assert_allclose(np.linalg.norm(v1, axis=1), 1.0, rtol=1e-3)
    assert not np.allclose(v1[0], v1[1])


def test_rerank_engine_orders_by_score():
    eng = RerankEngine()
    res = eng.op_rerank([{"question": "about optics",
                          "candidates": [{"text": f"c{i}"} for i in
                                         range(6)],
                          "top_k": 3}])[0]
    assert len(res) == 3
    scores = [r["rerank_score"] for r in res]
    assert scores == sorted(scores, reverse=True)


def test_vectordb_topk_exact():
    db = VectorDBEngine(ingest_latency_per_vec=0, search_latency=0)
    vecs = np.eye(4, dtype=np.float32)
    db.op_ingest([{"collection": "c", "vectors": vecs,
                   "meta": [{"text": f"d{i}"} for i in range(4)]}])
    res = db.op_search([{"collection": "c",
                         "query_vec": np.array([0, 0, 1, 0], np.float32),
                         "top_k": 2}])[0]
    assert res[0]["text"] == "d2"
    assert res[0]["score"] > res[1]["score"]


def test_chunker_overlap_and_count():
    ch = ChunkerEngine()
    docs = [{"id": "d", "text": " ".join(f"w{i}" for i in range(100))}]
    chunks = ch.op_chunk([{"docs": docs, "chunk_size": 40,
                           "overlap": 10}])[0]
    assert len(chunks) == ChunkerEngine.count_chunks(docs, 40, 10)
    assert chunks[0]["text"].split()[-10:] == \
        chunks[1]["text"].split()[:10]


def test_llm_engine_partial_prefill_state_continuity():
    eng = LLMEngine("t", get_config("tiny-lite-llm"), max_len=128)
    # split prefill: instruction then question on the same sid
    eng.op_prefill([{"sid": "a", "text": "system instruction words"}])
    st = eng.states["a"]
    assert st.pos == 3
    eng.op_prefill([{"sid": "a", "text": "user question here now"}])
    assert eng.states["a"].pos == 7
    out = eng.op_decode([{"sid": "a", "max_new": 4}])
    assert len(out) == 1 and isinstance(out[0], str)
    eng.release("a")
    assert "a" not in eng.states


def test_llm_engine_batched_decode_isolated_states():
    eng = LLMEngine("t", get_config("tiny-lite-llm"), max_len=128)
    eng.op_prefill([{"sid": "x", "text": "alpha beta gamma"},
                    {"sid": "y", "text": "delta epsilon zeta eta"}])
    # batched decode must equal per-sequence decode
    o_batch = eng.op_decode([{"sid": "x", "max_new": 3},
                             {"sid": "y", "max_new": 3}])
    eng2 = LLMEngine("t2", get_config("tiny-lite-llm"), max_len=128, seed=0)
    eng2.op_prefill([{"sid": "x", "text": "alpha beta gamma"}])
    o_solo = eng2.op_decode([{"sid": "x", "max_new": 3}])
    assert o_batch[0] == o_solo[0]


def test_sim_llm_prefix_cache_reduces_prefill():
    eng = SimLLMEngine("s", max_batch=4)
    eng.use_prefix_cache = True
    instr = "one two three four five six"
    eng.get_prefix_state(instr)
    before = eng.stats["prefill_tokens"]
    eng.op_prefill([{"sid": "q", "text": instr + " question words"}])
    assert eng.stats["prefill_tokens"] - before == 2   # only the new part


def test_sim_embedding_calibration_fig4():
    """Paper Fig 4a: 48 requests, batch 16 vs 4 => ~1.33x total-time win."""
    t = {}
    for bs in (4, 16):
        eng = SimEmbeddingEngine(max_batch=bs)
        for i in range(0, 48, bs):
            eng.op_embed([{"texts": [f"c{j}" for j in range(i, i + bs)]}])
        t[bs] = eng.stats["busy_ms"]
    assert 1.2 < t[4] / t[16] < 1.5
